"""Block-level equivalence tests: every fused/chunked/parallel form against
its step-by-step oracle, plus hypothesis sweeps on the attention math."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.models import blocks, build_model, ssd, xlstm_blocks
from repro.models.layers import chunked_attention, reference_attention


class TestChunkedAttention:
    @settings(max_examples=10, deadline=None)
    @given(
        b=st.integers(1, 3),
        hkv=st.sampled_from([1, 2, 4]),
        g=st.sampled_from([1, 2, 4]),
        sq=st.integers(3, 48),
        d=st.sampled_from([8, 16, 32]),
        causal=st.booleans(),
        block_k=st.sampled_from([4, 16, 64]),
    )
    def test_matches_reference(self, b, hkv, g, sq, d, causal, block_k):
        hq = hkv * g
        key = jax.random.PRNGKey(b * 1000 + sq)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, hq, sq, d))
        k = jax.random.normal(ks[1], (b, hkv, sq, d))
        v = jax.random.normal(ks[2], (b, hkv, sq, d))
        out = chunked_attention(q, k, v, causal=causal, block_k=block_k)
        ref = reference_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)

    def test_sliding_window(self):
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (1, 2, 32, 16))
        k = jax.random.normal(ks[1], (1, 2, 32, 16))
        v = jax.random.normal(ks[2], (1, 2, 32, 16))
        out = chunked_attention(q, k, v, causal=True, window=jnp.asarray(8), block_k=8)
        ref = reference_attention(q, k, v, causal=True, window=jnp.asarray(8))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)

    def test_softcap(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 2, 16, 16)) * 4
        k = jax.random.normal(ks[1], (1, 2, 16, 16)) * 4
        v = jax.random.normal(ks[2], (1, 2, 16, 16))
        out = chunked_attention(q, k, v, attn_softcap=5.0, block_k=4)
        ref = reference_attention(q, k, v, attn_softcap=5.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


class TestMoE:
    @pytest.mark.parametrize("arch", ["dbrx-132b", "deepseek-v3-671b"])
    def test_dispatch_matches_dense_oracle(self, arch):
        cfg = get_config(arch).reduced()
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.num_experts))
        )
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0), jnp.float32)
        layer = jax.tree.map(lambda a: a[0], params["layers"])
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model))
        y = blocks.moe_apply(cfg, layer["ffn"], x)
        y_ref = blocks.moe_dense_ref(cfg, layer["ffn"], x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-4)

    def test_capacity_drops_tokens_not_correctness(self):
        """With tiny capacity the layer still runs and outputs are finite
        (dropped tokens keep their residual)."""
        cfg = get_config("dbrx-132b").reduced()
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.1)
        )
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0), jnp.float32)
        layer = jax.tree.map(lambda a: a[0], params["layers"])
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
        y = blocks.moe_apply(cfg, layer["ffn"], x)
        assert bool(jnp.all(jnp.isfinite(y)))


class TestSSD:
    @settings(max_examples=8, deadline=None)
    @given(
        b=st.integers(1, 2),
        t=st.integers(2, 40),
        h=st.sampled_from([1, 2]),
        p=st.sampled_from([4, 8]),
        n=st.sampled_from([4, 8]),
        chunk=st.sampled_from([4, 8, 16]),
    )
    def test_chunked_matches_stepwise(self, b, t, h, p, n, chunk):
        ks = jax.random.split(jax.random.PRNGKey(t * 7 + h), 5)
        x = jax.random.normal(ks[0], (b, t, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
        bm = jax.random.normal(ks[3], (b, t, n))
        cm = jax.random.normal(ks[4], (b, t, n))
        y, s = ssd._ssd_chunked(x, dt, a, bm, cm, chunk)
        y_ref, s_ref = ssd.ssd_reference(x, dt, a, bm, cm)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=2e-3, atol=2e-3)

    def test_block_prefill_then_decode(self):
        cfg = get_config("zamba2-2.7b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0), jnp.float32)
        lparams = jax.tree.map(lambda a: a[0, 0], params["groups"])
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, cfg.d_model))
        # full pass
        y_full, _ = ssd.ssd_block_apply(cfg, lparams, x)
        # prefix pass + one-step decode
        y_pre, cache = ssd.ssd_block_apply(cfg, lparams, x[:, :-1])
        y_dec, _ = ssd.ssd_block_apply(cfg, lparams, x[:, -1:], cache=cache)
        np.testing.assert_allclose(
            np.asarray(y_full[:, -1]), np.asarray(y_dec[:, 0]), rtol=2e-3, atol=2e-3
        )


class TestXLSTM:
    def test_mlstm_parallel_matches_recurrent(self):
        cfg = get_config("xlstm-350m").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0), jnp.float32)
        lparams = jax.tree.map(lambda a: a[0, 0], params["pairs"]["mlstm"])
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, cfg.d_model))
        y_par, state_par = xlstm_blocks.mlstm_block_apply(cfg, lparams, x)
        # recurrent: step one token at a time from zero state
        d_in, nh, dh = xlstm_blocks.mlstm_dims(cfg)
        state = xlstm_blocks._mlstm_zero_state(2, nh, dh)
        outs = []
        for t in range(10):
            o, state = xlstm_blocks.mlstm_block_apply(cfg, lparams, x[:, t : t + 1], cache=state)
            outs.append(o)
        y_rec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_rec), rtol=2e-3, atol=2e-3)
        # prefill's folded state matches the recurrent end state
        np.testing.assert_allclose(
            np.asarray(state_par["c"]), np.asarray(state["c"]), rtol=2e-3, atol=2e-3
        )

    def test_slstm_streaming_consistency(self):
        cfg = get_config("xlstm-350m").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0), jnp.float32)
        lparams = jax.tree.map(lambda a: a[0], params["pairs"]["slstm"])
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, cfg.d_model))
        y_full, _ = xlstm_blocks.slstm_block_apply(cfg, lparams, x)
        y_a, st = xlstm_blocks.slstm_block_apply(cfg, lparams, x[:, :5])
        y_b, _ = xlstm_blocks.slstm_block_apply(cfg, lparams, x[:, 5:], cache=st)
        y_split = jnp.concatenate([y_a, y_b], axis=1)
        np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_split), rtol=2e-3, atol=2e-3)


class TestMLA:
    def test_absorbed_decode_matches_expanded(self):
        cfg = get_config("deepseek-v3-671b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0), jnp.float32)
        layer = jax.tree.map(lambda a: a[0], params["layers"])
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, cfg.d_model))
        positions = jnp.arange(9)
        y_full, kv = blocks.mla_apply(cfg, layer["attn"], x, positions=positions)
        # prefill on prefix, then absorbed single-step decode
        y_pre, kv_pre = blocks.mla_apply(cfg, layer["attn"], x[:, :-1], positions=jnp.arange(8))
        m = cfg.mla
        cache = {
            "ckv": jnp.pad(kv_pre["ckv"], ((0, 0), (0, 2), (0, 0))),
            "krope": jnp.pad(kv_pre["krope"], ((0, 0), (0, 2), (0, 0))),
        }
        y_dec, _ = blocks.mla_apply(
            cfg, layer["attn"], x[:, -1:], positions=jnp.asarray([8]),
            cache=cache, cache_len=jnp.asarray(8),
        )
        np.testing.assert_allclose(
            np.asarray(y_full[:, -1]), np.asarray(y_dec[:, 0]), rtol=2e-3, atol=2e-3
        )
