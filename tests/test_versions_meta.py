"""Unit + property tests: version algebra and tiny-tensor compaction."""

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import versions
from repro.core.meta import TINY_TENSOR_BYTES, TensorMeta, build_units


class TestVersions:
    def test_absolute(self):
        assert versions.resolve(7, latest=3) == 7
        assert versions.resolve(0, latest=None) == 0

    def test_relative(self):
        assert versions.resolve("latest", latest=5) == 5
        assert versions.resolve("latest-2", latest=5) == 3
        assert versions.resolve("latest", latest=None) is None
        assert versions.resolve("latest-9", latest=5) is None  # pre-history

    def test_bad_specs(self):
        with pytest.raises(ValueError):
            versions.resolve("newest", latest=1)
        with pytest.raises(ValueError):
            versions.resolve(-1, latest=1)

    @given(st.integers(0, 100), st.integers(0, 100))
    def test_relative_resolution_property(self, latest, lag):
        got = versions.resolve(f"latest-{lag}", latest)
        if lag <= latest:
            assert got == latest - lag
        else:
            assert got is None


def _metas(sizes):
    return [
        TensorMeta(name=f"t{i}", shape=(s,), dtype="uint8", nbytes=s)
        for i, s in enumerate(sizes)
    ]


class TestCompaction:
    def test_large_tensors_pass_through(self):
        units = build_units(_metas([TINY_TENSOR_BYTES, TINY_TENSOR_BYTES * 2]))
        assert len(units) == 2
        assert all(not u.is_compact for u in units)

    def test_tiny_tensors_bucketed(self):
        units = build_units(_metas([100] * 50))
        assert len(units) == 1
        assert units[0].is_compact and len(units[0].members) == 50

    @settings(max_examples=200)
    @given(st.lists(st.integers(1, 3 * TINY_TENSOR_BYTES), min_size=1, max_size=40))
    def test_compaction_properties(self, sizes):
        metas = _metas(sizes)
        units = build_units(metas)
        # every byte appears exactly once
        assert sum(u.nbytes for u in units) == sum(sizes)
        # indices are dense and ordered
        assert [u.index for u in units] == list(range(len(units)))
        # bucket layouts are contiguous and within the limit
        seen = set()
        for u in units:
            if u.is_compact:
                off = 0
                assert u.nbytes <= TINY_TENSOR_BYTES
                for name, o, n in u.layout:
                    assert o == off
                    off += n
                    seen.add(name)
            else:
                assert u.nbytes >= TINY_TENSOR_BYTES
                seen.add(u.name)
        assert seen == {m.name for m in metas}
