"""Wire-codec subsystem tests: int8 round-trips across the model-zoo
dtypes, wire-format integrity, chunk/row alignment, server-side per-link
negotiation, both data planes (threaded bytes + fluid sim), and the
``codec="raw"`` bit-identity guarantee."""

import math
import threading

import numpy as np
import pytest

from repro.core import ReferenceServer, TensorHubClient
from repro.core.errors import TensorHubError
from repro.core.meta import WorkerInfo
from repro.core.oplog import OpLog
from repro.transfer.codec import (
    CodecError,
    DeltaCodec,
    FixedRatioCodec,
    Int8Codec,
    StaleBaseError,
    get_codec,
    unit_wire_dtype,
    wire_ratio,
)
from repro.transfer.engine import (
    LocalTransport,
    TransportError,
    WorkerRegistry,
    WorkerStore,
)


def _np_dtype(name):
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _rand_bytes(dtype: str, n: int, seed=0, scale=3.0) -> np.ndarray:
    x = (np.random.RandomState(seed).randn(n) * scale).astype(_np_dtype(dtype))
    return np.ascontiguousarray(x).view(np.uint8).reshape(-1)


def _rel_err(decoded: np.ndarray, original: np.ndarray, dtype: str) -> float:
    a = decoded.view(_np_dtype(dtype)).astype(np.float32)
    b = original.view(_np_dtype(dtype)).astype(np.float32)
    denom = max(float(np.max(np.abs(b))), 1e-12)
    return float(np.max(np.abs(a - b))) / denom


class TestInt8Wire:
    """Pure codec: framing, round-trips, integrity."""

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16", "float16", "float64"])
    @pytest.mark.parametrize("n", [1, 255, 256, 1000, 4096, 100001])
    def test_roundtrip(self, dtype, n):
        c = get_codec("int8")
        payload = _rand_bytes(dtype, n, seed=n)
        wire = c.encode(payload, dtype)
        assert wire.nbytes == c.wire_nbytes(payload.nbytes, dtype)
        decoded = c.decode(wire)
        assert decoded.nbytes == payload.nbytes
        assert _rel_err(decoded, payload, dtype) < 0.01

    def test_all_zero_rows_exact(self):
        c = get_codec("int8")
        payload = np.zeros(3000, np.float32).view(np.uint8).reshape(-1)
        assert np.array_equal(c.decode(c.encode(payload, "float32")), payload)

    def test_extreme_value_rows(self):
        c = get_codec("int8")
        x = np.full(1000, 3.0e38, np.float32)
        x[::7] = -3.0e38
        payload = x.view(np.uint8).reshape(-1)
        decoded = c.decode(c.encode(payload, "float32"))
        assert _rel_err(decoded, payload, "float32") < 0.01

    def test_non_finite_weights_passthrough_bit_exact(self):
        """Transient NaN/Inf weights (RL loss spikes) must not brick the
        cross-DC transfer: encode falls back to the tagged bit-exact
        passthrough instead of producing non-finite scales."""
        c = get_codec("int8")
        for poison in (np.nan, np.inf, -np.inf):
            x = np.random.RandomState(0).randn(1000).astype(np.float32)
            x[137] = poison
            payload = x.view(np.uint8).reshape(-1)
            wire = c.encode(payload, "float32")
            assert np.array_equal(c.decode(wire), payload)
        # f64 values that overflow the f32 quantization grid too
        big = np.full(300, 1e308, np.float64).view(np.uint8).reshape(-1)
        assert np.array_equal(c.decode(c.encode(big, "float64")), big)

    def test_non_float_passthrough_bit_exact(self):
        c = get_codec("int8")
        payload = np.arange(999, dtype=np.int32).view(np.uint8).reshape(-1)
        wire = c.encode(payload, "int32")
        assert np.array_equal(c.decode(wire), payload)

    def test_unknown_dtype_passthrough(self):
        c = get_codec("int8")
        payload = np.frombuffer(b"hello world!", np.uint8)
        assert np.array_equal(c.decode(c.encode(payload, None)), payload)

    def test_wire_smaller_than_payload(self):
        """The headline ratios: ~0.2539x of f32 bytes (~3.9x reduction),
        ~0.5078x of bf16 (~2.0x) at per-256 f32 scales."""
        c = get_codec("int8")
        r32 = wire_ratio(c, [4 << 20] * 8, "float32")
        r16 = wire_ratio(c, [4 << 20] * 8, "bfloat16")
        assert math.isclose(r32, (1 + 4 / 256) / 4, rel_tol=1e-3)
        assert math.isclose(r16, (1 + 4 / 256) / 2, rel_tol=1e-3)
        assert 3.8 < 1 / r32 < 4.0
        assert 1.9 < 1 / r16 < 2.1

    def test_truncated_wire_rejected(self):
        c = get_codec("int8")
        wire = c.encode(_rand_bytes("float32", 1000), "float32")
        with pytest.raises(CodecError):
            c.decode(wire[:-3])
        with pytest.raises(CodecError):
            c.decode(wire[:4])

    def test_bad_magic_rejected(self):
        c = get_codec("int8")
        wire = c.encode(_rand_bytes("float32", 1000), "float32").copy()
        wire[:4] = 0
        with pytest.raises(CodecError):
            c.decode(wire)

    def test_corrupt_scales_rejected(self):
        """Scale integrity: a NaN/inf scale fails the wire-level check."""
        c = get_codec("int8")
        wire = c.encode(_rand_bytes("float32", 1000), "float32").copy()
        wire[20:24] = np.frombuffer(
            np.float32(np.nan).tobytes(), np.uint8
        )  # first scale word
        with pytest.raises(CodecError):
            c.decode(wire)

    def test_chunk_rows_match_whole_unit(self):
        """Row-aligned sub-range encodes produce exactly the rows of the
        whole-payload encoding — chunked units reassemble bit-identically
        to an unchunked transfer."""
        c = get_codec("int8")
        payload = _rand_bytes("float32", 50000, seed=7)
        full = c.decode(c.encode(payload, "float32"))
        rb = c.row_bytes("float32")
        for per in (rb, 3 * rb, 17 * rb):
            parts, off = [], 0
            while off < payload.nbytes:
                step = min(per, payload.nbytes - off)
                parts.append(c.decode(c.encode(payload[off : off + step], "float32")))
                off += step
            assert np.array_equal(np.concatenate(parts), full)

    def test_backends_agree(self):
        """kernels/quant-backed path vs the pure-NumPy fallback: same
        scheme, same rounding; scales may differ by 1 ulp (XLA folds the
        /127 into a reciprocal multiply), so compare loosely and check
        each decodes within tolerance."""
        payload = _rand_bytes("float32", 12345, seed=3)
        cn, cj = Int8Codec(backend="numpy"), Int8Codec(backend="auto")
        dn = cn.decode(cn.encode(payload, "float32"))
        dj = cj.decode(cj.encode(payload, "float32"))
        assert _rel_err(dn, payload, "float32") < 0.01
        assert _rel_err(dj, payload, "float32") < 0.01
        assert _rel_err(dn, dj, "float32") < 1e-3

    def test_numpy_matches_pallas_kernel(self):
        """The NumPy fallback quantizes exactly like the Pallas kernel
        (interpret mode): same q, scales to 1 ulp."""
        jax = pytest.importorskip("jax")
        from repro.kernels.quant.kernel import quantize_rows

        rows = (np.random.RandomState(5).randn(8, 256) * 2).astype(np.float32)
        qk, sk = quantize_rows(jax.numpy.asarray(rows), interpret=True)
        c = Int8Codec(backend="numpy")
        qn, sn = c._quant_rows(rows)
        assert np.max(np.abs(qn.astype(np.int32) - np.asarray(qk, np.int32))) <= 1
        np.testing.assert_allclose(sn, np.asarray(sk), rtol=1e-6)

    def test_registry(self):
        assert get_codec("raw").name == "raw"
        assert get_codec("int8").name == "int8"
        fixed = get_codec("fixed:0.25")
        assert isinstance(fixed, FixedRatioCodec) and fixed.ratio == 0.25
        with pytest.raises(TensorHubError):
            get_codec("zstd")
        with pytest.raises(TensorHubError):
            get_codec("fixed:nope")

    def test_fixed_ratio_is_sim_only(self):
        fixed = get_codec("fixed:0.5")
        with pytest.raises(CodecError):
            fixed.encode(np.zeros(8, np.uint8), "float32")
        with pytest.raises(CodecError):
            fixed.decode(np.zeros(8, np.uint8))

    def test_raw_is_identity(self):
        raw = get_codec("raw")
        payload = _rand_bytes("bfloat16", 777)
        assert raw.encode(payload, "bfloat16") is payload
        assert raw.decode(payload) is payload
        assert raw.wire_nbytes(123, None) == 123


class TestDeltaWire:
    """delta:<base> framing: residual round-trips against a held base,
    stale-base detection, fallback frames, wire sizing."""

    def _versions(self, dtype, n, changed_frac=0.25, seed=5):
        """Correlated (base, payload) pair: ``changed_frac`` of the quant
        rows differ, the rest are bit-identical. ``held`` is what an
        int8-seeded destination actually holds for the base version."""
        base = _rand_bytes(dtype, n, seed=seed)
        npd = _np_dtype(dtype)
        x = base.view(npd).astype(np.float32)
        rows = -(-n // 256)
        k = int(rows * changed_frac)
        y = x.copy()
        if k:
            y[: k * 256] = y[: k * 256] * 1.001 + 0.01
        payload = np.ascontiguousarray(y.astype(npd)).view(np.uint8).reshape(-1)
        i8 = get_codec("int8")
        held = i8.decode(i8.encode(base, dtype))
        return base, payload, held

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    @pytest.mark.parametrize("n", [1000, 256 * 40 + 17, 100001])
    def test_roundtrip_changed_rows(self, dtype, n):
        c = get_codec("delta:int8")
        base, payload, held = self._versions(dtype, n)
        wire = c.encode(payload, dtype, base=base)
        out = c.decode(wire, base=held)
        assert out.nbytes == payload.nbytes
        assert _rel_err(out, payload, dtype) < 0.01
        # the headline property: fewer wire bytes than a plain int8 frame
        assert wire.nbytes < get_codec("int8").wire_nbytes(payload.nbytes, dtype)

    def test_skipped_rows_bit_exact_vs_int8_baseline(self):
        """An unchanged row decodes to exactly the destination's held
        bytes — which (int8 round-trip being idempotent) are exactly what
        a fresh int8 pull of the new version would have delivered."""
        c = get_codec("delta:int8")
        i8 = get_codec("int8")
        n = 256 * 64
        base, payload, held = self._versions("float32", n, changed_frac=0.25)
        out = c.decode(c.encode(payload, "float32", base=base), base=held)
        baseline = i8.decode(i8.encode(payload, "float32"))
        cut = (256 * 16) * 4  # first quarter of rows changed
        assert np.array_equal(out[cut:], held[cut:])
        assert np.array_equal(out[cut:], baseline[cut:])

    def test_error_no_worse_than_int8(self):
        c = get_codec("delta:int8")
        i8 = get_codec("int8")
        base, payload, held = self._versions("float32", 256 * 64)
        out = c.decode(c.encode(payload, "float32", base=base), base=held)
        baseline = i8.decode(i8.encode(payload, "float32"))
        assert _rel_err(out, payload, "float32") <= (
            _rel_err(baseline, payload, "float32") + 1e-6
        )

    def test_identical_versions_ship_bitmap_only(self):
        c = get_codec("delta:int8")
        base, _, held = self._versions("float32", 256 * 64, changed_frac=0.0)
        wire = c.encode(base, "float32", base=base)
        assert wire.nbytes == c.wire_nbytes_at(base.nbytes, "float32", 0.0)
        assert wire.nbytes < 0.01 * get_codec("int8").wire_nbytes(
            base.nbytes, "float32"
        )
        assert np.array_equal(c.decode(wire, base=held), held)

    def test_zero_residual_rows_skipped(self):
        """A row whose bits changed but that lands exactly on the bytes
        the destination already holds (zero residual) still ships as a
        single bitmap bit."""
        c = get_codec("delta:int8")
        i8 = get_codec("int8")
        base = _rand_bytes("float32", 256 * 8, seed=3)
        held = i8.decode(i8.encode(base, "float32"))
        payload = base.copy()
        payload[: 256 * 4] = held[: 256 * 4]  # row 0 moved onto the quant grid
        wire = c.encode(payload, "float32", base=base)
        assert wire.nbytes == c.wire_nbytes_at(base.nbytes, "float32", 0.0)
        assert np.array_equal(c.decode(wire, base=held), held)

    def test_non_finite_payload_falls_back_bit_exact(self):
        c = get_codec("delta:int8")
        base, payload, _ = self._versions("float32", 1000)
        poisoned = payload.view(np.float32).copy()
        poisoned[137] = np.nan
        pb = poisoned.view(np.uint8).reshape(-1)
        wire = c.encode(pb, "float32", base=base)
        # fallback frames decode without a base (int8 passthrough)
        assert np.array_equal(c.decode(wire), pb)

    def test_no_base_encode_falls_back(self):
        ci = get_codec("delta:int8")
        i8 = get_codec("int8")
        base, payload, _ = self._versions("float32", 1000)
        wire = ci.encode(payload, "float32")  # destination is fresh
        assert np.array_equal(ci.decode(wire), i8.decode(i8.encode(payload, "float32")))
        # a raw-based delta must keep raw's bit-identity guarantee
        cr = get_codec("delta:raw")
        wire = cr.encode(payload, "float32")
        assert np.array_equal(cr.decode(wire), payload)

    def test_delta_raw_roundtrip(self):
        c = get_codec("delta:raw")
        base, payload, _ = self._versions("float32", 256 * 40 + 17)
        wire = c.encode(payload, "float32", base=base)
        out = c.decode(wire, base=base)  # raw destination holds exact bytes
        assert wire.nbytes < payload.nbytes
        assert _rel_err(out, payload, "float32") < 0.01
        cut = (-(-(256 * 40 + 17) // 256) // 4) * 256 * 4
        assert np.array_equal(out[cut:], base[cut:])

    def test_stale_base_rejected(self):
        c = get_codec("delta:int8")
        base, payload, held = self._versions("float32", 256 * 16)
        wire = c.encode(payload, "float32", base=base)
        with pytest.raises(StaleBaseError):
            c.decode(wire)  # base evicted
        with pytest.raises(StaleBaseError):
            c.decode(wire, base=held[:-4])  # wrong size
        with pytest.raises(StaleBaseError):
            c.decode(wire, base=np.zeros_like(held))  # digest mismatch
        # StaleBaseError is a CodecError: undistinguishing callers degrade
        assert issubclass(StaleBaseError, CodecError)

    def test_truncated_delta_frame_not_stale(self):
        """A torn frame with a perfectly good base is wire corruption
        (corrupt evidence, quarantine), never a stale-base fallback."""
        c = get_codec("delta:int8")
        base, payload, held = self._versions("float32", 256 * 16)
        wire = c.encode(payload, "float32", base=base)
        for cut in (wire.nbytes - 3, 20, 7):
            with pytest.raises(CodecError) as ei:
                c.decode(wire[:cut], base=held)
            assert not isinstance(ei.value, StaleBaseError)

    def test_chunked_delta_rows_match_whole(self):
        """Row-aligned sub-range encodes (the chunked-unit path) decode to
        exactly the rows of the whole-payload encoding."""
        c = get_codec("delta:int8")
        i8 = get_codec("int8")
        base, payload, _ = self._versions("float32", 256 * 52)
        held = i8.decode(i8.encode(base, "float32"))
        whole = c.decode(c.encode(payload, "float32", base=base), base=held)
        rb = c.row_bytes("float32")
        for per in (rb, 13 * rb):
            parts, off = [], 0
            while off < payload.nbytes:
                step = min(per, payload.nbytes - off)
                w = c.encode(payload[off : off + step], "float32", base=base[off : off + step])
                parts.append(c.decode(w, base=held[off : off + step]))
                off += step
            assert np.array_equal(np.concatenate(parts), whole)

    def test_registry_and_attrs(self):
        c = get_codec("delta:int8")
        assert isinstance(c, DeltaCodec) and c.name == "delta:int8"
        assert c.needs_base and not c.lossless
        assert c.row_bytes("float32") == get_codec("int8").row_bytes("float32")
        assert get_codec("delta:raw").name == "delta:raw"
        assert not get_codec("int8").needs_base  # baseless codecs unchanged
        with pytest.raises(TensorHubError):
            get_codec("delta:fixed:0.5")
        with pytest.raises(TensorHubError):
            get_codec("delta:nope")

    def test_wire_sizing_model(self):
        c = get_codec("delta:int8")
        n = 4 << 20
        sizes = [c.wire_nbytes_at(n, "float32", f) for f in (0.0, 0.25, 0.5, 1.0)]
        assert sizes == sorted(sizes)
        i8 = get_codec("int8").wire_nbytes(n, "float32")
        assert sizes[1] < 0.3 * i8  # 25% changed rows -> ~4x fewer bytes
        assert sizes[3] >= i8  # all rows kept: digest+bitmap overhead
        assert c.wire_nbytes(n, "float32") == sizes[3]
        # the sim's per-manifest ratio follows the same model
        r_delta = wire_ratio(c, [n] * 4, "float32", delta_kept_frac=0.25)
        r_int8 = wire_ratio(get_codec("int8"), [n] * 4, "float32")
        assert r_delta < 0.3 * r_int8
        # non-quantizable payloads ride as tagged passthrough
        assert c.wire_nbytes_at(999, None, 0.25) == 999 + 20


class TestQuantOpsWireBytes:
    """Satellite: ``compressed_bytes`` must not count zero-padding rows."""

    def test_clamp_to_true_payload(self):
        jax = pytest.importorskip("jax")
        from repro.kernels.quant import compressed_bytes, quantize

        n = 1000  # not a multiple of row_len
        x = jax.numpy.asarray(np.random.RandomState(0).randn(n).astype(np.float32))
        q, s, shape = quantize(x, row_len=256, interpret=True)
        assert q.size == 1024  # padded to the row grid
        true = compressed_bytes(q, s, num_elements=n)
        padded = compressed_bytes(q, s)
        assert true == n * 1 + s.size * 4
        assert padded > true  # the old accounting over-reported
        # and the true ratio matches the codec's wire size formula minus
        # the framing header
        c = get_codec("int8")
        assert true == c.wire_nbytes(4 * n, "float32") - 20

    def test_exact_multiple_unchanged(self):
        jax = pytest.importorskip("jax")
        from repro.kernels.quant import compressed_bytes, quantize

        x = jax.numpy.asarray(np.ones((256, 4), np.float32))
        q, s, _ = quantize(x, row_len=256, interpret=True)
        assert compressed_bytes(q, s) == compressed_bytes(q, s, num_elements=1024)


class TestStoreWriteGuards:
    """Satellite: a dead worker must refuse writes like it refuses reads."""

    def _store(self):
        st = WorkerStore("w0")
        st.register({"t": np.arange(1024, dtype=np.float32)})
        return st

    def test_write_range_refuses_failed_store(self):
        st = self._store()
        st.failed = True
        with pytest.raises(TransportError):
            st.write_range("t", 0, np.zeros(16, np.uint8))

    def test_write_unit_refuses_failed_store(self):
        st = self._store()
        unit = st.units[0]
        st.failed = True
        with pytest.raises(TransportError):
            st.write_unit(unit, np.zeros(unit.nbytes, np.uint8))

    def test_live_store_accepts_writes(self):
        st = self._store()
        st.write_range("t", 0, np.zeros(16, np.uint8))
        unit = st.units[0]
        st.write_unit(unit, np.zeros(unit.nbytes, np.uint8))


def _add_stores(registry, replica, tensors, shard_idx=0):
    st = WorkerStore(f"{replica}/shard{shard_idx}")
    st.register(tensors)
    registry.add(replica, shard_idx, st)
    return st


class TestTransportCodec:
    """LocalTransport with a negotiated codec: decoded-bytes checksums,
    wire-byte accounting, chunk alignment."""

    def _pair(self, n=100000, dtype="float32"):
        reg = WorkerRegistry()
        x = (np.random.RandomState(1).randn(n) * 2).astype(_np_dtype(dtype))
        src = _add_stores(reg, "src", {"t": x})
        dst = _add_stores(reg, "dst", {"t": np.zeros_like(x)})
        return LocalTransport(reg), src, dst, x

    def test_pull_unit_int8(self):
        tp, src, dst, x = self._pair()
        unit = src.units[0]
        manifest = src.build_manifest()
        tp.pull_unit("src", 0, unit, manifest.checksums[0], dst, codec="int8")
        c = get_codec("int8")
        expect = c.decode(c.encode(src.read_unit(unit), "float32"))
        assert np.array_equal(dst.read_unit(unit), expect)
        assert tp.bytes_moved == c.wire_nbytes(unit.nbytes, "float32")
        assert tp.bytes_moved < unit.nbytes * 0.26

    def test_pull_unit_raw_bit_identity(self):
        tp, src, dst, x = self._pair()
        unit = src.units[0]
        manifest = src.build_manifest()
        tp.pull_unit("src", 0, unit, manifest.checksums[0], dst)
        assert np.array_equal(dst.read_unit(unit), src.read_unit(unit))
        assert tp.bytes_moved == unit.nbytes  # wire bytes == payload bytes

    def test_read_unit_range_alignment_enforced(self):
        tp, src, dst, x = self._pair()
        unit = src.units[0]
        rb = get_codec("int8").row_bytes("float32")
        with pytest.raises(CodecError):
            tp.read_unit_range("src", 0, unit, rb // 2, rb, codec="int8")
        # a misaligned *length* is only legal as the final chunk
        with pytest.raises(CodecError):
            tp.read_unit_range("src", 0, unit, 0, rb + 4, codec="int8")

    def test_chunked_reassembly_matches_whole_pull(self):
        tp, src, dst, x = self._pair()
        unit = src.units[0]
        c = get_codec("int8")
        whole = c.decode(c.encode(src.read_unit(unit), "float32"))
        rb = c.row_bytes("float32")
        per = 13 * rb
        out = np.empty(unit.nbytes, np.uint8)
        off = 0
        while off < unit.nbytes:
            step = min(per, unit.nbytes - off)
            out[off : off + step] = tp.read_unit_range(
                "src", 0, unit, off, step, codec="int8"
            )
            off += step
        assert np.array_equal(out, whole)

    def test_wire_frame_read_matches_encode(self):
        """decode=False returns the undecoded wire frame — exactly what
        the codec would emit for that range (the fused reshard path
        parses it client-side)."""
        tp, src, dst, x = self._pair()
        unit = src.units[0]
        c = get_codec("int8")
        wire = tp.read_unit_range(
            "src", 0, unit, 0, unit.nbytes, codec="int8", decode=False
        )
        assert np.array_equal(wire, c.encode(src.read_unit(unit), "float32"))
        assert tp.bytes_moved == wire.nbytes

    def test_wire_frame_read_rejects_base_referencing_codec(self):
        """A delta frame is undecodable without the destination's held
        base — wire-mode reads must refuse it up front."""
        tp, src, dst, x = self._pair()
        unit = src.units[0]
        with pytest.raises(CodecError):
            tp.read_unit_range(
                "src", 0, unit, 0, unit.nbytes, codec="delta:int8",
                decode=False,
            )

    def test_compact_bucket_mixed_dtypes_passthrough(self):
        reg = WorkerRegistry()
        tensors = {
            "a": np.ones(100, np.float32),
            "b": np.arange(100, dtype=np.int32),
        }
        src = _add_stores(reg, "src", tensors)
        dst = _add_stores(
            reg, "dst", {k: np.zeros_like(v) for k, v in tensors.items()}
        )
        tp = LocalTransport(reg)
        unit = src.units[0]
        assert unit.is_compact and src.unit_dtype(unit) is None
        tp.pull_unit("src", 0, unit, src.build_manifest().checksums[0], dst, codec="int8")
        # mixed-dtype bucket rides as tagged passthrough: bit-exact
        assert np.array_equal(dst.get("a"), tensors["a"])
        assert np.array_equal(dst.get("b"), tensors["b"])

    def test_unit_dtype_resolution(self):
        metas = {}
        st = WorkerStore("w")
        st.register(
            {
                "big": np.zeros(1 << 20, np.float32),  # standalone unit
                "t1": np.zeros(128, np.float32),
                "t2": np.zeros(128, np.float32),
            }
        )
        by_unit = {u.name: st.unit_dtype(u) for u in st.units}
        assert by_unit["big"] == "float32"
        compact = [u for u in st.units if u.is_compact][0]
        assert st.unit_dtype(compact) == "float32"  # homogeneous bucket
        del metas


class TestNegotiation:
    """Server-side per-link-class codec negotiation."""

    def _open(self, s, name, dc, shards=1, model="m"):
        for i in range(shards):
            s.open(
                model,
                name,
                shards,
                i,
                worker=WorkerInfo(f"{name}/s{i}", f"{dc}/{name}", dc),
            )
            s.register(model, name, i)

    def _publish(self, s, name, version=0, units=4, shards=1, model="m"):
        from repro.transfer.simcluster import make_manifest

        for i in range(shards):
            s.publish(
                model, name, i, version, make_manifest([1 << 20] * units), op_id=version
            )

    def test_wan_slices_default_int8(self):
        s = ReferenceServer()
        self._open(s, "pub", "dc0")
        self._publish(s, "pub")
        self._open(s, "r", "dc1")
        a = s.begin_replicate("m", "r", 0, 0, op_id=0)
        assert a.transport == "tcp" and a.codec == "int8"
        assert all(sl.codec == "int8" for sl in a.slices(4))

    def test_intra_dc_stays_raw(self):
        s = ReferenceServer()
        self._open(s, "pub", "dc0")
        self._publish(s, "pub")
        self._open(s, "r", "dc0")
        a = s.begin_replicate("m", "r", 0, 0, op_id=0)
        assert a.transport == "rdma" and a.codec == "raw"
        assert all(sl.codec == "raw" for sl in a.slices(4))

    def test_resharded_unquantizable_payload_degrades_to_raw(self):
        """Resharded pulls are codec-capable, but a lossy codec needs a
        quantizable payload: uint8 source manifests force the negotiation
        down to raw (and count the degrade)."""
        from repro.transfer.simcluster import make_layout_manifests

        s = ReferenceServer()
        manifests = make_layout_manifests([1 << 20] * 4, 2, dtype="uint8")
        for i in range(2):
            s.open(
                "m", "pub", 2, i, worker=WorkerInfo(f"pub/s{i}", "dc0/pub", "dc0")
            )
            s.register("m", "pub", i)
            s.publish("m", "pub", i, 0, manifests[i], op_id=0)
        self._open(s, "r", "dc1", shards=1)
        a = s.begin_replicate("m", "r", 0, 0, op_id=0)
        assert a.resharded and a.transport == "tcp"
        assert a.codec == "raw"
        assert all(sl.codec == "raw" for sl in a.sources)
        assert s.stats["codec_degrades"] >= 1

    def test_reroute_preserves_wan_codec(self):
        s = ReferenceServer()
        self._open(s, "pub0", "dc0")
        self._publish(s, "pub0")
        self._open(s, "pub1", "dc0")
        # pub1 holds the version too (replicate + complete)
        a1 = s.begin_replicate("m", "pub1", 0, 0, op_id=0)
        s.update_progress("m", "pub1", 0, 0, 4)
        s.complete_replicate("m", "pub1", 0, 0, op_id=1)
        self._open(s, "r", "dc1")
        a = s.begin_replicate("m", "r", 0, 0, op_id=0)
        assert a.codec == "int8"
        s.report_transfer_failure("m", "r", a.source)
        a2 = s.get_assignment("m", "r")
        assert a2 is not None and a2.source != a.source
        assert a2.codec == "int8"  # still WAN-crossing after the re-plan

    def test_custom_and_invalid_wan_codec(self):
        s = ReferenceServer(wan_codec="fixed:0.25")
        assert s.config()["wan_codec"] == "fixed:0.25"
        with pytest.raises(TensorHubError):
            ReferenceServer(wan_codec="zstd")

    def test_failover_preserves_wan_codec(self):
        from repro.core.failover import recover

        log = OpLog()
        s = ReferenceServer(wan_codec="raw", log=log)
        self._open(s, "pub", "dc0")
        self._publish(s, "pub")
        s.crash()
        recovered = recover(log)
        assert recovered.config()["wan_codec"] == "raw"
        self._open(recovered, "r", "dc1")
        a = recovered.begin_replicate("m", "r", 0, 0, op_id=0)
        assert a.codec == "raw"

    def _seed_correlated(self, s):
        """pub (dc0) retires v0 and publishes v1 after r (dc1) fully
        replicated v0 — the correlated-update shape delta targets."""
        self._open(s, "pub", "dc0")
        self._publish(s, "pub", version=0)
        self._open(s, "r", "dc1")
        s.begin_replicate("m", "r", 0, 0, op_id=0)
        s.update_progress("m", "r", 0, 0, 4)
        s.complete_replicate("m", "r", 0, 0, op_id=1)
        s.unpublish("m", "pub", 0, op_id=10)
        self._publish(s, "pub", version=1)

    def test_update_negotiates_delta(self):
        s = ReferenceServer()
        self._seed_correlated(s)
        d = s.begin_update("m", "r", 0, "latest", op_id=2)
        assert d.updated and d.assignment.codec == "delta:int8"
        assert all(sl.codec == "delta:int8" for sl in d.assignment.slices(4))
        assert s.stats["delta_assignments"] == 1

    def test_fresh_dest_negotiates_plain(self):
        s = ReferenceServer()
        self._seed_correlated(s)
        self._open(s, "fresh", "dc1")
        a = s.begin_replicate("m", "fresh", 0, "latest", op_id=0)
        assert a.codec == "int8"  # no prior version to diff against

    def test_wan_delta_disabled(self):
        s = ReferenceServer(wan_delta=False)
        assert s.config()["wan_delta"] is False
        self._seed_correlated(s)
        d = s.begin_update("m", "r", 0, "latest", op_id=2)
        assert d.updated and d.assignment.codec == "int8"
        assert s.stats["delta_assignments"] == 0

    def test_prior_version_mismatch_negotiates_plain(self):
        """Source retired v1 while dest still holds v0: residuals against
        the wrong base are never negotiated."""
        s = ReferenceServer()
        self._seed_correlated(s)
        s.unpublish("m", "pub", 0, op_id=20)
        self._publish(s, "pub", version=2)
        d = s.begin_update("m", "r", 0, "latest", op_id=2)
        assert d.updated and d.assignment.version == 2
        assert d.assignment.codec == "int8"

    def test_non_delta_capable_wan_codec_skips_delta(self):
        s = ReferenceServer(wan_codec="fixed:0.5")
        self._seed_correlated(s)
        d = s.begin_update("m", "r", 0, "latest", op_id=2)
        assert d.updated and d.assignment.codec == "fixed:0.5"

    def test_aliased_unquantizable_payload_degrades_to_raw(self):
        """An aliased layout (same shard count, different unit
        boundaries) runs the interval-read path, which is codec-capable —
        but this source publishes uint8 units, so the lossy codec can't
        align to a quantization row grid and the pull degrades to raw at
        plan time, counting the degrade."""
        from repro.transfer.simcluster import make_manifest

        s = ReferenceServer()
        self._open(s, "pub", "dc0")
        self._publish(s, "pub", version=0)
        self._open(s, "alias", "dc0")
        # same shard count, same bytes, different unit boundaries
        s.publish("m", "alias", 0, 0, make_manifest([2 << 20] * 2), op_id=0)
        s.fail_replica("m", "pub")
        self._open(s, "r", "dc1")
        a = s.begin_replicate("m", "r", 0, 0, op_id=0)
        assert a.source == "alias" and a.codec == "raw"
        assert s.stats["codec_degrades"] >= 1

    def test_failover_preserves_wan_delta(self):
        """The delta negotiation settings and the prior-version bookkeeping
        they key on must replay bit-identically across a controller crash
        — including a live delta assignment."""
        from repro.core.failover import recover, state_digest

        log = OpLog()
        s = ReferenceServer(wan_delta=False, log=log)
        self._seed_correlated(s)
        s.begin_update("m", "r", 0, "latest", op_id=2)
        digest = state_digest(s)
        s.crash()
        recovered = recover(log)
        assert recovered.config()["wan_delta"] is False
        assert state_digest(recovered) == digest
        # and the delta path itself survives replay: a wan_delta server
        # that negotiated delta:int8 pre-crash re-derives it post-crash
        log2 = OpLog()
        s2 = ReferenceServer(log=log2)
        self._seed_correlated(s2)
        d = s2.begin_update("m", "r", 0, "latest", op_id=2)
        assert d.assignment.codec == "delta:int8"
        digest2 = state_digest(s2)
        s2.crash()
        rec2 = recover(log2)
        assert rec2.config()["wan_delta"] is True
        assert state_digest(rec2) == digest2


def _threaded_tensors(seed=2.0):
    """Model-zoo-ish shard: a standalone f32 unit, a standalone bf16 unit
    with a non-multiple-of-256 element count, and tiny tensors that
    compact into a (homogeneous) bucket."""
    import ml_dtypes

    rng = np.random.RandomState(int(seed))
    return {
        "w_f32": (rng.randn(1 << 20) * seed).astype(np.float32),  # 4 MiB
        "w_bf16": (rng.randn((1 << 20) + 777) * seed).astype(ml_dtypes.bfloat16),
        "tiny0": (rng.randn(2048) * seed).astype(np.float32),
        "tiny1": (rng.randn(2048) * seed).astype(np.float32),
    }


def _correlated_tensors(nrows=4096, changed_rows=1024, mutate=False):
    """Two correlated weight versions (one RL step apart): v1 and a v2
    that differs in exactly ``changed_rows`` of the ``nrows`` quant rows."""
    rng = np.random.default_rng(21)
    w = rng.standard_normal((nrows, 256)).astype(np.float32)
    if mutate:
        w[:changed_rows] = w[:changed_rows] * 1.001 + 0.01
    return {"w": w}


def _run_group(handles, fn):
    errs = []

    def wrap(h):
        try:
            fn(h)
        except BaseException as e:  # noqa: BLE001
            errs.append(e)

    ts = [threading.Thread(target=wrap, args=(h,)) for h in handles]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=120)
    if errs:
        raise errs[0]


class TestThreadedCrossDC:
    """End-to-end through the threaded client: real bytes, negotiated
    codecs, checksums verified over decoded bytes."""

    def _publish(self, hub, dc="dc0"):
        pub = hub.open("m", "pub", 1, 0, datacenter=dc)
        pub.register(_threaded_tensors())
        pub.publish(0)
        return pub

    def _reader(self, hub, name, dc="dc1", **kw):
        h = hub.open("m", name, 1, 0, datacenter=dc, **kw)
        h.register({k: np.zeros_like(v) for k, v in _threaded_tensors().items()})
        return h

    def _max_rel(self, reader, src_tensors):
        worst = 0.0
        for k, v in src_tensors.items():
            got = np.asarray(reader.store.get(k), np.float32)
            want = np.asarray(v, np.float32)
            denom = max(float(np.max(np.abs(want))), 1e-12)
            worst = max(worst, float(np.max(np.abs(got - want))) / denom)
        return worst

    def test_int8_wan_pull(self):
        hub = TensorHubClient(ReferenceServer())
        self._publish(hub)
        total = sum(v.nbytes for v in _threaded_tensors().values())
        r = self._reader(hub, "r")
        r.replicate("latest")
        assert self._max_rel(r, _threaded_tensors()) < 0.01
        # wire bytes: f32 unit at ~0.254x, bf16 at ~0.508x, bucket ~0.254x
        assert hub.transport.bytes_moved < 0.45 * total
        r.close()

    def test_raw_reproduces_byte_counts_bit_for_bit(self):
        hub = TensorHubClient(ReferenceServer(wan_codec="raw"))
        self._publish(hub)
        src = _threaded_tensors()
        total = sum(v.nbytes for v in src.values())
        r = self._reader(hub, "r")
        r.replicate("latest")
        assert hub.transport.bytes_moved == total  # exactly today's wire
        for k, v in src.items():
            assert np.array_equal(
                r.store.get(k).view(np.uint8), v.view(np.uint8)
            )
        r.close()

    def test_chain_off_lossy_replica_verifies(self):
        """A dc1 reader seeded over int8 re-registers its own checksums;
        a second dc1 reader then raw-chains off it with end-to-end
        verification against the *decoded* bytes."""
        server = ReferenceServer()
        hub = TensorHubClient(server)
        self._publish(hub)
        r1 = self._reader(hub, "r1")
        r1.replicate("latest")
        moved = hub.transport.bytes_moved
        r2 = self._reader(hub, "r2")
        r2.replicate("latest")
        # r2 pulled intra-DC (raw): full payload bytes, from r1's copy
        total = sum(v.nbytes for v in _threaded_tensors().values())
        assert hub.transport.bytes_moved - moved == total
        for k in _threaded_tensors():
            assert np.array_equal(
                r2.store.get(k).view(np.uint8), r1.store.get(k).view(np.uint8)
            )
        # and the manifest r2 verified against carries real checksums now
        m = server.replica_manifest("m", 0, "r1", 0)
        assert any(m.checksums)
        r2.close()
        r1.close()

    def test_divergence_propagates_down_raw_chains(self):
        """Regression: r2 raw-chains off the int8-seeded r1, so r2's
        bytes diverge from the publisher's even though r2's own plan was
        lossless. A third reader sourcing from r2 (after r1 is evicted)
        must verify against r2's re-registered checksums, not the
        publisher family's — without divergence propagation this raised
        ChecksumError."""
        server = ReferenceServer()
        hub = TensorHubClient(server)
        self._publish(hub)
        r1 = self._reader(hub, "r1")
        r1.replicate("latest")
        r2 = self._reader(hub, "r2")
        r2.replicate("latest")
        hub.registry.fail_replica("r1")
        server.fail_replica("m", "r1")
        r3 = self._reader(hub, "r3")
        r3.replicate("latest", timeout=60)
        for k in _threaded_tensors():
            assert np.array_equal(
                r3.store.get(k).view(np.uint8), r2.store.get(k).view(np.uint8)
            )
        # r2 registered its own (divergent) manifest with real checksums
        m = server.replica_manifest("m", 0, "r2", 0)
        assert any(m.checksums)

    def test_chunked_giant_unit_matches_unchunked(self):
        srv = ReferenceServer()
        hub_whole = TensorHubClient(srv)
        self._publish(hub_whole)
        r_whole = self._reader(hub_whole, "rw")
        r_whole.replicate("latest")
        # fresh server/hub with chunking: 4 MiB unit -> 1 MiB chunks
        srv2 = ReferenceServer()
        hub_chunk = TensorHubClient(srv2, chunk_bytes=1 << 20)
        pub2 = hub_chunk.open("m", "pub", 1, 0, datacenter="dc0")
        pub2.register(_threaded_tensors())
        pub2.publish(0)
        r_chunk = self._reader(hub_chunk, "rc")
        r_chunk.replicate("latest")
        for k in _threaded_tensors():
            assert np.array_equal(
                r_chunk.store.get(k).view(np.uint8),
                r_whole.store.get(k).view(np.uint8),
            ), f"chunked reassembly diverged for {k}"

    def test_nan_weights_cross_dc(self):
        """End-to-end: a published shard containing NaN still replicates
        over the default int8 WAN negotiation (bit-exact passthrough for
        the poisoned unit, quantized for the rest)."""
        hub = TensorHubClient(ReferenceServer())
        tensors = _threaded_tensors()
        tensors["w_f32"][1234] = np.nan
        pub = hub.open("m", "pub", 1, 0, datacenter="dc0")
        pub.register(tensors)
        pub.publish(0)
        r = hub.open("m", "r", 1, 0, datacenter="dc1")
        r.register({k: np.zeros_like(v) for k, v in tensors.items()})
        r.replicate(0, timeout=60)
        # the poisoned tensor arrived bit-exact (passthrough)
        assert np.array_equal(
            r.store.get("w_f32").view(np.uint8), tensors["w_f32"].view(np.uint8)
        )

    def test_sibling_with_divergent_checksums_dropped(self):
        """_validated_slices drops a same-layout sibling whose manifest
        checksums differ from the primary's — its bytes diverged (e.g. an
        int8-descended replica pooled with a faithful one), so verifying
        its units against the primary's checksums would spuriously fail."""
        from repro.core.meta import SourceSlice

        hub = TensorHubClient(ReferenceServer())
        rng = np.random.RandomState(0)
        a = hub.open("m", "a", 1, 0, datacenter="dc0")
        a.register({"t": rng.randn(1 << 20).astype(np.float32)})
        a.publish(0)
        b = hub.open("m", "b", 1, 0, datacenter="dc0")
        b.register({"t": rng.randn(1 << 20).astype(np.float32)})  # different bytes
        # forge b as a second holder of v0 with its own (divergent) manifest
        hub.server.publish("m", "b", 0, 0, b.store.build_manifest(), op_id=0)
        reader = hub.open("m", "r", 1, 0, datacenter="dc0")
        reader.register({"t": np.zeros(1 << 20, np.float32)})
        manifest_a = hub.server.replica_manifest("m", 0, "a", 0)

        def sl(name):
            return SourceSlice(
                source=name, source_kind="gpu", transport="rdma",
                start_unit=0, stop_unit=1,
            )

        kept = reader._validated_slices([sl("a"), sl("b")], 0, manifest_a)
        assert [s.source for s in kept] == ["a"]

    def test_dest_preemption_not_blamed_on_source(self):
        """Regression: the new write guard makes a preempted DESTINATION
        raise TransportError; the client must surface it rather than
        report the healthy source dead (which would evict it
        cluster-wide)."""
        server = ReferenceServer()
        hub = TensorHubClient(server)
        self._publish(hub)
        r = self._reader(hub, "r")
        r.store.failed = True  # dest preempted before/while pulling
        with pytest.raises(TransportError):
            r.replicate("latest", timeout=30)
        info = server._models["m"].replicas.get("pub")
        assert info is not None and not info.failed  # source still healthy

    def test_update_path_uses_wan_codec(self):
        hub = TensorHubClient(ReferenceServer())
        pub = self._publish(hub)
        r = self._reader(hub, "r")
        r.replicate(0)
        pub.unpublish()
        pub.store.register(_threaded_tensors(seed=5.0))
        pub.publish(1)
        before = hub.transport.bytes_moved
        assert r.update("latest")
        total = sum(v.nbytes for v in _threaded_tensors().values())
        assert hub.transport.bytes_moved - before < 0.45 * total
        assert self._max_rel(r, _threaded_tensors(seed=5.0)) < 0.01

    def _correlated_update(
        self, *, wan_delta=True, scramble_dest=False, drop_source_base=False
    ):
        """publish v0 -> r replicates cross-DC -> publish a correlated v1
        -> r updates. Returns (update-leg wire bytes, r's final tensor,
        hub, server)."""
        server = ReferenceServer(wan_delta=wan_delta)
        hub = TensorHubClient(server)
        pub = hub.open("m", "pub", 1, 0, datacenter="dc0")
        pub.register(_correlated_tensors())
        pub.publish(0)
        r = hub.open("m", "r", 1, 0, datacenter="dc1")
        r.register({"w": np.zeros((4096, 256), np.float32)})
        r.replicate(0)
        pub.unpublish()
        if drop_source_base:
            pub.store.drop_base()
        pub.store.register(_correlated_tensors(mutate=True))
        pub.publish(1)
        if scramble_dest:
            r.store.get("w")[:] = 0.0  # base evicted/diverged mid-plan
        before = hub.transport.bytes_moved
        assert r.update("latest")
        wire = hub.transport.bytes_moved - before
        return wire, r.store.get("w").copy(), hub, server

    def test_delta_update_ships_fewer_wan_bytes(self):
        wire_i8, out_i8, _, _ = self._correlated_update(wan_delta=False)
        wire_d, out_d, hub, server = self._correlated_update()
        assert server.stats["delta_assignments"] >= 1
        assert hub.transport.delta_stale_fallbacks == 0
        # 25% changed rows: ~4x fewer WAN bytes than plain int8
        assert wire_d < 0.3 * wire_i8
        want = _correlated_tensors(mutate=True)["w"]
        assert float(np.max(np.abs(out_d - want))) / float(np.max(np.abs(want))) < 0.01
        # unchanged rows arrive bit-identical to the plain-int8 outcome
        assert np.array_equal(out_d[1024:], out_i8[1024:])

    def test_delta_stale_base_falls_back_byte_identical(self):
        """A destination whose held base was evicted mid-plan decodes the
        frame's digest mismatch as StaleBaseError, transparently re-pulls
        plain int8, and lands byte-identical to a non-delta update."""
        wire_i8, out_i8, _, _ = self._correlated_update(wan_delta=False)
        wire_s, out_s, hub, _ = self._correlated_update(scramble_dest=True)
        assert hub.transport.delta_stale_fallbacks >= 1
        assert np.array_equal(out_s, out_i8)
        # both the refused delta frame and the int8 re-send crossed the wire
        assert wire_s > wire_i8

    def test_delta_source_without_base_sends_plain_int8(self):
        """A source that dropped its base snapshot (steal/failover onto a
        replica that can't serve residuals) emits plain int8 fallback
        frames at encode time — no stale event, byte-identical result."""
        wire_i8, out_i8, _, _ = self._correlated_update(wan_delta=False)
        wire_f, out_f, hub, _ = self._correlated_update(drop_source_base=True)
        assert hub.transport.delta_stale_fallbacks == 0
        assert wire_f == wire_i8
        assert np.array_equal(out_f, out_i8)

    @pytest.mark.timeout(120)
    def test_truncated_frame_heals_via_corrupt_quarantine(self):
        """Regression: a CodecError raised during wire decode used to
        crash the puller. A fault-injected truncated int8 frame must now
        route through the healing path — corrupt evidence, quarantine,
        alternate-source re-fetch — and finish with good bytes."""
        from repro.core.client import RetryPolicy
        from repro.transfer.faults import (
            FaultPlan,
            FaultSpec,
            ThreadedFaultInjector,
        )

        server = ReferenceServer(quarantine_threshold=2, quarantine_probation=60.0)
        inj = ThreadedFaultInjector(
            FaultPlan(seed=11, faults=(FaultSpec("truncate", "pub", severity=1.0),))
        )
        clean = TensorHubClient(server)
        hub = TensorHubClient(
            server,
            registry=clean.registry,
            retry_policy=RetryPolicy(
                fail_detect=0.3, retry_limit=5, retry_backoff=0.01,
                hedge_threshold=8.0, hedge_min_samples=16,
            ),
            faults=inj,
        )
        rng = np.random.RandomState(30)
        want = (rng.randn(1 << 18) * 3).astype(np.float32)
        pub = clean.open("m", "pub", 1, 0, datacenter="dc0")
        pub.register({"w": want.copy()})
        pub.publish(0)
        # healthy alternate source ("pub" sorts first, so the faulty
        # replica is the deterministic initial pick)
        spare = clean.open("m", "spare", 1, 0, datacenter="dc0")
        spare.register({"w": np.zeros_like(want)})
        spare.replicate("latest")
        dest = hub.open("m", "dest", 1, 0, datacenter="dc1")
        dest.register({"w": np.zeros_like(want)})
        inj.arm()
        dest.replicate("latest", timeout=60)
        got = dest.store.get("w")
        rel = float(np.max(np.abs(got - want))) / float(np.max(np.abs(want)))
        assert rel < 0.01  # int8-decoded bytes from the healthy source
        assert server.stats["corrupt_reports"] >= 1
        assert server.stats["quarantines"] >= 1
        assert server.stats["evictions"] == 0


class TestSimCodec:
    """Fluid plane: wire bytes derive from the codec's per-manifest ratio."""

    def _wan_bytes(self, **kw):
        from repro.transfer.simcluster import SimCluster

        cl = SimCluster(**kw)
        units = [int(1e9)] * 4
        tr = cl.add_replica("m", "tr", 2, datacenter="dc0", unit_bytes=units)
        ro = cl.add_replica("m", "ro", 2, datacenter="dc1", unit_bytes=units)
        tr.open()
        ro.open()
        cl.run()
        tr.publish(0)
        cl.run()
        ro.replicate("latest")
        cl.run()
        return sum(b for n, b in cl.net.link_bytes.items() if ":vpc_up" in n)

    def test_int8_default_vs_raw(self):
        raw = self._wan_bytes(wan_codec="raw")
        q = self._wan_bytes()  # default int8
        assert math.isclose(raw, 8e9, rel_tol=1e-6)
        ratio = wire_ratio(get_codec("int8"), [int(1e9)] * 4, "float32")
        assert math.isclose(q, raw * ratio, rel_tol=1e-6)
        assert 3.8 < raw / q < 4.0  # the ~3.9x WAN reduction

    def test_intra_dc_unaffected_by_wan_codec(self):
        from repro.transfer.simcluster import SimCluster

        for codec in ("raw", "int8"):
            cl = SimCluster(wan_codec=codec)
            units = [int(1e9)] * 4
            a = cl.add_replica("m", "a", 1, datacenter="dc0", unit_bytes=units)
            b = cl.add_replica("m", "b", 1, datacenter="dc0", unit_bytes=units)
            a.open()
            b.open()
            cl.run()
            a.publish(0)
            cl.run()
            b.replicate("latest")
            cl.run()
            rdma = sum(b_ for n, b_ in cl.net.link_bytes.items() if ":up" in n)
            assert math.isclose(rdma, 4e9, rel_tol=1e-6)

    def _reshard_wan_bytes(self, **kw):
        from repro.transfer.simcluster import SimCluster

        cl = SimCluster(**kw)
        g = [int(1e9)] * 4
        tr = cl.add_replica("m", "tr", 2, datacenter="dc0", global_unit_bytes=g)
        ro = cl.add_replica("m", "ro", 4, datacenter="dc1", global_unit_bytes=g)
        tr.open()
        ro.open()
        cl.run()
        tr.publish(0)
        cl.run()
        ev = ro.replicate("latest")
        cl.run()
        assert ev.triggered and ev.error is None
        return sum(b for n, b in cl.net.link_bytes.items() if ":vpc_up" in n)

    def test_cross_dc_reshard_forced_raw_bit_exact(self):
        """wan_codec="raw": resharded interval flows move exactly the
        payload bytes (zero row-grid widening on a raw plan)."""
        wan = self._reshard_wan_bytes(wan_codec="raw")
        assert math.isclose(wan, 4e9, rel_tol=1e-6)

    def test_cross_dc_reshard_negotiates_int8(self):
        """The default WAN codec now rides the resharded interval path:
        wire bytes shrink by the codec's ratio (>= 3.5x vs forced raw)."""
        raw = self._reshard_wan_bytes(wan_codec="raw")
        coded = self._reshard_wan_bytes()
        assert raw / coded >= 3.5

    def test_legacy_tcp_compression_scales_resharded_flows(self):
        """Regression: the deprecated scalar scaled EVERY WAN TCP flow —
        resharded interval flows included (codec negotiation keeps those
        raw, so the alias must bypass it to preserve old accounting)."""
        import warnings as _warnings

        from repro.transfer.simcluster import SimCluster

        with _warnings.catch_warnings():
            _warnings.simplefilter("ignore", DeprecationWarning)
            cl = SimCluster(tcp_compression=0.5)
        g = [int(1e9)] * 4
        tr = cl.add_replica("m", "tr", 2, datacenter="dc0", global_unit_bytes=g)
        ro = cl.add_replica("m", "ro", 4, datacenter="dc1", global_unit_bytes=g)
        tr.open()
        ro.open()
        cl.run()
        tr.publish(0)
        cl.run()
        ev = ro.replicate("latest")
        cl.run()
        assert ev.triggered and ev.error is None
        wan = sum(b for n, b in cl.net.link_bytes.items() if ":vpc_up" in n)
        assert math.isclose(wan, 4e9 * 0.5, rel_tol=1e-6)

    def test_delta_reshard_resolves_to_base(self):
        """A resharded assignment carrying a delta codec collapses to the
        delta's base on the interval path (no held prior version exists
        at interval granularity): one policy point, both data planes."""
        from repro.transfer.codec import reshard_wire_codec

        assert reshard_wire_codec("delta:int8") == "int8"
        assert reshard_wire_codec("delta:raw") == "raw"
        assert reshard_wire_codec("int8") == "int8"
        assert reshard_wire_codec("raw") == "raw"

    def _update_wan_bytes(self, **kw):
        """Warm update flow: publish v0, replicate, retire, publish v1,
        update — the correlated shape where delta is negotiated. Returns
        the update leg's WAN bytes."""
        from repro.transfer.simcluster import SimCluster

        cl = SimCluster(**kw)
        units = [4 << 20]
        tr = cl.add_replica("m", "tr", 1, datacenter="dc0", unit_bytes=units)
        ro = cl.add_replica("m", "ro", 1, datacenter="dc1", unit_bytes=units)
        tr.open()
        ro.open()
        cl.run()
        tr.publish(0)
        cl.run()
        ro.replicate("latest")
        cl.run()
        before = dict(cl.net.link_bytes)
        tr.unpublish()
        cl.run()
        tr.publish(1)
        cl.run()
        ev = ro.update("latest")
        cl.run()
        assert ev.triggered and ev.error is None
        wan = sum(
            b - before.get(n, 0)
            for n, b in cl.net.link_bytes.items()
            if ":vpc_up" in n
        )
        return wan, cl

    def test_delta_update_models_kept_fraction(self):
        wan_i8, _ = self._update_wan_bytes(wan_codec="int8", wan_delta=False)
        wan_d, cl = self._update_wan_bytes(
            wan_codec="int8", wan_delta=True, delta_kept_frac=0.25
        )
        assert cl.server.stats["delta_assignments"] >= 1
        # byte model follows the codec's own sizing exactly
        expect = get_codec("delta:int8").wire_nbytes_at(4 << 20, "float32", 0.25)
        assert math.isclose(wan_d, expect, rel_tol=1e-6)
        assert wan_d < 0.3 * wan_i8

    def test_threaded_and_sim_delta_parity(self):
        """WAN bytes for the same correlated update (25% of rows changed,
        one 4 MiB f32 unit) agree across the two data planes."""
        wan_sim, _ = self._update_wan_bytes(
            wan_codec="int8", wan_delta=True, delta_kept_frac=0.25
        )
        s = ReferenceServer(wan_codec="int8")
        hub = TensorHubClient(s)
        pub = hub.open("m", "pub", 1, 0, datacenter="dc0")
        pub.register(_correlated_tensors())
        pub.publish(0)
        r = hub.open("m", "r", 1, 0, datacenter="dc1")
        r.register({"w": np.zeros((4096, 256), np.float32)})
        r.replicate(0)
        pub.unpublish()
        pub.store.register(_correlated_tensors(mutate=True))
        pub.publish(1)
        before = hub.transport.bytes_moved
        assert r.update("latest")
        wan_thr = hub.transport.bytes_moved - before
        assert abs(wan_thr - wan_sim) / wan_sim < 0.02
