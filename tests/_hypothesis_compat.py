"""Hypothesis compatibility layer for the test suite.

``hypothesis`` is a declared test dependency (see pyproject.toml), but
the suite must still *collect and run* in environments where it is not
installed. When the real library is importable it is re-exported
unchanged; otherwise a minimal seeded-random fallback implements exactly
the strategy subset this suite uses (integers, floats, booleans,
sampled_from, lists, binary) and ``@given`` draws a fixed number of
deterministic examples per test — property coverage degrades gracefully
instead of the module failing to import.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random

    #: fallback examples per property (capped: no shrinking, keep it quick)
    _MAX_EXAMPLES = 25

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=1 << 30):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(seq):
            items = list(seq)
            return _Strategy(lambda rng: items[rng.randrange(len(items))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10, **_kw):
            return _Strategy(
                lambda rng: [
                    elements.example(rng)
                    for _ in range(rng.randint(min_size, max_size))
                ]
            )

        @staticmethod
        def binary(min_size=0, max_size=64):
            return _Strategy(
                lambda rng: bytes(
                    rng.randrange(256)
                    for _ in range(rng.randint(min_size, max_size))
                )
            )

    st = _Strategies()

    def settings(max_examples=_MAX_EXAMPLES, deadline=None, **_kw):  # noqa: ARG001
        def deco(fn):
            fn._compat_max_examples = min(max_examples, _MAX_EXAMPLES)
            return fn

        return deco

    def given(*gargs, **gkwargs):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_compat_max_examples", _MAX_EXAMPLES)
                rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
                for _ in range(n):
                    drawn = [s.example(rng) for s in gargs]
                    named = {k: s.example(rng) for k, s in gkwargs.items()}
                    fn(*args, *drawn, **named, **kwargs)

            # pytest must not mistake strategy params for fixtures:
            # positional strategies bind right-to-left (like hypothesis),
            # keyword strategies by name; expose only what remains.
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            if gargs:
                params = params[: len(params) - len(gargs)]
            params = [p for p in params if p.name not in gkwargs]
            wrapper.__signature__ = sig.replace(parameters=params)
            wrapper.__dict__.pop("__wrapped__", None)
            return wrapper

        return deco
