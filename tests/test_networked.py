"""Networked-tier integration tests: real processes, real sockets.

Everything here spawns the controller (``python -m repro.net.controller``)
and workers as genuine OS processes via ``tests/procs.py`` and talks to
them over localhost HTTP — the multi-process deployment shape of the
paper's production service, exercised end to end:

* byte-identical delivery through the socketed data plane, compared
  against the in-process path pulling the same weights;
* heartbeat-expiry eviction of a SIGKILLed worker, with later readers
  re-planned onto the surviving source;
* SIGKILL of the controller mid-pull, restart from the WAL on a fresh
  port, and the parked reader resuming to byte-identical completion.

Excluded from tier-1 by the ``networked`` marker (see pyproject addopts);
CI runs this tier in its own job with ``-m networked``.
"""

import hashlib
import os
import time

import numpy as np
import pytest

from procs import ProcSet
from repro.core.client import TensorHubClient
from repro.core.server import ReferenceServer
from repro.net.client import RemoteClient, read_address

pytestmark = pytest.mark.networked

#: one deterministic model shared by every process in these tests: any
#: two digests over these tensors agree iff the delivered bytes do.
#: TH_N / TH_DIM control the unit count — tensors under the 2 MiB tiny
#: threshold compact into one bucket (one unit), tensors above it become
#: one unit each (what the mid-pull kill test needs to stretch a pull)
WEIGHTS_SRC = """
import hashlib
import os
import numpy as np

def weights():
    n = int(os.environ.get("TH_N", "6"))
    dim = int(os.environ.get("TH_DIM", "96"))
    rng = np.random.default_rng(7)
    return {f"w{i}": rng.standard_normal((dim, dim), dtype=np.float32)
            for i in range(n)}

def digest(store, names):
    return hashlib.sha256(
        b"".join(store.get(k).tobytes() for k in sorted(names))
    ).hexdigest()
"""


def _weights(n=6, dim=96):
    rng = np.random.default_rng(7)
    return {
        f"w{i}": rng.standard_normal((dim, dim), dtype=np.float32)
        for i in range(n)
    }


def _expected_digest(n=6, dim=96):
    w = _weights(n, dim)
    return hashlib.sha256(b"".join(w[k].tobytes() for k in sorted(w))).hexdigest()


def _inprocess_digest():
    """The same replicate through the in-process path — the byte-identity
    oracle the networked readers are compared against."""
    hub = TensorHubClient(ReferenceServer())
    pub = hub.open("m", "pub", 1, 0)
    pub.register(_weights())
    pub.publish(0)
    sub = hub.open("m", "sub", 1, 0)
    sub.register({k: np.zeros_like(v) for k, v in _weights().items()})
    sub.replicate(0)
    return hashlib.sha256(
        b"".join(sub.store.get(k).tobytes() for k in sorted(_weights()))
    ).hexdigest()


def _controller_args(tmp, **kw):
    addr_file = os.path.join(tmp, "controller.addr")
    wal = os.path.join(tmp, "controller.wal")
    args = ["--addr-file", addr_file, "--wal", wal]
    for flag, val in kw.items():
        args += [f"--{flag.replace('_', '-')}", str(val)]
    return addr_file, wal, args


PUBLISHER_SRC = WEIGHTS_SRC + """
import os, time
from repro.net.worker import NetWorker

worker = NetWorker("pub-proc", addr_file=os.environ["TH_ADDR_FILE"])
h = worker.open("m", "pub", 1, 0)
w = weights()
h.register(w)
h.publish(0)
print("PUBLISHED", digest(h.store, w), flush=True)
time.sleep(float(os.environ.get("TH_LINGER", "120")))
"""

READER_SRC = WEIGHTS_SRC + """
import os, time
import numpy as np
from repro.net.worker import NetWorker

name = os.environ["TH_REPLICA"]
worker = NetWorker(name + "-proc", addr_file=os.environ["TH_ADDR_FILE"],
                   throttle_s=float(os.environ.get("TH_THROTTLE", "0")))
h = worker.open("m", name, 1, 0)
w = weights()
h.register({k: np.zeros_like(v) for k, v in w.items()})
print("PULL_START", flush=True)
h.replicate(0)
print("DONE", digest(h.store, w), flush=True)
time.sleep(float(os.environ.get("TH_LINGER", "120")))
"""


@pytest.mark.timeout(300)
def test_publish_multi_worker_pull_byte_identity(tmp_path):
    """register -> publish -> two readers pull over real sockets; every
    delivered copy is byte-identical to the in-process path's."""
    expected = _expected_digest()
    assert _inprocess_digest() == expected  # the oracle agrees with itself
    with ProcSet() as procs:
        addr_file, _, args = _controller_args(
            str(tmp_path), heartbeat_timeout=30.0
        )
        controller = procs.spawn_module("controller", "repro.net.controller", *args)
        controller.await_pattern(r"READY", deadline=60)

        env = {"TH_ADDR_FILE": addr_file}
        publisher = procs.spawn_py("publisher", PUBLISHER_SRC, extra_env=env)
        m = publisher.await_pattern(r"PUBLISHED (\w+)", deadline=60)
        assert m.group(1) == expected, publisher.tails()

        readers = [
            procs.spawn_py(
                f"reader{i}", READER_SRC,
                extra_env={**env, "TH_REPLICA": f"r{i}"},
            )
            for i in (1, 2)
        ]
        for r in readers:
            m = r.await_pattern(r"DONE (\w+)", deadline=120)
            assert m.group(1) == expected, (
                f"networked pull diverged from the in-process bytes\n"
                + procs.failure_report()
            )

        # the transfers really crossed the control plane's sockets
        rc = RemoteClient(read_address(addr_file))
        counters = rc.metrics()["counters"]
        assert counters["publishes"] >= 1
        assert counters["replications_completed"] >= 2
        rc.close()


@pytest.mark.timeout(300)
def test_sigkilled_worker_is_heartbeat_evicted_and_readers_replan(tmp_path):
    """SIGKILL the publisher: its heartbeats stop, the controller's expiry
    ticker evicts it, and a later reader is planned onto the surviving
    replica — completing with identical bytes."""
    expected = _expected_digest()
    with ProcSet() as procs:
        addr_file, _, args = _controller_args(
            str(tmp_path), heartbeat_timeout=1.5, tick_interval=0.25
        )
        controller = procs.spawn_module("controller", "repro.net.controller", *args)
        controller.await_pattern(r"READY", deadline=60)
        rc = RemoteClient(read_address(addr_file))

        env = {"TH_ADDR_FILE": addr_file}
        publisher = procs.spawn_py("publisher", PUBLISHER_SRC, extra_env=env)
        publisher.await_pattern(r"PUBLISHED", deadline=60)

        # first reader completes while the publisher is alive: version 0
        # now has a surviving source besides the publisher
        r1 = procs.spawn_py(
            "reader1", READER_SRC, extra_env={**env, "TH_REPLICA": "r1"}
        )
        m = r1.await_pattern(r"DONE (\w+)", deadline=120)
        assert m.group(1) == expected, procs.failure_report()

        publisher.kill()  # SIGKILL: no unregister, no goodbye — only silence

        deadline = time.monotonic() + 60
        while rc.metrics()["counters"]["evictions"] < 1:
            assert time.monotonic() < deadline, (
                "no heartbeat-expiry eviction within 60s\n"
                + procs.failure_report()
            )
            time.sleep(0.2)
        assert "pub" not in rc.availability("m", 0), (
            "evicted publisher still advertised as a source"
        )

        # a fresh reader must be planned onto r1 (the only live source)
        r2 = procs.spawn_py(
            "reader2", READER_SRC, extra_env={**env, "TH_REPLICA": "r2"}
        )
        m = r2.await_pattern(r"DONE (\w+)", deadline=120)
        assert m.group(1) == expected, procs.failure_report()
        assert "r1" in rc.availability("m", 0)
        rc.close()


@pytest.mark.timeout(300)
def test_controller_sigkill_wal_restart_resumes_mid_pull(tmp_path):
    """SIGKILL the controller while a throttled reader is mid-pull, then
    restart it from the WAL on a fresh port: the parked reader fails over
    through the address file and completes byte-identically."""
    # 8 tensors x 2.25 MiB: each clears the 2 MiB tiny threshold, so the
    # pull moves 8 separate units — with 50ms throttle per remote unit
    # the transfer spans >=0.4s, a wide window for the kill to land in
    expected = _expected_digest(n=8, dim=768)
    with ProcSet() as procs:
        addr_file, wal, args = _controller_args(
            str(tmp_path), heartbeat_timeout=30.0
        )
        controller = procs.spawn_module("controller", "repro.net.controller", *args)
        controller.await_pattern(r"READY", deadline=60)
        first_addr = read_address(addr_file)

        env = {"TH_ADDR_FILE": addr_file, "TH_N": "8", "TH_DIM": "768"}
        publisher = procs.spawn_py("publisher", PUBLISHER_SRC, extra_env=env)
        publisher.await_pattern(r"PUBLISHED", deadline=60)

        reader = procs.spawn_py(
            "reader", READER_SRC,
            extra_env={**env, "TH_REPLICA": "r1", "TH_THROTTLE": "0.05"},
        )
        reader.await_pattern(r"PULL_START", deadline=60)
        time.sleep(0.12)  # land the kill inside the throttled pull

        assert "DONE" not in reader.read_stdout(), (
            "pull finished before the kill could land mid-pull; raise "
            "TH_THROTTLE or the unit count\n" + procs.failure_report()
        )
        controller.kill()  # SIGKILL: the WAL is all that survives

        restarted = procs.spawn_module(
            "controller2", "repro.net.controller", *args
        )
        restarted.await_pattern(r"READY", deadline=60)
        second_addr = read_address(addr_file)
        assert second_addr != first_addr, "fresh port expected after restart"

        # the parked reader fails over via the address file and resumes
        m = reader.await_pattern(r"DONE (\w+)", deadline=120)
        assert m.group(1) == expected, (
            "post-failover bytes diverged\n" + procs.failure_report()
        )

        # the restarted controller (recovered from the WAL) carried the
        # replication to completion in its own books
        rc = RemoteClient(second_addr)
        assert rc.metrics()["counters"]["replications_completed"] >= 1
        assert rc.ping()["crashed"] is False
        rc.close()
