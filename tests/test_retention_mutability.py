"""Retention protocol (3.3) and mutability contract (3.2) — unit tests +
a hypothesis state-machine property over random op interleavings."""

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.errors import MutabilityViolationError
from repro.core.server import KIND_OFFLOAD, ReferenceServer, offload_name

from tests.test_server_consistency import manifest, open_replica, publish


class TestMutabilityContract:
    def test_publish_twice_requires_unpublish(self):
        s = ReferenceServer()
        open_replica(s, "t")
        publish(s, "t", 0)
        with pytest.raises(MutabilityViolationError):
            publish(s, "t", 1, op=1)

    def test_unpublish_then_publish_ok(self):
        s = ReferenceServer()
        open_replica(s, "t", retain=None)
        publish(s, "t", 0)
        for i in range(2):
            s.unpublish("m", "t", i, op_id=1)
        assert s.finish_unpublish("m", "t")
        publish(s, "t", 1, op=2)
        assert s.latest("m") == 1

    def test_unpublish_drains_inflight_readers(self):
        """The server must wait for in-flight replication before the
        publisher may reuse buffers (3.2)."""
        s = ReferenceServer()
        open_replica(s, "t")
        publish(s, "t", 0)
        open_replica(s, "r")
        for i in range(2):
            s.begin_replicate("m", "r", i, 0, op_id=0)
        res = s.unpublish("m", "t", 0, op_id=1)
        s.unpublish("m", "t", 1, op_id=1)
        assert not res.drained
        assert not s.finish_unpublish("m", "t")  # reader still in flight
        for i in range(2):
            s.complete_replicate("m", "r", i, 0, op_id=1)
        assert s.finish_unpublish("m", "t")

    def test_unregister_while_published_raises(self):
        s = ReferenceServer()
        open_replica(s, "t")
        publish(s, "t", 0)
        with pytest.raises(MutabilityViolationError):
            s.unregister("m", "t", 0)


class TestRetentionProtocol:
    def test_last_copy_of_retained_version_offloads(self):
        s = ReferenceServer()
        open_replica(s, "t", retain="latest")
        publish(s, "t", 0)
        res = s.unpublish("m", "t", 0, op_id=1)
        s.unpublish("m", "t", 1, op_id=1)
        assert res.offload_required and res.offload_version == 0
        # completing the offload satisfies availability
        for i in range(2):
            s.publish_offload("m", "t", i, 0, manifest(), op_id=2)
        assert s.finish_unpublish("m", "t")
        assert offload_name("t") in s.list_versions("m")[0]

    def test_no_offload_when_replicated_elsewhere(self):
        s = ReferenceServer()
        open_replica(s, "t", retain="latest")
        open_replica(s, "r")
        publish(s, "t", 0)
        for i in range(2):
            s.begin_replicate("m", "r", i, 0, op_id=0)
        for i in range(2):
            s.complete_replicate("m", "r", i, 0, op_id=1)
        res = s.unpublish("m", "t", 0, op_id=2)
        s.unpublish("m", "t", 1, op_id=2)
        assert not res.offload_required  # the rollout holds a live copy

    def test_offload_released_when_no_longer_retained(self):
        s = ReferenceServer()
        open_replica(s, "t", retain="latest")
        publish(s, "t", 0)
        res = s.unpublish("m", "t", 0, op_id=1)
        s.unpublish("m", "t", 1, op_id=1)
        assert res.offload_required
        for i in range(2):
            s.publish_offload("m", "t", i, 0, manifest(), op_id=2)
        # a newer version shifts the retain window; the offload is released
        publish(s, "t", 1, op=3)
        assert 0 not in s.list_versions("m")
        evs = s.poll_events("t/s0")
        assert any(e.kind == "offload_release" and e.version == 0 for e in evs)

    def test_spot_replicas_do_not_count_for_retention(self):
        s = ReferenceServer()
        open_replica(s, "t", retain="latest")
        open_replica(s, "spot_r", spot=True)
        publish(s, "t", 0)
        for i in range(2):
            s.begin_replicate("m", "spot_r", i, 0, op_id=0)
        for i in range(2):
            s.complete_replicate("m", "spot_r", i, 0, op_id=1)
        res = s.unpublish("m", "t", 0, op_id=2)
        s.unpublish("m", "t", 1, op_id=2)
        # the only other copy is on a spot instance: still offload
        assert res.offload_required

    def test_lost_retained_version_is_graceful(self):
        """4.5: if the last non-spot copy dies, readers get a graceful
        unavailable (parked), not a crash."""
        s = ReferenceServer()
        open_replica(s, "t", retain="latest")
        publish(s, "t", 0)
        s.fail_replica("m", "t")
        open_replica(s, "r")
        a = s.begin_replicate("m", "r", 0, "latest", op_id=0)
        assert a is None  # parked until a new version is published


@settings(max_examples=60, deadline=None)
@given(
    ops=st.lists(
        st.sampled_from(["publish", "unpublish", "replicate", "update"]),
        min_size=4,
        max_size=24,
    )
)
def test_retention_invariant_random_ops(ops):
    """Property: after any op sequence, every version inside any live
    replica's retain window that was ever published and still has a
    non-spot holder (GPU or offload) remains listable — and the trainer is
    never told to drop its last retained copy without offloading."""
    s = ReferenceServer()
    open_replica(s, "t", retain="latest")
    open_replica(s, "r")
    version = 0
    published = False
    r_holds = None
    r_inflight = None
    t_op = iter(range(1000))
    r_op = iter(range(1000, 2000))
    for op in ops:
        if op == "publish" and not published:
            version += 1
            publish(s, "t", version, op=next(t_op))
            published = True
        elif op == "unpublish" and published:
            oid = next(t_op)
            res = s.unpublish("m", "t", 0, op_id=oid)
            s.unpublish("m", "t", 1, op_id=oid)
            if res.offload_required:
                oid = next(t_op)
                for i in range(2):
                    s.publish_offload("m", "t", i, res.offload_version, manifest(), op_id=oid)
            published = False
        elif op == "replicate" and published and r_holds is None and r_inflight is None:
            oid = next(r_op)
            a = [s.begin_replicate("m", "r", i, "latest", op_id=oid) for i in range(2)]
            if a[0] is not None:
                r_inflight = (a[0].version, next(r_op))
        elif op == "update" and r_inflight is not None:
            v, oid = r_inflight
            for i in range(2):
                s.complete_replicate("m", "r", i, v, op_id=oid)
            r_holds = v
            r_inflight = None
        # invariant: the latest published version is always available
        latest = s.latest("m")
        if latest is not None and (published or r_holds == latest):
            listed = s.list_versions("m")
            assert latest in listed, f"latest v{latest} lost! ops={ops}"
