"""Control-plane fault tolerance: replayable op log, snapshots, server
failover, and crash-recovery interleavings.

Three layers of coverage:

* **Replay equivalence** (property-based, no transfers): random op
  sequences applied live vs. replayed from the log produce bit-identical
  servers; snapshot-at-random-prefix + replay-suffix equals full replay.
* **Op-boundary crash sweep** (sim-driven): kill the controller at
  *every* op boundary of a publish -> replicate -> update trace, recover
  from log (+snapshot), and require the final state to equal the
  uncrashed run with every reader completing.
* **Threaded crash recovery** (real bytes): the controller dies mid-pull
  — with and without losing the unflushed group-commit tail — clients
  fail over, re-assert their state, and finish with byte-identical
  weights.
"""

import random
import threading

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import ReferenceServer, TensorHubClient, failover
from repro.core.errors import (
    ConsistencyError,
    ServerUnavailableError,
    TensorHubError,
)
from repro.core.meta import ShardManifest, TensorMeta, TransferUnit, WorkerInfo
from repro.core.oplog import OpLog
from repro.transfer.simcluster import SimCluster

GB = 1e9


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def manifest(n_units=3, unit_bytes=64):
    tensors = tuple(
        TensorMeta(f"t{i}", (unit_bytes,), "uint8", unit_bytes) for i in range(n_units)
    )
    units = tuple(
        TransferUnit(index=i, name=f"t{i}", nbytes=unit_bytes) for i in range(n_units)
    )
    return ShardManifest(tensors=tensors, units=units, checksums=(0,) * n_units)


def worker(replica, shard, dc="dc0"):
    return WorkerInfo(f"{replica}/s{shard}", f"{dc}/{replica}", dc, False)


def open_replica(s, name, shards=2, dc="dc0"):
    for i in range(shards):
        s.open("m", name, shards, i, worker=worker(name, i, dc))
        s.register("m", name, i)


def assert_equivalent(a: ReferenceServer, b: ReferenceServer) -> None:
    """Bit-identical: full state digest plus the user-facing queries the
    issue calls out explicitly."""
    assert failover.state_digest(a) == failover.state_digest(b)
    assert a.list_versions("m") == b.list_versions("m")
    for v in a.list_versions("m"):
        assert a.availability("m", v) == b.availability("m", v)
        assert a.manifest("m", v, 0) == b.manifest("m", v, 0)


# ---------------------------------------------------------------------------
# op log mechanics
# ---------------------------------------------------------------------------


class TestOpLog:
    def test_group_commit_batches_flushes(self):
        log = OpLog(group_commit=4)
        for i in range(10):
            log.append("tick", (float(i),))
        assert log.flushes == 2  # two full batches of 4
        assert log.committed_seq == 8
        assert log.lose_tail() == 2  # the unflushed tail of 2
        assert [r.seq for r in log.committed()] == list(range(1, 9))

    def test_jsonl_round_trip(self):
        log = OpLog()
        s = ReferenceServer(log=log)
        open_replica(s, "pub")
        for i in range(2):
            s.publish("m", "pub", i, 0, manifest(), op_id=0)
        clone = OpLog.from_jsonl(log.to_jsonl())
        assert clone.config == log.config
        assert [r.seq for r in clone.committed()] == [r.seq for r in log.committed()]
        assert_equivalent(s, failover.recover(clone))

    def test_file_backed_log(self, tmp_path):
        path = str(tmp_path / "ops.jsonl")
        log = OpLog(path=path, group_commit=2)
        s = ReferenceServer(log=log)
        open_replica(s, "pub")
        log.flush()
        text = open(path).read()
        assert_equivalent(s, failover.recover(OpLog.from_jsonl(text)))

    def test_compaction_truncates_history(self):
        log = OpLog()
        s = ReferenceServer(log=log)
        open_replica(s, "pub")
        for i in range(2):
            s.publish("m", "pub", i, 0, manifest(), op_id=0)
        n_before = len(list(log.committed()))
        log.compact(failover.take_snapshot(s))
        assert list(log.committed(after=log.snapshot.seq)) == []
        assert n_before > 0
        rec = failover.recover(log)
        assert_equivalent(s, rec)
        # post-compaction ops land after the snapshot and replay on top
        open_replica(rec, "r")
        rec.begin_replicate("m", "r", 0, "latest", op_id=0)
        rec.begin_replicate("m", "r", 1, "latest", op_id=0)
        assert_equivalent(rec, failover.recover(log))


# ---------------------------------------------------------------------------
# replay equivalence (property-based)
# ---------------------------------------------------------------------------


def _random_trace(server: ReferenceServer, rng: random.Random, n_ops: int) -> None:
    """Drive a seeded pseudo-random op sequence. Invalid transitions are
    allowed — the server rejects them deterministically and the failures
    are part of the replayed history."""
    names = ["r0", "r1", "r2", "r3"]
    version = [0]
    ops = [None] * len(names)

    def next_op(i):
        ops[i] = (ops[i] or 0) + 1
        return ops[i]

    def macro(kind, i):
        name = names[i]
        if kind == "open":
            open_replica(server, name, 2, dc=rng.choice(["dc0", "dc1"]))
        elif kind == "publish":
            v, op = version[0], next_op(i)
            version[0] += 1
            for s in range(2):
                server.publish("m", name, s, v, manifest(), op_id=op)
        elif kind == "replicate":
            op = next_op(i)
            for s in range(2):
                server.begin_replicate("m", name, s, "latest", op_id=op)
        elif kind == "update":
            op = next_op(i)
            for s in range(2):
                server.begin_update("m", name, s, "latest", op_id=op)
        elif kind == "progress":
            p = rng.randint(0, 3)
            for s in range(2):
                server.update_progress("m", name, s, rng.randint(0, version[0]), p)
        elif kind == "complete":
            v, op = rng.randint(0, max(0, version[0] - 1)), next_op(i)
            for s in range(2):
                server.complete_replicate("m", name, s, v, op_id=op)
        elif kind == "unpublish":
            op = next_op(i)
            for s in range(2):
                server.unpublish("m", name, s, op_id=op)
            server.finish_unpublish("m", name)
        elif kind == "fail":
            server.fail_replica("m", name, reason="fuzz")
        elif kind == "events":
            server.poll_events(f"{name}/s0")
        elif kind == "heartbeat":
            server.heartbeat("m", name, 0, now=rng.random() * 10)
        elif kind == "suspect":
            # gray-failure evidence: strikes, quarantines and probation
            # windows are part of the replayed state
            server.report_transfer_failure(
                "m",
                name,
                rng.choice([n for n in names if n != name]),
                rng.choice(["transient", "transient", "corrupt", "fatal"]),
                now=rng.random() * 10,
            )
        elif kind == "tick":
            server.tick(rng.random() * 20)

    kinds = [
        "open", "open", "publish", "publish", "replicate", "replicate",
        "update", "progress", "progress", "complete", "unpublish",
        "fail", "events", "heartbeat", "suspect", "suspect", "tick",
    ]
    for _ in range(n_ops):
        try:
            macro(rng.choice(kinds), rng.randrange(len(names)))
        except TensorHubError:
            pass  # deterministic rejection: replay hits the same wall


class TestReplayEquivalence:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(min_value=0, max_value=2**30))
    def test_live_equals_replayed(self, seed):
        log = OpLog()
        live = ReferenceServer(log=log)
        _random_trace(live, random.Random(seed), n_ops=40)
        assert_equivalent(live, failover.recover(log))

    @settings(max_examples=20, deadline=None)
    @given(
        st.integers(min_value=0, max_value=2**30),
        st.integers(min_value=1, max_value=120),
    )
    def test_snapshot_prefix_plus_suffix_equals_full_replay(self, seed, cut):
        """Snapshot at a random record prefix, replay only the suffix:
        identical to replaying the whole history."""
        log = OpLog()
        live = ReferenceServer(log=log)
        snap = {}

        def hook(rec):
            if rec.seq == cut and not snap:
                # the record was appended but not yet executed: the state
                # covers records < cut
                snap["s"] = failover.take_snapshot(live, seq=rec.seq - 1)

        log.on_append = hook
        _random_trace(live, random.Random(seed), n_ops=40)
        full = failover.recover(log)
        if snap:
            log.compact(snap["s"])
            assert list(log.committed())[:1] == [] or (
                next(log.committed()).seq > snap["s"].seq
            )
        assert_equivalent(live, failover.recover(log))
        assert_equivalent(full, failover.recover(log))

    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=0, max_value=2**30))
    def test_jsonl_round_trip_replay(self, seed):
        """Durability: the JSONL image of the log replays identically."""
        log = OpLog()
        live = ReferenceServer(log=log)
        _random_trace(live, random.Random(seed), n_ops=25)
        assert_equivalent(live, failover.recover(OpLog.from_jsonl(log.to_jsonl())))


# ---------------------------------------------------------------------------
# idempotency under re-delivery (failover retry semantics)
# ---------------------------------------------------------------------------


class TestRedeliveryIdempotency:
    def _completed_setup(self):
        s = ReferenceServer(log=OpLog())
        open_replica(s, "pub")
        open_replica(s, "r")
        for i in range(2):
            s.publish("m", "pub", i, 0, manifest(), op_id=0)
        for i in range(2):
            s.begin_replicate("m", "r", i, 0, op_id=0)
        for i in range(2):
            s.update_progress("m", "r", i, 0, 3)
        for i in range(2):
            s.complete_replicate("m", "r", i, 0, op_id=1)
        return s

    def test_duplicate_complete_replicate_is_noop(self):
        s = self._completed_setup()
        before = failover.state_digest(s)
        stats = dict(s.stats)
        for i in range(2):  # full group re-delivered after reconnect
            s.complete_replicate("m", "r", i, 0, op_id=1)
        assert s.stats == stats
        # the second delivery must not bump source_gen / re-release refs
        assert failover.state_digest(s) == before

    def test_duplicate_publish_is_noop(self):
        s = self._completed_setup()
        before = failover.state_digest(s)
        for i in range(2):
            s.publish("m", "pub", i, 0, manifest(), op_id=0)
        assert s.stats["publishes"] == 1
        assert failover.state_digest(s) == before

    def test_divergent_redelivery_still_raises(self):
        s = self._completed_setup()
        with pytest.raises(ConsistencyError):
            s.begin_replicate("m", "r", 0, 0, op_id=1)  # op_id 1 ran "complete"

    def test_poll_events_redelivery_is_noop(self):
        s = self._completed_setup()
        s.fail_replica("m", "pub", reason="emit events")
        evs = s.poll_events("pub/s0")
        assert evs  # eviction notice delivered
        assert s.poll_events("pub/s0") == []  # re-poll after reconnect

    def test_done_txn_memory_prunes_by_recency_not_op_id(self):
        """High-base reassert op ids must not squat the idempotency cache:
        pruning is by insertion recency, so the most recent ops stay
        cached whatever their numeric ids."""
        s = ReferenceServer()
        open_replica(s, "r", shards=1)
        st = s._models["m"]  # noqa: SLF001 - harness introspection
        # a reassert-namespace op retires first...
        s.begin_replicate("m", "r", 0, "latest", op_id=3_000_000)
        # ...then a long run of normal ops
        for op in range(12):
            s.begin_replicate("m", "r", 0, "latest", op_id=op)
        kept = [k[1] for k in st.done_txns if k[0] == "r"]
        assert 3_000_000 not in kept  # oldest entry was evicted
        assert kept == list(range(4, 12))  # the 8 most recent survive

    def test_crashed_server_refuses_everything(self):
        s = self._completed_setup()
        s.crash()
        with pytest.raises(ServerUnavailableError):
            s.list_versions("m")
        with pytest.raises(ServerUnavailableError):
            s.publish("m", "pub", 0, 1, manifest(), op_id=9)


# ---------------------------------------------------------------------------
# sim-driven op-boundary crash sweep
# ---------------------------------------------------------------------------


def _sim_trace(crash_at=None, snapshot_every=None):
    """publish -> replicate(x2) -> roll version -> update(x2), with an
    optional controller crash+recovery at committed record ``crash_at``
    and optional periodic snapshot compaction. Returns (cluster, log,
    completed_event_flags)."""
    log = OpLog()
    cl = SimCluster(log=log, control_latency=0.001)
    fired = {"crash": False}

    def hook(rec):
        if (
            snapshot_every is not None
            and rec.seq % snapshot_every == 0
            and not cl.server.is_crashed
        ):
            log.compact(failover.take_snapshot(cl.server, seq=rec.seq - 1))
        if crash_at is not None and rec.seq >= crash_at and not fired["crash"]:
            fired["crash"] = True
            cl.crash_and_recover()

    log.on_append = hook
    units = [GB] * 4
    pub = cl.add_replica("m", "pub", 2, unit_bytes=units)
    r1 = cl.add_replica("m", "r1", 2, unit_bytes=units)
    r2 = cl.add_replica("m", "r2", 2, unit_bytes=units)
    for r in (pub, r1, r2):
        r.open()
    cl.run()
    pub.publish(0)
    cl.run()
    reps = [r1.replicate("latest"), r2.replicate("latest")]
    cl.run()
    rolls = [r1.unpublish(), r2.unpublish()]
    cl.run()
    pub2 = cl.add_replica("m", "pub2", 2, unit_bytes=units)
    pub2.open()
    cl.run()
    pub2.publish(1)
    cl.run()
    ups = [r1.update("latest"), r2.update("latest")]
    cl.run(until=300.0)
    done = [e.triggered and e.error is None for e in reps + rolls + ups]
    return cl, log, done, fired["crash"]


class TestOpBoundaryCrashSweep:
    def test_uncrashed_trace_completes(self):
        cl, log, done, crashed = _sim_trace()
        assert all(done) and not crashed
        assert log.last_seq > 40  # enough boundaries to make the sweep real

    def test_crash_at_every_op_boundary(self):
        """The tentpole acceptance: a controller killed at an arbitrary op
        boundary recovers from the log and the run converges to the exact
        uncrashed final state, with every reader finishing."""
        base_cl, base_log, base_done, _ = _sim_trace()
        assert all(base_done)
        base_cl.server.attach_log(None)
        want = failover.state_digest(base_cl.server)
        n = base_log.last_seq
        for k in range(1, n + 1, 3):
            cl, log, done, crashed = _sim_trace(crash_at=k)
            assert crashed, f"crash point {k} never reached"
            assert all(done), f"a reader failed after crash at record {k}"
            cl.server.attach_log(None)
            assert failover.state_digest(cl.server) == want, (
                f"state diverged after crash at record {k}"
            )

    def test_crash_sweep_with_snapshot_compaction(self):
        """Same sweep with periodic snapshot+compact: recovery from
        snapshot + suffix must be indistinguishable from full replay."""
        base_cl, base_log, base_done, _ = _sim_trace()
        base_cl.server.attach_log(None)
        want = failover.state_digest(base_cl.server)
        n = base_log.last_seq
        for k in range(5, n + 1, 11):
            cl, log, done, crashed = _sim_trace(crash_at=k, snapshot_every=10)
            assert crashed and all(done)
            cl.server.attach_log(None)
            assert failover.state_digest(cl.server) == want, (
                f"snapshot recovery diverged at record {k}"
            )


# ---------------------------------------------------------------------------
# threaded crash recovery (real bytes)
# ---------------------------------------------------------------------------

BIG = 3 * 1024 * 1024  # above TINY_TENSOR_BYTES: one transfer unit per tensor


def big_tensors(seed: int, n=5):
    rng = np.random.default_rng(seed)
    return {
        f"w{i}": rng.integers(0, 255, size=BIG, dtype=np.uint8) for i in range(n)
    }


def threaded_group(hub, name, make, shards=1):
    handles = [hub.open("m", name, shards, i) for i in range(shards)]
    for h in handles:
        h.register(make())
    return handles


def run_threads(handles, fn):
    errs = []

    def wrap(h):
        try:
            fn(h)
        except BaseException as e:  # noqa: BLE001
            errs.append(e)

    ts = [threading.Thread(target=wrap, args=(h,)) for h in handles]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
    if errs:
        raise errs[0]


class TestThreadedCrashRecovery:
    @pytest.mark.timeout(120)
    def test_crash_mid_pull_byte_identical(self):
        """Controller dies while two readers pull concurrently; after
        failover to the recovered server every reader finishes with
        byte-identical weights."""
        log = OpLog()
        server = ReferenceServer(log=log)
        hub = TensorHubClient(server, failover_timeout=15.0)
        state = {"progress_records": 0, "crashed": False}

        def hook(rec):
            if rec.op != "update_progress" or state["crashed"]:
                return
            state["progress_records"] += 1
            if state["progress_records"] == 3:
                state["crashed"] = True
                hub.server.crash()
                hub.failover(failover.recover(log))

        pubs = threaded_group(hub, "pub", lambda: big_tensors(1))
        run_threads(pubs, lambda h: h.publish(0))
        r1 = threaded_group(hub, "r1", lambda: big_tensors(2))
        r2 = threaded_group(hub, "r2", lambda: big_tensors(3))
        log.on_append = hook  # arm only for the pull phase
        run_threads(r1 + r2, lambda h: h.replicate("latest"))
        assert state["crashed"], "the crash point was never reached"
        for h in r1 + r2:
            for name, arr in pubs[0].store.tensors().items():
                np.testing.assert_array_equal(h.store.get(name), arr)
        # the recovered server is coherent: both readers are published copies
        assert set(hub.server.list_versions("m")[0]) >= {"pub", "r1", "r2"}

    @pytest.mark.timeout(120)
    def test_tail_loss_reassert_resumes_pull(self):
        """Group-commit tail loss: the crash eats every record of the
        reader's session (open, register, begin, progress). The client
        re-asserts registration and its in-flight replicate on the
        recovered server and still finishes byte-identically."""
        log = OpLog(group_commit=1_000_000)  # nothing flushes on its own
        server = ReferenceServer(log=log)
        hub = TensorHubClient(server, failover_timeout=15.0)
        pubs = threaded_group(hub, "pub", lambda: big_tensors(7))
        run_threads(pubs, lambda h: h.publish(0))
        log.flush()  # publisher state is durable; the reader's won't be
        state = {"progress_records": 0, "crashed": False, "lost": 0}

        def hook(rec):
            if rec.op != "update_progress" or state["crashed"]:
                return
            state["progress_records"] += 1
            if state["progress_records"] == 2:
                state["crashed"] = True
                state["lost"] = log.lose_tail()
                hub.server.crash()
                hub.failover(failover.recover(log))

        log.on_append = hook
        r1 = threaded_group(hub, "r1", lambda: big_tensors(8))
        got = []
        run_threads(r1, lambda h: got.append(h.replicate("latest")))
        assert state["crashed"] and state["lost"] > 0
        assert got == [0]
        for name, arr in pubs[0].store.tensors().items():
            np.testing.assert_array_equal(r1[0].store.get(name), arr)
        # the re-asserted reader is a first-class copy on the new server
        assert "r1" in hub.server.list_versions("m")[0]
        assert hub.server.replica_version("m", "r1") == 0

    @pytest.mark.timeout(120)
    def test_lost_publish_reasserted(self):
        """The recovered server lost the publish itself: handles vouch for
        their registered (immutable) bytes again, and a later reader is
        served correctly."""
        log = OpLog(group_commit=1_000_000)
        server = ReferenceServer(log=log)
        hub = TensorHubClient(server, failover_timeout=15.0)
        pubs = threaded_group(hub, "pub", lambda: big_tensors(11), shards=2)
        log.flush()  # open+register durable
        run_threads(pubs, lambda h: h.publish(0))
        with hub._cv:  # noqa: SLF001 - test harness
            assert log.lose_tail() > 0  # the publish records
            hub.server.crash()
            hub.failover(failover.recover(log))
        assert hub.server.latest("m") == 0  # re-published during reassert
        r = threaded_group(hub, "r", lambda: big_tensors(12), shards=2)
        run_threads(r, lambda h: h.replicate(0))
        for h, p in zip(r, pubs):
            for name, arr in p.store.tensors().items():
                np.testing.assert_array_equal(h.store.get(name), arr)

    @pytest.mark.timeout(120)
    def test_partial_publish_loss_rejoins_group(self):
        """The crash eats one shard's publish record but not its peer's:
        the lost shard's reassert re-joins the original group op (same
        op id), the transaction completes, and readers see both shards'
        manifests."""
        log = OpLog(group_commit=1_000_000)
        server = ReferenceServer(log=log)
        hub = TensorHubClient(server, failover_timeout=15.0)
        pubs = threaded_group(hub, "pub", lambda: big_tensors(21), shards=2)
        log.flush()
        pubs[0].publish(0)
        log.flush()  # shard0's publish is durable...
        pubs[1].publish(0)  # ...shard1's stays in the tail
        with hub._cv:  # noqa: SLF001 - test harness
            assert log.lose_tail() == 1
            hub.server.crash()
            hub.failover(failover.recover(log))
        assert hub.server.shard_progress("m", "pub", 0, 1) > 0  # re-joined
        r = threaded_group(hub, "r", lambda: big_tensors(22), shards=2)
        run_threads(r, lambda h: h.replicate(0))
        for h, p in zip(r, pubs):
            for name, arr in p.store.tensors().items():
                np.testing.assert_array_equal(h.store.get(name), arr)

    @pytest.mark.timeout(120)
    def test_reader_opened_before_publisher_mid_update_crash(self):
        """Handle order must not matter: the reader was opened before the
        publisher, so naive one-pass re-assertion would re-issue its
        begin_update("latest") against a server that has not re-learned
        v0 yet (resolving to not-updated and stranding the pull). The
        two-phase reassert re-publishes first."""
        log = OpLog(group_commit=1_000_000)
        server = ReferenceServer(log=log)
        hub = TensorHubClient(server, failover_timeout=15.0)
        r1 = threaded_group(hub, "r1", lambda: big_tensors(31))  # reader FIRST
        pubs = threaded_group(hub, "pub", lambda: big_tensors(30))
        log.flush()  # opens/registers durable...
        run_threads(pubs, lambda h: h.publish(0))  # ...the publish is not
        state = {"progress_records": 0, "crashed": False}

        def hook(rec):
            if rec.op != "update_progress" or state["crashed"]:
                return
            state["progress_records"] += 1
            if state["progress_records"] == 2:
                state["crashed"] = True
                assert log.lose_tail() > 0  # eats publish + begin_update
                hub.server.crash()
                hub.failover(failover.recover(log))

        log.on_append = hook
        updated = []
        run_threads(r1, lambda h: updated.append(h.update("latest")))
        assert state["crashed"] and updated == [True]
        for name, arr in pubs[0].store.tensors().items():
            np.testing.assert_array_equal(r1[0].store.get(name), arr)

    @pytest.mark.timeout(120)
    def test_cross_client_failover_order(self):
        """Publisher and reader live in different client processes and
        the READER's client fails over first: its re-issued begin cannot
        resolve yet, so the stranded pull parks a replicate for the
        absolute version (_reestablish) and resumes once the publisher's
        client re-asserts."""
        log = OpLog(group_commit=1_000_000)
        server = ReferenceServer(log=log)
        from repro.transfer.engine import WorkerRegistry

        registry = WorkerRegistry()  # shared "fabric" across processes
        hub_pub = TensorHubClient(server, registry=registry, failover_timeout=15.0)
        hub_r = TensorHubClient(server, registry=registry, failover_timeout=15.0)
        pubs = threaded_group(hub_pub, "pub", lambda: big_tensors(41))
        log.flush()
        run_threads(pubs, lambda h: h.publish(0))  # unflushed
        r1 = threaded_group(hub_r, "r1", lambda: big_tensors(42))
        state = {"progress_records": 0, "crashed": False}

        def hook(rec):
            if rec.op != "update_progress" or state["crashed"]:
                return
            state["progress_records"] += 1
            if state["progress_records"] == 2:
                state["crashed"] = True
                assert log.lose_tail() > 0
                server.crash()
                recovered = failover.recover(log)
                hub_r.failover(recovered)  # reader first: worst order
                hub_pub.failover(recovered)
        log.on_append = hook
        # update, not replicate: a re-issued begin_update cannot park, so
        # only the _reestablish fallback can revive the stranded pull
        got = []
        run_threads(r1, lambda h: got.append(h.update("latest")))
        assert state["crashed"] and got == [True]
        for name, arr in pubs[0].store.tensors().items():
            np.testing.assert_array_equal(r1[0].store.get(name), arr)

    def test_client_event_redelivery_is_noop(self):
        """process_events() after a reconnect may see events the crashed
        server already delivered; handling them twice must be harmless."""
        log = OpLog()
        server = ReferenceServer(log=log)
        hub = TensorHubClient(server)
        pubs = threaded_group(hub, "pub", lambda: big_tensors(4), shards=1)
        run_threads(pubs, lambda h: h.publish(0))
        # force a retention offload, then release it
        h = pubs[0]
        h.unpublish()  # no retain: no offload, but events may queue
        h.process_events()
        h.process_events()  # re-delivery: no-op, no exception
