"""Gray-failure machinery: deterministic fault plans, checksum
zero-sentinel hardening, quarantine/probation classification, and
threaded byte-identity under injected gray faults.

The sim plane's end-to-end chaos coverage (straggler/flaky/corrupt/hang
x both planes, stall bounds, bit-identical replay) lives in
``benchmarks/chaos.py``; these are the unit-level contracts.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import ReferenceServer, TensorHubClient, failover
from repro.core.errors import TransportError
from repro.core.oplog import OpLog
from repro.transfer import checksum as checksum_lib
from repro.transfer.engine import WorkerStore
from repro.transfer.faults import (
    FaultPlan,
    FaultSpec,
    RetryPolicy,
    SimFaultInjector,
    ThreadedFaultInjector,
)
from repro.transfer.simcluster import SimCluster

from tests.test_failover import manifest, open_replica


def tensors(seed: float, n=6, shape=(64, 32)):
    return {f"w{i}": np.full(shape, seed + i, dtype=np.float32) for i in range(n)}


def run_group(handles, fn):
    errs = []

    def wrap(h):
        try:
            fn(h)
        except BaseException as e:  # noqa: BLE001
            errs.append(e)

    ts = [threading.Thread(target=wrap, args=(h,)) for h in handles]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
    if errs:
        raise errs[0]


# ---------------------------------------------------------------------------
# fault plans: seeded, per-fault independent RNG streams
# ---------------------------------------------------------------------------


class TestFaultPlan:
    def test_same_seed_same_draws(self):
        spec = FaultSpec("flaky", "a", severity=0.5)
        p1 = FaultPlan(seed=3, faults=(spec,))
        p2 = FaultPlan(seed=3, faults=(spec,))
        r1, r2 = p1.rng(0), p2.rng(0)
        assert [r1.random() for _ in range(32)] == [r2.random() for _ in range(32)]

    def test_streams_independent_of_other_faults(self):
        """Adding or removing one fault never perturbs the draws of the
        others (stream keyed on (seed, index), not a shared RNG)."""
        a = FaultSpec("flaky", "a", severity=0.5)
        b = FaultSpec("corrupt", "b", severity=0.5)
        solo = FaultPlan(seed=9, faults=(a,)).rng(0)
        paired = FaultPlan(seed=9, faults=(a, b)).rng(0)
        assert [solo.random() for _ in range(32)] == [
            paired.random() for _ in range(32)
        ]

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            FaultSpec("meteor", "a")
        with pytest.raises(ValueError):
            FaultSpec("flaky", "a", severity=1.5)
        with pytest.raises(ValueError):
            FaultSpec("slow", "a", direction="sideways")

    def test_threaded_flaky_draws_reproducible(self):
        """Two injectors armed on the same plan flake on the same draw
        sequence (decision determinism; thread interleaving aside)."""
        plan = FaultPlan(seed=5, faults=(FaultSpec("flaky", "src", severity=0.5),))

        def decisions(inj):
            out = []
            for _ in range(64):
                try:
                    inj.before_read("src", 0)
                    out.append(False)
                except TransportError as e:
                    assert e.transient
                    out.append(True)
            return out

        t = [0.0]
        i1 = ThreadedFaultInjector(plan, clock=lambda: t[0]).arm()
        i2 = ThreadedFaultInjector(plan, clock=lambda: t[0]).arm()
        assert decisions(i1) == decisions(i2)
        assert any(decisions(ThreadedFaultInjector(plan, clock=lambda: t[0]).arm()))

    def test_sim_injector_windows_relative_to_install(self):
        """A plan installed mid-run (after a healthy warm-up) schedules
        its windows from the install instant, mirroring arm()."""
        cl = SimCluster()
        cl.env.now = 3.0
        inj = SimFaultInjector(cl, FaultPlan(seed=0, faults=(
            FaultSpec("flaky", "ra", start=0.0, duration=1.0, severity=1.0),
        )))
        assert inj.flaky_hit("ra", 3.5)  # inside [3.0, 4.0)
        assert not inj.flaky_hit("ra", 4.5)  # window over
        assert not inj.flaky_hit("rb", 3.5)  # wrong target


# ---------------------------------------------------------------------------
# checksum: a real payload can never alias the "disabled" sentinel
# ---------------------------------------------------------------------------


class TestChecksumZeroSentinel:
    def test_symmetric_payload_folds_nonzero(self):
        """Six identical-patterned fp32 tensors compact into one bucket
        whose weighted sums cancel to exactly 0 — the value the transfer
        layer reads as "verification disabled". The fold must remap it,
        or corrupt bytes from that unit would propagate unverified."""
        st = WorkerStore("x")
        st.register(tensors(3.0, n=6))
        m = st.build_manifest()
        assert all(c != 0 for c in m.checksums)

    def test_zero_fold_remaps_to_standin_and_still_detects(self):
        buf = np.concatenate(
            [np.full(64 * 32, 3.0 + i, dtype=np.float32) for i in range(6)]
        )
        c = checksum_lib.checksum(buf)
        assert c == checksum_lib.ZERO_STANDIN
        flipped = buf.copy().view(np.uint8)
        flipped[17] ^= 0xFF
        assert checksum_lib.checksum(flipped) != c

    def test_fold64_matches_host_remap(self):
        from repro.kernels.checksum import fold64

        assert fold64((0, 0)) == checksum_lib.ZERO_STANDIN
        assert fold64((1, 2)) == (2 << 32) | 1
        assert checksum_lib.checksum(b"") == 0  # empty stays the sentinel


# ---------------------------------------------------------------------------
# quarantine / probation classification on the server
# ---------------------------------------------------------------------------


class TestQuarantine:
    def _server(self, **kw):
        s = ReferenceServer(
            quarantine_threshold=2, quarantine_probation=10.0, **kw
        )
        open_replica(s, "pub")
        open_replica(s, "src")
        open_replica(s, "r")
        for shard in range(2):
            s.publish("m", "pub", shard, 0, manifest(), op_id=0)
            s.publish("m", "src", shard, 0, manifest(), op_id=0)
        return s

    def test_transient_strikes_then_quarantine_not_eviction(self):
        s = self._server()
        s.report_transfer_failure("m", "r", "src", "transient", 1.0)
        assert s.stats["quarantines"] == 0
        s.report_transfer_failure("m", "r", "src", "transient", 2.0)
        assert s.stats["quarantines"] == 1
        assert s.stats["evictions"] == 0
        info = s._models["m"].replicas["src"]  # noqa: SLF001
        assert info.quarantined_until == 12.0 and not info.failed

    def test_corrupt_quarantines_immediately(self):
        s = self._server()
        s.report_transfer_failure("m", "r", "src", "corrupt", 1.0)
        assert s.stats["quarantines"] == 1
        assert s.stats["corrupt_reports"] == 1
        assert not s._models["m"].replicas["src"].failed  # noqa: SLF001

    def test_probation_lift_keeps_one_strike_headroom(self):
        """An expired quarantine rejoins one strike short of the
        threshold: a single further transient report re-benches it."""
        s = self._server()
        s.report_transfer_failure("m", "r", "src", "corrupt", 1.0)
        s.tick(12.0)
        assert s.stats["probation_lifts"] == 1
        info = s._models["m"].replicas["src"]  # noqa: SLF001
        assert info.quarantined_until is None
        s.report_transfer_failure("m", "r", "src", "transient", 13.0)
        assert s.stats["quarantines"] == 2

    def test_quarantined_source_benched_while_healthy_exists(self):
        s = self._server()
        s.report_transfer_failure("m", "r", "src", "corrupt", 1.0)
        a = s.begin_replicate("m", "r", 0, 0, op_id=1)
        assert a.source == "pub"

    def test_quarantined_source_is_last_resort(self):
        """Suspect source beats no source: when the only holder of the
        version is quarantined, pulls still get scheduled onto it."""
        s = ReferenceServer(quarantine_threshold=2, quarantine_probation=10.0)
        open_replica(s, "pub")
        open_replica(s, "r")
        for shard in range(2):
            s.publish("m", "pub", shard, 0, manifest(), op_id=0)
        s.report_transfer_failure("m", "r", "pub", "corrupt", 1.0)
        assert s.stats["quarantines"] == 1
        a = s.begin_replicate("m", "r", 0, 0, op_id=1)
        assert a is not None and a.source == "pub"

    def test_fatal_evidence_still_evicts(self):
        s = self._server()
        s.report_transfer_failure("m", "r", "src", "fatal", 1.0)
        assert s.stats["evictions"] == 1
        assert s.stats["quarantines"] == 0
        # evicted, not benched: never scheduled again
        a = s.begin_replicate("m", "r", 0, 0, op_id=1)
        assert a.source == "pub"

    def test_quarantine_state_replays_from_op_log(self):
        """Crash-and-recover (PR 4 harness) reproduces quarantine strikes,
        windows, and probation lifts bit-identically from the log."""
        log = OpLog()
        s = self._server(log=log)
        s.report_transfer_failure("m", "r", "src", "transient", 1.0)
        s.report_transfer_failure("m", "r", "src", "corrupt", 2.0)
        s.tick(5.0)  # mid-probation: quarantine still active
        assert failover.state_digest(s) == failover.state_digest(
            failover.recover(log)
        )
        s.tick(12.5)  # probation lifted
        s.report_transfer_failure("m", "r", "src", "transient", 13.0)
        rec = failover.recover(log)
        assert failover.state_digest(s) == failover.state_digest(rec)
        assert rec.stats["quarantines"] == s.stats["quarantines"] == 2


# ---------------------------------------------------------------------------
# threaded plane: byte identity under gray faults
# ---------------------------------------------------------------------------


POLICY = RetryPolicy(
    fail_detect=0.3, retry_limit=5, retry_backoff=0.01,
    hedge_threshold=8.0, hedge_min_samples=16,
)


def _topology(kind_faults, **server_kw):
    """pub (gray) -> peer (healthy warm-up) -> dest (pull under faults)."""
    server = ReferenceServer(
        quarantine_threshold=2, quarantine_probation=60.0, **server_kw
    )
    inj = ThreadedFaultInjector(FaultPlan(seed=11, faults=kind_faults))
    clean = TensorHubClient(server)
    hub = TensorHubClient(
        server, registry=clean.registry, retry_policy=POLICY, faults=inj
    )
    pubs = [clean.open("m", "pub", 2, i) for i in range(2)]
    for h in pubs:
        h.register(tensors(3.0))
    run_group(pubs, lambda h: h.publish(0))
    peers = [clean.open("m", "peer", 2, i) for i in range(2)]
    for h in peers:
        h.register(tensors(0.0))
    run_group(peers, lambda h: h.replicate("latest"))
    dests = [hub.open("m", "dest", 2, i) for i in range(2)]
    for h in dests:
        h.register(tensors(0.0))
    inj.arm()
    return server, inj, dests


class TestThreadedByteIdentity:
    @pytest.mark.timeout(120)
    def test_corrupt_source_rerouted_bytes_identical(self):
        server, inj, dests = _topology(
            (FaultSpec("corrupt", "pub", severity=1.0),)
        )
        run_group(dests, lambda h: h.replicate("latest"))
        want = tensors(3.0)
        for h in dests:
            for k, v in want.items():
                assert np.array_equal(h.store.get(k), v)
        assert server.stats["quarantines"] >= 1
        assert server.stats["evictions"] == 0

    @pytest.mark.timeout(120)
    def test_flaky_source_retries_bytes_identical(self):
        server, inj, dests = _topology(
            (FaultSpec("flaky", "pub", severity=0.4),)
        )
        run_group(dests, lambda h: h.replicate("latest"))
        want = tensors(3.0)
        for h in dests:
            for k, v in want.items():
                assert np.array_equal(h.store.get(k), v)
        assert server.stats["evictions"] == 0

    @pytest.mark.timeout(120)
    def test_hang_detected_and_rerouted(self):
        server, inj, dests = _topology(
            (FaultSpec("hang", "pub", duration=5.0),)
        )
        t0 = time.monotonic()
        run_group(dests, lambda h: h.replicate("latest"))
        elapsed = time.monotonic() - t0
        inj.release()
        want = tensors(3.0)
        for h in dests:
            for k, v in want.items():
                assert np.array_equal(h.store.get(k), v)
        # healed via deadline detection + re-route, not by waiting out
        # the full 5 s hang window
        assert elapsed < 4.0
        assert server.stats["quarantines"] >= 1
        assert server.stats["evictions"] == 0
