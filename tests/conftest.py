"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real CPU device; only repro.launch.dryrun fakes 512."""

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture()
def np_rng():
    return np.random.default_rng(0)
