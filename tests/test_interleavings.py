"""Exhaustive interleaving tests (paper 4.6): enumerate EVERY merge of two
clients' control-plane op streams against one server and assert the
consistency/availability invariants hold in all of them — plus randomized
(seeded, reproducible) fault-injection interleavings for swarm
replication: kill/preempt random swarm sources and bump progress at
adversarial ticks, then check payload bit-identity, checksum integrity
and simulator quiescence.

This is the FoundationDB-style deterministic simulation the paper credits
for uncovering subtle concurrency bugs; because all requests originate
from one process, every execution is reproducible.
"""

import itertools
import random
import threading
import time

import numpy as np
import pytest

from repro.core import TensorHubClient
from repro.core.errors import TensorHubError
from repro.core.server import IN_PROGRESS, ReferenceServer
from repro.transfer.simcluster import SimCluster

from tests.test_server_consistency import manifest, open_replica


def merges(a, b):
    """All interleavings of two sequences (preserving each one's order)."""
    if not a:
        yield tuple(b)
        return
    if not b:
        yield tuple(a)
        return
    for rest in merges(a[1:], b):
        yield (a[0],) + rest
    for rest in merges(a, b[1:]):
        yield (b[0],) + rest


def publisher_stream(name, versions):
    """Per-shard op stream for a 2-shard publisher rolling versions."""
    ops = []
    op_id = 0
    for v in versions:
        for shard in range(2):
            ops.append(("publish", name, shard, v, op_id))
        op_id += 1
        for shard in range(2):
            ops.append(("unpublish", name, shard, op_id))
        op_id += 1
    return ops


def reader_stream(name):
    return [
        ("replicate", name, 0, "latest", 0),
        ("replicate", name, 1, "latest", 0),
        ("complete", name, 0, 1),
        ("complete", name, 1, 1),
    ]


def apply_op(server, op, state):
    kind = op[0]
    if kind == "publish":
        _, name, shard, v, op_id = op
        server.publish("m", name, shard, v, manifest(), op_id=op_id)
    elif kind == "unpublish":
        _, name, shard, op_id = op
        res = server.unpublish("m", name, shard, op_id=op_id)
        if res.offload_required and shard == 1:
            oid = 900 + op_id
            for s in range(2):
                server.publish_offload("m", name, s, res.offload_version, manifest(), op_id=oid)
    elif kind == "replicate":
        _, name, shard, spec, op_id = op
        a = server.begin_replicate("m", name, shard, spec, op_id=op_id)
        state.setdefault("assign", {})[(name, shard)] = a
    elif kind == "complete":
        _, name, shard, op_id = op
        a = state.get("assign", {}).get((name, shard))
        if a is None:  # parked replicate: redeem first
            a = server.redeem("m", name, op_id=0)
        if a is not None:
            server.complete_replicate("m", name, shard, a.version, op_id=op_id)
            state.setdefault("done", set()).add((name, shard))


@pytest.mark.timeout(300)
def test_all_interleavings_publisher_vs_reader():
    """Publisher rolls v0 -> v1 while a reader replicates 'latest'.

    Invariants checked in every interleaving:
    * both reader shards resolve the SAME version (group snapshot),
    * the resolved version was published at assignment time,
    * the latest published version stays listable (retention),
    * the server never raises anything but defined TensorHubErrors.
    """
    pub_ops = publisher_stream("pub", [0, 1])
    read_ops = reader_stream("r")
    n = 0
    for schedule in merges(pub_ops, read_ops):
        n += 1
        server = ReferenceServer()
        open_replica(server, "pub", retain="latest")
        open_replica(server, "r")
        state = {}
        for op in schedule:
            try:
                apply_op(server, op, state)
            except TensorHubError:
                pass  # defined, graceful errors are allowed
        # invariant: if both shards got assignments, they saw one version
        a0 = state.get("assign", {}).get(("r", 0))
        a1 = state.get("assign", {}).get(("r", 1))
        if a0 is not None and a1 is not None:
            assert a0.version == a1.version, f"split-brain in schedule {schedule}"
        # invariant: the latest version is always available somewhere
        latest = server.latest("m")
        if latest is not None:
            assert latest in server.list_versions("m"), f"lost v{latest}"
    assert n == 495  # C(12,4): all merges were actually enumerated


@pytest.mark.timeout(300)
def test_all_interleavings_two_readers_share_sources():
    """Two readers replicate concurrently from one publisher; in every
    interleaving both complete and the refcounts drain back to zero."""
    r1 = reader_stream("r1")
    r2 = reader_stream("r2")
    count = 0
    for schedule in itertools.islice(merges(r1, r2), 0, None):
        count += 1
        server = ReferenceServer()
        open_replica(server, "pub")
        open_replica(server, "r1")
        open_replica(server, "r2")
        for shard in range(2):
            server.publish("m", "pub", shard, 0, manifest(), op_id=0)
        state = {}
        for op in schedule:
            apply_op(server, op, state)
        assert state.get("done") == {("r1", 0), ("r1", 1), ("r2", 0), ("r2", 1)}
        # all in-flight refcounts drained
        st = server._models["m"]  # noqa: SLF001 - test introspection
        for vmap in st.versions.values():
            for rv in vmap.values():
                assert rv.refcount == 0, f"leaked refcount in {schedule}"
    assert count == 70  # C(8,4)


# ---------------------------------------------------------------------------
# randomized swarm fault injection (seeded, reproducible)
# ---------------------------------------------------------------------------

GB = 1e9


@pytest.mark.timeout(300)
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_swarm_kills_sim_quiesces(seed):
    """Kill random swarm sources at adversarial ticks (aligned to unit-flow
    boundaries, where claims/progress/epochs race hardest): every survivor
    completes with full per-shard progress, the victims' replicate groups
    error out, and the event loop quiesces — no deadlocked waiter keeps
    virtual time running to the horizon."""
    rng = random.Random(seed)
    cl = SimCluster()
    units = [GB] * 12
    n_dest = 6
    pubs = [cl.add_replica("m", f"pub{i}", 2, unit_bytes=units) for i in range(2)]
    dests = [
        cl.add_replica("m", f"dst{i}", 2, unit_bytes=units, is_spot=True)
        for i in range(n_dest)
    ]
    for r in pubs + dests:
        r.open()
    cl.run()
    pubs[0].publish(0)
    cl.run()
    seeds = [p.replicate("latest") for p in pubs[1:]]
    cl.run()
    assert all(e.triggered and e.error is None for e in seeds)
    t0 = cl.env.now
    events = {d.name: d.replicate("latest") for d in dests}
    # adversarial ticks: kills land right at unit-flow boundaries (one
    # 1 GB unit over an effective ~23 GB/s uplink), plus a tiny jitter
    # either side so both "just before" and "just after" races occur
    unit_t = GB / (cl.hw.tensorhub_rdma_eff * cl.hw.rdma_per_shard)
    victims = rng.sample([d.name for d in dests], rng.randint(1, 3))
    for v in victims:
        k = rng.randint(1, 10)
        jitter = rng.choice([-1e-4, 0.0, 1e-4])
        cl.env.schedule(max(1e-3, k * unit_t + jitter), lambda v=v: cl.kill_replica(v))
    cl.run(until=300.0)
    # quiesced: no keyed waiter is still parked (a reader gating forever on
    # a progress counter that will never advance would show up here)
    pending = [
        k
        for k, ev in cl.env._keyed.items()  # noqa: SLF001 - test introspection
        if ev._waiters or ev._callbacks  # noqa: SLF001
    ]
    assert not pending, f"sim did not quiesce; parked waiters on {pending}"
    for d in dests:
        ev = events[d.name]
        if d.name in victims:
            continue  # a preempted group may error or (if late kill) finish
        assert ev.triggered and ev.error is None, f"{d.name} did not complete"
        for s in d.shards:
            assert (
                cl.server.shard_progress("m", d.name, 0, s.idx) == len(units)
            ), f"{d.name}/s{s.idx}: incomplete progress"
    # no replica left mid-replication: swarm state fully unwound
    st = cl.server._models["m"]  # noqa: SLF001 - test introspection
    for vmap in st.versions.values():
        for rv in vmap.values():
            if rv.replica in victims:
                continue
            assert rv.status != IN_PROGRESS, f"{rv.replica} stuck in-progress"


@pytest.mark.timeout(300)
@pytest.mark.parametrize("seed", [0, 1])
def test_random_swarm_kills_threaded_bit_identical(seed):
    """Threaded client with real bytes: random swarm-source kills at random
    delays; surviving readers converge to bit-identical payloads with
    whole-unit checksums verified end to end."""
    rng = random.Random(seed)

    def tensors(tag: float):
        g = np.random.default_rng(int(tag))
        return {
            "big": g.integers(0, 255, size=(96, 1024), dtype=np.uint8),
            "w": np.full((64, 8), tag, dtype=np.float32),
        }

    server = ReferenceServer()
    hub = TensorHubClient(server, window=3, chunk_bytes=4096)
    pub = [hub.open("m", "pub", 1, 0)]
    pub[0].register(tensors(42.0))
    pub[0].publish(0)
    mirrors = []
    for i in range(2):  # extra full copies that become kill targets
        h = hub.open("m", f"mir{i}", 1, 0)
        h.register(tensors(0.0))
        h.replicate(0)
        mirrors.append(h)

    victims = rng.sample([m.replica for m in mirrors], rng.randint(1, 2))

    def killer():
        for v in victims:
            time.sleep(rng.uniform(0.01, 0.08))
            hub.registry.fail_replica(v)
            with hub._cv:  # noqa: SLF001 — failure injection
                server.fail_replica("m", v, reason="random preemption")

    kt = threading.Thread(target=killer, daemon=True)
    readers = [hub.open("m", f"r{i}", 1, 0) for i in range(3)]
    for r in readers:
        r.register(tensors(float(i := readers.index(r))))
    errs = []

    def pull(h):
        try:
            h.replicate(0)
        except BaseException as e:  # noqa: BLE001
            errs.append((h.replica, e))

    ts = [threading.Thread(target=pull, args=(r,)) for r in readers]
    kt.start()
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=120)
    kt.join(timeout=10)
    assert not errs, f"readers failed: {errs}"
    want = tensors(42.0)
    for r in readers:
        for name, arr in want.items():
            assert np.array_equal(r.store.get(name), arr), (seed, r.replica, name)


@pytest.mark.timeout(300)
def test_random_progress_bumps_against_planner():
    """Server-level adversarial interleaving: random progress bumps, joins,
    publishes and kills in random (seeded) order; after every op the swarm
    planner's invariants hold for every in-progress reader — the plan
    tiles the remaining range with no gaps/overlaps and references only
    live replicas."""
    for seed in range(6):
        rng = random.Random(seed)
        server = ReferenceServer()
        open_replica(server, "pub")
        for shard in range(2):
            server.publish("m", "pub", shard, 0, manifest(), op_id=0)
        readers = []
        ops = 0
        for step in range(60):
            ops += 1
            roll = rng.random()
            try:
                if roll < 0.3 and len(readers) < 5:
                    name = f"r{len(readers)}"
                    open_replica(server, name)
                    for shard in range(2):
                        server.begin_replicate("m", name, shard, 0, op_id=0)
                    readers.append(name)
                elif roll < 0.8 and readers:
                    name = rng.choice(readers)
                    shard = rng.randrange(2)
                    bump = rng.randint(1, 8)
                    server.update_progress("m", name, shard, 0, bump)
                elif readers and roll < 0.9:
                    victim = rng.choice(readers)
                    readers.remove(victim)
                    server.fail_replica("m", victim, reason="adversarial")
            except TensorHubError:
                pass  # defined errors allowed; invariants must still hold
            st = server._models["m"]  # noqa: SLF001 - test introspection
            n_units = server.manifest("m", 0, 0).num_units
            vmap = st.versions.get(0, {})
            for rv in vmap.values():
                if rv.status != IN_PROGRESS or not rv.plan:
                    continue
                pos = rv.plan[0][1]
                for src, a, b in rv.plan:
                    assert a == pos and b >= a, f"seed {seed}: torn plan {rv.plan}"
                    pos = b
                    assert src in vmap, f"seed {seed}: dead source {src} in plan"
                assert pos in (n_units, -1), f"seed {seed}: plan does not tile: {rv.plan}"


@pytest.mark.timeout(120)
def test_heartbeat_eviction_races_inflight_pull():
    """Gray failure: a slow-but-alive publisher stops heartbeating and is
    evicted by ``tick`` while a destination is mid-pull FROM it. The
    eviction must not wedge or corrupt the pull — the reader re-plans
    onto the healthy peer and converges to bit-identical payloads."""
    from repro.transfer.faults import FaultPlan, FaultSpec, RetryPolicy
    from repro.transfer.faults import ThreadedFaultInjector

    def tensors(tag: float):
        # 4 MB each — above the tiny-tensor compaction cutoff, so each
        # tensor is its own transfer unit and the pull spans many reads
        # (a wide window for the eviction to land inside)
        return {
            f"w{i}": np.full((1024, 1024), tag + i, dtype=np.float32)
            for i in range(3)
        }

    server = ReferenceServer(heartbeat_timeout=1.0)
    # slow reads from pub stretch the pull; fail_detect is kept far above
    # the stall so the *eviction*, not deadline quarantine, is the event
    # under test
    inj = ThreadedFaultInjector(
        FaultPlan(seed=13, faults=(FaultSpec("slow", "pub", stall=0.05),))
    )
    policy = RetryPolicy(
        fail_detect=30.0, retry_limit=4, retry_backoff=0.01,
        hedge_threshold=1e9, hedge_min_samples=1 << 30,
    )
    clean = TensorHubClient(server, chunk_bytes=1 << 20)
    hub = TensorHubClient(
        server,
        registry=clean.registry,
        chunk_bytes=1 << 20,
        retry_policy=policy,
        faults=inj,
    )
    pubs = [clean.open("m", "pub", 2, i) for i in range(2)]
    for h in pubs:
        h.register(tensors(5.0))
    run_threads(pubs, lambda h: h.publish(0))
    peers = [clean.open("m", "peer", 2, i) for i in range(2)]
    for h in peers:
        h.register(tensors(0.0))
    run_threads(peers, lambda h: h.replicate(0))

    dests = [hub.open("m", "dest", 2, i) for i in range(2)]
    for h in dests:
        h.register(tensors(0.0))
    inj.arm()

    def evict_pub_mid_pull():
        time.sleep(0.08)  # land inside the slowed multi-unit pull
        with hub._cv:  # noqa: SLF001 — failure injection
            # survivors heartbeat, the gray publisher does not: the tick
            # evicts exactly pub while dest is reading from it
            for survivor in ("peer", "dest"):
                for shard in range(2):
                    server.heartbeat("m", survivor, shard, now=2.0)
            assert server.tick(2.0) == ["pub"]
            hub._cv.notify_all()

    kt = threading.Thread(target=evict_pub_mid_pull, daemon=True)
    kt.start()
    run_threads(dests, lambda h: h.replicate(0))
    kt.join(timeout=10)
    inj.release()
    assert server.stats["evictions"] == 1
    want = tensors(5.0)
    for h in dests:
        for name, arr in want.items():
            assert np.array_equal(h.store.get(name), arr), (h.shard_idx, name)


def run_threads(handles, fn):
    errs = []

    def wrap(h):
        try:
            fn(h)
        except BaseException as e:  # noqa: BLE001
            errs.append(e)

    ts = [threading.Thread(target=wrap, args=(h,)) for h in handles]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=90)
    if errs:
        raise errs[0]
