"""Exhaustive interleaving tests (paper 4.6): enumerate EVERY merge of two
clients' control-plane op streams against one server and assert the
consistency/availability invariants hold in all of them.

This is the FoundationDB-style deterministic simulation the paper credits
for uncovering subtle concurrency bugs; because all requests originate
from one process, every execution is reproducible.
"""

import itertools

import pytest

from repro.core.errors import TensorHubError
from repro.core.server import ReferenceServer

from tests.test_server_consistency import manifest, open_replica


def merges(a, b):
    """All interleavings of two sequences (preserving each one's order)."""
    if not a:
        yield tuple(b)
        return
    if not b:
        yield tuple(a)
        return
    for rest in merges(a[1:], b):
        yield (a[0],) + rest
    for rest in merges(a, b[1:]):
        yield (b[0],) + rest


def publisher_stream(name, versions):
    """Per-shard op stream for a 2-shard publisher rolling versions."""
    ops = []
    op_id = 0
    for v in versions:
        for shard in range(2):
            ops.append(("publish", name, shard, v, op_id))
        op_id += 1
        for shard in range(2):
            ops.append(("unpublish", name, shard, op_id))
        op_id += 1
    return ops


def reader_stream(name):
    return [
        ("replicate", name, 0, "latest", 0),
        ("replicate", name, 1, "latest", 0),
        ("complete", name, 0, 1),
        ("complete", name, 1, 1),
    ]


def apply_op(server, op, state):
    kind = op[0]
    if kind == "publish":
        _, name, shard, v, op_id = op
        server.publish("m", name, shard, v, manifest(), op_id=op_id)
    elif kind == "unpublish":
        _, name, shard, op_id = op
        res = server.unpublish("m", name, shard, op_id=op_id)
        if res.offload_required and shard == 1:
            oid = 900 + op_id
            for s in range(2):
                server.publish_offload("m", name, s, res.offload_version, manifest(), op_id=oid)
    elif kind == "replicate":
        _, name, shard, spec, op_id = op
        a = server.begin_replicate("m", name, shard, spec, op_id=op_id)
        state.setdefault("assign", {})[(name, shard)] = a
    elif kind == "complete":
        _, name, shard, op_id = op
        a = state.get("assign", {}).get((name, shard))
        if a is None:  # parked replicate: redeem first
            a = server.redeem("m", name, op_id=0)
        if a is not None:
            server.complete_replicate("m", name, shard, a.version, op_id=op_id)
            state.setdefault("done", set()).add((name, shard))


@pytest.mark.timeout(300)
def test_all_interleavings_publisher_vs_reader():
    """Publisher rolls v0 -> v1 while a reader replicates 'latest'.

    Invariants checked in every interleaving:
    * both reader shards resolve the SAME version (group snapshot),
    * the resolved version was published at assignment time,
    * the latest published version stays listable (retention),
    * the server never raises anything but defined TensorHubErrors.
    """
    pub_ops = publisher_stream("pub", [0, 1])
    read_ops = reader_stream("r")
    n = 0
    for schedule in merges(pub_ops, read_ops):
        n += 1
        server = ReferenceServer()
        open_replica(server, "pub", retain="latest")
        open_replica(server, "r")
        state = {}
        for op in schedule:
            try:
                apply_op(server, op, state)
            except TensorHubError:
                pass  # defined, graceful errors are allowed
        # invariant: if both shards got assignments, they saw one version
        a0 = state.get("assign", {}).get(("r", 0))
        a1 = state.get("assign", {}).get(("r", 1))
        if a0 is not None and a1 is not None:
            assert a0.version == a1.version, f"split-brain in schedule {schedule}"
        # invariant: the latest version is always available somewhere
        latest = server.latest("m")
        if latest is not None:
            assert latest in server.list_versions("m"), f"lost v{latest}"
    assert n == 495  # C(12,4): all merges were actually enumerated


@pytest.mark.timeout(300)
def test_all_interleavings_two_readers_share_sources():
    """Two readers replicate concurrently from one publisher; in every
    interleaving both complete and the refcounts drain back to zero."""
    r1 = reader_stream("r1")
    r2 = reader_stream("r2")
    count = 0
    for schedule in itertools.islice(merges(r1, r2), 0, None):
        count += 1
        server = ReferenceServer()
        open_replica(server, "pub")
        open_replica(server, "r1")
        open_replica(server, "r2")
        for shard in range(2):
            server.publish("m", "pub", shard, 0, manifest(), op_id=0)
        state = {}
        for op in schedule:
            apply_op(server, op, state)
        assert state.get("done") == {("r1", 0), ("r1", 1), ("r2", 0), ("r2", 1)}
        # all in-flight refcounts drained
        st = server._models["m"]  # noqa: SLF001 - test introspection
        for vmap in st.versions.values():
            for rv in vmap.values():
                assert rv.refcount == 0, f"leaked refcount in {schedule}"
    assert count == 70  # C(8,4)
