"""Reusable subprocess harness for multi-process tests.

Grown out of ``test_distributed_subprocess.py``'s inline ``run_py``:
everything here exists so a test that spawns real processes fails with a
*diagnosis* instead of a bare timeout — every helper enforces a hard
deadline and dumps captured stdout/stderr tails into the assertion
message when a child misbehaves.

* :func:`run_py` — run a Python snippet to completion in a fresh
  interpreter (the XLA-device tests and the networked equivalence
  checks).
* :class:`Proc` / :class:`ProcSet` — long-lived children (controller,
  workers) with spawn/await-pattern/kill/stop lifecycle, per-process log
  files (kept under ``$REPRO_PROC_LOG_DIR`` when set, else a tempdir),
  and SIGKILL-everything cleanup so a failing test never leaks children.
* :func:`free_port` — OS-assigned TCP port for subprocess servers.

The deadline default comes from ``$REPRO_PROC_DEADLINE`` (seconds,
default 420) so CI can tighten or relax every subprocess test in one
place instead of editing scattered constants.
"""

from __future__ import annotations

import os
import re
import signal
import socket
import subprocess
import sys
import tempfile
import textwrap
import time
from typing import Dict, List, Optional

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

DEFAULT_DEADLINE = float(os.environ.get("REPRO_PROC_DEADLINE", "420"))

_TAIL_BYTES = 3000


def free_port() -> int:
    """An OS-assigned free TCP port (racy by nature; fine for tests that
    bind immediately, and subprocess servers prefer port 0 + an address
    file anyway)."""
    s = socket.socket()
    try:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]
    finally:
        s.close()


def _tail(text: Optional[str]) -> str:
    if not text:
        return "<empty>"
    return text[-_TAIL_BYTES:]


def build_env(
    *, devices: Optional[int] = None, extra: Optional[Dict[str, str]] = None
) -> Dict[str, str]:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if devices is not None:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    if extra:
        env.update(extra)
    return env


def run_py(
    code: str,
    *,
    devices: Optional[int] = None,
    deadline: Optional[float] = None,
    extra_env: Optional[Dict[str, str]] = None,
) -> str:
    """Run a Python snippet in a fresh interpreter; returns its stdout.

    A non-zero exit or a blown deadline raises AssertionError carrying
    both output tails — the failure is diagnosable from the pytest
    report alone, without hunting for child logs."""
    deadline = DEFAULT_DEADLINE if deadline is None else deadline
    argv = [sys.executable, "-c", textwrap.dedent(code)]
    try:
        res = subprocess.run(
            argv,
            capture_output=True,
            text=True,
            timeout=deadline,
            env=build_env(devices=devices, extra=extra_env),
        )
    except subprocess.TimeoutExpired as e:
        out = e.stdout.decode("utf-8", "replace") if isinstance(e.stdout, bytes) else e.stdout
        err = e.stderr.decode("utf-8", "replace") if isinstance(e.stderr, bytes) else e.stderr
        raise AssertionError(
            f"subprocess exceeded the {deadline:.0f}s deadline\n"
            f"--- stdout tail ---\n{_tail(out)}\n"
            f"--- stderr tail ---\n{_tail(err)}"
        ) from None
    assert res.returncode == 0, (
        f"subprocess exited {res.returncode}\n"
        f"--- stdout tail ---\n{_tail(res.stdout)}\n"
        f"--- stderr tail ---\n{_tail(res.stderr)}"
    )
    return res.stdout


class Proc:
    """One long-lived child process with captured logs.

    Logs stream to files (not pipes), so a child blocked on a full pipe
    buffer can never deadlock a test, and the files survive a SIGKILL
    for post-mortem tails."""

    def __init__(
        self,
        name: str,
        argv: List[str],
        *,
        log_dir: str,
        env: Optional[Dict[str, str]] = None,
    ) -> None:
        self.name = name
        self.argv = argv
        self.stdout_path = os.path.join(log_dir, f"{name}.out")
        self.stderr_path = os.path.join(log_dir, f"{name}.err")
        self._stdout_f = open(self.stdout_path, "wb")
        self._stderr_f = open(self.stderr_path, "wb")
        self.popen = subprocess.Popen(
            argv,
            stdout=self._stdout_f,
            stderr=self._stderr_f,
            env=env if env is not None else build_env(),
        )

    @property
    def pid(self) -> int:
        return self.popen.pid

    def alive(self) -> bool:
        return self.popen.poll() is None

    def read_stdout(self) -> str:
        with open(self.stdout_path, "r", encoding="utf-8", errors="replace") as fh:
            return fh.read()

    def read_stderr(self) -> str:
        with open(self.stderr_path, "r", encoding="utf-8", errors="replace") as fh:
            return fh.read()

    def tails(self) -> str:
        return (
            f"[{self.name}] argv={self.argv} rc={self.popen.poll()}\n"
            f"--- {self.name} stdout tail ---\n{_tail(self.read_stdout())}\n"
            f"--- {self.name} stderr tail ---\n{_tail(self.read_stderr())}"
        )

    def await_pattern(
        self, pattern: str, *, deadline: Optional[float] = None
    ) -> "re.Match":
        """Block until ``pattern`` (regex) appears on the child's stdout;
        returns the match. Dies with full tails if the child exits or the
        deadline passes first."""
        deadline = DEFAULT_DEADLINE if deadline is None else deadline
        end = time.monotonic() + deadline
        rx = re.compile(pattern)
        while True:
            m = rx.search(self.read_stdout())
            if m:
                return m
            if not self.alive():
                raise AssertionError(
                    f"{self.name} exited before printing {pattern!r}\n{self.tails()}"
                )
            if time.monotonic() >= end:
                raise AssertionError(
                    f"{self.name}: no {pattern!r} within {deadline:.0f}s\n{self.tails()}"
                )
            time.sleep(0.05)

    def wait(self, *, deadline: Optional[float] = None) -> int:
        deadline = DEFAULT_DEADLINE if deadline is None else deadline
        try:
            return self.popen.wait(timeout=deadline)
        except subprocess.TimeoutExpired:
            self.kill()
            raise AssertionError(
                f"{self.name} still running after {deadline:.0f}s\n{self.tails()}"
            ) from None

    def kill(self) -> None:
        """SIGKILL — the crash the fault-tolerance tests are about."""
        if self.alive():
            try:
                self.popen.send_signal(signal.SIGKILL)
            except ProcessLookupError:
                pass
        self.popen.wait()
        self._close_files()

    def stop(self, *, deadline: float = 10.0) -> int:
        """SIGTERM and wait (graceful shutdown path)."""
        if self.alive():
            try:
                self.popen.terminate()
            except ProcessLookupError:
                pass
        try:
            rc = self.popen.wait(timeout=deadline)
        except subprocess.TimeoutExpired:
            self.popen.kill()
            rc = self.popen.wait()
        self._close_files()
        return rc

    def _close_files(self) -> None:
        for fh in (self._stdout_f, self._stderr_f):
            try:
                fh.close()
            except Exception:
                pass


class ProcSet:
    """Context manager owning a set of children; everything is SIGKILLed
    on exit no matter how the test ends, and ``failure_report()`` bundles
    every child's tails for the assertion message."""

    def __init__(self, log_dir: Optional[str] = None) -> None:
        self.log_dir = (
            log_dir
            or os.environ.get("REPRO_PROC_LOG_DIR")
            or tempfile.mkdtemp(prefix="repro-procs-")
        )
        os.makedirs(self.log_dir, exist_ok=True)
        self.procs: List[Proc] = []

    def spawn(
        self, name: str, argv: List[str], *, env: Optional[Dict[str, str]] = None
    ) -> Proc:
        p = Proc(name, argv, log_dir=self.log_dir, env=env)
        self.procs.append(p)
        return p

    def spawn_py(
        self,
        name: str,
        code: str,
        *,
        extra_env: Optional[Dict[str, str]] = None,
        devices: Optional[int] = None,
    ) -> Proc:
        return self.spawn(
            name,
            [sys.executable, "-c", textwrap.dedent(code)],
            env=build_env(devices=devices, extra=extra_env),
        )

    def spawn_module(
        self,
        name: str,
        module: str,
        *args: str,
        extra_env: Optional[Dict[str, str]] = None,
    ) -> Proc:
        return self.spawn(
            name,
            [sys.executable, "-m", module, *args],
            env=build_env(extra=extra_env),
        )

    def failure_report(self) -> str:
        return "\n".join(p.tails() for p in self.procs)

    def __enter__(self) -> "ProcSet":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        for p in self.procs:
            p.kill()
