"""Wire-protocol property/fuzz tests — tier-1, in-process, no sockets.

The :class:`~repro.net.service.ReferenceService` frame handler is total:
whatever bytes arrive, the response is a well-formed frame — a result,
or a typed error. These tests pin that contract from both ends:

* every request/response payload round-trips the generic wire codec
  (the op results a real session produces, compared field-for-field
  against an identical in-process server);
* truncated, garbage, wrong-version, and wrong-shape frames come back
  as clean ``ProtocolError`` frames — no hang, no stack-trace
  disconnect, no exception out of ``handle_frame``;
* op-id redelivery through the full wire path returns the cached result
  (the WAL's done-txn cache is the RPC idempotency layer) and divergent
  reuse still raises ``ConsistencyError``;
* typed errors cross the wire as themselves, including the
  ``ServerUnavailableError`` that makes remote clients park.
"""

import json

import pytest

from _hypothesis_compat import given, settings, st
from repro.core.errors import (
    ConsistencyError,
    ServerUnavailableError,
    ShardLayoutError,
    TensorHubError,
    TransportError,
)
from repro.core.meta import ShardManifest, TensorMeta, TransferUnit, WorkerInfo
from repro.core.server import CONTROL_OPS, ReferenceServer
from repro.net import protocol
from repro.net.protocol import ProtocolError
from repro.net.service import ReferenceService


def manifest(n_units=2, unit_bytes=100):
    tensors = tuple(
        TensorMeta(f"t{i}", (unit_bytes,), "uint8", unit_bytes)
        for i in range(n_units)
    )
    units = tuple(
        TransferUnit(index=i, name=f"t{i}", nbytes=unit_bytes)
        for i in range(n_units)
    )
    return ShardManifest(tensors=tensors, units=units, checksums=(0,) * n_units)


def worker(replica, shard, dc="dc0", spot=False):
    return WorkerInfo(f"{replica}/s{shard}", f"{dc}/{replica}", dc, spot)


def wire_call(svc, op, *args, **kw):
    """One op through the complete wire path: encode -> frame handler ->
    decode (raising the typed error an error frame carries)."""
    return protocol.decode_response(
        svc.handle_frame(protocol.encode_request(op, args, kw))
    )


def fresh_service():
    return ReferenceService(ReferenceServer())


def open_replica(call, name, shards=2, dc="dc0", retain=None):
    for i in range(shards):
        call("open", "m", name, shards, i,
             worker=worker(name, i, dc), retain=retain)
        call("register", "m", name, i)


def session_trace(call):
    """A realistic control-plane session (publish -> replicate -> update
    -> progress -> events), returning every op result in order. Driving
    it through two transports and comparing is the round-trip proof for
    all the payload types a session produces."""
    results = []
    open_replica(call, "pub", retain="latest")
    open_replica(call, "sub")
    for i in range(2):
        results.append(call("publish", "m", "pub", i, 0, manifest(), op_id=0))
    for i in range(2):
        results.append(call("begin_replicate", "m", "sub", i, "latest", op_id=1))
    for i in range(2):
        results.append(call("update_progress", "m", "sub", i, 0, 1))
        results.append(call("shard_progress", "m", "pub", 0, i))
    for i in range(2):
        results.append(call("complete_replicate", "m", "sub", i, 0, op_id=2))
    results.append(call("manifest", "m", 0, 0))
    results.append(call("replica_manifest", "m", 0, "sub", 1))
    results.append(call("get_assignment", "m", "sub"))
    results.append(call("assignment_epoch", "m", "sub", 0))
    results.append(call("source_progress", "m", "pub", 0))
    results.append(call("list_versions", "m"))
    results.append(call("latest", "m"))
    results.append(call("availability", "m", 0))
    results.append(call("replica_version", "m", "sub"))
    results.append(call("replica_datacenter", "m", "sub"))
    results.append(call("num_shards", "m"))
    for i in range(2):
        results.append(call("begin_update", "m", "sub", i, "latest", op_id=3))
    results.append(call("poll_events", "pub/s0"))
    results.append(call("config"))
    return results


class TestRoundTrip:
    def test_session_payloads_survive_the_wire(self):
        """Every result of a full session through the wire path equals
        the in-process result — dataclasses, tuples, dicts, enums and
        all. This is the schema round-trip test for the payloads that
        actually cross the protocol."""
        svc = fresh_service()
        direct = ReferenceServer()
        wired = session_trace(lambda op, *a, **k: wire_call(svc, op, *a, **k))
        plain = session_trace(lambda op, *a, **k: getattr(direct, op)(*a, **k))
        assert wired == plain

    def test_metrics_and_exposition_cross_the_wire(self):
        svc = fresh_service()
        open_replica(lambda op, *a, **k: wire_call(svc, op, *a, **k), "pub")
        m = wire_call(svc, "metrics")
        assert m["state"]["models"] == 1.0
        text = wire_call(svc, "metrics_text")
        assert "tensorhub_publishes" in text

    @settings(max_examples=25, deadline=None)
    @given(st.sampled_from(sorted(CONTROL_OPS)))
    def test_request_encoding_round_trips_op_names(self, op):
        enc = protocol.encode_request(op, (1, "x"), {"k": (2, 3)})
        got_op, args, kw = protocol.decode_request(enc)
        assert (got_op, args, kw) == (op, [1, "x"], {"k": (2, 3)})

    def test_manifest_round_trips_exactly(self):
        m = manifest(n_units=3, unit_bytes=17)
        enc = protocol.encode_request("publish", ("m", "pub", 0, 0, m), {})
        _, args, _ = protocol.decode_request(enc)
        assert args[4] == m and isinstance(args[4], ShardManifest)


class TestMalformedFrames:
    """handle_frame never raises, never hangs, never returns junk."""

    def _assert_protocol_error(self, svc, data):
        out = svc.handle_frame(data)
        frame = json.loads(out.decode("utf-8"))
        assert frame["ok"] is False, frame
        assert frame["error"]["kind"] == "ProtocolError", frame
        assert frame["v"] == protocol.PROTOCOL_VERSION
        # and the client side re-raises it as the typed error
        with pytest.raises(ProtocolError):
            protocol.decode_response(out)

    @settings(max_examples=50, deadline=None)
    @given(st.binary(min_size=0, max_size=200))
    def test_garbage_bytes(self, data):
        svc = fresh_service()
        out = svc.handle_frame(data)
        frame = json.loads(out.decode("utf-8"))
        assert frame["v"] == protocol.PROTOCOL_VERSION
        assert frame["ok"] is False
        # random bytes essentially never form a valid frame; whatever the
        # failure mode, it must surface as a ProtocolError frame
        assert frame["error"]["kind"] == "ProtocolError"

    @settings(max_examples=40, deadline=None)
    @given(st.integers(min_value=0, max_value=200))
    def test_truncated_frames(self, cut):
        svc = fresh_service()
        whole = protocol.encode_request(
            "publish", ("m", "pub", 0, 0, manifest()), {"op_id": 0}
        )
        cut = min(cut, len(whole) - 1)  # strictly truncated
        self._assert_protocol_error(svc, whole[:cut])

    @settings(max_examples=20, deadline=None)
    @given(st.sampled_from([0, 2, 7, -1, 999, None, "1"]))
    def test_wrong_version_rejected(self, v):
        svc = fresh_service()
        data = json.dumps({"v": v, "op": "latest", "args": ["m"], "kw": {}}).encode()
        self._assert_protocol_error(svc, data)

    def test_unknown_fields_rejected(self):
        svc = fresh_service()
        data = json.dumps(
            {"v": 1, "op": "latest", "args": ["m"], "kw": {}, "extra": 1}
        ).encode()
        self._assert_protocol_error(svc, data)

    def test_wrong_shapes_rejected(self):
        svc = fresh_service()
        bad = [
            json.dumps(["not", "a", "dict"]).encode(),
            json.dumps({"v": 1, "op": "", "args": [], "kw": {}}).encode(),
            json.dumps({"v": 1, "op": "latest", "args": "m", "kw": {}}).encode(),
            json.dumps({"v": 1, "op": "latest", "args": [], "kw": []}).encode(),
            json.dumps({"v": 1, "op": 7, "args": [], "kw": {}}).encode(),
        ]
        for data in bad:
            self._assert_protocol_error(svc, data)

    def test_undecodable_argument_payload_rejected(self):
        svc = fresh_service()
        data = json.dumps(
            {"v": 1, "op": "latest",
             "args": [{"__dc__": "NoSuchClass", "fields": {}}], "kw": {}}
        ).encode()
        self._assert_protocol_error(svc, data)

    def test_non_whitelisted_ops_rejected(self):
        svc = fresh_service()
        for op in ("_record", "attach_log", "__init__", "nonexistent", "svc.nope"):
            with pytest.raises(ProtocolError):
                wire_call(svc, op)
        # the rejection happened before any server dispatch
        assert svc.server.seq == 0


class TestIdempotentRedelivery:
    def test_duplicate_op_id_returns_cached_result(self):
        """The done-txn cache *is* the RPC retry story: a client that
        lost the response re-sends and gets the identical result with no
        double mutation."""
        svc = fresh_service()
        call = lambda op, *a, **k: wire_call(svc, op, *a, **k)  # noqa: E731
        open_replica(call, "pub")
        r1 = call("publish", "m", "pub", 0, 1, manifest(), op_id=0)
        r2 = call("publish", "m", "pub", 0, 1, manifest(), op_id=0)
        assert r1 == r2
        assert wire_call(svc, "metrics")["counters"]["publishes"] == 1.0

    def test_divergent_op_id_reuse_raises_consistency_error(self):
        svc = fresh_service()
        call = lambda op, *a, **k: wire_call(svc, op, *a, **k)  # noqa: E731
        open_replica(call, "pub")
        open_replica(call, "r")
        call("publish", "m", "pub", 0, 1, manifest(), op_id=0)
        call("publish", "m", "pub", 1, 1, manifest(), op_id=0)
        call("begin_replicate", "m", "r", 0, "latest", op_id=5)
        with pytest.raises(ConsistencyError):
            call("begin_replicate", "m", "r", 1, 0, op_id=5)  # divergent args


class TestTypedErrorTransport:
    def test_domain_errors_reraise_as_themselves(self):
        svc = fresh_service()
        call = lambda op, *a, **k: wire_call(svc, op, *a, **k)  # noqa: E731
        call("open", "m", "sub", 2, 0, worker=worker("sub", 0, "dc0"), retain=None)
        with pytest.raises(ShardLayoutError):
            # one replica spanning two datacenters is a layout violation
            call("open", "m", "sub", 2, 1, worker=worker("sub", 1, "dc1"), retain=None)
        with pytest.raises(ConsistencyError):
            call("open", "m", "sub", 2, 0, worker=worker("sub", 0, "dc0"), retain=None)

    def test_server_unavailable_crosses_the_wire(self):
        """The error that makes remote clients park must arrive as
        exactly ServerUnavailableError, not a generic failure."""
        svc = fresh_service()
        svc.server.crash()
        with pytest.raises(ServerUnavailableError):
            wire_call(svc, "latest", "m")
        # crashed-but-responsive is visible without a typed error too
        assert wire_call(svc, "svc.ping")["crashed"] is True

    def test_transport_error_transient_flag_round_trips(self):
        for transient in (True, False):
            frame = json.loads(
                protocol.encode_error(
                    TransportError("boom", transient=transient)
                ).decode()
            )
            with pytest.raises(TransportError) as exc_info:
                protocol.raise_from_error(frame["error"])
            assert exc_info.value.transient is transient

    def test_unknown_error_kind_degrades_to_tensorhub_error(self):
        with pytest.raises(TensorHubError) as exc_info:
            protocol.raise_from_error({"kind": "FutureError", "message": "m1"})
        assert "FutureError" in str(exc_info.value)

    @settings(max_examples=30, deadline=None)
    @given(st.binary(min_size=0, max_size=100))
    def test_garbage_responses_raise_protocol_error(self, data):
        with pytest.raises(ProtocolError):
            protocol.decode_response(data)


class TestServiceDirectory:
    def test_announce_peer_retract(self):
        svc = fresh_service()
        wire_call(svc, "svc.announce", "w0", "pub", 0, "127.0.0.1:1234")
        assert wire_call(svc, "svc.peer", "pub", 0) == "127.0.0.1:1234"
        assert wire_call(svc, "svc.peers") == {("pub", 0): "127.0.0.1:1234"}
        wire_call(svc, "svc.retract", "pub", 0)
        assert wire_call(svc, "svc.peer", "pub", 0) is None

    def test_directory_is_not_server_state(self):
        """Peer addresses are transport facts: announcing must not move
        the replayed-state digest (the equivalence sweep relies on it)."""
        from repro.core.failover import state_digest

        svc = fresh_service()
        before = state_digest(svc.server)
        wire_call(svc, "svc.announce", "w0", "pub", 0, "127.0.0.1:1")
        assert state_digest(svc.server) == before

    def test_rpc_stats_sections(self):
        svc = fresh_service()
        wire_call(svc, "latest", "m")
        svc.handle_frame(b"garbage")
        m = wire_call(svc, "svc.metrics")
        assert m["rpc"]["latest"]["calls"] >= 1.0
        assert m["rpc"]["malformed"]["errors"] >= 1.0
        text = svc.metrics_text()
        assert 'tensorhub_rpc_calls_total{op="latest"}' in text


class TestRemoteClientSurface:
    def test_every_control_op_is_proxied_not_shadowed(self):
        """RemoteClient's own attributes must not silently eat remotable
        ops. ``close`` is the one sanctioned overlap: it proxies the
        server op when given arguments (a bare call closes the socket).
        This caught a real bug — ``handle.close()`` over the wire hit
        the connection teardown instead of the server's ``close`` op."""
        from repro.core.server import CONTROL_OPS
        from repro.net.client import RemoteClient

        shadowed = set(dir(RemoteClient)) & CONTROL_OPS
        assert shadowed <= {"close"}, f"ops shadowed by client attrs: {shadowed}"
