"""Codec-capable resharding: row-grid planning properties, fused
dequant+repack parity, cross-transport byte identity, end-to-end int8
reshard through the threaded client, and data-plane connection pooling.

The tentpole contract under test: a cross-DC pull between mismatched
shard layouts carries the negotiated wire codec end to end — the planner
widens interval reads to the quantization row grid, the transport ships
undecoded wire frames, and the fused dequant+gather path writes repacked
rows directly — while a raw plan stays bit-exact with the pre-codec
planner (zero widening).
"""

import threading

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import ReferenceServer, TensorHubClient
from repro.core.meta import WorkerInfo
from repro.resharding import (
    ReshardExecutor,
    layout_from_manifests,
    plan_shard,
    rowgrid,
    tp_shard,
)
from repro.transfer.codec import Int8Codec, get_codec, parse_int8_frame
from repro.transfer.engine import LocalTransport, WorkerRegistry, WorkerStore
from repro.transfer.simcluster import make_layout_manifests

RB = Int8Codec().row_bytes("float32")  # 256 elems * 4 B


# ---------------------------------------------------------------------------
# row-grid helpers: pure alignment properties
# ---------------------------------------------------------------------------


class TestRowGrid:
    @settings(max_examples=50, deadline=None)
    @given(n=st.integers(0, 1 << 20), a=st.sampled_from([1, 2, 512, RB]))
    def test_chunk_align_properties(self, n, a):
        out = rowgrid.chunk_align(n, a)
        assert out >= n and out % a == 0 and out - n < max(a, 1)

    @settings(max_examples=100, deadline=None)
    @given(
        offset=st.integers(0, 1 << 18),
        nbytes=st.integers(1, 1 << 16),
        rb=st.sampled_from([256 * 2, RB, 256 * 8]),
        slack=st.integers(0, 4 * RB),
    )
    def test_snap_satisfies_read_contract(self, offset, nbytes, rb, slack):
        """The widened range starts on the row grid and stops either on
        it or exactly at the unit end — precisely the alignment
        ``read_unit_range`` enforces for coded reads."""
        unit_nbytes = offset + nbytes + slack
        lead, tail = rowgrid.snap(offset, nbytes, rb, unit_nbytes)
        start = offset - lead
        stop = offset + nbytes + tail
        assert 0 <= lead < rb and tail >= 0
        assert start % rb == 0
        assert stop % rb == 0 or stop == unit_nbytes
        assert stop <= unit_nbytes

    def test_row_granularity_is_max_over_codecs(self):
        assert rowgrid.row_granularity(["raw"], "float32") == 1
        assert rowgrid.row_granularity(["raw", "int8"], "float32") == RB
        assert rowgrid.row_granularity(["int8"], "bfloat16") == 256 * 2


# ---------------------------------------------------------------------------
# planner: codec-aware plans tile exactly and stay within source bounds
# ---------------------------------------------------------------------------


def _layouts(sizes, src_tp, dst_tp, dtype="float32"):
    src = layout_from_manifests(
        dict(enumerate(make_layout_manifests(sizes, src_tp, dtype=dtype))),
        src_tp,
    )
    dst_manifests = make_layout_manifests(sizes, dst_tp, dtype=dtype)
    dst = layout_from_manifests(dict(enumerate(dst_manifests)), dst_tp)
    return src, dst, dst_manifests


class TestCodecPlans:
    @settings(max_examples=25, deadline=None)
    @given(
        src_tp=st.sampled_from([1, 2, 3, 4, 8]),
        dst_tp=st.sampled_from([1, 2, 4]),
        sizes=st.lists(
            st.integers(RB // 4, 1 << 18), min_size=1, max_size=4
        ),
    )
    def test_int8_plan_row_aligned_and_tiles(self, src_tp, dst_tp, sizes):
        """Property sweep: every interval of an int8 plan reads a
        row-grid-aligned range that stays inside its source unit (the
        per-source ceiling), and the un-widened payloads still tile each
        destination unit exactly."""
        sizes = [s * 4 for s in sizes]  # element-aligned float32 tensors
        src, dst, dst_manifests = _layouts(sizes, src_tp, dst_tp)
        for shard in range(dst_tp):
            plan = plan_shard(
                src, dst, shard,
                num_dest_units=dst_manifests[shard].num_units,
                codec="int8",
            )
            covered = {u.index: 0 for u in dst_manifests[shard].units}
            for iv in plan.intervals:
                rb = RB  # all-f32 layouts
                start = iv.read_offset
                stop = start + iv.read_nbytes
                assert start >= 0 and start % rb == 0, iv
                assert stop % rb == 0 or stop == iv.src_unit_nbytes, iv
                assert stop <= iv.src_unit_nbytes, iv
                assert iv.read_nbytes == iv.lead + iv.nbytes + iv.tail
                covered[iv.dest_unit] += iv.nbytes
            for u in dst_manifests[shard].units:
                assert covered[u.index] == u.nbytes, (shard, u.index)

    def test_raw_plan_has_zero_widening(self):
        """A raw plan is bit-compatible with the pre-codec planner: no
        row-grid widening anywhere (wire bytes == payload bytes)."""
        src, dst, dst_manifests = _layouts([1 << 20] * 3, 4, 2)
        for shard in range(2):
            plan = plan_shard(
                src, dst, shard,
                num_dest_units=dst_manifests[shard].num_units,
                codec="raw",
            )
            for iv in plan.intervals:
                assert iv.lead == 0 and iv.tail == 0
                assert iv.read_nbytes == iv.nbytes


# ---------------------------------------------------------------------------
# fused dequant+repack parity
# ---------------------------------------------------------------------------


def _frames(rng, specs):
    """Encode per-spec float32 payloads; return (parsed frames, wires)."""
    frames, wires = [], []
    for n_elems in specs:
        x = (rng.standard_normal(n_elems) * 2).astype(np.float32)
        wire = get_codec("int8").encode(x.view(np.uint8).reshape(-1), "float32")
        frames.append(parse_int8_frame(wire))
        wires.append(wire)
    return frames, wires


class TestFusedParity:
    @pytest.mark.parametrize("interpret_kernel", [False, True])
    def test_fused_matches_staged_decode(self, interpret_kernel):
        """Fused placement decode (numpy + interpreter kernel) is
        bit-identical to decode-whole-frame-then-trim — including
        lead/tail trimming and a passthrough overlay."""
        from repro.kernels.quant import fused_repack, fused_repack_np

        rng = np.random.default_rng(5)
        frames, wires = _frames(rng, [1024, 2048, 512])
        c = get_codec("int8")
        # (frame, lead, nbytes, unit_offset): trim rows off frame 1, and
        # make frame 2 a passthrough (non-finite payload)
        bad = np.full(256, np.inf, np.float32)
        pw = c.encode(bad.view(np.uint8).reshape(-1), "float32")
        pf = parse_int8_frame(pw)
        assert pf.is_passthrough
        placements = [
            (frames[0], 0, 4096, 0),
            (frames[1], RB, 4096, 4096),  # lead-trimmed by one row
            (pf, 4, 1000, 8192),  # passthrough overlay, odd offsets
        ]
        out_nbytes = 4096 + 4096 + 1024
        want = np.zeros(out_nbytes, np.uint8)
        want[0:4096] = c.decode(wires[0])[0:4096]
        want[4096:8192] = c.decode(wires[1])[RB : RB + 4096]
        want[8192 : 8192 + 1000] = bad.view(np.uint8)[4 : 4 + 1000]
        got_np = fused_repack_np(placements, out_nbytes)
        assert np.array_equal(got_np, want)
        if interpret_kernel:
            got_k = fused_repack(placements, out_nbytes, interpret=True)
            assert np.array_equal(got_k, want)

    def test_executor_fused_repack_matches_staged(self):
        """ReshardExecutor.fused_repack over a real plan's wire frames ==
        staged decode into staging + repack."""
        sizes = [RB * 64, RB * 40]
        src, dst, dst_manifests = _layouts(sizes, 4, 2)
        rng = np.random.default_rng(9)
        # materialize the source shards' unit payloads
        src_payloads = {}
        src_manifests = make_layout_manifests(sizes, 4, dtype="float32")
        for s, m in enumerate(src_manifests):
            for u in m.units:
                src_payloads[(s, u.index)] = (
                    (rng.standard_normal(u.nbytes // 4) * 2)
                    .astype(np.float32).view(np.uint8).reshape(-1)
                )
        c = get_codec("int8")
        for shard in range(2):
            plan = plan_shard(
                src, dst, shard,
                num_dest_units=dst_manifests[shard].num_units,
                codec="int8",
            )
            ex = ReshardExecutor(plan, dst_manifests[shard])
            for unit, placed in ex.unit_batches():
                frames, staging = [], ex.make_staging(unit.index)
                for p in placed:
                    iv = p.interval
                    payload = src_payloads[(iv.source_shard, iv.source_unit)]
                    wire = c.encode(
                        payload[iv.read_offset : iv.read_offset + iv.read_nbytes],
                        "float32",
                    )
                    frames.append(wire)
                    staging[
                        p.staging_offset : p.staging_offset + iv.nbytes
                    ] = c.decode(wire)[iv.lead : iv.lead + iv.nbytes]
                fused = ex.fused_repack(unit.index, frames)
                staged = ex.repack(unit.index, staging)
                assert np.array_equal(fused, staged), (shard, unit.index)


# ---------------------------------------------------------------------------
# threaded client end to end: negotiated int8 over a resharded pull
# ---------------------------------------------------------------------------


def _model_tensors(seed=0):
    """Row-grid-friendly model: every TP-{1,2,4} slice is a whole number
    of 256-element quantization rows, so the resharded decode is
    comparable bit-for-bit against a same-layout int8 pull."""
    rng = np.random.default_rng(seed)
    return {
        "w0": rng.standard_normal((4096, 8)).astype(np.float32),
        "w1": rng.standard_normal((2048, 4)).astype(np.float32),
    }


def _run_group(handles, fn):
    errs = []

    def wrap(h):
        try:
            fn(h)
        except BaseException as e:  # noqa: BLE001
            errs.append(e)

    ts = [threading.Thread(target=wrap, args=(h,)) for h in handles]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
    if errs:
        raise errs[0]


def _open_tp_group(hub, name, tp, glob, *, zeros=False, dc="dc0"):
    handles = [hub.open("m", name, tp, i, datacenter=dc) for i in range(tp)]
    for h in handles:
        local, lay = tp_shard(glob, h.shard_idx, tp)
        if zeros:
            local = {n: np.zeros_like(a) for n, a in local.items()}
        h.register(local, layout=lay)
    return handles


def _reshard_pull(src_tp, dst_tp, *, wan_codec="int8", seed=0):
    """Publish at ``src_tp`` in dc0, reshard-replicate at ``dst_tp`` in
    dc1; returns (sub handles, wire bytes moved)."""
    glob = _model_tensors(seed)
    hub = TensorHubClient(ReferenceServer(wan_codec=wan_codec))
    pubs = _open_tp_group(hub, "pub", src_tp, glob, dc="dc0")
    _run_group(pubs, lambda h: h.publish(0))
    before = hub.transport.bytes_moved
    subs = _open_tp_group(hub, "sub", dst_tp, glob, zeros=True, dc="dc1")
    _run_group(subs, lambda h: h.replicate(0))
    return glob, subs, hub.transport.bytes_moved - before


class TestEndToEndInt8Reshard:
    def test_cross_dc_reshard_carries_int8_and_shrinks_wire(self):
        """Acceptance: a cross-DC TP-mismatched pull carries int8 end to
        end — values match the int8 round-trip, wire bytes >= 3.5x
        smaller than the same pull forced raw."""
        glob, subs, coded_bytes = _reshard_pull(4, 2)
        for h in subs:
            want, _ = tp_shard(glob, h.shard_idx, 2)
            for n, arr in want.items():
                got = h.store.get(n)
                # lossy codec: close values, not identical bits
                assert np.allclose(got, arr, rtol=0.02, atol=0.02), n
                assert not np.array_equal(got, arr)
        _, _, raw_bytes = _reshard_pull(4, 2, wan_codec="raw")
        assert raw_bytes / coded_bytes >= 3.5

    @pytest.mark.parametrize("src_tp,dst_tp", [(4, 2), (2, 4)])
    def test_decoded_bytes_match_same_layout_int8_pull(self, src_tp, dst_tp):
        """Acceptance: the resharded int8 decode is bit-identical to a
        same-layout int8 pull of the same weights (row-aligned shard
        splits share the quantization grid, so per-row scales agree)."""
        glob, resharded, _ = _reshard_pull(src_tp, dst_tp)
        glob2, same_layout, _ = _reshard_pull(dst_tp, dst_tp)
        for ha, hb in zip(resharded, same_layout):
            for n in glob:
                assert np.array_equal(
                    ha.store.get(n).view(np.uint8),
                    hb.store.get(n).view(np.uint8),
                ), (n, ha.shard_idx)

    def test_raw_reshard_stays_bit_exact(self):
        """Forced-raw reshard is byte-identical to the publisher (the
        pre-refactor wire behavior)."""
        glob, subs, _ = _reshard_pull(4, 2, wan_codec="raw")
        for h in subs:
            want, _ = tp_shard(glob, h.shard_idx, 2)
            for n, arr in want.items():
                np.testing.assert_array_equal(h.store.get(n), arr)

    def test_fused_kernel_path_matches_numpy_path(self):
        """device_repack=True routes the resharded decode through the
        fused Pallas kernel (interpreter off-TPU) — same bytes as the
        NumPy fusion."""
        glob = _model_tensors()
        hub = TensorHubClient(ReferenceServer())
        pubs = _open_tp_group(hub, "pub", 4, glob, dc="dc0")
        _run_group(pubs, lambda h: h.publish(0))
        subs_np = _open_tp_group(hub, "np", 2, glob, zeros=True, dc="dc1")
        _run_group(subs_np, lambda h: h.replicate(0))
        subs_k = [
            hub.open("m", "kern", 2, i, datacenter="dc1", device_repack=True)
            for i in range(2)
        ]
        for h in subs_k:
            local, lay = tp_shard(glob, h.shard_idx, 2)
            h.register(
                {n: np.zeros_like(a) for n, a in local.items()}, layout=lay
            )
        _run_group(subs_k, lambda h: h.replicate(0))
        for ha, hb in zip(subs_k, subs_np):
            for n in glob:
                assert np.array_equal(
                    ha.store.get(n).view(np.uint8),
                    hb.store.get(n).view(np.uint8),
                ), n


# ---------------------------------------------------------------------------
# negotiation scope: degrade only for genuinely unalignable plans
# ---------------------------------------------------------------------------


class TestDegradeScope:
    def _server_with_reshard(self, dtype):
        s = ReferenceServer()
        manifests = make_layout_manifests([1 << 20] * 4, 2, dtype=dtype)
        for i in range(2):
            s.open(
                "m", "pub", 2, i,
                worker=WorkerInfo(f"pub/s{i}", "dc0/pub", "dc0"),
            )
            s.register("m", "pub", i)
            s.publish("m", "pub", i, 0, manifests[i], op_id=0)
        s.open("m", "r", 1, 0, worker=WorkerInfo("r/s0", "dc1/r", "dc1"))
        s.register("m", "r", 0)
        return s

    def test_quantizable_reshard_negotiates_int8_no_degrade(self):
        s = self._server_with_reshard("float32")
        a = s.begin_replicate("m", "r", 0, 0, op_id=0)
        assert a.resharded and a.codec == "int8"
        assert s.stats["codec_degrades"] == 0

    def test_unquantizable_reshard_degrades_and_counts(self):
        """codec_degrades ticks ONLY for genuinely unalignable payloads:
        every source tensor non-quantizable (uint8) -> raw + one tick."""
        s = self._server_with_reshard("uint8")
        a = s.begin_replicate("m", "r", 0, 0, op_id=0)
        assert a.resharded and a.codec == "raw"
        assert s.stats["codec_degrades"] == 1


# ---------------------------------------------------------------------------
# remote transport: cross-transport byte identity + connection pooling
# ---------------------------------------------------------------------------


class TestRemoteDataPlane:
    def _served_source(self):
        from repro.net.data import RemoteTransport, WorkerDataServer

        rng = np.random.default_rng(3)
        x = (rng.standard_normal(RB * 16 // 4) * 2).astype(np.float32)
        src_reg = WorkerRegistry()
        st_ = WorkerStore("src/shard0")
        st_.register({"t": x})
        src_reg.add("src", 0, st_)
        server = WorkerDataServer(src_reg).start()
        remote = RemoteTransport(
            WorkerRegistry(), lambda *_: server.address
        )
        local = LocalTransport(src_reg)
        return server, remote, local, st_

    def test_wire_frames_identical_across_transports(self):
        """The networked data plane returns byte-identical int8 wire
        frames (and decoded ranges) to the in-process transport."""
        server, remote, local, st_ = self._served_source()
        try:
            unit = st_.units[0]
            for decode in (True, False):
                for off, n in [(0, unit.nbytes), (RB, 4 * RB)]:
                    a = local.read_unit_range(
                        "src", 0, unit, off, n, codec="int8", decode=decode
                    )
                    b = remote.read_unit_range(
                        "src", 0, unit, off, n, codec="int8", decode=decode
                    )
                    assert np.array_equal(a, b), (decode, off, n)
        finally:
            remote.close_pool()
            server.shutdown()

    def test_connection_pool_reuses_sockets(self):
        """Satellite: per-(host, port) keep-alive pooling — a windowed
        pull's worth of reads opens O(pool) sockets, not O(reads)."""
        server, remote, local, st_ = self._served_source()
        try:
            unit = st_.units[0]
            for _ in range(10):
                remote.read_unit_range("src", 0, unit, 0, RB, codec="int8")
            assert remote.conn_opens <= 2
            assert remote.conn_reuses >= 8
            assert remote.conn_opens + remote.conn_reuses >= 10
        finally:
            remote.close_pool()
            server.shutdown()
