"""Multi-device distribution tests, run in subprocesses.

XLA locks the host device count at first jax init, so these spawn fresh
interpreters with ``--xla_force_host_platform_device_count`` set — the
same mechanism the dry-run uses, validated here at 8 devices where real
numeric comparison is cheap.
"""

import pytest

from procs import run_py as _run_py


def run_py(code: str, devices: int = 8) -> str:
    # shared harness: deadline from $REPRO_PROC_DEADLINE (default 420s)
    # with stdout/stderr tail dumps on both failure and timeout
    return _run_py(code, devices=devices)


@pytest.mark.timeout(500)
def test_shardmap_moe_matches_oracle_on_8_devices():
    out = run_py(
        """
        import dataclasses
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import blocks, build_model, optim

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_config("dbrx-132b").reduced()
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0), jnp.float32)
        layer = jax.tree.map(lambda a: a[0], params["layers"])
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 12, cfg.d_model))
        y_ref = blocks.moe_dense_ref(cfg, layer["ffn"], x)
        with mesh, optim.optimizations(mesh=mesh, shardmap_moe=True):
            y = jax.jit(lambda p, xx: blocks.moe_apply_shardmap(cfg, p, xx))(layer["ffn"], x)
        err = float(jnp.max(jnp.abs(y - y_ref)))
        assert err < 1e-5, err
        print("SHARDMAP_OK", err)
        """
    )
    assert "SHARDMAP_OK" in out


@pytest.mark.timeout(500)
def test_train_step_numerics_invariant_to_sharding():
    """One train step on a 2x4 mesh equals the single-device step."""
    out = run_py(
        """
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.configs import get_config
        from repro.models import abstract_tree, build_model
        from repro.sharding import TRAIN_RULES, tree_shardings
        from repro.training import AdamW, make_train_step

        cfg = get_config("llama3-8b").reduced()
        model = build_model(cfg)
        opt = AdamW(lr=1e-3, weight_decay=0.0)
        step = make_train_step(model, cfg, opt)
        params = model.init(jax.random.PRNGKey(0), jnp.float32)
        state = opt.init(params)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab)

        p1, _, m1 = jax.jit(step)(params, state, {"tokens": toks})

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        psh = tree_shardings(model.param_specs(), TRAIN_RULES, mesh)
        with mesh:
            p2, _, m2 = jax.jit(step, in_shardings=(psh, None, None))(
                params, state, {"tokens": toks})
        # cross-device reduction reassociation (sharded-vocab softmax, grad
        # all-reduce) + AdamW's rsqrt amplification -> compare to ~1e-3
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
        worst = 0.0
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            worst = max(worst, float(jnp.max(jnp.abs(a - b))))
        assert worst < 5e-3, f"max param divergence {worst}"
        print("SHARDED_STEP_OK", worst)
        """
    )
    assert "SHARDED_STEP_OK" in out


@pytest.mark.timeout(500)
def test_h1_constraint_preserves_numerics():
    out = run_py(
        """
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.configs import get_config
        from repro.models import build_model, optim

        cfg = get_config("llama3-8b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0), jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab)
        base = model.forward(params, {"tokens": toks})
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with mesh, optim.optimizations(mesh=mesh, shard_attn_heads=True):
            opt_out = jax.jit(lambda p, t: model.forward(p, {"tokens": t}))(params, toks)
        np.testing.assert_allclose(np.asarray(base), np.asarray(opt_out), rtol=2e-4, atol=2e-4)
        print("H1_NUMERICS_OK")
        """
    )
    assert "H1_NUMERICS_OK" in out
