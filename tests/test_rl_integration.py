"""Integration: the full RL loop (Fig 4) across trainer + rollout threads
with real weight bytes moving through TensorHub."""

import threading
import time

import pytest

from repro.configs import get_config
from repro.core import ReferenceServer, TensorHubClient
from repro.data.synthetic import PromptSet
from repro.rl import RLConfig, RolloutWorker, TrainerWorker


@pytest.mark.timeout(300)
def test_rl_loop_end_to_end():
    model_cfg = get_config("llama3-8b").reduced()
    cfg = RLConfig(num_steps=3, prompt_len=6, response_len=8, num_prompts=2, group_size=2)
    server = ReferenceServer()
    hub = TensorHubClient(server)
    prompts = PromptSet(vocab=model_cfg.vocab, prompt_len=cfg.prompt_len)
    queue, stop = [], threading.Event()
    trainer = TrainerWorker(hub, cfg, model_cfg, queue)
    workers = [
        RolloutWorker(f"rollout-{i}", hub, cfg, model_cfg, prompts, queue, stop)
        for i in range(2)
    ]
    for w in workers:
        w.start()
    try:
        for step in range(cfg.num_steps):
            deadline = time.monotonic() + 240
            while len(queue) < 2:
                for w in workers:
                    if w.error:
                        raise w.error
                assert time.monotonic() < deadline, "rollouts stalled"
                time.sleep(0.05)
            m = trainer.train_on([queue.pop(0), queue.pop(0)])
            assert m["version"] == step + 1
    finally:
        stop.set()
        for w in workers:
            w.join(timeout=90)
    for w in workers:
        if w.error:
            raise w.error
    trainer.close()
    # every published version was replicated at least once; no corruption
    assert server.stats["publishes"] >= cfg.num_steps
    assert server.stats["replications_completed"] >= 2
    # rollouts converged to a recent version
    assert all(w.weights_version is not None and w.weights_version >= 1 for w in workers)
