"""Integration: the full RL loop (Fig 4) across trainer + rollout threads
with real weight bytes moving through TensorHub — plus the swarm-pull
strong-consistency scenario (trainer rolls v+1 while rollouts are mid-
swarm-pull of v; no rollout may ever observe a torn version)."""

import threading
import time

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ReferenceServer, TensorHubClient
from repro.data.synthetic import PromptSet
from repro.rl import RLConfig, RolloutWorker, TrainerWorker


@pytest.mark.timeout(300)
def test_rl_loop_end_to_end():
    model_cfg = get_config("llama3-8b").reduced()
    cfg = RLConfig(num_steps=3, prompt_len=6, response_len=8, num_prompts=2, group_size=2)
    server = ReferenceServer()
    hub = TensorHubClient(server)
    prompts = PromptSet(vocab=model_cfg.vocab, prompt_len=cfg.prompt_len)
    queue, stop = [], threading.Event()
    trainer = TrainerWorker(hub, cfg, model_cfg, queue)
    workers = [
        RolloutWorker(f"rollout-{i}", hub, cfg, model_cfg, prompts, queue, stop)
        for i in range(2)
    ]
    for w in workers:
        w.start()
    try:
        for step in range(cfg.num_steps):
            deadline = time.monotonic() + 240
            while len(queue) < 2:
                for w in workers:
                    if w.error:
                        raise w.error
                assert time.monotonic() < deadline, "rollouts stalled"
                time.sleep(0.05)
            m = trainer.train_on([queue.pop(0), queue.pop(0)])
            assert m["version"] == step + 1
    finally:
        stop.set()
        for w in workers:
            w.join(timeout=90)
    for w in workers:
        if w.error:
            raise w.error
    trainer.close()
    # every published version was replicated at least once; no corruption
    assert server.stats["publishes"] >= cfg.num_steps
    assert server.stats["replications_completed"] >= 2
    # rollouts converged to a recent version
    assert all(w.weights_version is not None and w.weights_version >= 1 for w in workers)


# ---------------------------------------------------------------------------
# swarm pull vs. concurrent publish: strong consistency (Table 2 semantics)
# ---------------------------------------------------------------------------


def _weights(version: int):
    """Deterministic per-version weights, distinguishable byte-for-byte."""
    rng = np.random.default_rng(1000 + version)
    return {
        "wq": rng.integers(0, 255, size=(128, 512), dtype=np.uint8),
        "wk": np.full((64, 64), float(version), dtype=np.float32),
        "scale": np.full((8,), 0.5 + version, dtype=np.float32),
    }


def _expect_version(handle, version: int) -> None:
    want = _weights(version)
    for name, arr in want.items():
        got = handle.store.get(name)
        assert np.array_equal(got, arr), (
            f"{handle.replica}: tensor {name} is not pure v{version} "
            "(torn or stale bytes observed)"
        )


@pytest.mark.timeout(300)
def test_publish_next_version_during_swarm_pull_no_torn_reads():
    """Fig 4 steady state under swarm replication: rollouts are mid-swarm-
    pull of v1 (several concurrent readers, each other's prefixes in the
    availability map) while the trainer unpublishes v1 and publishes v2.

    Strong consistency requires: (a) every rollout's replicate(v1) lands
    pure v1 bytes — the retention drain means the trainer cannot mutate
    buffers readers still pull from; (b) a subsequent update("latest")
    lands pure v2; (c) no interleaving ever shows a mix of the two."""
    server = ReferenceServer()
    hub = TensorHubClient(server, window=3, chunk_bytes=8192)

    trainer = hub.open("rl", "trainer", 1, 0)
    trainer.register(_weights(1))
    trainer.publish(1)
    # a second full copy so rollout pulls multi-source from the start
    mirror = hub.open("rl", "mirror", 1, 0)
    mirror.register(_weights(0))
    mirror.replicate(1)

    rollouts = [hub.open("rl", f"rollout-{i}", 1, 0) for i in range(3)]
    for i, r in enumerate(rollouts):
        r.register(_weights(0))

    pulled = threading.Barrier(len(rollouts) + 1, timeout=60)
    errs = []

    def pull(h):
        try:
            v = h.replicate(1)
            assert v == 1
            _expect_version(h, 1)  # pure v1: no v2 bytes leaked mid-pull
            pulled.wait()
        except BaseException as e:  # noqa: BLE001
            errs.append((h.replica, e))
            try:
                pulled.wait()
            except threading.BrokenBarrierError:
                pass

    threads = [threading.Thread(target=pull, args=(r,)) for r in rollouts]
    for t in threads:
        t.start()

    # trainer rolls the version while the swarm pull is in flight: the
    # unpublish drains (readers hold refcounts) before buffers may mutate
    trainer.unpublish()
    for name, arr in _weights(2).items():
        trainer.store.get(name)[...] = arr  # legal only after drain
    trainer.publish(2)
    pulled.wait()
    for t in threads:
        t.join(timeout=60)
    assert not errs, f"rollout errors: {errs}"

    # every rollout flips to v2 atomically via update("latest")
    for r in rollouts:
        assert r.update("latest") is True
        assert r.current_version == 2
        _expect_version(r, 2)
    for r in rollouts + [mirror, trainer]:
        r.close()
