"""Per-architecture smoke tests (deliverable f): reduced same-family
config, one forward + one train step on CPU, output shapes + finite values;
decode consistency for the decoder families."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import AUDIO, VLM
from repro.data.synthetic import audio_batch
from repro.models import build_model, param_count
from repro.training import AdamW, make_train_step

B, S = 2, 16


def make_batch(cfg, key=0):
    rng = np.random.default_rng(key)
    if cfg.family == AUDIO:
        return {k: jnp.asarray(v) for k, v in audio_batch(B, S, cfg.frontend_dim, cfg.vocab, key).items()}
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(B, S)), jnp.int32)
    if cfg.family == VLM:
        return {
            "tokens": toks[:, : S - cfg.num_patches],
            "patches": jnp.asarray(rng.standard_normal((B, cfg.num_patches, cfg.d_model)), jnp.float32),
        }
    return {"tokens": toks}


@pytest.fixture(scope="module")
def smoke_models():
    out = {}
    for arch in ARCH_IDS:
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0), jnp.float32)
        out[arch] = (cfg, model, params)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, smoke_models):
    cfg, model, params = smoke_models[arch]
    logits = model.forward(params, make_batch(cfg))
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch, smoke_models):
    cfg, model, params = smoke_models[arch]
    opt = AdamW(lr=1e-3, weight_decay=0.0)
    step = jax.jit(make_train_step(model, cfg, opt))
    opt_state = opt.init(params)
    p2, o2, metrics = step(params, opt_state, make_batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    # params changed and stayed finite
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(p2)[0]
    assert l0.shape == l1.shape
    assert bool(jnp.all(jnp.isfinite(l1)))
    assert o2.step == 1


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS if not get_config(a).encoder_only])
def test_prefill_decode_matches_forward(arch, smoke_models):
    cfg, model, params = smoke_models[arch]
    if cfg.moe is not None:  # avoid capacity drops in the equivalence check
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.num_experts))
        )
        model = build_model(cfg)
    batch = make_batch(cfg)
    toks = batch["tokens"]
    full = model.forward(params, batch)
    pre = dict(batch)
    pre["tokens"] = toks[:, :-1]
    total = toks.shape[1] + (cfg.num_patches if cfg.family == VLM else 0)
    _, cache, clen = model.prefill(params, pre, max_len=total + 2)
    dec, _ = model.decode(params, cache, toks[:, -1:], clen)
    np.testing.assert_allclose(
        np.asarray(full[:, -1]), np.asarray(dec[:, -1]), rtol=2e-3, atol=2e-3
    )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_sane(arch):
    """Full config parameter count is within 12% of the published size
    implied by the arch name (sanity that the spec tree matches the
    assignment table)."""
    expected = {
        "dbrx-132b": 132e9, "deepseek-v3-671b": 671e9, "llama3-8b": 8e9,
        "deepseek-coder-33b": 33e9, "gemma2-2b": 2.6e9, "yi-34b": 34e9,
        "internvl2-2b": 2e9, "zamba2-2.7b": 2.7e9, "xlstm-350m": 0.35e9,
        "hubert-xlarge": 0.96e9,
    }[arch]
    cfg = get_config(arch)
    n = param_count(build_model(cfg))
    assert abs(n - expected) / expected < 0.35, f"{arch}: {n/1e9:.2f}B vs {expected/1e9:.1f}B"
