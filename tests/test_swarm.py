"""Swarm replication tests: the unit-granular availability map, the
ceiling-aware swarm planner, plan growth (epoch bumps), topology
weighting, and the never-read-past-source-prefix guard — server-level
property tests (hypothesis via the compat shim) plus threaded-client
end-to-end swarm pulls with real, verified bytes."""

import threading
import time

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import ReferenceServer, TensorHubClient
from repro.core.errors import TensorHubError
from repro.core.meta import (
    Assignment,
    ShardManifest,
    SourceSlice,
    TensorMeta,
    TransferUnit,
    WorkerInfo,
)
from repro.core.server import IN_PROGRESS, PUBLISHED
from repro.transfer.engine import WorkerStore


def manifest(n_units=8, unit_bytes=100):
    tensors = tuple(
        TensorMeta(f"t{i}", (unit_bytes,), "uint8", unit_bytes) for i in range(n_units)
    )
    units = tuple(
        TransferUnit(index=i, name=f"t{i}", nbytes=unit_bytes) for i in range(n_units)
    )
    return ShardManifest(tensors=tensors, units=units, checksums=(0,) * n_units)


def worker(replica, shard, dc="dc0", node=None):
    return WorkerInfo(f"{replica}/s{shard}", node or f"{dc}/{replica}", dc, False)


def open_replica(s, name, shards=2, dc="dc0", node=None):
    for i in range(shards):
        s.open("m", name, shards, i, worker=worker(name, i, dc, node))
        s.register("m", name, i)


def publish(s, name, version, shards=2, op=0, n_units=8):
    for i in range(shards):
        s.publish("m", name, i, version, manifest(n_units), op_id=op)


def assign(s, name, spec=0, op=0, shards=2):
    a = None
    for i in range(shards):
        a = s.begin_replicate("m", name, i, spec, op_id=op)
    return a


def start_partial(s, name, version, progress, shards=2, op=0, n_units=8):
    """Open a replica, begin replicating, and drive its per-shard progress
    counters to ``progress`` — a partial prefix the swarm may serve."""
    open_replica(s, name, shards=shards)
    a = assign(s, name, version, op=op, shards=shards)
    for i in range(shards):
        if progress > 0:
            s.update_progress("m", name, i, version, progress)
    return a


def plan_of(s, name, version=0):
    rv = s._models["m"].versions[version][name]  # noqa: SLF001 — introspection
    return list(rv.plan)


def check_tiles(plan, start, n_units):
    """The tiling invariant: sorted, contiguous, gap-free, overlap-free."""
    pos = start
    for _, a, b in plan:
        assert a == pos, f"gap/overlap at {a} (expected {pos}) in {plan}"
        assert b >= a
        pos = b
    assert pos == n_units, f"plan does not cover [{start}, {n_units}): {plan}"


# ---------------------------------------------------------------------------
# availability map
# ---------------------------------------------------------------------------


class TestAvailabilityMap:
    def test_published_and_partial_prefixes(self):
        s = ReferenceServer()
        open_replica(s, "pub")
        publish(s, "pub", 0)
        start_partial(s, "r1", 0, progress=3)
        av = s.availability("m", 0)
        assert av["pub"] == 8  # fully published: every unit
        assert av["r1"] == 3  # in-progress: the completed prefix

    def test_min_over_shards(self):
        """A group's servable prefix is the min over its shards — the only
        prefix every shard of a reader can pull in lockstep."""
        s = ReferenceServer()
        open_replica(s, "pub")
        publish(s, "pub", 0)
        start_partial(s, "r1", 0, progress=0)
        s.update_progress("m", "r1", 0, 0, 6)
        s.update_progress("m", "r1", 1, 0, 2)
        assert s.availability("m", 0)["r1"] == 2

    def test_mid_publish_replica_counts_its_prefix(self):
        """A replica with only some shards published serves like a partial
        source, not a full one."""
        s = ReferenceServer()
        open_replica(s, "pub")
        publish(s, "pub", 0)
        open_replica(s, "mid")
        s.publish("m", "mid", 0, 0, manifest(), op_id=0)  # shard 1 missing
        assert s.availability("m", 0)["mid"] == 0


# ---------------------------------------------------------------------------
# swarm planning (direct)
# ---------------------------------------------------------------------------


class TestSwarmPlanning:
    def test_partial_peer_joins_the_plan(self):
        """One published + one announced partial peer: the swarm partitions
        across both, the partial slice bounded by its ceiling."""
        s = ReferenceServer()
        open_replica(s, "pub")
        publish(s, "pub", 0)
        start_partial(s, "r1", 0, progress=4)
        open_replica(s, "r2")
        a = assign(s, "r2", 0, op=0)
        assert {sl.source for sl in a.sources} == {"pub", "r1"}
        check_tiles([(sl.source, sl.start_unit, sl.stop_unit) for sl in a.sources], 0, 8)
        for sl in a.sources:
            if sl.source == "r1":
                assert 0 <= sl.ceiling <= 4
                assert sl.stop_unit <= sl.ceiling  # never past the prefix
            else:
                assert sl.ceiling == -1  # published: unbounded
        assert s.stats["swarm_assignments"] >= 1

    def test_swarm_off_reproduces_pre_swarm_plans(self):
        """swarm=False: a partial peer is never admitted; the planner
        behaves exactly like the PR 2 scheduler (pipeline chain here)."""
        for swarm in (True, False):
            s = ReferenceServer(swarm=swarm)
            open_replica(s, "pub")
            publish(s, "pub", 0)
            start_partial(s, "r1", 0, progress=4)
            open_replica(s, "r2")
            a = assign(s, "r2", 0, op=0)
            srcs = {sl.source for sl in a.sources}
            if swarm:
                assert srcs == {"pub", "r1"}
            else:
                assert len(a.sources) <= 1  # single-source chain, as PR 2

    def test_same_dc_partial_beats_cross_dc_published(self):
        """Topology weighting: a same-DC in-progress peer outranks a
        cross-DC published source — the WAN link carries exactly one copy
        (the peer's own seed pull)."""
        s = ReferenceServer()
        open_replica(s, "remote", dc="dc0")
        publish(s, "remote", 0)
        open_replica(s, "seed", dc="dc1")
        assign(s, "seed", 0, op=0)  # dc1's seeding replica (cross-DC chain)
        for i in range(2):
            s.update_progress("m", "seed", i, 0, 5)
        open_replica(s, "r", dc="dc1")
        a = assign(s, "r", 0, op=0)
        assert a.source == "seed" and a.transport == "rdma"
        assert all(sl.source != "remote" for sl in a.sources)

    def test_growth_on_peer_announcement(self):
        """A reader on a contended published source grows its plan (epoch
        bump) when a swarm peer announces a prefix; the new plan starts at
        the reader's completed prefix — completed units are never re-read."""
        s = ReferenceServer(pipeline_replication=True)
        open_replica(s, "pub")
        publish(s, "pub", 0)
        open_replica(s, "x")
        assign(s, "x", 0, op=0)  # loads pub (refcount 1)
        start_partial(s, "r1", 0, progress=0, op=0)
        # r1 completes units while another peer announces its prefix
        start_partial(s, "peer", 0, progress=6, op=0)
        for i in range(2):
            s.update_progress("m", "r1", i, 0, 2)
        a = s.get_assignment("m", "r1")
        if a.epoch > 0:  # grew: the tail re-tiled over the richer pool
            assert min(sl.start_unit for sl in a.sources) >= 2
            check_tiles(
                [(sl.source, sl.start_unit, sl.stop_unit) for sl in a.sources],
                min(sl.start_unit for sl in a.sources),
                8,
            )

    def test_source_death_repartitions_only_unserved_tail(self):
        s = ReferenceServer()
        open_replica(s, "pub")
        publish(s, "pub", 0)
        start_partial(s, "peer", 0, progress=8)
        open_replica(s, "r")
        a = assign(s, "r", 0, op=0)
        assert {sl.source for sl in a.sources} == {"pub", "peer"}
        for i in range(2):
            s.update_progress("m", "r", i, 0, 3)
        s.fail_replica("m", "peer", reason="spot preemption")
        b = s.get_assignment("m", "r")
        assert b.epoch > a.epoch
        assert all(sl.source != "peer" for sl in b.sources)
        assert min(sl.start_unit for sl in b.sources) == 3  # tail only

    def test_no_read_cycles(self):
        """Two readers never end up in each other's plans (a cycle whose
        tails would gate on each other forever)."""
        s = ReferenceServer()
        open_replica(s, "pub")
        publish(s, "pub", 0)
        start_partial(s, "r1", 0, progress=4, op=0)
        start_partial(s, "r2", 0, progress=4, op=0)
        # drive growth on both; plans must stay acyclic
        for name in ("r1", "r2"):
            for i in range(2):
                s.update_progress("m", name, i, 0, 5)
        vmap = s._models["m"].versions[0]  # noqa: SLF001

        def sources_of(n):
            rv = vmap[n]
            return {x for x, _, _ in rv.plan} | ({rv.source} if rv.source else set())

        assert not ("r2" in sources_of("r1") and "r1" in sources_of("r2"))


# ---------------------------------------------------------------------------
# property-based planner invariants (hypothesis via the compat shim)
# ---------------------------------------------------------------------------


class TestPlannerProperties:
    @settings(max_examples=40, deadline=None)
    @given(
        n_units=st.integers(min_value=1, max_value=24),
        n_pub=st.integers(min_value=0, max_value=3),
        peer_progress=st.lists(
            st.integers(min_value=0, max_value=24), min_size=0, max_size=4
        ),
        extra_load=st.integers(min_value=0, max_value=3),
    )
    def test_plan_tiles_and_respects_ceilings(
        self, n_units, n_pub, peer_progress, extra_load
    ):
        """Random availability states: every swarm plan exactly tiles the
        destination's unit list; no slice assigned to a partial source
        exceeds its progress ceiling unless it is the tail slice of a
        pool with no fully-available source (chain-tail, progress-gated)."""
        s = ReferenceServer()
        vmap_progress = {}
        for i in range(n_pub):
            open_replica(s, f"pub{i}")
            publish(s, f"pub{i}", 0, n_units=n_units)
        for j, p in enumerate(peer_progress):
            if n_pub == 0 and j == 0:
                # someone must publish first or nothing can replicate
                open_replica(s, "seed")
                publish(s, "seed", 0, n_units=n_units)
            p = min(p, n_units)
            start_partial(s, f"peer{j}", 0, progress=p, op=0, n_units=n_units)
            vmap_progress[f"peer{j}"] = p
        if n_pub == 0 and not peer_progress:
            return  # nothing published: nothing to plan
        if extra_load and n_pub:
            st_m = s._models["m"]  # noqa: SLF001
            st_m.versions[0][f"pub{0}"].refcount += extra_load
        open_replica(s, "dest")
        a = assign(s, "dest", 0, op=0)
        assert a is not None
        slices = a.slices(n_units)
        check_tiles([(sl.source, sl.start_unit, sl.stop_unit) for sl in slices], 0, n_units)
        has_unbounded = any(sl.ceiling < 0 for sl in slices)
        for k, sl in enumerate(slices):
            if sl.ceiling < 0:
                continue  # fully published at plan time: unbounded
            if sl.stop_unit > sl.ceiling and sl.start_unit < sl.stop_unit:
                # only the tail slice may be progress-gated (chain-tail
                # semantics), and never when a fully published source is
                # in the plan to absorb the tail
                assert k == len(slices) - 1, f"non-tail slice past ceiling: {slices}"
                assert not has_unbounded, f"gated tail beside full source: {slices}"

    @settings(max_examples=25, deadline=None)
    @given(
        n_units=st.integers(min_value=4, max_value=24),
        done=st.integers(min_value=0, max_value=23),
        peer_progress=st.integers(min_value=1, max_value=24),
    )
    def test_epoch_bumps_never_reread_completed_units(
        self, n_units, done, peer_progress
    ):
        """Whatever triggers a re-partition (growth, death), the new plan
        starts at or after the reader's completed prefix."""
        done = min(done, n_units - 1)
        s = ReferenceServer()
        open_replica(s, "pub")
        publish(s, "pub", 0, n_units=n_units)
        # peer announces a prefix first, so the reader's plan includes it
        start_partial(
            s, "peer", 0, progress=min(peer_progress, n_units), op=0, n_units=n_units
        )
        open_replica(s, "r")
        a = assign(s, "r", 0, op=0)
        assert {sl.source for sl in a.sources} >= {"peer"} or len(a.sources) <= 1
        for i in range(2):
            if done:
                s.update_progress("m", "r", i, 0, done)
        s.fail_replica("m", "peer", reason="churn")  # re-plan: peer in r's plan
        b = s.get_assignment("m", "r")
        if b is not None and b.sources and b.epoch > a.epoch:
            assert min(sl.start_unit for sl in b.sources) >= done


# ---------------------------------------------------------------------------
# never-read-past-source-prefix guard (engine + threaded client)
# ---------------------------------------------------------------------------


class TestPrefixGuard:
    def test_store_refuses_reads_past_watermark(self):
        big = 3 * 1024 * 1024  # above TINY_TENSOR_BYTES: one unit per tensor
        store = WorkerStore("w0")
        store.register(
            {
                "a": np.zeros(big, dtype=np.uint8),
                "b": np.ones(big, dtype=np.uint8),
            }
        )
        units = store.units
        assert len(units) == 2
        store.serving_prefix = 1
        store.read_unit(units[0])  # prefix unit: served
        with pytest.raises(TensorHubError):
            store.read_unit(units[1])
        with pytest.raises(TensorHubError):
            # range reads of a not-yet-final tensor are refused too
            store.read_range(units[1].name, 0, 4)
        store.serving_prefix = None
        store.read_unit(units[1])  # unrestricted once replication completes

    def test_manifest_checksums_ignore_watermark(self):
        """The owner may always checksum its own buffers (publish path)."""
        store = WorkerStore("w0")
        store.register({"a": np.arange(64, dtype=np.uint8)})
        store.serving_prefix = 0
        m = store.build_manifest(with_checksums=True)
        assert any(m.checksums)

    def test_registration_lifts_stale_watermark(self):
        """A watermark left by an aborted pull must not poison the store
        for later versions: re-registering fresh buffers clears it."""
        store = WorkerStore("w0")
        store.register({"a": np.arange(64, dtype=np.uint8)})
        store.serving_prefix = 0  # aborted pull left the guard armed
        store.register({"a": np.ones(64, dtype=np.uint8)})
        assert store.serving_prefix is None
        store.read_unit(store.units[0])  # serves again

    def test_publish_lifts_stale_watermark(self):
        """Publishing vouches for every byte: a handle that aborted a pull
        and then publishes serves all units again."""
        from repro.core import ReferenceServer, TensorHubClient

        server = ReferenceServer()
        hub = TensorHubClient(server)
        h = hub.open("m", "pub", 1, 0)
        h.register({"a": np.arange(64, dtype=np.uint8)})
        h.store.serving_prefix = 0  # simulate an aborted pull's leftover
        h.publish(0)
        assert h.store.serving_prefix is None
        h.store.read_unit(h.store.units[0])


def tensors(seed: float):
    rng = np.random.default_rng(int(seed))
    return {
        "big": rng.integers(0, 255, size=(64, 1024), dtype=np.uint8),
        "w0": np.full((32, 16), seed, dtype=np.float32),
        "w1": np.full((32, 16), seed + 1, dtype=np.float32),
    }


def group(hub, name, shards, make, **kw):
    handles = [hub.open("m", name, shards, i, **kw) for i in range(shards)]
    for h in handles:
        h.register(make())
    return handles


def run_group(handles, fn):
    errs = []

    def wrap(h):
        try:
            fn(h)
        except BaseException as e:  # noqa: BLE001
            errs.append(e)

    ts = [threading.Thread(target=wrap, args=(h,)) for h in handles]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=120)
    if errs:
        raise errs[0]


class TestThreadedSwarm:
    def test_concurrent_readers_swarm_bit_identical(self):
        """Several readers replicate concurrently (each other's prefixes in
        the availability map); all end bit-identical with checksums on."""
        server = ReferenceServer()
        hub = TensorHubClient(server, window=3, chunk_bytes=4096)
        pubs = group(hub, "pub", 2, lambda: tensors(11.0))
        run_group(pubs, lambda h: h.publish(0))
        readers = [group(hub, f"r{i}", 2, lambda: tensors(float(i))) for i in range(3)]
        flat = [h for g in readers for h in g]
        run_group(flat, lambda h: h.replicate(0))
        want = tensors(11.0)
        for h in flat:
            for name, arr in want.items():
                assert np.array_equal(h.store.get(name), arr), (h.replica, name)
        # every reader's store is unrestricted again
        assert all(h.store.serving_prefix is None for h in flat)

    def test_swarm_source_death_mid_pull_recovers(self):
        """Kill a replica that served its prefix into the swarm: survivors
        re-partition the unserved tail and still converge bit-identically."""
        server = ReferenceServer()
        hub = TensorHubClient(server, window=2, chunk_bytes=4096)
        pubs = group(hub, "pub", 1, lambda: tensors(13.0))
        run_group(pubs, lambda h: h.publish(0))
        mirror = group(hub, "mirror", 1, lambda: tensors(0.0))
        run_group(mirror, lambda h: h.replicate(0))  # second full copy

        def killer():
            time.sleep(0.05)
            hub.registry.fail_replica("mirror")
            with hub._cv:  # noqa: SLF001 — failure injection
                server.fail_replica("m", "mirror", reason="spot preemption")

        t = threading.Thread(target=killer, daemon=True)
        t.start()
        subs = [group(hub, f"s{i}", 1, lambda: tensors(0.0)) for i in range(2)]
        flat = [h for g in subs for h in g]
        run_group(flat, lambda h: h.replicate(0))
        t.join(timeout=10)
        want = tensors(13.0)
        for h in flat:
            for name, arr in want.items():
                assert np.array_equal(h.store.get(name), arr), (h.replica, name)
