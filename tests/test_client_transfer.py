"""Threaded-client end-to-end tests: real byte movement through the
LocalTransport, checksum verification, peer chaining, failure re-route."""

import threading

import numpy as np
import pytest

from repro.core import ChecksumError, ReferenceServer, TensorHubClient


def tensors(seed: float, n=3, shape=(32, 16)):
    return {f"w{i}": np.full(shape, seed + i, dtype=np.float32) for i in range(n)}


def group(hub, name, shards, register_with=None, **kw):
    handles = [hub.open("m", name, shards, i, **kw) for i in range(shards)]
    if register_with is not None:
        for h in handles:
            h.register(register_with())
    return handles


def run_group(handles, fn):
    errs = []

    def wrap(h):
        try:
            fn(h)
        except BaseException as e:  # noqa: BLE001
            errs.append(e)

    ts = [threading.Thread(target=wrap, args=(h,)) for h in handles]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
    if errs:
        raise errs[0]


class TestEndToEnd:
    def test_publish_replicate_bytes(self):
        hub = TensorHubClient(ReferenceServer())
        pubs = group(hub, "pub", 2, lambda: tensors(1.0))
        run_group(pubs, lambda h: h.publish(0))
        subs = group(hub, "sub", 2, lambda: tensors(0.0))
        got = []
        run_group(subs, lambda h: got.append(h.replicate("latest")))
        assert got == [0, 0]
        for h in subs:
            assert np.allclose(h.store.get("w2"), 3.0)

    def test_peer_to_peer_chain(self):
        """A replica created by replicate() serves the next reader."""
        server = ReferenceServer()
        hub = TensorHubClient(server)
        pubs = group(hub, "pub", 1, lambda: tensors(5.0))
        run_group(pubs, lambda h: h.publish(0))
        r1 = group(hub, "r1", 1, lambda: tensors(0.0))
        run_group(r1, lambda h: h.replicate(0))
        r2 = group(hub, "r2", 1, lambda: tensors(0.0))
        assignments = []
        orig = server.begin_replicate

        def spy(*a, **k):
            res = orig(*a, **k)
            assignments.append(res)
            return res

        server.begin_replicate = spy
        run_group(r2, lambda h: h.replicate(0))
        assert assignments[0].source in ("r1", "pub")
        assert np.allclose(r2[0].store.get("w0"), 5.0)

    def test_update_polling(self):
        hub = TensorHubClient(ReferenceServer())
        pubs = group(hub, "pub", 2, lambda: tensors(1.0), retain="latest")
        run_group(pubs, lambda h: h.publish(0))
        subs = group(hub, "sub", 2, lambda: tensors(0.0))
        run_group(subs, lambda h: h.replicate("latest"))
        # nothing new yet
        updated = []
        run_group(subs, lambda h: updated.append(h.update("latest")))
        assert updated == [False, False]
        # publisher rolls a version
        run_group(pubs, lambda h: h.unpublish())
        for h in pubs:
            h.store.register(tensors(9.0))
        run_group(pubs, lambda h: h.publish(1))
        updated = []
        run_group(subs, lambda h: updated.append(h.update("latest")))
        assert updated == [True, True]
        assert np.allclose(subs[0].store.get("w0"), 9.0)

    def test_checksum_detects_contract_violation(self):
        """Mutating published weights (contract violation) is caught by the
        end-to-end checksum (4.6)."""
        hub = TensorHubClient(ReferenceServer())
        pubs = group(hub, "pub", 1, lambda: tensors(1.0))
        run_group(pubs, lambda h: h.publish(0))
        # violate the contract: scribble on the published buffer
        pubs[0].store.get("w0")[:] = 777.0
        subs = group(hub, "sub", 1, lambda: tensors(0.0))
        with pytest.raises(ChecksumError):
            run_group(subs, lambda h: h.replicate(0))

    def test_retention_offload_roundtrip(self):
        """Trainer unpublishes the only copy of a retained version: the
        offload copy must serve a later reader with intact bytes."""
        hub = TensorHubClient(ReferenceServer())
        pubs = group(hub, "pub", 2, lambda: tensors(4.0), retain="latest")
        run_group(pubs, lambda h: h.publish(0))
        run_group(pubs, lambda h: h.unpublish())  # triggers offload
        # trainer now mutates its GPU buffers freely
        for h in pubs:
            h.store.get("w0")[:] = -1.0
        subs = group(hub, "sub", 2, lambda: tensors(0.0))
        run_group(subs, lambda h: h.replicate(0))
        assert np.allclose(subs[0].store.get("w0"), 4.0)  # offload bytes, not -1

    def test_source_failure_reroutes_mid_transfer(self):
        """Kill the assigned source once the transfer starts; the reader
        must finish from another replica."""
        server = ReferenceServer(pipeline_replication=True)
        hub = TensorHubClient(server)
        big = lambda: {f"w{i}": np.full((256, 256), float(i), np.float32) for i in range(8)}
        pubs = group(hub, "pub", 1, big)
        run_group(pubs, lambda h: h.publish(0))
        r1 = group(hub, "r1", 1, big)
        run_group(r1, lambda h: h.replicate(0))
        r2 = group(hub, "r2", 1, big)

        # r2 will be routed to r1 (least loaded); kill r1 after it starts
        def kill_soon():
            import time

            time.sleep(0.05)
            hub.registry.fail_replica("r1")
            server.fail_replica("m", "r1", reason="test kill")

        killer = threading.Thread(target=kill_soon)
        killer.start()
        run_group(r2, lambda h: h.replicate(0))
        killer.join()
        assert np.allclose(r2[0].store.get("w7"), 7.0)
