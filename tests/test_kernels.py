"""Pallas kernel validation (interpret mode on CPU): shape/dtype sweeps
against the pure-jnp oracles, per the deliverable-(c) requirement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.checksum import checksum_ref, fold64, tensor_checksum
from repro.kernels.checksum.kernel import checksum_words
from repro.kernels.checksum.ops import host_equivalent
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.quant import dequantize, quantize, quantize_ref
from repro.kernels.quant.kernel import quantize_rows


class TestFlashAttention:
    @pytest.mark.parametrize(
        "b,hq,hkv,sq,sk,d,causal,cap",
        [
            (2, 4, 2, 128, 128, 64, True, 0.0),
            (1, 8, 8, 256, 256, 128, True, 50.0),  # gemma2-style softcap
            (2, 4, 1, 96, 160, 64, False, 0.0),  # ragged, cross-len, MQA
            (1, 2, 2, 384, 384, 256, True, 0.0),  # gemma2 head_dim 256
            (1, 16, 4, 64, 64, 128, True, 0.0),  # GQA 4:1
        ],
    )
    def test_against_oracle(self, b, hq, hkv, sq, sk, d, causal, cap):
        ks = jax.random.split(jax.random.PRNGKey(sq + d), 3)
        q = jax.random.normal(ks[0], (b, hq, sq, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, hkv, sk, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, hkv, sk, d), jnp.float32)
        out = flash_attention(q, k, v, causal=causal, softcap=cap, interpret=True)
        ref = attention_ref(q, k, v, causal=causal, softcap=cap)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (1, 2, 128, 64)).astype(dtype)
        k = jax.random.normal(ks[1], (1, 2, 128, 64)).astype(dtype)
        v = jax.random.normal(ks[2], (1, 2, 128, 64)).astype(dtype)
        out = flash_attention(q, k, v, interpret=True)
        ref = attention_ref(q, k, v)
        assert out.dtype == dtype
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
        )

    def test_block_shape_sweep(self):
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (1, 2, 200, 64))
        k = jax.random.normal(ks[1], (1, 2, 200, 64))
        v = jax.random.normal(ks[2], (1, 2, 200, 64))
        ref = attention_ref(q, k, v)
        for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
            out = flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


class TestChecksum:
    @pytest.mark.parametrize(
        "shape,dtype",
        [
            ((1024,), jnp.float32),
            ((333, 7), jnp.bfloat16),
            ((65536,), jnp.float32),
            ((1,), jnp.float32),
            ((100001,), jnp.int32),
        ],
    )
    def test_kernel_matches_host(self, shape, dtype):
        if dtype == jnp.int32:
            x = jnp.arange(np.prod(shape), dtype=dtype).reshape(shape)
        else:
            x = jax.random.normal(jax.random.PRNGKey(1), shape).astype(dtype)
        got = fold64(np.asarray(tensor_checksum(x, interpret=True)))
        assert got == host_equivalent(x)

    @settings(max_examples=30, deadline=None)
    @given(st.binary(min_size=0, max_size=4096))
    def test_kernel_matches_host_bytes(self, raw):
        from repro.transfer.checksum import checksum as host_checksum

        pad = (-len(raw)) % 4
        buf = raw + b"\0" * pad
        words = jnp.asarray(np.frombuffer(buf, np.uint32)) if buf else jnp.zeros((0,), jnp.uint32)
        if words.size == 0:
            return
        pair = checksum_words(words, interpret=True)
        assert fold64(np.asarray(pair)) == host_checksum(buf)

    def test_ref_matches_kernel(self):
        words = jax.random.bits(jax.random.PRNGKey(0), (5000,), jnp.uint32)
        np.testing.assert_array_equal(
            np.asarray(checksum_words(words, interpret=True)),
            np.asarray(checksum_ref(words)),
        )

    def test_detects_corruption_and_reorder(self):
        x = jax.random.normal(jax.random.PRNGKey(5), (4096,))
        base = fold64(np.asarray(tensor_checksum(x, interpret=True)))
        flipped = x.at[17].set(x[17] + 1.0)
        assert fold64(np.asarray(tensor_checksum(flipped, interpret=True))) != base
        swapped = x.at[jnp.asarray([3, 400])].set(x[jnp.asarray([400, 3])])
        assert fold64(np.asarray(tensor_checksum(swapped, interpret=True))) != base


class TestQuant:
    @pytest.mark.parametrize("shape", [(64, 128), (1000, 555), (3, 7, 64)])
    def test_roundtrip(self, shape):
        x = jax.random.normal(jax.random.PRNGKey(2), shape) * 3.0
        q, s, orig = quantize(x, row_len=128, interpret=True)
        xr = dequantize(q, s, orig)
        assert xr.shape == x.shape
        rel = float(jnp.max(jnp.abs(xr - x)) / jnp.max(jnp.abs(x)))
        assert rel < 0.01

    def test_kernel_matches_ref(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (512, 256))
        qk, sk_ = quantize_rows(x, interpret=True)
        qr, sr = quantize_ref(x)
        np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
        np.testing.assert_allclose(np.asarray(sk_), np.asarray(sr), rtol=1e-6)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_sweep(self, dtype):
        x = (jax.random.normal(jax.random.PRNGKey(4), (256, 128)) * 2).astype(dtype)
        q, s, orig = quantize(x, row_len=128, interpret=True)
        xr = dequantize(q, s, orig, dtype=jnp.float32)
        rel = float(jnp.max(jnp.abs(xr - x.astype(jnp.float32))))
        assert rel < 0.1

    def test_compression_ratio(self):
        from repro.kernels.quant import compressed_bytes

        x = jax.random.normal(jax.random.PRNGKey(5), (1024, 1024))
        q, s, _ = quantize(x, row_len=1024, interpret=True)
        assert compressed_bytes(q, s) < x.size * 4 / 3.5  # ~4x smaller
