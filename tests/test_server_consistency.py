"""Deterministic consistency tests for the reference server (paper 4.6):
one test process issues requests on behalf of multiple clients in chosen
interleavings — the FoundationDB-style simulation approach. No threads, no
transfers, no GPUs: the control plane alone."""

import pytest

from repro.core.errors import (
    ConsistencyError,
    MutabilityViolationError,
    StaleHandleError,
    VersionUnavailableError,
)
from repro.core.meta import ShardManifest, TensorMeta, TransferUnit, WorkerInfo
from repro.core.server import ReferenceServer


def manifest(n_units=2, unit_bytes=100):
    tensors = tuple(
        TensorMeta(f"t{i}", (unit_bytes,), "uint8", unit_bytes) for i in range(n_units)
    )
    units = tuple(
        TransferUnit(index=i, name=f"t{i}", nbytes=unit_bytes) for i in range(n_units)
    )
    return ShardManifest(tensors=tensors, units=units, checksums=(0,) * n_units)


def worker(replica, shard, dc="dc0", spot=False):
    return WorkerInfo(f"{replica}/s{shard}", f"{dc}/{replica}", dc, spot)


def open_replica(s, name, shards=2, dc="dc0", retain=None, spot=False):
    for i in range(shards):
        s.open("m", name, shards, i, worker=worker(name, i, dc, spot), retain=retain)
        s.register("m", name, i)


def publish(s, name, version, shards=2, op=0):
    for i in range(shards):
        s.publish("m", name, i, version, manifest(), op_id=op)


class TestGroupTransactions:
    def test_fig6_interleaved_latest(self):
        """Fig 6: shard0 of replica-0 resolves 'latest' -> v12; a new v13
        is published in between; shard1's identical request must still see
        v12 (the transaction snapshot), not v13."""
        s = ReferenceServer()
        open_replica(s, "pub")
        open_replica(s, "pub2")
        open_replica(s, "reader")
        publish(s, "pub", 12)
        a0 = s.begin_replicate("m", "reader", 0, "latest", op_id=0)
        assert a0 is not None and a0.version == 12
        publish(s, "pub2", 13)  # interleaved publish
        a1 = s.begin_replicate("m", "reader", 1, "latest", op_id=0)
        assert a1 is not None and a1.version == 12  # consistent snapshot

    def test_divergent_group_op_raises(self):
        s = ReferenceServer()
        open_replica(s, "pub")
        publish(s, "pub", 1)
        open_replica(s, "r")
        s.begin_replicate("m", "r", 0, "latest", op_id=0)
        with pytest.raises(ConsistencyError):
            s.begin_replicate("m", "r", 1, 0, op_id=0)  # different args

    def test_double_arrival_is_idempotent(self):
        """Re-delivery of the same op by the same shard (a client retry
        after a controller failover) returns the cached result and
        mutates nothing; only *divergent* ops on one op id raise."""
        s = ReferenceServer()
        open_replica(s, "pub")
        r1 = s.publish("m", "pub", 0, 1, manifest(), op_id=0)
        r2 = s.publish("m", "pub", 0, 1, manifest(), op_id=0)  # re-delivered
        assert r1 == r2
        assert s.stats["publishes"] == 1
        # the duplicate did not count as shard1's arrival: the group is
        # still waiting, so a conflicting op id reuse still trips
        with pytest.raises(ConsistencyError):
            s.begin_replicate("m", "pub", 1, "latest", op_id=0)

    def test_update_decision_is_group_wide(self):
        s = ReferenceServer()
        open_replica(s, "pub")
        open_replica(s, "r")
        publish(s, "pub", 0)
        # group replicates v0
        for i in range(2):
            s.begin_replicate("m", "r", i, "latest", op_id=0)
        for i in range(2):
            s.complete_replicate("m", "r", i, 0, op_id=1)
        d0 = s.begin_update("m", "r", 0, "latest", op_id=2)
        assert not d0.updated  # already current
        # interleaved: the publisher rolls to v1 between the two shards
        for i in range(2):
            s.unpublish("m", "pub", i, op_id=1)
        assert s.finish_unpublish("m", "pub")
        publish(s, "pub", 1, op=2)
        d1 = s.begin_update("m", "r", 1, "latest", op_id=2)
        assert d1.updated == d0.updated  # snapshot: both say False


class TestScheduling:
    def test_least_loaded_source(self):
        # legacy single-source mode: readers spread across the replicas
        s = ReferenceServer(max_sources=1)
        open_replica(s, "a")
        open_replica(s, "b")
        publish(s, "a", 0)
        publish(s, "b", 0)
        # first reader -> one of them; second reader -> the other
        open_replica(s, "r1")
        open_replica(s, "r2")
        src1 = {s.begin_replicate("m", "r1", i, 0, op_id=0).source for i in range(2)}
        src2 = {s.begin_replicate("m", "r2", i, 0, op_id=0).source for i in range(2)}
        assert src1 != src2  # load balanced across the two replicas

    def test_multi_source_partitions_units(self):
        # default mode: each reader stripes its unit list across BOTH
        # published replicas instead of pinning to one
        s = ReferenceServer()
        open_replica(s, "a")
        open_replica(s, "b")
        publish(s, "a", 0)
        publish(s, "b", 0)
        open_replica(s, "r1")
        a = s.begin_replicate("m", "r1", 0, 0, op_id=0)
        assert {sl.source for sl in a.sources} == {"a", "b"}
        ranges = sorted((sl.start_unit, sl.stop_unit) for sl in a.sources)
        assert ranges[0][0] == 0 and ranges[-1][1] == 2  # tiles [0, 2)
        assert ranges[0][1] == ranges[1][0]  # contiguous, no overlap

    def test_same_dc_preferred(self):
        s = ReferenceServer()
        open_replica(s, "far", dc="dc0")
        open_replica(s, "near", dc="dc1")
        publish(s, "far", 0)
        publish(s, "near", 0)
        open_replica(s, "r", dc="dc1")
        a = s.begin_replicate("m", "r", 0, 0, op_id=0)
        assert a.source == "near" and a.transport == "rdma"

    def test_cross_dc_falls_back_to_tcp_seeding(self):
        s = ReferenceServer()
        open_replica(s, "far", dc="dc0")
        publish(s, "far", 0)
        open_replica(s, "r", dc="dc1")
        a = s.begin_replicate("m", "r", 0, 0, op_id=0)
        assert a.transport == "tcp" and a.seeding

    def test_pipeline_source_can_be_in_progress(self):
        s = ReferenceServer(pipeline_replication=True)
        open_replica(s, "pub")
        publish(s, "pub", 0)
        open_replica(s, "r1")
        open_replica(s, "r2")
        for i in range(2):
            s.begin_replicate("m", "r1", i, 0, op_id=0)
        a = s.begin_replicate("m", "r2", 0, 0, op_id=0)
        assert a.source == "r1"  # least-loaded: the in-progress replica

    def test_no_pipeline_only_published_sources(self):
        s = ReferenceServer(pipeline_replication=False)
        open_replica(s, "pub")
        publish(s, "pub", 0)
        open_replica(s, "r1")
        open_replica(s, "r2")
        for i in range(2):
            s.begin_replicate("m", "r1", i, 0, op_id=0)
        a = s.begin_replicate("m", "r2", 0, 0, op_id=0)
        assert a.source == "pub"


class TestFailures:
    def test_reader_rerouted_after_source_death(self):
        s = ReferenceServer(pipeline_replication=True)
        open_replica(s, "pub")
        publish(s, "pub", 0)
        open_replica(s, "r1")
        open_replica(s, "r2")
        for i in range(2):
            s.begin_replicate("m", "r1", i, 0, op_id=0)
        for i in range(2):
            assert s.begin_replicate("m", "r2", i, 0, op_id=0).source == "r1"
        s.report_transfer_failure("m", "r2", "r1")
        a = s.get_assignment("m", "r2")
        assert a is not None and a.source == "pub"

    def test_evicted_replica_handles_go_stale(self):
        s = ReferenceServer()
        open_replica(s, "pub")
        publish(s, "pub", 0)
        s.fail_replica("m", "pub")
        with pytest.raises(StaleHandleError):
            s.heartbeat("m", "pub", 0, now=1.0)

    def test_heartbeat_timeout_eviction(self):
        s = ReferenceServer(heartbeat_timeout=1.0)
        open_replica(s, "pub")
        publish(s, "pub", 0)
        s.heartbeat("m", "pub", 0, now=0.0)
        s.heartbeat("m", "pub", 1, now=0.0)
        assert s.tick(0.5) == []
        assert s.tick(2.0) == ["pub"]
        assert s.list_versions("m") == {}

    def test_failed_publisher_does_not_serve(self):
        s = ReferenceServer()
        open_replica(s, "pub")
        publish(s, "pub", 0)
        s.fail_replica("m", "pub")
        open_replica(s, "r")
        with pytest.raises(VersionUnavailableError):
            # parked is fine; direct assign must not find the dead source
            a = s.begin_replicate("m", "r", 0, 0, op_id=0)
            if a is None:
                raise VersionUnavailableError("parked: acceptable")

    def test_soft_state_backup_server(self):
        """4.5: a fresh backup server needs no state transfer — the next
        publish repopulates it."""
        backup = ReferenceServer()
        open_replica(backup, "pub")
        publish(backup, "pub", 7)
        assert backup.latest("m") == 7
