"""Direct unit tests for data-plane pieces previously covered only
indirectly through the swarm suites: ``ShardHandle._pull_units_span``
resume/re-plan behavior and ``read_unit_range`` boundary cases."""

import numpy as np
import pytest

from repro.core import ReferenceServer, TensorHubClient
from repro.core.errors import TensorHubError
from repro.transfer.engine import LocalTransport, WorkerRegistry, WorkerStore

BIG = 3 * 1024 * 1024  # above TINY_TENSOR_BYTES: one transfer unit per tensor
N_UNITS = 5


def big_tensors(seed: int, n=N_UNITS):
    rng = np.random.default_rng(seed)
    return {
        f"w{i}": rng.integers(0, 255, size=BIG, dtype=np.uint8) for i in range(n)
    }


def publish_replica(hub, name, seed, version=0):
    h = hub.open("m", name, 1, 0)
    h.register(big_tensors(seed))
    h.publish(version)
    return h


class TestPullUnitsSpanDirect:
    def _reader(self, hub):
        r = hub.open("m", "r", 1, 0)
        r.register({f"w{i}": np.zeros(BIG, dtype=np.uint8) for i in range(N_UNITS)})
        return r

    def test_resume_from_nonzero_prefix(self):
        """A span started at done=k pulls exactly the remaining units and
        advances the server counter to the full count."""
        server = ReferenceServer()
        hub = TensorHubClient(server)
        pub = publish_replica(hub, "a", seed=1)
        r = self._reader(hub)
        with hub._cv:  # noqa: SLF001 - direct data-plane drive
            a = server.begin_replicate("m", "r", 0, 0, op_id=r._next_op())
        src_manifest = pub.store.build_manifest()
        moved_before = hub.transport.bytes_moved
        done = r._pull_units_span(a, "r", r.store, 2, src_manifest)
        assert done == N_UNITS
        assert hub.transport.bytes_moved - moved_before == (N_UNITS - 2) * BIG
        assert server.shard_progress("m", "r", 0, 0) == N_UNITS
        # only the resumed tail was written; the skipped prefix is intact
        assert not r.store.get("w0").any() and not r.store.get("w1").any()
        for i in range(2, N_UNITS):
            np.testing.assert_array_equal(
                r.store.get(f"w{i}"), pub.store.get(f"w{i}")
            )

    def test_resume_after_epoch_bump(self):
        """The server re-partitions the plan mid-span (epoch bump): the
        executor drains, refetches the assignment, and resumes from its
        completed prefix on the new plan — no unit is re-read."""
        server = ReferenceServer()
        hub = TensorHubClient(server)
        pub_a = publish_replica(hub, "a", seed=1)
        publish_replica(hub, "b", seed=1)
        r = self._reader(hub)
        with hub._cv:  # noqa: SLF001
            a = server.begin_replicate("m", "r", 0, 0, op_id=r._next_op())
        assert len(a.sources) == 2  # multi-source plan over {a, b}
        # server-side re-partition onto a alone, as after a failure sweep
        st = server._models["m"]  # noqa: SLF001 - harness hook
        rv = st.versions[0]["r"]
        server._install_plan(  # noqa: SLF001
            st, 0, rv, st.replicas["r"], [("a", 0, N_UNITS)]
        )
        assert rv.assign_epoch == 1 and a.epoch == 0  # handle's plan is stale
        done = r._pull_units_span(a, "r", r.store, 0, pub_a.store.build_manifest())
        assert done == N_UNITS
        assert server.shard_progress("m", "r", 0, 0) == N_UNITS
        for i in range(N_UNITS):
            np.testing.assert_array_equal(
                r.store.get(f"w{i}"), pub_a.store.get(f"w{i}")
            )


class TestReadUnitRangeBoundaries:
    def _setup(self):
        registry = WorkerRegistry()
        store = WorkerStore("src/shard0")
        rng = np.random.default_rng(0)
        store.register(
            {f"w{i}": rng.integers(0, 255, size=BIG, dtype=np.uint8) for i in range(3)}
        )
        registry.add("src", 0, store)
        return LocalTransport(registry), store

    def test_zero_length_tail_chunk(self):
        """offset == nbytes == end-of-unit is a valid no-op read (the
        chunk planner can emit it at exact-divisor boundaries)."""
        transport, store = self._setup()
        unit = store.units[0]
        out = transport.read_unit_range("src", 0, unit, unit.nbytes, 0)
        assert out.nbytes == 0

    def test_chunk_past_end_rejected(self):
        transport, store = self._setup()
        unit = store.units[0]
        with pytest.raises(TensorHubError):
            transport.read_unit_range("src", 0, unit, unit.nbytes - 10, 11)

    def test_negative_length_rejected(self):
        transport, store = self._setup()
        unit = store.units[0]
        with pytest.raises(TensorHubError):
            transport.read_unit_range("src", 0, unit, 4, -1)

    def test_chunk_exactly_at_serving_prefix_refused(self):
        """The never-read-past-source-prefix guard applies at chunk
        granularity: unit index == serving_prefix holds non-final bytes."""
        transport, store = self._setup()
        store.serving_prefix = 1
        ok = transport.read_unit_range("src", 0, store.units[0], 0, 128)
        assert ok.nbytes == 128  # unit 0 < prefix: served
        with pytest.raises(TensorHubError):
            transport.read_unit_range("src", 0, store.units[1], 0, 128)

    def test_chunk_served_after_prefix_advances(self):
        transport, store = self._setup()
        store.serving_prefix = 1
        with pytest.raises(TensorHubError):
            transport.read_unit_range("src", 0, store.units[1], 0, 128)
        store.serving_prefix = 2  # owner completed unit 1: now final
        out = transport.read_unit_range("src", 0, store.units[1], 0, 128)
        np.testing.assert_array_equal(
            out, store.get("w1").view(np.uint8).reshape(-1)[:128]
        )
