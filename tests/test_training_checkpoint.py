"""Optimizer, objectives, grad accumulation, checkpoint/restart, sharding
rules, and the HLO analyzer."""

import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec


def make_abstract_mesh(shape, names):
    """AbstractMesh across JAX API generations: newer versions take a
    ``((name, size), ...)`` shape tuple, older ones ``(shape, names)``."""
    try:
        return AbstractMesh(tuple(zip(names, shape)))
    except TypeError:
        return AbstractMesh(shape, names)

from repro import checkpoint as ckpt
from repro.configs import get_config
from repro.data.synthetic import BigramStream, PromptSet
from repro.models import build_model
from repro.sharding import SERVE_RULES, TRAIN_RULES, spec_for
from repro.training import (
    AdamW,
    cosine_schedule,
    group_relative_advantages,
    grpo_loss,
    lm_cross_entropy,
    make_train_step,
)


class TestAdamW:
    def test_converges_on_quadratic(self):
        opt = AdamW(lr=0.1, weight_decay=0.0, grad_clip=0.0)
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = opt.init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}  # d/dw w^2
            params, state = opt.update(grads, state, params)
        assert float(jnp.max(jnp.abs(params["w"]))) < 0.05

    def test_bf16_state_option(self):
        opt = AdamW(state_dtype=jnp.bfloat16)
        params = {"w": jnp.ones((4, 4))}
        state = opt.init(params)
        assert state.mu["w"].dtype == jnp.bfloat16
        p2, s2 = opt.update({"w": jnp.ones((4, 4))}, state, params)
        assert s2.mu["w"].dtype == jnp.bfloat16

    def test_grad_clip(self):
        opt = AdamW(lr=1.0, grad_clip=1.0, weight_decay=0.0)
        params = {"w": jnp.zeros(3)}
        state = opt.init(params)
        p2, _ = opt.update({"w": jnp.asarray([1e6, 0.0, 0.0])}, state, params)
        assert float(jnp.max(jnp.abs(p2["w"]))) < 1.1  # clipped step

    def test_schedule(self):
        sched = cosine_schedule(warmup=10, total=100)
        assert float(sched(jnp.asarray(0))) == 0.0
        assert math.isclose(float(sched(jnp.asarray(10))), 1.0, rel_tol=1e-5)
        assert float(sched(jnp.asarray(100))) < 1e-5


class TestObjectives:
    def test_lm_ce_perfect_prediction(self):
        toks = jnp.asarray([[1, 2, 3, 1]])
        logits = jax.nn.one_hot(jnp.asarray([[2, 3, 1, 0]]), 5) * 100.0
        loss, m = lm_cross_entropy(logits, toks)
        assert float(loss) < 1e-3 and float(m["accuracy"]) == 1.0

    def test_grpo_direction(self):
        """Positive advantage pushes sampled-token logprob up."""
        vocab, b, s = 7, 4, 6
        toks = jax.random.randint(jax.random.PRNGKey(0), (b, s), 0, vocab)
        logits = jnp.zeros((b, s, vocab))
        blp = jnp.full((b, s - 1), -jnp.log(vocab))
        adv = jnp.asarray([1.0, 1.0, -1.0, -1.0])
        mask = jnp.ones((b, s - 1), bool)

        def loss_fn(lg):
            return grpo_loss(lg, toks, blp, adv, mask)[0]

        g = jax.grad(loss_fn)(logits)
        tok_grad = jnp.take_along_axis(g[:, :-1], toks[:, 1:][..., None], axis=-1)[..., 0]
        # gradient descent increases logits where advantage > 0
        assert float(tok_grad[0].sum()) < 0 and float(tok_grad[2].sum()) > 0

    def test_group_advantages_zero_mean(self):
        r = jnp.asarray([1.0, 0.0, 3.0, 2.0])
        adv = group_relative_advantages(r, group_size=2)
        np.testing.assert_allclose(np.asarray(adv.reshape(2, 2).mean(1)), 0.0, atol=1e-6)


class TestGradAccumulation:
    def test_accum_matches_full_batch(self):
        cfg = get_config("llama3-8b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0), jnp.float32)
        opt = AdamW(lr=1e-2, weight_decay=0.0, grad_clip=0.0)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0, cfg.vocab)
        step1 = jax.jit(make_train_step(model, cfg, opt, accum=1))
        step2 = jax.jit(make_train_step(model, cfg, opt, accum=2))
        p1, _, _ = step1(params, opt.init(params), {"tokens": toks})
        p2, _, _ = step2(params, opt.init(params), {"tokens": toks})
        # accumulation order differs -> tolerate float reassociation noise
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-4)


class TestLossGoesDown:
    def test_bigram_learnable(self):
        cfg = get_config("llama3-8b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0), jnp.float32)
        opt = AdamW(lr=3e-3, weight_decay=0.0)
        step = jax.jit(make_train_step(model, cfg, opt))
        state = opt.init(params)
        stream = BigramStream(vocab=cfg.vocab, seq_len=32, batch=8, seed=0, branching=2)
        losses = []
        for _ in range(30):
            batch = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
            params, state, m = step(params, state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] * 0.75, losses


class TestCheckpoint:
    def test_roundtrip_and_latest(self, tmp_path):
        tree = {"a": jnp.arange(10, dtype=jnp.float32), "b": [jnp.ones((3, 3)), jnp.zeros(2)]}
        ckpt.save(str(tmp_path), 5, tree, metadata={"stream_offset": 42})
        ckpt.save(str(tmp_path), 9, jax.tree.map(lambda x: x + 1, tree))
        assert ckpt.latest_step(str(tmp_path)) == 9
        restored, step, meta = ckpt.restore(str(tmp_path), tree, step=5)
        assert step == 5 and meta["stream_offset"] == 42
        np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(10, dtype=np.float32))

    def test_no_partial_checkpoint_visible(self, tmp_path):
        tree = {"w": jnp.ones(4)}
        ckpt.save(str(tmp_path), 1, tree)
        # a stale tmp dir from a crashed save must not affect LATEST
        os.makedirs(tmp_path / "step_00000002.tmp")
        assert ckpt.latest_step(str(tmp_path)) == 1

    def test_stream_resumes_deterministically(self):
        s1 = BigramStream(vocab=64, seq_len=8, batch=2, seed=3)
        batches = [s1.next_batch()["tokens"] for _ in range(5)]
        s2 = BigramStream(vocab=64, seq_len=8, batch=2, seed=3, offset=3)
        np.testing.assert_array_equal(s2.next_batch()["tokens"], batches[3])

    def test_prompt_reward_range(self):
        ps = PromptSet(vocab=64, prompt_len=4, seed=0)
        seqs = ps.sample(6, step=0)
        full = np.concatenate([seqs, seqs[:, -1:]], axis=1)
        r = ps.reward(full, prompt_len=4)
        assert r.shape == (6,) and np.all((0 <= r) & (r <= 1))


class TestShardingRules:
    MESH = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))

    def test_divisibility_fallback(self):
        # gemma2: 4 kv heads cannot shard 16 ways -> replicated
        spec = spec_for((4, 32, 256), ("kv_heads", None, "head_dim"), TRAIN_RULES, self.MESH)
        assert spec == PartitionSpec(None, None, "model")

    def test_first_fit_conflict(self):
        # [experts, embed, expert_mlp]: experts takes model; expert_mlp skipped
        spec = spec_for((16, 7168, 2048), ("experts", "embed", "expert_mlp"), TRAIN_RULES, self.MESH)
        assert spec == PartitionSpec("model", ("pod", "data"), None)

    def test_serve_ep_over_two_axes(self):
        spec = spec_for((256, 7168, 2048), ("experts", "embed", "expert_mlp"), SERVE_RULES, self.MESH)
        assert spec == PartitionSpec(("data", "model"), None, None)

    def test_single_pod_mesh_drops_pod_axis(self):
        mesh = make_abstract_mesh((16, 16), ("data", "model"))
        spec = spec_for((256, 4096), ("batch", None), TRAIN_RULES, mesh)
        assert spec == PartitionSpec("data", None)


class TestHloAnalyzer:
    def test_scan_trip_count_multiplies_flops(self):
        from repro.launch.hlo_analyzer import analyze

        k = jnp.ones((64, 64), jnp.float32)

        def f(x):
            def body(c, _):
                return c @ k, None

            out, _ = jax.lax.scan(body, x, None, length=17)
            return out

        compiled = jax.jit(f).lower(jnp.ones((64, 64))).compile()
        costs = analyze(compiled.as_text())
        expected = 17 * 2 * 64 * 64 * 64
        assert abs(costs.dot_flops - expected) / expected < 0.01

    def test_collective_parse(self):
        from repro.launch.hlo_analyzer import analyze

        hlo = """
HloModule test

ENTRY %main (p: f32[16,8]) -> f32[16,8] {
  %p = f32[16,8]{1,0} parameter(0)
  %ag = f32[32,8]{1,0} all-gather(%p), channel_id=1, replica_groups={{0,1}}, dimensions={0}
  ROOT %ar = f32[16,8]{1,0} all-reduce(%p), channel_id=2, replica_groups={{0,1}}, to_apply=%add
}
"""
        costs = analyze(hlo)
        assert costs.collective_bytes["all-gather"] == 32 * 8 * 4
        assert costs.collective_bytes["all-reduce"] == 16 * 8 * 4
