"""Multi-source transfer scheduler tests: unit-list partitioning,
topology preference, re-partitioning on source death, work stealing,
per-source-shard reader accounting — server-level (no threads, no sim),
plus threaded-client end-to-end pulls with windows and chunking."""

import threading
import time

import numpy as np
import pytest

from repro.core import ReferenceServer, TensorHubClient
from repro.core.meta import ShardManifest, TensorMeta, TransferUnit, WorkerInfo
from repro.core.server import PUBLISHED, Assignment, SourceSlice

GB = 10**9


def manifest(n_units=8, unit_bytes=100):
    tensors = tuple(
        TensorMeta(f"t{i}", (unit_bytes,), "uint8", unit_bytes) for i in range(n_units)
    )
    units = tuple(
        TransferUnit(index=i, name=f"t{i}", nbytes=unit_bytes) for i in range(n_units)
    )
    return ShardManifest(tensors=tensors, units=units, checksums=(0,) * n_units)


def worker(replica, shard, dc="dc0", node=None):
    return WorkerInfo(
        f"{replica}/s{shard}", node or f"{dc}/{replica}", dc, False
    )


def open_replica(s, name, shards=2, dc="dc0", node=None):
    for i in range(shards):
        s.open("m", name, shards, i, worker=worker(name, i, dc, node))
        s.register("m", name, i)


def publish(s, name, version, shards=2, op=0, n_units=8, unit_bytes=100):
    for i in range(shards):
        s.publish(
            "m", name, i, version, manifest(n_units, unit_bytes), op_id=op
        )


def assign(s, name, spec=0, op=0, shards=2):
    a = None
    for i in range(shards):
        a = s.begin_replicate("m", name, i, spec, op_id=op)
    return a


def ranges_of(a: Assignment):
    return [(sl.source, sl.start_unit, sl.stop_unit) for sl in a.sources]


class TestPartitioning:
    def test_tiles_unit_list_exactly(self):
        s = ReferenceServer()
        for name in ("a", "b", "c"):
            open_replica(s, name)
            publish(s, name, 0)
        open_replica(s, "r")
        a = assign(s, "r")
        assert len(a.sources) == 3
        pos = 0
        for sl in a.sources:
            assert sl.start_unit == pos
            assert sl.stop_unit >= sl.start_unit
            pos = sl.stop_unit
        assert pos == 8  # tiles [0, 8) with no gaps or overlaps

    def test_fairness_unequal_loads(self):
        """A source already serving readers gets a smaller unit range."""
        s = ReferenceServer()
        for name in ("a", "b"):
            open_replica(s, name)
            publish(s, name, 0)
        # load source "a" with two extra reader sessions (harness hook)
        s._models["m"].versions[0]["a"].refcount += 2  # noqa: SLF001
        open_replica(s, "r")
        a = assign(s, "r")
        share = {sl.source: sl.stop_unit - sl.start_unit for sl in a.sources}
        assert share["b"] > share["a"]  # least-loaded gets the bigger range
        assert a.sources[0].source == "b"  # ...and the head of the list
        assert sum(share.values()) == 8

    def test_single_source_mode_disables_partitioning(self):
        s = ReferenceServer(max_sources=1)
        for name in ("a", "b"):
            open_replica(s, name)
            publish(s, name, 0)
        open_replica(s, "r")
        a = assign(s, "r")
        assert len(a.sources) <= 1

    def test_more_sources_than_units_adds_empty_ranges(self):
        """With fewer units than sources the extras join with empty
        ranges so chunking can still spread a giant unit across them."""
        s = ReferenceServer()
        for name in ("a", "b", "c"):
            open_replica(s, name)
            publish(s, name, 0, n_units=2)
        open_replica(s, "r")
        a = assign(s, "r")
        assert len(a.sources) == 3
        assert sum(sl.stop_unit - sl.start_unit for sl in a.sources) == 2

    def test_slices_normalize_legacy_assignment(self):
        a = Assignment(
            version=0, source="x", source_kind="gpu", transport="rdma"
        )
        (sl,) = a.slices(5)
        assert (sl.source, sl.start_unit, sl.stop_unit) == ("x", 0, 5)
        open_ended = Assignment(
            version=0, source="x", source_kind="gpu", transport="rdma",
            sources=(SourceSlice("x", "gpu", "rdma", 2, -1),),
        )
        (sl,) = open_ended.slices(7)
        assert (sl.start_unit, sl.stop_unit) == (2, 7)


class TestTopology:
    def test_same_node_preferred_over_same_dc(self):
        s = ReferenceServer()
        open_replica(s, "near", node="dc0/shared-node")
        open_replica(s, "far", node="dc0/other-node")
        publish(s, "near", 0)
        publish(s, "far", 0)
        open_replica(s, "r", node="dc0/shared-node")
        a = assign(s, "r")
        assert a.sources[0].source == "near"  # same-node serves the head
        assert {sl.source for sl in a.sources} == {"near", "far"}

    def test_cross_dc_replicas_never_in_partition(self):
        s = ReferenceServer()
        open_replica(s, "local", dc="dc1")
        open_replica(s, "remote", dc="dc0")
        publish(s, "local", 0)
        publish(s, "remote", 0)
        open_replica(s, "r", dc="dc1")
        a = assign(s, "r")
        assert all(sl.source == "local" for sl in a.slices(8))

    def test_only_cross_dc_falls_back_to_seeding(self):
        s = ReferenceServer()
        for name in ("far1", "far2"):
            open_replica(s, name, dc="dc0")
            publish(s, name, 0)
        open_replica(s, "r", dc="dc1")
        a = assign(s, "r")
        assert len(a.sources) == 1 and a.seeding and a.transport == "tcp"


class TestRepartition:
    def test_source_death_repartitions_remaining_units(self):
        s = ReferenceServer()
        for name in ("a", "b", "c"):
            open_replica(s, name)
            publish(s, name, 0)
        open_replica(s, "r")
        a = assign(s, "r")
        assert len(a.sources) == 3 and a.epoch == 0
        for i in range(2):
            s.update_progress("m", "r", i, 0, 3)  # completed prefix [0, 3)
        dead = a.sources[0].source
        s.report_transfer_failure("m", "r", dead)
        b = s.get_assignment("m", "r")
        assert b.epoch > a.epoch
        assert dead not in {sl.source for sl in b.sources}
        assert min(sl.start_unit for sl in b.sources) == 3  # resumes at prefix
        assert max(sl.stop_unit for sl in b.sources) == 8

    def test_refcounts_released_on_complete(self):
        s = ReferenceServer()
        for name in ("a", "b"):
            open_replica(s, name)
            publish(s, name, 0)
        open_replica(s, "r")
        assign(s, "r")
        vmap = s._models["m"].versions[0]  # noqa: SLF001
        assert vmap["a"].refcount == 1 and vmap["b"].refcount == 1
        assert vmap["a"].shard_readers == {0: 1, 1: 1}
        for i in range(2):
            s.complete_replicate("m", "r", i, 0, op_id=1)
        assert vmap["a"].refcount == 0 and vmap["b"].refcount == 0
        assert vmap["a"].shard_readers == {0: 0, 1: 0}

    def test_epoch_stable_without_repartition(self):
        s = ReferenceServer()
        for name in ("a", "b"):
            open_replica(s, name)
            publish(s, name, 0)
        open_replica(s, "r")
        assign(s, "r")
        for p in range(1, 5):
            for i in range(2):
                s.update_progress("m", "r", i, 0, p)
        assert s.assignment_epoch("m", "r", 0) == 0


class TestWorkStealing:
    def _contended_reader(self, s):
        """One publisher, two readers pinned to it (no pipeline chains):
        the published source is contended (refcount 2)."""
        open_replica(s, "a")
        publish(s, "a", 0)
        for r in ("r1", "r2"):
            open_replica(s, r)
            assign(s, r)

    def test_late_source_gets_remaining_units(self):
        s = ReferenceServer(pipeline_replication=False)
        self._contended_reader(s)
        open_replica(s, "late")
        publish(s, "late", 0, op=7)
        # the steal fires on the reader's next progress report
        for i in range(2):
            s.update_progress("m", "r1", i, 0, 2)
        a = s.get_assignment("m", "r1")
        assert {sl.source for sl in a.sources} == {"a", "late"}
        # the steal fired on the first shard's report, when the group's
        # min progress was still 0: the new plan re-covers [0, 8) and the
        # reader resumes from its own completed prefix
        assert min(sl.start_unit for sl in a.sources) == 0
        assert max(sl.stop_unit for sl in a.sources) == 8
        assert a.epoch == 1
        assert s.stats["work_steals"] >= 1

    def test_no_steal_when_disabled(self):
        s = ReferenceServer(pipeline_replication=False, work_stealing=False)
        self._contended_reader(s)
        open_replica(s, "late")
        publish(s, "late", 0, op=7)
        for i in range(2):
            s.update_progress("m", "r1", i, 0, 2)
        assert s.assignment_epoch("m", "r1", 0) == 0
        assert s.stats["work_steals"] == 0

    def test_dedicated_chain_not_broken(self):
        """A reader relaying off a dedicated (refcount-1) source keeps it:
        re-planning a healthy fine-grained chain would only add churn."""
        s = ReferenceServer(pipeline_replication=False)
        open_replica(s, "a")
        publish(s, "a", 0)
        open_replica(s, "r1")
        assign(s, "r1")  # sole reader of "a"
        open_replica(s, "late")
        publish(s, "late", 0, op=7)
        for i in range(2):
            s.update_progress("m", "r1", i, 0, 2)
        assert s.assignment_epoch("m", "r1", 0) == 0


class TestPinnedScheduler:
    def test_every_reader_hits_first_candidate(self):
        s = ReferenceServer(scheduler="pinned", max_sources=1)
        for name in ("a", "b"):
            open_replica(s, name)
            publish(s, name, 0)
        srcs = set()
        for r in ("r1", "r2", "r3"):
            open_replica(s, r)
            srcs.add(assign(s, r).source)
        assert srcs == {"a"}  # no load balancing: the naive baseline


# ---------------------------------------------------------------------------
# threaded client end-to-end: windows + chunks move real, verified bytes
# ---------------------------------------------------------------------------


def tensors(seed: float):
    rng = np.random.default_rng(int(seed))
    return {
        # one tensor above the (tiny) chunk threshold, several below
        "big": rng.integers(0, 255, size=(64, 1024), dtype=np.uint8),
        "w0": np.full((32, 16), seed, dtype=np.float32),
        "w1": np.full((32, 16), seed + 1, dtype=np.float32),
    }


def group(hub, name, shards, make, **kw):
    handles = [hub.open("m", name, shards, i, **kw) for i in range(shards)]
    for h in handles:
        h.register(make())
    return handles


def run_group(handles, fn):
    errs = []

    def wrap(h):
        try:
            fn(h)
        except BaseException as e:  # noqa: BLE001
            errs.append(e)

    ts = [threading.Thread(target=wrap, args=(h,)) for h in handles]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
    if errs:
        raise errs[0]


class TestThreadedWindowedPull:
    def test_multi_source_window_bit_identical(self):
        """window>1 + chunking + two sources: bytes must be bit-identical
        with checksums verified end to end."""
        server = ReferenceServer()
        hub = TensorHubClient(server, window=3, chunk_bytes=4096)
        pubs = group(hub, "pub", 2, lambda: tensors(7.0))
        run_group(pubs, lambda h: h.publish(0))
        mirror = group(hub, "mirror", 2, lambda: tensors(0.0))
        run_group(mirror, lambda h: h.replicate(0))  # second published copy
        subs = group(hub, "sub", 2, lambda: tensors(1.0))
        run_group(subs, lambda h: h.replicate(0))
        assert server.stats["multi_source_assignments"] >= 1
        want = tensors(7.0)
        for h in subs:
            for name, arr in want.items():
                assert np.array_equal(h.store.get(name), arr), name

    def test_window_pull_single_source_identical(self):
        server = ReferenceServer()
        hub = TensorHubClient(server, window=4, chunk_bytes=1 << 14)
        pubs = group(hub, "pub", 1, lambda: tensors(3.0))
        run_group(pubs, lambda h: h.publish(0))
        subs = group(hub, "sub", 1, lambda: tensors(0.0))
        run_group(subs, lambda h: h.replicate("latest"))
        want = tensors(3.0)
        for name, arr in want.items():
            assert np.array_equal(subs[0].store.get(name), arr), name

    def test_windowed_pull_survives_source_death(self):
        """Kill one of two sources mid-transfer: the reader re-partitions
        onto the survivor and still produces bit-identical bytes."""
        server = ReferenceServer()
        hub = TensorHubClient(server, window=2, chunk_bytes=4096)
        pubs = group(hub, "pub", 1, lambda: tensors(9.0))
        run_group(pubs, lambda h: h.publish(0))
        mirror = group(hub, "mirror", 1, lambda: tensors(0.0))
        run_group(mirror, lambda h: h.replicate(0))
        killed = threading.Event()

        def killer():
            time.sleep(0.05)
            hub.registry.fail_replica("mirror")
            with hub._cv:  # noqa: SLF001 — test harness failure injection
                server.fail_replica("m", "mirror", reason="test kill")
            killed.set()

        t = threading.Thread(target=killer, daemon=True)
        t.start()
        subs = group(hub, "sub", 1, lambda: tensors(0.0))
        run_group(subs, lambda h: h.replicate(0))
        t.join(timeout=10)
        assert killed.is_set()
        want = tensors(9.0)
        for name, arr in want.items():
            assert np.array_equal(subs[0].store.get(name), arr), name

    def test_legacy_window1_path_still_works(self):
        server = ReferenceServer(max_sources=1)
        hub = TensorHubClient(server, window=1, chunk_bytes=None)
        pubs = group(hub, "pub", 2, lambda: tensors(5.0))
        run_group(pubs, lambda h: h.publish(0))
        subs = group(hub, "sub", 2, lambda: tensors(0.0))
        run_group(subs, lambda h: h.replicate("latest"))
        for h in subs:
            assert np.array_equal(h.store.get("big"), tensors(5.0)["big"])
