"""Cross-layout resharding: planner tiling properties over the paper
workload configs, end-to-end reshard-replicate bytes equality, repack
kernel-vs-ref parity, and failure re-planning in virtual time."""

import threading

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.paper_workloads import WORKLOADS
from repro.core import ReferenceServer, ShardLayoutError, TensorHubClient
from repro.core.meta import ShardManifest, TensorMeta, build_units
from repro.resharding import (
    layout_from_manifests,
    plan_reshard,
    plan_shard,
    tp_shard,
)
from repro.transfer.simcluster import SimCluster, make_layout_manifests

TP_DEGREES = [1, 2, 3, 4, 8]


def model_tensors(seed=0):
    """A small model with mixed ranks: dim-0 shardable, dim-1 shardable
    (first dim indivisible by most TPs), and a replicated odd-size bias."""
    rng = np.random.default_rng(seed)
    return {
        "wqkv": rng.standard_normal((24, 16)).astype(np.float32),
        "wout": rng.standard_normal((7, 24)).astype(np.float32),  # dim-1 shard
        "embed": rng.standard_normal((48,)).astype(np.float32),
        "bias": rng.standard_normal((5,)).astype(np.float32),  # replicated
    }


def manifest_for(local, lay, with_checksums=False):
    metas = [
        TensorMeta(
            name=n,
            shape=tuple(a.shape),
            dtype=str(a.dtype),
            nbytes=a.nbytes,
            global_shape=lay[n][0],
            offset=lay[n][1],
        )
        for n, a in local.items()
    ]
    units = build_units(metas)
    return ShardManifest(
        tensors=tuple(metas), units=tuple(units), checksums=(0,) * len(units)
    )


def layouts_for(glob, tp):
    ms = {i: manifest_for(*tp_shard(glob, i, tp)) for i in range(tp)}
    return layout_from_manifests(ms, tp)


class TestPlannerProperties:
    @pytest.mark.parametrize("src_tp", TP_DEGREES)
    @pytest.mark.parametrize("dst_tp", TP_DEGREES)
    def test_exact_tiling_and_value_identity(self, src_tp, dst_tp):
        """Every (source, dest) TP pair: intervals tile each dest tensor
        exactly (validated by the planner) and executing them against the
        source buffers reproduces the dest slices bit for bit."""
        glob = model_tensors()
        plan = plan_reshard(
            layouts_for(glob, src_tp), layouts_for(glob, dst_tp), stripe_min=16
        )
        src_locals = [tp_shard(glob, j, src_tp)[0] for j in range(src_tp)]
        for sp in plan.shards:
            d_local, _ = tp_shard(glob, sp.dest_shard, dst_tp)
            for name, want in d_local.items():
                out = np.zeros(want.nbytes, np.uint8)
                for iv in sp.intervals:
                    if iv.tensor != name:
                        continue
                    src = src_locals[iv.source_shard][name].view(np.uint8).reshape(-1)
                    out[iv.dst_offset : iv.dst_stop] = src[iv.src_offset : iv.src_stop]
                assert np.array_equal(out, want.view(np.uint8).reshape(-1)), (
                    src_tp, dst_tp, sp.dest_shard, name,
                )

    @pytest.mark.parametrize("wname", sorted(WORKLOADS))
    @pytest.mark.parametrize("dst_tp", [2, 8])
    def test_paper_workload_layouts_tile(self, wname, dst_tp):
        """1-D contiguous layouts at paper-workload sizes: plans tile and
        byte totals match the destination's share exactly."""
        w = WORKLOADS[wname]
        units = [b * w.num_shards for b in w.unit_bytes(8)]
        src = layout_from_manifests(
            dict(enumerate(make_layout_manifests(units, w.num_shards))),
            w.num_shards,
        )
        dst = layout_from_manifests(
            dict(enumerate(make_layout_manifests(units, dst_tp))), dst_tp
        )
        plan = plan_reshard(src, dst)
        assert plan.total_bytes == sum(units)
        for sp in plan.shards:
            assert sp.total_bytes == sum(
                m.total_bytes
                for i, m in enumerate(make_layout_manifests(units, dst_tp))
                if i == sp.dest_shard
            )

    @settings(max_examples=25, deadline=None)
    @given(
        src_tp=st.sampled_from(TP_DEGREES),
        dst_tp=st.sampled_from(TP_DEGREES),
        sizes=st.lists(st.integers(64, 4096), min_size=1, max_size=5),
        seed=st.integers(0, 1000),
    )
    def test_random_1d_layouts_tile(self, src_tp, dst_tp, sizes, seed):
        """Property sweep: random global unit sizes, any TP pair — the
        planner's own validation (no gaps/overlaps) must hold and byte
        totals must be conserved."""
        del seed  # layouts are deterministic given sizes; kept for draw variety
        src = layout_from_manifests(
            dict(enumerate(make_layout_manifests(sizes, src_tp))), src_tp
        )
        dst = layout_from_manifests(
            dict(enumerate(make_layout_manifests(sizes, dst_tp))), dst_tp
        )
        plan = plan_reshard(src, dst, stripe_min=32)
        assert plan.total_bytes == sum(sizes)

    def test_striping_across_sources(self):
        """Scale-down: a dest shard's slice spans several source shards;
        the plan must stripe across >= 2 of them (acceptance criterion)."""
        glob = model_tensors()
        plan = plan_reshard(layouts_for(glob, 4), layouts_for(glob, 2), stripe_min=16)
        for sp in plan.shards:
            assert len(sp.source_shards_used) >= 2, sp.dest_shard

    def test_incompatible_layouts_raise(self):
        glob = model_tensors()
        other = {k: v for k, v in glob.items() if k != "bias"}
        with pytest.raises(ShardLayoutError):
            plan_reshard(layouts_for(other, 2), layouts_for(glob, 4))
        # same names, different global shape
        resized = dict(glob)
        resized["embed"] = np.zeros((64,), np.float32)
        with pytest.raises(ShardLayoutError):
            plan_reshard(layouts_for(resized, 2), layouts_for(glob, 2))

    def test_missing_descriptor_needs_identical_shape(self):
        """No layout metadata -> treated as replicated; convertible only
        when local shapes agree."""
        a = {0: manifest_for({"w": np.zeros((4, 4), np.float32)},
                             {"w": (None, None)})}
        b = {0: manifest_for({"w": np.zeros((2, 4), np.float32)},
                             {"w": (None, None)})}
        with pytest.raises(ShardLayoutError):
            plan_shard(layout_from_manifests(a, 1), layout_from_manifests(b, 1), 0)


# ---------------------------------------------------------------------------
# end-to-end: threaded client
# ---------------------------------------------------------------------------


def run_group(handles, fn):
    errs = []

    def wrap(h):
        try:
            fn(h)
        except BaseException as e:  # noqa: BLE001
            errs.append(e)

    ts = [threading.Thread(target=wrap, args=(h,)) for h in handles]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
    if errs:
        raise errs[0]


def open_tp_group(hub, name, tp, glob, *, zeros=False, **kw):
    handles = [hub.open("m", name, tp, i, **kw) for i in range(tp)]
    for h in handles:
        local, lay = tp_shard(glob, h.shard_idx, tp)
        if zeros:
            local = {n: np.zeros_like(a) for n, a in local.items()}
        h.register(local, layout=lay)
    return handles


class TestEndToEndReshard:
    @pytest.mark.parametrize("src_tp,dst_tp", [(4, 2), (2, 4), (2, 3)])
    def test_reshard_replicate_bytes_equal(self, src_tp, dst_tp):
        """A dest replica with a different TP degree completes replicate()
        with bit-identical reassembled tensors, striping interval reads
        across the source shards."""
        glob = model_tensors()
        hub = TensorHubClient(ReferenceServer())
        pubs = open_tp_group(hub, "pub", src_tp, glob)
        run_group(pubs, lambda h: h.publish(0))

        pulled = []
        orig = hub.transport.read_unit_range

        def spy(src_replica, src_shard, *a, **kw):
            pulled.append(src_shard)
            return orig(src_replica, src_shard, *a, **kw)

        hub.transport.read_unit_range = spy
        subs = open_tp_group(hub, "sub", dst_tp, glob, zeros=True)
        got = []
        run_group(subs, lambda h: got.append(h.replicate("latest")))
        assert got == [0] * dst_tp
        for h in subs:
            want, _ = tp_shard(glob, h.shard_idx, dst_tp)
            for n, arr in want.items():
                np.testing.assert_array_equal(h.store.get(n), arr)
        if src_tp > dst_tp:
            # scale-down: interval reads touched >= 2 distinct source shards
            assert len(set(pulled)) >= 2
        assert all(h.intervals_pulled > 0 for h in subs)

    def test_device_repack_path(self):
        """Pallas-kernel repack produces the same bytes as the NumPy path."""
        glob = model_tensors(seed=3)
        hub = TensorHubClient(ReferenceServer())
        pubs = open_tp_group(hub, "pub", 4, glob)
        run_group(pubs, lambda h: h.publish(0))
        subs = open_tp_group(hub, "sub", 2, glob, zeros=True, device_repack=True)
        run_group(subs, lambda h: h.replicate(0))
        for h in subs:
            want, _ = tp_shard(glob, h.shard_idx, 2)
            for n, arr in want.items():
                np.testing.assert_array_equal(h.store.get(n), arr)

    def test_same_shard_count_different_axes_reshards(self):
        """Equal shard counts do NOT imply equal layouts: a dest sharded
        along a different axis than the source must take the reshard path
        (unit-for-unit copying would silently scramble weights)."""
        rng = np.random.default_rng(11)
        glob = {"w": rng.standard_normal((8, 8)).astype(np.float32)}
        hub = TensorHubClient(ReferenceServer())
        pubs = [hub.open("m", "rows", 4, i) for i in range(4)]
        for h in pubs:  # axis-0 sharding
            local, lay = tp_shard(glob, h.shard_idx, 4)
            h.register(local, layout=lay)
        run_group(pubs, lambda h: h.publish(0))
        subs = [hub.open("m", "cols", 4, i) for i in range(4)]
        for h in subs:  # axis-1 sharding, same shard count
            local, lay = tp_shard(glob, h.shard_idx, 4, axis_overrides={"w": 1})
            h.register({n: np.zeros_like(a) for n, a in local.items()}, layout=lay)
        run_group(subs, lambda h: h.replicate(0))
        for h in subs:
            want, _ = tp_shard(glob, h.shard_idx, 4, axis_overrides={"w": 1})
            np.testing.assert_array_equal(h.store.get("w"), want["w"])
        assert all(h.intervals_pulled > 0 for h in subs)  # reshard path ran

    def test_resharded_replica_serves_same_layout_reader(self):
        """A replica materialized via reshard serves a later same-layout
        reader through the plain unit pipe (its manifest family was
        registered at put_manifest time)."""
        glob = model_tensors(seed=5)
        hub = TensorHubClient(ReferenceServer())
        pubs = open_tp_group(hub, "pub", 4, glob)
        run_group(pubs, lambda h: h.publish(0))
        first = open_tp_group(hub, "r1", 2, glob, zeros=True)
        run_group(first, lambda h: h.replicate(0))
        second = open_tp_group(hub, "r2", 2, glob, zeros=True)
        run_group(second, lambda h: h.replicate(0))
        for h in second:
            want, _ = tp_shard(glob, h.shard_idx, 2)
            for n, arr in want.items():
                np.testing.assert_array_equal(h.store.get(n), arr)


# ---------------------------------------------------------------------------
# virtual time: failure re-planning + stall accounting
# ---------------------------------------------------------------------------


class TestSimReshard:
    def test_reshard_completes_and_stripes_bandwidth(self):
        units = [int(2e9)] * 4
        cl = SimCluster()
        tr = cl.add_replica("m", "tr0", 4, global_unit_bytes=units)
        ro = cl.add_replica("m", "ro0", 2, global_unit_bytes=units)
        tr.open()
        ro.open()
        cl.run()
        tr.publish(0)
        cl.run()
        ev = ro.replicate("latest")
        cl.run()
        assert ev.triggered and ev.error is None
        assert all(s.worker.total_stall > 0 for s in ro.shards)

    def test_source_death_mid_reshard_replans(self):
        """Kill the assigned source mid-reshard: the reader re-routes to a
        surviving replica with ANOTHER layout and still completes."""
        units = [int(2e9)] * 4
        cl = SimCluster()
        tr = cl.add_replica("m", "tr0", 4, global_unit_bytes=units)
        sa = cl.add_replica("m", "sa0", 2, global_unit_bytes=units)
        ro = cl.add_replica("m", "ro0", 8, global_unit_bytes=units)
        for r in (tr, sa, ro):
            r.open()
        cl.run()
        tr.publish(0)
        cl.run()
        sa.replicate("latest")
        cl.run()
        ev = ro.replicate("latest")
        cl.env.schedule(0.1, lambda: cl.kill_replica("tr0"))
        cl.run()
        assert ev.triggered and ev.error is None


# ---------------------------------------------------------------------------
# repack kernel parity
# ---------------------------------------------------------------------------


class TestRepackKernel:
    @settings(max_examples=20, deadline=None)
    @given(
        out_nbytes=st.integers(1, 8192),
        seed=st.integers(0, 10_000),
    )
    def test_kernel_matches_ref(self, out_nbytes, seed):
        from repro.kernels.repack import (
            random_instructions,
            repack_bytes,
            repack_ref,
        )

        rng = np.random.default_rng(seed)
        instrs = random_instructions(rng, out_nbytes)
        staging = rng.integers(
            0, 256, sum(n for _, _, n in instrs), dtype=np.uint8
        )
        got = np.asarray(repack_bytes(staging, instrs, out_nbytes, interpret=True))
        np.testing.assert_array_equal(got, repack_ref(staging, instrs, out_nbytes))

    def test_gather_ref_matches_kernel(self):
        from repro.kernels.repack import gather_bytes, gather_ref
        import jax.numpy as jnp

        rng = np.random.default_rng(0)
        staging = jnp.asarray(rng.integers(0, 256, 1024, dtype=np.uint8))
        idx = jnp.asarray(rng.integers(0, 1024, 3000, dtype=np.int32))
        np.testing.assert_array_equal(
            np.asarray(gather_bytes(staging, idx, interpret=True)),
            np.asarray(gather_ref(staging, idx)),
        )

    def test_executor_kernel_vs_numpy(self):
        """Full executor repack: kernel path == NumPy path on a real plan."""
        from repro.resharding import ReshardExecutor

        glob = model_tensors(seed=7)
        src = layouts_for(glob, 4)
        dst = layouts_for(glob, 2)
        local, lay = tp_shard(glob, 0, 2)
        manifest = manifest_for(local, lay)
        plan = plan_shard(src, dst, 0, stripe_min=16, num_dest_units=manifest.num_units)
        ex_np = ReshardExecutor(plan, manifest, use_kernel=False)
        ex_k = ReshardExecutor(plan, manifest, use_kernel=True)
        rng = np.random.default_rng(1)
        for unit, placed in ex_np.unit_batches():
            staging = rng.integers(
                0, 256, ex_np.staging_bytes(unit.index), dtype=np.uint8
            )
            np.testing.assert_array_equal(
                ex_np.repack(unit.index, staging), ex_k.repack(unit.index, staging)
            )
