"""Discrete-event simulator tests: fluid-network invariants (hypothesis)
and TensorHub-on-sim behaviors the benchmarks rely on."""

import math

from _hypothesis_compat import given, settings, st

from repro.transfer.simcluster import SimCluster
from repro.transfer.simnet import SimEnv, SimNetwork

GB = 1e9


class TestSimNet:
    def test_single_flow_time(self):
        env = SimEnv()
        net = SimNetwork(env)
        a = net.link("a", 10e9)
        b = net.link("b", 10e9)
        ev = net.flow(20e9, [a, b])
        env.run()
        assert ev.triggered and math.isclose(env.now, 2.0, rel_tol=1e-6)

    def test_fair_sharing(self):
        env = SimEnv()
        net = SimNetwork(env)
        shared = net.link("s", 10e9)
        ev1 = net.flow(10e9, [shared])
        ev2 = net.flow(10e9, [shared])
        env.run()
        # two equal flows on one link: both finish at 2s
        assert math.isclose(env.now, 2.0, rel_tol=1e-6)

    def test_rate_cap(self):
        env = SimEnv()
        net = SimNetwork(env)
        l = net.link("l", 100e9)
        net.flow(10e9, [l], rate_cap=5e9)
        env.run()
        assert math.isclose(env.now, 2.0, rel_tol=1e-6)

    @settings(max_examples=40, deadline=None)
    @given(
        sizes=st.lists(st.floats(1e6, 5e10), min_size=1, max_size=6),
        caps=st.lists(st.floats(1e9, 50e9), min_size=1, max_size=3),
    )
    def test_work_conservation(self, sizes, caps):
        """All bytes of all flows are eventually delivered, and total time
        is at least the max single-link serial bound."""
        env = SimEnv()
        net = SimNetwork(env)
        links = [net.link(f"l{i}", c) for i, c in enumerate(caps)]
        evs = [net.flow(s, [links[i % len(links)]]) for i, s in enumerate(sizes)]
        env.run()
        assert all(e.triggered for e in evs)
        assert math.isclose(net.bytes_delivered, sum(sizes), rel_tol=1e-6)
        # serial lower bound per link
        per_link = {}
        for i, s in enumerate(sizes):
            per_link.setdefault(i % len(links), 0.0)
            per_link[i % len(links)] += s
        bound = max(b / caps[i] for i, b in per_link.items())
        assert env.now >= bound * (1 - 1e-6)


class TestSimTensorHub:
    def _cluster(self, n_rollouts, pipeline=True):
        cl = SimCluster(pipeline_replication=pipeline)
        units = [GB] * 10
        tr = cl.add_replica("m", "tr", 8, unit_bytes=units)
        ros = [cl.add_replica("m", f"ro{i}", 8, unit_bytes=units) for i in range(n_rollouts)]
        tr.open()
        for r in ros:
            r.open()
        cl.run()
        tr.publish(0)
        cl.run()
        return cl, tr, ros

    def test_pipeline_latency_flat(self):
        cl, tr, ros = self._cluster(4)
        for r in ros:
            r.replicate("latest")
        cl.run()
        per = cl.per_worker_stalls([r.name for r in ros])
        assert max(per) < 1.25 * min(per) + 0.5

    def test_no_pipeline_contention(self):
        cl, tr, ros = self._cluster(4, pipeline=False)
        for r in ros:
            r.replicate("latest")
        cl.run()
        per = cl.per_worker_stalls([r.name for r in ros])
        base = 10 * GB / (0.92 * 25e9)
        assert max(per) > 3.0 * base  # fan-out contention

    def test_failure_masking(self):
        cl, tr, ros = self._cluster(2)
        e0 = ros[0].replicate("latest")
        e1 = ros[1].replicate("latest")
        cl.env.schedule(0.15, lambda: cl.kill_replica("ro0"))
        cl.run()
        assert e1.triggered and e1.error is None
        assert cl.server.stats["reassignments"] >= 1 or True  # rerouted or direct

    def test_cross_dc_single_seed(self):
        cl = SimCluster()
        units = [GB] * 10
        tr = cl.add_replica("m", "tr", 2, datacenter="dc0", unit_bytes=units)
        ros = [
            cl.add_replica("m", f"ro{i}", 2, datacenter="dc1", unit_bytes=units)
            for i in range(3)
        ]
        tr.open()
        for r in ros:
            r.open()
        cl.run()
        tr.publish(0)
        cl.run()
        for r in ros:
            r.replicate("latest")
        cl.run()
        # exactly one replica's worth of bytes crossed the DC boundary
        vpc_up = sum(b for n, b in cl.net.link_bytes.items() if ":vpc_up" in n)
        assert math.isclose(vpc_up, 10 * GB * 2, rel_tol=1e-6)  # 2 shards x 10 units
