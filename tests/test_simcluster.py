"""Discrete-event simulator tests: fluid-network invariants (hypothesis)
and TensorHub-on-sim behaviors the benchmarks rely on."""

import math

from _hypothesis_compat import given, settings, st

from repro.core.meta import WorkerInfo
from repro.transfer.simcluster import SimCluster
from repro.transfer.simnet import SimEnv, SimNetwork

GB = 1e9


class TestSimNet:
    def test_single_flow_time(self):
        env = SimEnv()
        net = SimNetwork(env)
        a = net.link("a", 10e9)
        b = net.link("b", 10e9)
        ev = net.flow(20e9, [a, b])
        env.run()
        assert ev.triggered and math.isclose(env.now, 2.0, rel_tol=1e-6)

    def test_fair_sharing(self):
        env = SimEnv()
        net = SimNetwork(env)
        shared = net.link("s", 10e9)
        ev1 = net.flow(10e9, [shared])
        ev2 = net.flow(10e9, [shared])
        env.run()
        # two equal flows on one link: both finish at 2s
        assert math.isclose(env.now, 2.0, rel_tol=1e-6)

    def test_rate_cap(self):
        env = SimEnv()
        net = SimNetwork(env)
        l = net.link("l", 100e9)
        net.flow(10e9, [l], rate_cap=5e9)
        env.run()
        assert math.isclose(env.now, 2.0, rel_tol=1e-6)

    @settings(max_examples=40, deadline=None)
    @given(
        sizes=st.lists(st.floats(1e6, 5e10), min_size=1, max_size=6),
        caps=st.lists(st.floats(1e9, 50e9), min_size=1, max_size=3),
    )
    def test_work_conservation(self, sizes, caps):
        """All bytes of all flows are eventually delivered, and total time
        is at least the max single-link serial bound."""
        env = SimEnv()
        net = SimNetwork(env)
        links = [net.link(f"l{i}", c) for i, c in enumerate(caps)]
        evs = [net.flow(s, [links[i % len(links)]]) for i, s in enumerate(sizes)]
        env.run()
        assert all(e.triggered for e in evs)
        assert math.isclose(net.bytes_delivered, sum(sizes), rel_tol=1e-6)
        # serial lower bound per link
        per_link = {}
        for i, s in enumerate(sizes):
            per_link.setdefault(i % len(links), 0.0)
            per_link[i % len(links)] += s
        bound = max(b / caps[i] for i, b in per_link.items())
        assert env.now >= bound * (1 - 1e-6)


class TestSimTensorHub:
    def _cluster(self, n_rollouts, pipeline=True):
        cl = SimCluster(pipeline_replication=pipeline)
        units = [GB] * 10
        tr = cl.add_replica("m", "tr", 8, unit_bytes=units)
        ros = [cl.add_replica("m", f"ro{i}", 8, unit_bytes=units) for i in range(n_rollouts)]
        tr.open()
        for r in ros:
            r.open()
        cl.run()
        tr.publish(0)
        cl.run()
        return cl, tr, ros

    def test_pipeline_latency_flat(self):
        cl, tr, ros = self._cluster(4)
        for r in ros:
            r.replicate("latest")
        cl.run()
        per = cl.per_worker_stalls([r.name for r in ros])
        assert max(per) < 1.25 * min(per) + 0.5

    def test_no_pipeline_contention(self):
        cl, tr, ros = self._cluster(4, pipeline=False)
        for r in ros:
            r.replicate("latest")
        cl.run()
        per = cl.per_worker_stalls([r.name for r in ros])
        base = 10 * GB / (0.92 * 25e9)
        assert max(per) > 3.0 * base  # fan-out contention

    def test_failure_masking(self):
        cl, tr, ros = self._cluster(2)
        e0 = ros[0].replicate("latest")
        e1 = ros[1].replicate("latest")
        cl.env.schedule(0.15, lambda: cl.kill_replica("ro0"))
        cl.run()
        assert e1.triggered and e1.error is None
        assert cl.server.stats["reassignments"] >= 1 or True  # rerouted or direct

    def _cross_dc_wan_bytes(self, **kw):
        cl = SimCluster(**kw)
        units = [GB] * 10
        tr = cl.add_replica("m", "tr", 2, datacenter="dc0", unit_bytes=units)
        ros = [
            cl.add_replica("m", f"ro{i}", 2, datacenter="dc1", unit_bytes=units)
            for i in range(3)
        ]
        tr.open()
        for r in ros:
            r.open()
        cl.run()
        tr.publish(0)
        cl.run()
        for r in ros:
            r.replicate("latest")
        cl.run()
        return cl, sum(b for n, b in cl.net.link_bytes.items() if ":vpc_up" in n)

    def test_cross_dc_single_seed(self):
        # exactly one replica's worth of bytes crosses the DC boundary;
        # with codec="raw" the wire bytes are the weight bytes bit-for-bit
        _, vpc_up = self._cross_dc_wan_bytes(wan_codec="raw")
        assert math.isclose(vpc_up, 10 * GB * 2, rel_tol=1e-6)  # 2 shards x 10 units

    def test_cross_dc_single_seed_int8_wire(self):
        # default negotiation: WAN-crossing slices carry the int8 codec,
        # and the sim derives wire bytes from the codec's actual ratio
        # over the shard manifest — not a hand-set scalar
        from repro.transfer.codec import get_codec, wire_ratio

        cl, vpc_up = self._cross_dc_wan_bytes()
        ratio = wire_ratio(get_codec("int8"), [int(GB)] * 10, cl.codec_dtype)
        assert ratio < 0.26  # ~0.2539 for float32 elements
        assert math.isclose(vpc_up, 10 * GB * 2 * ratio, rel_tol=1e-6)

    def test_tcp_compression_deprecated_alias(self):
        # the legacy scalar still works (as a fixed-ratio codec) but warns
        import pytest

        with pytest.warns(DeprecationWarning):
            _, vpc_up = self._cross_dc_wan_bytes(tcp_compression=0.5)
        assert math.isclose(vpc_up, 10 * GB * 2 * 0.5, rel_tol=1e-6)


def _fanout(n_dest, m_src, units, **kw):
    """M publishers holding v0, N destinations pulling it concurrently.
    Returns (makespan, cluster)."""
    cl = SimCluster(**kw)
    pubs = [cl.add_replica("m", f"pub{i}", 2, unit_bytes=units) for i in range(m_src)]
    dests = [cl.add_replica("m", f"dst{i}", 2, unit_bytes=units) for i in range(n_dest)]
    for r in pubs + dests:
        r.open()
    cl.run()
    pubs[0].publish(0)
    cl.run()
    for p in pubs[1:]:
        p.replicate("latest")
    cl.run()
    t0 = cl.env.now
    finish = {}
    for d in dests:
        ev = d.replicate("latest")
        ev.add_callback(
            lambda e, n=d.name: (
                finish.setdefault(n, cl.env.now) if e.error is None else None
            )
        )
    cl.run()
    assert len(finish) == n_dest, f"incomplete fan-out: {sorted(finish)}"
    return max(finish.values()) - t0, cl


class TestWindowedMultiSource:
    def test_multi_source_partition_used(self):
        # the first destination (no in-progress relay available) gets a
        # multi-source partition across the published pool; later ones
        # prefer chaining off it — both paths must deliver every byte
        t, cl = _fanout(2, 3, [GB] * 8)
        assert cl.server.stats["multi_source_assignments"] >= 1
        # all bytes delivered exactly once per destination shard
        assert math.isclose(
            cl.net.bytes_delivered, (3 - 1 + 2) * 8 * GB * 2, rel_tol=1e-6
        )

    def test_beats_pinned_baseline(self):
        t_multi, _ = _fanout(8, 4, [GB] * 8)
        t_pinned, _ = _fanout(
            8, 4, [GB] * 8,
            window=1, chunk_bytes=None, max_sources=1,
            scheduler="pinned", pipeline_replication=False,
        )
        assert t_pinned > 3.0 * t_multi

    def test_window1_chunkoff_reproduces_sequential_path(self):
        """The legacy knobs replay the pre-scheduler data plane exactly
        (recorded timing from the sequential implementation)."""
        t, _ = _fanout(
            1, 1, [GB] * 16, window=1, chunk_bytes=None, max_sources=1
        )
        assert math.isclose(t, 0.6984521739, rel_tol=1e-6)

    def test_chunking_splits_giant_unit_across_sources(self):
        """A single giant tensor per shard: chunking aggregates several
        source uplinks where the sequential path binds to one flow."""
        t_seq, _ = _fanout(
            2, 3, [12 * GB], window=1, chunk_bytes=None, max_sources=1
        )
        t_chunk, cl = _fanout(2, 3, [12 * GB], chunk_bytes=GB)
        assert cl.server.stats["multi_source_assignments"] >= 2
        assert t_chunk < 0.7 * t_seq

    def test_source_death_mid_windowed_pull(self):
        """Kill one plan member mid-transfer: the reader re-partitions
        onto the survivors and completes."""
        cl = SimCluster()
        units = [GB] * 12
        pubs = [cl.add_replica("m", f"pub{i}", 2, unit_bytes=units) for i in range(3)]
        dst = cl.add_replica("m", "dst", 2, unit_bytes=units)
        for r in pubs + [dst]:
            r.open()
        cl.run()
        pubs[0].publish(0)
        cl.run()
        for p in pubs[1:]:
            p.replicate("latest")
        cl.run()
        ev = dst.replicate("latest")
        cl.env.schedule(0.15, lambda: cl.kill_replica("pub1"))
        cl.run()
        assert ev.triggered and ev.error is None, ev.error
        assert cl.server.stats["reassignments"] >= 1

    def test_progress_prefix_monotone_under_window(self):
        """Progress counters advance strictly over a contiguous prefix
        even though units complete out of order across sources."""
        cl = SimCluster()
        seen = []
        orig = cl.server.update_progress

        def spy(model, replica, shard_idx, version, progress):
            if replica == "dst":
                seen.append((shard_idx, progress))
            return orig(model, replica, shard_idx, version, progress)

        cl.server.update_progress = spy
        units = [GB] * 10
        pubs = [cl.add_replica("m", f"pub{i}", 2, unit_bytes=units) for i in range(2)]
        dst = cl.add_replica("m", "dst", 2, unit_bytes=units)
        for r in pubs + [dst]:
            r.open()
        cl.run()
        pubs[0].publish(0)
        cl.run()
        pubs[1].replicate("latest")
        cl.run()
        dst.replicate("latest")
        cl.run()
        per_shard = {}
        for shard, p in seen:
            assert p > per_shard.get(shard, 0)  # strictly increasing prefix
            per_shard[shard] = p
        assert per_shard == {0: 10, 1: 10}


class TestKeyedWakeups:
    def test_notify_keys_derived_from_server_registration(self):
        """>64-shard replicas known only to the server still wake every
        waiter (the old code fell back to a hard-coded 64)."""
        cl = SimCluster()
        info = WorkerInfo("big/s0", "dc0/big", "dc0", False)
        for i in range(80):
            cl.server.open("m", "big", 80, i, worker=info)
        woken = []

        def waiter(i):
            yield cl.env.key_wait(("progress", "big", i))
            woken.append(i)

        for i in (0, 63, 70, 79):
            cl.env.process(waiter(i))
        cl.env.run(until=0.001)
        assert not woken
        cl._notify_progress_keys("big")
        cl.env.run(until=0.002)
        assert sorted(woken) == [0, 63, 70, 79]

    def test_predicate_sweep_covers_unknown_keys(self):
        cl = SimCluster()
        hit = []

        def waiter():
            yield cl.env.key_wait(("ctl", "ghost", 99))
            hit.append(True)

        cl.env.process(waiter())
        cl.env.run(until=0.001)
        cl._notify_progress_keys("ghost")
        cl.env.run(until=0.002)
        assert hit

    def test_no_stale_keyed_entries_after_run(self):
        _, cl = _fanout(2, 2, [GB] * 6)
        stale = [
            k
            for k, ev in cl.env._keyed.items()
            if ev._waiters or ev._callbacks
        ]
        assert stale == []

    def test_safety_tick_recovers_missed_wakeup(self):
        """A waiter whose notify was lost is woken by the safety net once
        the hard event heap quiesces — delayed, never deadlocked."""
        env = SimEnv()
        woken = []

        def waiter():
            yield env.key_wait("never-notified")
            woken.append(env.now)

        env.process(waiter())
        env.run(until=100.0)
        assert woken and woken[0] == env.safety_tick

    def test_safety_tick_does_not_inflate_healthy_runs(self):
        env = SimEnv()
        net = SimNetwork(env)
        link = net.link("l", 10e9)

        def proc():
            yield env.key_wait("k")
            yield net.flow(10e9, [link])

        env.process(proc())
        env.schedule(0.5, lambda: env.key_notify("k"))
        env.run()
        assert math.isclose(env.now, 1.5, rel_tol=1e-6)  # no trailing ticks
