"""Transport equivalence sweep — tier-1, in-process, no sockets.

The tentpole split promises that the networked service is the *same*
control plane behind a different transport. This suite replays one
recorded op trace (publish -> replicate -> update -> failures -> crash)
through an in-process ``ReferenceServer`` and through a
``ReferenceService`` taking every op as an encoded wire frame, asserting
``state_digest`` equality at every single op boundary — any divergence
the wire codec, dispatcher, or error path introduces fails on the exact
op that caused it.

Also pins the WAL-file restart path the networked controller uses:
``OpLog.open_path`` / ``failover.recover_path`` rebuild a digest-
identical server from the file a dead process left, and keep appending
to it across multiple restarts.
"""

import pytest

from repro.core import failover
from repro.core.errors import ServerUnavailableError, TensorHubError
from repro.core.meta import ShardManifest, TensorMeta, TransferUnit, WorkerInfo
from repro.core.oplog import OpLog
from repro.core.server import ReferenceServer
from repro.net import protocol
from repro.net.service import ReferenceService


def manifest(n_units=2, unit_bytes=64):
    tensors = tuple(
        TensorMeta(f"t{i}", (unit_bytes,), "uint8", unit_bytes)
        for i in range(n_units)
    )
    units = tuple(
        TransferUnit(index=i, name=f"t{i}", nbytes=unit_bytes)
        for i in range(n_units)
    )
    return ShardManifest(tensors=tensors, units=units, checksums=(0,) * n_units)


def worker(replica, shard, dc="dc0"):
    return WorkerInfo(f"{replica}/s{shard}", f"{dc}/{replica}", dc, False)


def recorded_trace():
    """One deterministic control-plane history touching every family of
    mutation: opens, publishes, replication with progress, an update
    round, heartbeats/ticks, gray-failure evidence, an eviction, an
    unpublish — and a crash at the end. ``(op, args, kw)`` triples, the
    exact shape the wire protocol moves."""
    ops = []

    def rec(op, *args, **kw):
        ops.append((op, args, kw))

    for shard in range(2):
        rec("open", "m", "pub", 2, shard, worker=worker("pub", shard), retain="latest")
        rec("register", "m", "pub", shard)
    for shard in range(2):
        rec("open", "m", "sub", 2, shard, worker=worker("sub", shard), retain=None)
        rec("register", "m", "sub", shard)
    for shard in range(2):
        rec("publish", "m", "pub", shard, 0, manifest(), op_id=0)
        rec("heartbeat", "m", "pub", shard, 1.0)
    for shard in range(2):
        rec("begin_replicate", "m", "sub", shard, "latest", op_id=1)
    for shard in range(2):
        for progress in (1, 2):
            rec("update_progress", "m", "sub", shard, 0, progress)
    for shard in range(2):
        rec("complete_replicate", "m", "sub", shard, 0, op_id=2)
        rec("heartbeat", "m", "sub", shard, 2.0)
    rec("tick", 3.0)
    # a second version: the update path
    for shard in range(2):
        rec("unpublish", "m", "pub", shard, op_id=3)
    rec("finish_unpublish", "m", "pub")
    for shard in range(2):
        rec("publish", "m", "pub", shard, 1, manifest(n_units=3), op_id=4)
    for shard in range(2):
        rec("begin_update", "m", "sub", shard, "latest", op_id=5)
    # duplicate redelivery mid-trace: must be state-neutral on both paths
    rec("begin_update", "m", "sub", 0, "latest", op_id=5)
    for shard in range(2):
        rec("update_progress", "m", "sub", shard, 1, 3)
        rec("complete_replicate", "m", "sub", shard, 1, op_id=6)
    # gray-failure evidence and the probation machinery
    rec("report_transfer_failure", "m", "sub", "pub", "transient", 4.0)
    rec("report_transfer_failure", "m", "sub", "pub", "corrupt", 4.5)
    rec("tick", 5.0)
    rec("poll_events", "sub/s0")
    # a stale heartbeat pattern followed by an expiry sweep: eviction
    rec("heartbeat", "m", "pub", 0, 5.0)
    rec("tick", 100.0)
    rec("fail_replica", "m", "sub", "spot preemption")
    rec("poll_events", "pub/s0")
    # the crash: every op after this raises ServerUnavailableError
    rec("crash")
    rec("latest", "m")
    rec("tick", 101.0)
    return ops


def wire_apply(svc, op, args, kw):
    return protocol.decode_response(
        svc.handle_frame(protocol.encode_request(op, args, kw))
    )


class TestTransportEquivalence:
    def test_digest_equal_at_every_op_boundary(self):
        direct = ReferenceServer(heartbeat_timeout=10.0)
        svc = ReferenceService(ReferenceServer(heartbeat_timeout=10.0))
        assert failover.state_digest(direct) == failover.state_digest(svc.server)
        for i, (op, args, kw) in enumerate(recorded_trace()):
            outcome_direct = outcome_wire = None
            try:
                r_direct = getattr(direct, op)(*args, **kw)
            except TensorHubError as e:
                outcome_direct = type(e).__name__
                r_direct = None
            try:
                r_wire = wire_apply(svc, op, args, kw)
            except TensorHubError as e:
                outcome_wire = type(e).__name__
                r_wire = None
            assert outcome_direct == outcome_wire, (
                f"op {i} ({op}): error divergence "
                f"{outcome_direct!r} != {outcome_wire!r}"
            )
            assert r_direct == r_wire, (
                f"op {i} ({op}): result divergence\n{r_direct!r}\n{r_wire!r}"
            )
            assert failover.state_digest(direct) == failover.state_digest(
                svc.server
            ), f"op {i} ({op}): state digest diverged"

    def test_crash_marker_respected_on_both_paths(self):
        direct = ReferenceServer()
        svc = ReferenceService(ReferenceServer())
        direct.crash()
        svc.server.crash()
        with pytest.raises(ServerUnavailableError):
            direct.latest("m")
        with pytest.raises(ServerUnavailableError):
            wire_apply(svc, "latest", ("m",), {})
        assert failover.state_digest(direct) == failover.state_digest(svc.server)


class TestWalFileRestart:
    def _run_trace_until_crash(self, server):
        for op, args, kw in recorded_trace():
            if op == "crash":
                break
            try:
                getattr(server, op)(*args, **kw)
            except TensorHubError:
                pass

    def test_recover_path_rebuilds_identical_server(self, tmp_path):
        wal = str(tmp_path / "controller.wal")
        live = ReferenceServer(
            heartbeat_timeout=10.0, log=OpLog.open_path(wal)
        )
        self._run_trace_until_crash(live)
        live.log.close()  # the process dies; the file is what remains

        recovered = failover.recover_path(wal)
        assert failover.state_digest(recovered) == failover.state_digest(live)
        # the reopened log must keep appending where the file left off
        assert recovered.log is not None and recovered.log.path == wal

    def test_restart_twice_keeps_appending(self, tmp_path):
        """Kill -> recover -> mutate -> kill -> recover again: the WAL
        accumulates across incarnations and every recovery is digest-
        faithful to the server that wrote the tail."""
        wal = str(tmp_path / "controller.wal")
        first = ReferenceServer(log=OpLog.open_path(wal))
        first.open("m", "pub", 1, 0, worker=worker("pub", 0), retain=None)
        first.register("m", "pub", 0)
        first.publish("m", "pub", 0, 0, manifest(), op_id=0)
        first.log.close()

        second = failover.recover_path(wal)
        second.heartbeat("m", "pub", 0, 1.0)
        second.unpublish("m", "pub", 0, op_id=1)
        second.finish_unpublish("m", "pub")
        second.publish("m", "pub", 0, 1, manifest(n_units=3), op_id=2)
        digest_second = failover.state_digest(second)
        second.log.close()

        third = failover.recover_path(wal)
        assert failover.state_digest(third) == digest_second
        assert third.latest("m") == 1

    def test_compacted_wal_recovers_after_reopen(self, tmp_path):
        """Snapshot compaction then a restart: open_path must read the
        snapshot line plus the surviving suffix."""
        wal = str(tmp_path / "controller.wal")
        live = ReferenceServer(log=OpLog.open_path(wal))
        live.open("m", "pub", 1, 0, worker=worker("pub", 0), retain=None)
        live.register("m", "pub", 0)
        live.publish("m", "pub", 0, 0, manifest(), op_id=0)
        live.log.compact(failover.take_snapshot(live))
        # post-snapshot tail the recovery has to replay on top
        live.unpublish("m", "pub", 0, op_id=1)
        live.finish_unpublish("m", "pub")
        live.publish("m", "pub", 0, 1, manifest(), op_id=2)
        live.log.close()

        recovered = failover.recover_path(wal)
        assert failover.state_digest(recovered) == failover.state_digest(live)
        assert recovered.latest("m") == 1

    def test_blob_keys_stay_distinct_across_reopens(self, tmp_path):
        """A restarted controller's interned manifest blobs must not
        collide with keys already in the file (references resolve in
        file order, but distinct keys keep compaction sound)."""
        wal = str(tmp_path / "controller.wal")
        first = ReferenceServer(log=OpLog.open_path(wal))
        first.open("m", "pub", 1, 0, worker=worker("pub", 0), retain=None)
        first.register("m", "pub", 0)
        first.publish("m", "pub", 0, 0, manifest(), op_id=0)
        first.log.close()

        second = failover.recover_path(wal)
        second.unpublish("m", "pub", 0, op_id=1)
        second.finish_unpublish("m", "pub")
        second.publish("m", "pub", 0, 1, manifest(n_units=3), op_id=2)
        second.log.close()

        import json

        keys = []
        with open(wal, "r", encoding="utf-8") as fh:
            for line in fh:
                obj = json.loads(line)
                if obj.get("kind") == "blob":
                    keys.append(obj["key"])
        assert len(keys) == len(set(keys)), f"blob key collision: {keys}"
        assert len(keys) >= 2  # both incarnations interned a manifest
