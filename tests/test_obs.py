"""Telemetry plane tests: recorder semantics, virtual-clock fidelity,
disabled-path cost, Chrome trace export, per-link-class byte counters,
stall decomposition on both data planes, and server metrics consistency
across crash/replay."""

import json
import tracemalloc

import numpy as np
import pytest

from repro.core import ReferenceServer, TensorHubClient, failover
from repro.core.oplog import OpLog
from repro.obs import (
    DISABLED,
    STALL_COMPONENTS,
    Recorder,
    chrome_trace_events,
    render_timeline,
    stall_breakdown,
    write_chrome_trace,
)
from repro.obs.telemetry import NULL_SPAN
from repro.transfer.simcluster import SimCluster
from repro.transfer.simnet import SimEnv

GB = 1e9


def tensors(fill, n=2, elems=1024):
    return {f"w{i}": np.full(elems, fill, np.float32) for i in range(n)}


class TestRecorder:
    def test_span_nesting_and_attrs(self):
        rec = Recorder(clock=iter(range(100)).__next__)
        with rec.span("outer", track="t", a=1) as outer:
            outer.set(b=2)
            with rec.span("inner", track="t"):
                pass
            # a span on another track does NOT nest under "outer"
            rec.span("elsewhere", track="u").end()
        assert [e[0] for e in rec.events] == ["inner", "elsewhere", "outer"]
        by_name = {e[0]: e for e in rec.events}
        assert by_name["inner"][4] == "outer"  # parent
        assert by_name["elsewhere"][4] is None
        assert by_name["outer"][5] == {"a": 1, "b": 2}
        # spans are (name, track, t0, t1, ...) with t1 >= t0
        for name, track, t0, t1, _, _ in rec.events:
            assert t1 >= t0

    def test_end_is_idempotent(self):
        rec = Recorder()
        sp = rec.span("x")
        sp.end()
        sp.end()
        assert len(rec.events) == 1

    def test_counters_and_histograms(self):
        rec = Recorder()
        rec.counter_add("c", 2.0)
        rec.counter_add("c", 3.0)
        assert rec.counter("c") == 5.0
        for v in (3.0, 1.0, 2.0):
            rec.observe("h", v)
        s = rec.histogram_summary("h")
        assert (s["count"], s["min"], s["p50"], s["max"]) == (3, 1.0, 2.0, 3.0)

    def test_virtual_clock_spans_match_simenv_exactly(self):
        env = SimEnv()
        rec = Recorder(clock=lambda: env.now)
        sp = rec.span("window")
        env.schedule(2.5, lambda: None)
        env.run(until=5.0)
        sp.end()
        (_, _, t0, t1, _, _) = rec.events[0]
        assert (t0, t1) == (0.0, 5.0)  # exact virtual time, no clock noise

    def test_sim_flow_span_matches_fluid_transfer_time(self):
        cl = SimCluster(telemetry=True)
        pub = cl.add_replica("m", "pub", 1, unit_bytes=[GB])
        dst = cl.add_replica("m", "dst", 1, unit_bytes=[GB])
        pub.open()
        dst.open()
        cl.run()
        pub.publish(0)
        cl.run()
        dst.replicate("latest")
        cl.run()
        flows = [e for e in cl.recorder.events if e[0] == "flow"]
        assert flows, "telemetry=True must record flow spans"
        # fluid model: span duration == nbytes / bottleneck rate exactly
        (_, _, t0, t1, _, attrs) = flows[0]
        assert attrs["bytes"] == GB
        assert t1 - t0 == pytest.approx(GB / attrs["rate"] if "rate" in attrs
                                        else t1 - t0)
        assert t1 > t0

    def test_disabled_fast_path_allocates_nothing(self):
        rec = DISABLED
        assert rec.span("x", track="t") is NULL_SPAN
        # warm up: the first calls may touch lazy interpreter caches
        for _ in range(3):
            rec.counter_add("c", 1.0)
            rec.event("e")
            rec.observe("h", 1.0)
        tracemalloc.start()
        before = tracemalloc.take_snapshot()
        for _ in range(100):
            rec.counter_add("c", 1.0)
            rec.event("e")
            rec.observe("h", 1.0)
            sp = rec.span("x")
            sp.end()
        after = tracemalloc.take_snapshot()
        tracemalloc.stop()
        grown = [
            d for d in after.compare_to(before, "filename")
            if "telemetry.py" in (d.traceback[0].filename if d.traceback else "")
            and d.size_diff > 0
        ]
        assert not grown, grown
        assert rec.events == [] and rec.counters == {} and rec.histograms == {}


class TestExport:
    def _recorded(self):
        ticks = iter([0.0, 0.001, 0.002, 0.005, 0.007])
        rec = Recorder(clock=lambda: next(ticks))
        with rec.span("pull", track="r/s0", source="pub", bytes=1024):
            rec.span("verify", track="r/s0").end()
        rec.event("done", track="r/s0")
        return rec

    def test_chrome_trace_round_trip(self, tmp_path):
        rec = self._recorded()
        path = write_chrome_trace(rec, str(tmp_path / "trace.json"))
        with open(path) as fh:
            doc = json.loads(fh.read())
        events = doc["traceEvents"]
        meta = [e for e in events if e["ph"] == "M"]
        xs = [e for e in events if e["ph"] == "X"]
        assert meta and meta[0]["args"]["name"] == "r/s0"
        assert events[: len(meta)] == meta  # metadata first
        assert all(isinstance(e["ts"], int) and isinstance(e["dur"], int)
                   for e in xs)
        assert [e["ts"] for e in xs] == sorted(e["ts"] for e in xs)
        by_name = {e["name"]: e for e in xs}
        assert by_name["verify"]["args"]["parent"] == "pull"
        assert by_name["pull"]["args"]["bytes"] == 1024
        assert by_name["pull"]["dur"] == 5000  # ticks 0.000 -> 0.005, in us
        assert by_name["done"]["ts"] == 7000 and by_name["done"]["dur"] == 0

    def test_empty_recorder_exports(self):
        rec = Recorder()
        assert chrome_trace_events(rec) == []
        assert render_timeline(rec) == "(no spans recorded)\n"

    def test_render_timeline_contains_spans(self):
        out = render_timeline(self._recorded())
        assert "pull>verify" in out
        assert "source=pub" in out
        assert "[r/s0]" in out


class TestByteCounters:
    def _pull(self, wan_codec):
        hub = TensorHubClient(ReferenceServer(wan_codec=wan_codec))
        pub = hub.open("m", "pub", 1, 0, datacenter="dc0")
        pub.register(tensors(1.0, elems=1 << 12))
        pub.publish(0)
        r = hub.open("m", "r", 1, 0, datacenter="dc1")
        r.register(tensors(0.0, elems=1 << 12))
        r.replicate(0)
        return hub.transport

    def test_raw_wire_equals_decoded(self):
        tr = self._pull("raw")
        assert set(tr.wire_bytes) == {"vpc_up"}
        assert tr.wire_bytes == tr.decoded_bytes
        assert tr.bytes_moved == sum(tr.wire_bytes.values())

    def test_int8_wire_smaller_than_decoded(self):
        tr = self._pull("int8")
        assert set(tr.wire_bytes) == {"vpc_up"}
        assert tr.wire_bytes["vpc_up"] < tr.decoded_bytes["vpc_up"]
        assert tr.bytes_moved == sum(tr.wire_bytes.values())

    def test_same_dc_pull_is_rdma(self):
        hub = TensorHubClient(ReferenceServer())
        pub = hub.open("m", "pub", 1, 0)
        pub.register(tensors(1.0))
        pub.publish(0)
        r = hub.open("m", "r", 1, 0)
        r.register(tensors(0.0))
        r.replicate(0)
        assert set(hub.transport.wire_bytes) == {"rdma"}

    def test_sim_link_class_bytes(self):
        cl = SimCluster(wan_codec="raw")
        pub = cl.add_replica("m", "pub", 1, datacenter="dc0", unit_bytes=[GB])
        dst = cl.add_replica("m", "dst", 1, datacenter="dc1", unit_bytes=[GB])
        pub.open()
        dst.open()
        cl.run()
        pub.publish(0)
        cl.run()
        dst.replicate("latest")
        cl.run()
        by_class = cl.link_class_bytes()
        assert by_class.get("vpc_up", 0.0) == pytest.approx(GB)


class TestStallDecomposition:
    def test_sim_components_tile_total_exactly(self):
        cl = SimCluster()
        pubs = [cl.add_replica("m", f"p{i}", 2, unit_bytes=[GB] * 4)
                for i in range(2)]
        dsts = [cl.add_replica("m", f"d{i}", 2, unit_bytes=[GB] * 4)
                for i in range(3)]
        for r in pubs + dsts:
            r.open()
        cl.run()
        pubs[0].publish(0)
        cl.run()
        for p in pubs[1:]:
            p.replicate("latest")
        for d in dsts:
            d.replicate("latest")
        cl.run()
        names = [d.name for d in dsts]
        parts = cl.stall_decomposition(names)
        assert set(parts) == set(STALL_COMPONENTS)
        assert sum(parts.values()) == pytest.approx(cl.total_stall(names))
        assert parts["wire"] > 0.0 and parts["control"] > 0.0

    def test_threaded_breakdown_tiles_replicate_wall(self):
        rec = Recorder()
        hub = TensorHubClient(
            ReferenceServer(), recorder=rec, window=1, chunk_bytes=None
        )
        rng = np.random.RandomState(0)
        # random payloads: a constant fill folds to checksum 0 (reads as
        # "no checksum") and would silently skip the verify being tested
        weights = {f"w{i}": rng.randn(1 << 19).astype(np.float32) for i in range(2)}
        pub = hub.open("m", "pub", 1, 0)
        pub.register(weights)
        pub.publish(0)
        r = hub.open("m", "r", 1, 0)
        r.register({k: np.zeros_like(v) for k, v in weights.items()})
        rec.clear()
        t0 = rec.clock()
        r.replicate(0)
        wall = rec.clock() - t0
        parts = stall_breakdown(rec)
        assert set(parts) == set(STALL_COMPONENTS)
        total = sum(parts.values())
        # loose on a shared box; the benchmark asserts the 5% version
        assert total <= wall * 1.01
        assert total >= wall * 0.5
        assert parts["verify"] > 0.0

    def test_breakdown_of_empty_recorder_is_zero(self):
        assert stall_breakdown(Recorder()) == dict.fromkeys(STALL_COMPONENTS, 0.0)


class TestServerMetrics:
    def _server_with_history(self, log=None):
        s = ReferenceServer(log=log)
        hub = TensorHubClient(s)
        pub = hub.open("m", "pub", 1, 0)
        pub.register(tensors(1.0))
        pub.publish(0)
        r = hub.open("m", "r", 1, 0)
        r.register(tensors(0.0))
        r.replicate(0)
        return s

    def test_metrics_sections(self):
        m = self._server_with_history().metrics()
        assert set(m) == {"counters", "state", "gauges"}
        st = m["state"]
        assert st["models"] == 1
        assert st["replicas_published"] >= 1
        assert st["availability_units"] > 0
        assert m["gauges"]["failover_last_recovery_seconds"] == 0.0

    def test_metrics_equal_across_crash_replay(self):
        log = OpLog()
        s = self._server_with_history(log=log)
        twin = failover.recover(log)
        assert failover.state_digest(twin) == failover.state_digest(s)
        m1, m2 = s.metrics(), twin.metrics()
        # counters + state are part of the replayed-state contract;
        # gauges (wall clock, log internals) are explicitly exempt
        assert m1["counters"] == m2["counters"]
        assert m1["state"] == m2["state"]
        assert m2["gauges"]["failover_last_recovery_seconds"] > 0.0
        assert m2["gauges"]["oplog_committed_records"] == log.last_seq

    def test_metrics_text_exposition(self):
        s = self._server_with_history(log=OpLog())
        text = s.metrics_text()
        assert "# TYPE tensorhub_models gauge" in text
        assert "tensorhub_models 1\n" in text
        lines = [l for l in text.splitlines() if l and not l.startswith("#")]
        assert all(l.startswith("tensorhub_") for l in lines)
        # every sample line is "name value" with a parseable value
        for l in lines:
            name, value = l.rsplit(" ", 1)
            float(value)

    def test_metrics_on_dead_server_still_scrapes(self):
        log = OpLog()
        s = self._server_with_history(log=log)
        s.crash()
        # scraping a crashed controller must not raise: that is how its
        # death gets diagnosed
        m = s.metrics()
        assert m["state"]["models"] == 1
