"""Cross-datacenter weight transfer (paper 5.4) on the calibrated
event-driven cluster: seeding, smart skipping, and offload seeding.
WAN-crossing slices ride the default int8 wire codec (~3.9x fewer
bytes than raw f32 weights; pass ``SimCluster(wan_codec="raw")`` for
the paper's uncompressed 2.5 s seeding transfer).

    PYTHONPATH=src python examples/cross_dc.py
"""

from repro.configs.paper_workloads import WORKLOADS
from repro.transfer.simcluster import SimCluster

W = WORKLOADS["9B"]


def scenario(offload: bool) -> None:
    label = "offload seeding" if offload else "plain seeding + smart skipping"
    cl = SimCluster()
    units = W.unit_bytes(64)
    trainers = [
        cl.add_replica("m", f"tr{i}", W.num_shards, datacenter="dc0", unit_bytes=units)
        for i in range(W.num_trainer_replicas)
    ]
    rollouts = [
        cl.add_replica("m", f"ro{i}", W.num_shards, datacenter="dc1",
                       unit_bytes=units, offload_seeding=offload)
        for i in range(4)
    ]
    for r in trainers + rollouts:
        r.open()
    cl.run()
    for t in trainers:
        t.publish(0)
    cl.run()
    for r in rollouts:
        r.replicate("latest")
    cl.run()
    for t in trainers:
        t.unpublish()
    for r in rollouts:
        for s in r.shards:
            s.worker.total_stall = 0.0
    for t in trainers:
        t.publish(1)
    cl.run()

    done = {}

    def poller(rep):
        def gen():
            while True:
                res = None
                for s in rep.shards:
                    res = yield from s.g_update("latest")
                if res:
                    done[rep.name] = cl.env.now
                    return
                yield cl.env.timeout(0.2)

        return gen

    for r in rollouts:
        cl.env.process(poller(r)())
    cl.run(until=60)
    per = cl.per_worker_stalls([r.name for r in rollouts])
    vpc = sum(b for n, b in cl.net.link_bytes.items() if ":vpc_up" in n)
    print(f"[{label}]")
    print(f"  per-GPU stall (s): {[round(p, 2) for p in sorted(per)]}")
    print(f"  cross-DC traffic: {vpc/1e9:.0f} GB incl. cold start "
          f"(UCX baseline: {W.shard_bytes * 8 / 1e9:.0f} GB per version)")
    print(f"  smart skips: {cl.server.stats['smart_skips']}")


def main() -> None:
    scenario(offload=False)
    scenario(offload=True)


if __name__ == "__main__":
    main()
