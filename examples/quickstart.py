"""Quickstart: the Table-2 API in one file (paper Fig 4 patterns).

    PYTHONPATH=src python examples/quickstart.py

A trainer publishes weight versions; two rollout groups replicate and
poll-update them; the retention protocol offloads the last copy when the
trainer rolls forward before anyone pulled.
"""

import threading

import numpy as np

from repro.core import ReferenceServer, TensorHubClient


def weights(version: float) -> dict:
    return {
        "layer0/w": np.full((256, 256), version, np.float32),
        "layer0/b": np.full((256,), version, np.float32),
        "head/w": np.full((256, 512), version * 2, np.float32),
    }


def run_group(handles, fn):
    threads = [threading.Thread(target=fn, args=(h,)) for h in handles]
    for t in threads:
        t.start()
    for t in threads:
        t.join()


def main() -> None:
    server = ReferenceServer()
    hub = TensorHubClient(server)
    world = 2  # shards per replica (model parallelism)

    # --- trainer (Fig 4a): publish -> train -> unpublish -> publish ... ---
    trainer = [
        hub.open("actor", "trainer-0", world, i, retain="latest") for i in range(world)
    ]
    for h in trainer:
        h.register(weights(0.0))
    run_group(trainer, lambda h: h.publish(0))
    print("published v0:", server.list_versions("actor"))

    # --- standalone rollout (Fig 4b): replicate once, then poll update ---
    rollout = [hub.open("actor", "rollout-0", world, i) for i in range(world)]
    for h in rollout:
        h.register({k: np.zeros_like(v) for k, v in weights(0).items()})
    run_group(rollout, lambda h: h.replicate("latest"))
    print("rollout got v0; w[0,0] =", rollout[0].store.get("layer0/w")[0, 0])

    # trainer rolls a new version (mutability contract: unpublish first)
    run_group(trainer, lambda h: h.unpublish())
    for h in trainer:
        h.store.register(weights(1.0))  # "training" mutates the buffers
    run_group(trainer, lambda h: h.publish(1))

    updated = []
    run_group(rollout, lambda h: updated.append(h.update("latest")))
    print("update('latest') ->", updated, "; w[0,0] =", rollout[0].store.get("layer0/w")[0, 0])

    # a second rollout is served peer-to-peer (any replica is a source)
    rollout2 = [hub.open("actor", "rollout-1", world, i) for i in range(world)]
    for h in rollout2:
        h.register({k: np.zeros_like(v) for k, v in weights(0).items()})
    run_group(rollout2, lambda h: h.replicate("latest"))
    print("rollout-1 replicated; versions:", {v: sorted(r) for v, r in server.list_versions("actor").items()})

    # retention: trainer unpublishes while holding the ONLY copy of v2
    run_group(trainer, lambda h: h.unpublish())
    for h in trainer:
        h.store.register(weights(2.0))
    run_group(trainer, lambda h: h.publish(2))
    run_group(trainer, lambda h: h.unpublish())  # nobody pulled v2 yet -> offload
    print("after unpublish of last copy:", {v: sorted(r) for v, r in server.list_versions("actor").items()})
    print("server stats:", server.stats)

    for h in trainer + rollout + rollout2:
        h.close()


if __name__ == "__main__":
    main()
