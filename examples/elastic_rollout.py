"""Elastic rollout on 'spot instances' (paper 5.3): rollout workers join
and get preempted mid-training; TensorHub keeps the cluster self-healing
with zero trainer involvement.

    PYTHONPATH=src python examples/elastic_rollout.py
"""

import threading
import time
import traceback

from repro.configs import get_config
from repro.core import ReferenceServer, TensorHubClient
from repro.data.synthetic import PromptSet
from repro.rl import RLConfig, RolloutWorker, TrainerWorker


def main() -> None:
    model_cfg = get_config("llama3-8b").reduced()
    cfg = RLConfig(num_steps=6, prompt_len=6, response_len=10, num_prompts=2, group_size=2)
    server = ReferenceServer()
    hub = TensorHubClient(server)
    prompts = PromptSet(vocab=model_cfg.vocab, prompt_len=cfg.prompt_len)
    queue, stop = [], threading.Event()

    trainer = TrainerWorker(hub, cfg, model_cfg, queue)
    stable = RolloutWorker("standalone-0", hub, cfg, model_cfg, prompts, queue, stop)
    stable.start()

    spot_stop = threading.Event()
    spot = RolloutWorker(
        "elastic-0", hub, cfg, model_cfg, prompts, queue, spot_stop, is_spot=True
    )

    def check(workers):
        for w in workers:
            if w.error:
                traceback.print_exception(w.error)
                raise SystemExit(1)

    try:
        for step in range(cfg.num_steps):
            if step == 1:
                print(">>> scale-up: elastic-0 joins (pulls weights on demand)")
                spot.start()
            if step == 4:
                print(">>> preemption: elastic-0 killed without grace")
                spot_stop.set()
                hub.registry.fail_replica("elastic-0")
                server.fail_replica("m" if False else cfg.model_name, "elastic-0",
                                    reason="spot preemption")
            rollouts = trainer.wait_for_rollouts(1, timeout=300)
            check([stable])
            m = trainer.train_on(rollouts)
            live = sorted({r for rs in server.list_versions(cfg.model_name).values() for r in rs})
            print(f"step {step}: v{m['version']} reward {m['mean_reward']:.3f}  live replicas: {live}")
    finally:
        stop.set()
        spot_stop.set()
        stable.join(timeout=90)
        spot.join(timeout=10)
    trainer.close()
    print("stats:", server.stats)
    print(f"evictions handled: {server.stats['evictions']} (training never stopped)")


if __name__ == "__main__":
    main()
