"""End-to-end RL driver: GRPO training of a small LM with weight transfer
through TensorHub (the paper's full loop: generate -> score -> train ->
transfer).

    PYTHONPATH=src python examples/rl_end_to_end.py --steps 30
    PYTHONPATH=src python examples/rl_end_to_end.py --steps 300 --d-model 256 \
        --layers 8   # ~100M-scale run (slow on CPU)

The reward is rule-based (valid bigram-chain continuations); mean reward
rises as the policy learns the chain. Weight versions flow trainer ->
rollouts via publish/update; the server stats at the end show the
reference traffic.
"""

import argparse
import dataclasses
import threading
import time
import traceback

from repro.configs import get_config
from repro.core import ReferenceServer, TensorHubClient
from repro.data.synthetic import PromptSet
from repro.rl import RLConfig, RolloutWorker, TrainerWorker


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--rollout-workers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=0, help="override width (0 = reduced config)")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    model_cfg = dataclasses.replace(get_config("llama3-8b").reduced(), vocab=128)
    if args.d_model:
        model_cfg = dataclasses.replace(
            model_cfg,
            d_model=args.d_model,
            num_layers=args.layers or model_cfg.num_layers,
            vocab=args.vocab or 2048,
            d_ff=args.d_model * 4,
        )
    cfg = RLConfig(
        num_steps=args.steps, prompt_len=8, response_len=12,
        num_prompts=2, group_size=8, lr=args.lr,
        checkpoint_dir=args.ckpt_dir,
    )

    server = ReferenceServer()
    hub = TensorHubClient(server)
    prompts = PromptSet(vocab=model_cfg.vocab, prompt_len=cfg.prompt_len, branching=2)
    queue, stop = [], threading.Event()

    trainer = TrainerWorker(hub, cfg, model_cfg, queue)
    workers = [
        RolloutWorker(f"rollout-{i}", hub, cfg, model_cfg, prompts, queue, stop)
        for i in range(args.rollout_workers)
    ]
    for w in workers:
        w.start()

    t0 = time.time()
    try:
        for step in range(cfg.num_steps):
            rollouts = trainer.wait_for_rollouts(args.rollout_workers, timeout=600)
            for w in workers:
                if w.error:
                    traceback.print_exception(w.error)
                    raise SystemExit(1)
            m = trainer.train_on(rollouts)
            if step % 5 == 0 or step == cfg.num_steps - 1:
                print(
                    f"step {step:4d}  reward {m['mean_reward']:.3f}  "
                    f"loss {m['loss']:+.4f}  version {m['version']}  "
                    f"({time.time()-t0:.0f}s)"
                )
            if args.ckpt_dir and (step + 1) % 20 == 0:
                from repro import checkpoint as ckpt_lib

                ckpt_lib.save(args.ckpt_dir, step + 1, (trainer.params, trainer.opt_state))
    finally:
        stop.set()
        for w in workers:
            w.join(timeout=120)
    trainer.close()

    first = trainer.metrics_log[0]["mean_reward"]
    last10 = trainer.metrics_log[-10:]
    avg_last = sum(m["mean_reward"] for m in last10) / len(last10)
    print(f"\nreward: first {first:.3f} -> last-10 avg {avg_last:.3f}")
    print("server stats:", server.stats)
    print("rollout steps:", {w.name: w.steps_done for w in workers})


if __name__ == "__main__":
    main()
