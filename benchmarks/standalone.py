"""Table 3 + Fig 9 — RL training with standalone rollouts at production
scale (9B / 36B / 260B / mocked-1T up to 1024 GPUs).

Per training step: co-located trainer replicas publish the new version
(lightweight reference passing — trainers do NOT stall), every standalone
rollout replica pulls it (pipeline replication spreads the fan-out).
NCCL / UCX baselines interrupt every GPU for a global transfer stage.

Validates: trainers never stall under TensorHub; total-GPU-stall reduction
vs NCCL grows with scale, reaching ~6.7x on the 1T workload.
"""

from __future__ import annotations

from typing import Dict, List

from benchmarks import baselines
from repro.configs.paper_workloads import WORKLOADS, TransferWorkload
from repro.transfer.simcluster import SimCluster


def tensorhub_standalone(w: TransferWorkload, steps: int = 2) -> Dict[str, float]:
    cl = SimCluster()
    units = w.unit_bytes(64)
    trainers = [
        cl.add_replica("m", f"tr{i}", w.num_shards, unit_bytes=units)
        for i in range(w.num_trainer_replicas)
    ]
    rollouts = [
        cl.add_replica("m", f"ro{i}", w.num_shards, unit_bytes=units)
        for i in range(w.num_standalone_replicas)
    ]
    for r in trainers + rollouts:
        r.open()
    cl.run()
    for step in range(steps):
        for t in trainers:
            t.publish(step)
        cl.run()
        if step == 0:
            for r in rollouts:
                r.replicate("latest")
        else:
            for r in rollouts:
                r.update("latest")
        cl.run()
        for t in trainers:
            t.unpublish()
        cl.run()
    ro_names = [f"ro{i}" for i in range(w.num_standalone_replicas)]
    per = cl.per_worker_stalls(ro_names)
    return {
        "total_stall": cl.total_stall(ro_names) / steps,
        "mean_latency": sum(per) / len(per) / steps,
        "max_latency": max(per) / steps,
    }


def run() -> List[Dict]:
    rows = []
    for name, w in WORKLOADS.items():
        total_gpus = w.trainer_gpus + w.standalone_gpus
        th = tensorhub_standalone(w)
        nccl = baselines.nccl_total_stall(w.shard_bytes, total_gpus)
        ucx = baselines.ucx_total_stall(
            w.shard_bytes, total_gpus,
            fan_out=max(w.num_standalone_replicas // w.num_trainer_replicas, 1),
        )
        ideal = baselines.rdma_ideal_time(w.shard_bytes) * w.standalone_gpus
        rows.append(
            {
                "workload": name,
                "gpus": total_gpus,
                "tensorhub_total_stall_s": round(th["total_stall"], 1),
                "tensorhub_mean_latency_s": round(th["mean_latency"], 2),
                "nccl_total_stall_s": round(nccl, 1),
                "ucx_total_stall_s": round(ucx, 1),
                "rdma_ideal_total_s": round(ideal, 1),
                "vs_nccl": round(nccl / th["total_stall"], 1),
                "vs_ucx": round(ucx / th["total_stall"], 1),
            }
        )
    return rows


def validate(rows: List[Dict]) -> List[str]:
    checks = []
    one_t = next(r for r in rows if r["workload"] == "1T")
    checks.append(
        f"1T (1024 GPUs): {one_t['vs_nccl']}x total-stall reduction vs NCCL "
        f"(paper: up to 6.7x) -> {'OK' if one_t['vs_nccl'] >= 5.0 else 'MISMATCH'}"
    )
    checks.append(
        f"1T mean standalone latency {one_t['tensorhub_mean_latency_s']}s "
        f"(paper: 3.1s for 66 GB) -> "
        f"{'OK' if 2.5 <= one_t['tensorhub_mean_latency_s'] <= 3.8 else 'MISMATCH'}"
    )
    all_big = all(r["vs_nccl"] >= 5.0 for r in rows)
    checks.append(
        f"every workload >=5x vs NCCL (ratios {[r['vs_nccl'] for r in rows]}) "
        f"-> {'OK' if all_big else 'MISMATCH'}"
    )
    return checks


def main() -> None:
    rows = run()
    for r in rows:
        print(r)
    for c in validate(rows):
        print("  " + c)


if __name__ == "__main__":
    main()
