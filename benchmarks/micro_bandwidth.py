"""Fig 7a — RDMA bandwidth efficiency: one trainer group sends one shard
set to one rollout group; latency vs shard size, TensorHub (simulated real
control plane) against calibrated NCCL / UCX / Ray-object-store models and
the RDMA-ideal roofline.

Validates: TensorHub moves 50 GB/shard in ~2.2 s (>= 88% of the 25 GB/s
roofline) and orders TensorHub < NCCL < UCX << object store.
"""

from __future__ import annotations

from typing import Dict, List

from benchmarks import baselines, harness
from repro.transfer.simcluster import SimCluster

GB = 1e9
#: tensors are 50 MB each (5.1.1); shard size = count x 50 MB
SHARD_GBS = [1, 5, 10, 25, 50]
SHARD_GBS_QUICK = [1, 10, 50]


def tensorhub_latency(shard_gb: float) -> float:
    """Simulated transfer of one shard. The paper's shard is N x 50 MB
    tensors; post tiny-tensor compaction the wire moves fewer, larger
    units, so the simulation uses <=64 units (the per-unit setup latency
    it drops is ~50 us x N ~ tens of ms, negligible vs seconds)."""
    cl = SimCluster()
    n_units = min(int(shard_gb * GB / 50e6), 64)
    units = [shard_gb * GB / n_units] * n_units
    tr = cl.add_replica("m", "trainer", 8, unit_bytes=units)
    ro = cl.add_replica("m", "rollout", 8, unit_bytes=units)
    tr.open(), ro.open()
    cl.run()
    tr.publish(0)
    cl.run()
    t0 = cl.env.now
    ro.replicate("latest")
    cl.run()
    return cl.env.now - t0


def run(quick: bool = False) -> List[Dict]:
    rows = []
    for gb in (SHARD_GBS_QUICK if quick else SHARD_GBS):
        nbytes = gb * GB
        th = tensorhub_latency(gb)
        nccl = baselines.nccl_transfer_time(nbytes, total_gpus=16)
        ucx = baselines.ucx_transfer_time(nbytes, total_gpus=16)
        obj, crashed = baselines.object_store_time(nbytes)
        ideal = baselines.rdma_ideal_time(nbytes)
        rows.append(
            {
                "shard_gb": gb,
                "tensorhub_s": round(th, 3),
                "nccl_s": round(nccl, 3),
                "ucx_s": round(ucx, 3),
                "object_store_s": None if crashed else round(obj, 3),
                "object_store_crashed": crashed,
                "rdma_ideal_s": round(ideal, 3),
                "tensorhub_gbps": round(nbytes / th / 1e9, 2),
                "roofline_frac": round(ideal / th, 3),
            }
        )
    return rows


def validate(rows: List[Dict]) -> List[str]:
    checks = []
    last = rows[-1]  # 50 GB
    checks.append(
        f"50GB in {last['tensorhub_s']}s @ {last['tensorhub_gbps']} GB/s "
        f"(paper: 2.2s @ 22 GB/s) -> {'OK' if 2.0 <= last['tensorhub_s'] <= 2.5 else 'MISMATCH'}"
    )
    checks.append(
        f">=88% of roofline: {last['roofline_frac']*100:.0f}% "
        f"-> {'OK' if last['roofline_frac'] >= 0.85 else 'MISMATCH'}"
    )
    order = all(
        r["tensorhub_s"] < r["nccl_s"] < r["ucx_s"]
        and (r["object_store_s"] is None or r["ucx_s"] < r["object_store_s"])
        for r in rows
    )
    checks.append(f"ordering TH < NCCL < UCX << object-store -> {'OK' if order else 'MISMATCH'}")
    crash = any(r["object_store_crashed"] for r in rows)
    checks.append(f"object store crashes beyond 35 GB/shard -> {'OK' if crash else 'MISMATCH'}")
    return checks


if __name__ == "__main__":
    harness.bench_main("micro_bandwidth", run, validate)
