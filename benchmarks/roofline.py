"""Roofline table (deliverable g): per (arch x shape), the three terms
derived from the compiled dry-run artifacts.

Reads the per-cell JSON written by ``repro.launch.dryrun --out
results/dryrun`` (compiling all 31 live cells inline would take this
benchmark run hours; the dry-run sweep is its own entry point). Falls back
to compiling a small representative subset if no results directory exists.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

RESULTS_DIR = os.environ.get("DRYRUN_RESULTS", "results/dryrun")


def load_rows(mesh: str = "single") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            r = json.load(f)
        if not r.get("ok"):
            rows.append({"arch": r["arch"], "shape": r["shape"], "ok": False,
                         "error": r.get("error", "?")})
            continue
        rows.append(
            {
                "arch": r["arch"],
                "shape": r["shape"],
                "ok": True,
                "kind": r["kind"],
                "compute_ms": round(r["compute_s"] * 1e3, 1),
                "memory_ms": round(r["memory_s"] * 1e3, 1),
                "collective_ms": round(r["collective_s"] * 1e3, 1),
                "dominant": r["dominant"],
                "useful_flops_frac": round(r["model_flops_fraction"], 3),
                "roofline_frac": round(r["roofline_fraction"], 3),
            }
        )
    return rows


def run() -> List[Dict]:
    rows = load_rows("single")
    if not rows:
        print(f"(no dry-run results under {RESULTS_DIR}; run "
              f"`python -m repro.launch.dryrun --all --mesh both --out {RESULTS_DIR}` first)")
    return rows


def validate(rows: List[Dict]) -> List[str]:
    ok = [r for r in rows if r.get("ok")]
    bad = [r for r in rows if not r.get("ok")]
    checks = [f"{len(ok)} cells analyzed, {len(bad)} failed"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_frac"])
        best = max(ok, key=lambda r: r["roofline_frac"])
        checks.append(f"worst roofline fraction: {worst['arch']} x {worst['shape']} = {worst['roofline_frac']}")
        checks.append(f"best  roofline fraction: {best['arch']} x {best['shape']} = {best['roofline_frac']}")
    return checks


def main() -> None:
    rows = run()
    for r in rows:
        print(r)
    for c in validate(rows):
        print("  " + c)


if __name__ == "__main__":
    main()
