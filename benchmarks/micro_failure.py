"""Fig 7c — transparent failure masking: trainer -> rollout-A -> rollout-B
pipeline; rollout-A is killed mid-transfer; rollout-B must complete by
re-routing to the trainer, delayed only by the read-failure detection
timeout (``RetryPolicy.fail_detect``, default = the RDMA timeout).

Validates: B always completes; for kill times within the transfer window
the total time is ~(kill point + detection + remaining transfer); kills
after ~2.2s leave B unaffected.
"""

from __future__ import annotations

from typing import Dict, List

from benchmarks import harness
from repro.transfer.faults import DEFAULT_RETRY_POLICY
from repro.transfer.simcluster import SimCluster

GB = 1e9
SHARD_GB = 50
KILL_AT = [0.1, 0.5, 1.0, 1.5, 2.0, 2.5, 3.0]
KILL_AT_QUICK = [0.5, 1.5, 3.0]

#: reader-side failure-detection timeout the sim's kill_flows applies
#: (previously a hard-coded 4 s here, drifting from the actual knob)
DETECT = DEFAULT_RETRY_POLICY.fail_detect


def failure_run(kill_at: float) -> Dict[str, float]:
    cl = SimCluster()
    units = [SHARD_GB * GB / 64] * 64
    tr = cl.add_replica("m", "trainer", 8, unit_bytes=units)
    ra = cl.add_replica("m", "ra", 8, unit_bytes=units)
    rb = cl.add_replica("m", "rb", 8, unit_bytes=units)
    tr.open(), ra.open(), rb.open()
    cl.run()
    tr.publish(0)
    cl.run()
    t0 = cl.env.now
    # A pulls from the trainer; B is scheduled onto A (pipeline)
    ra.replicate("latest")
    done_b = rb.replicate("latest")
    cl.env.schedule(kill_at, lambda: cl.kill_replica("ra"))
    cl.run()
    assert done_b.triggered and done_b.error is None, "rollout-B must complete"
    b_stall = max(s.worker.total_stall for s in rb.shards)
    return {"kill_at": kill_at, "b_time_s": cl.env.now - t0, "b_stall_s": b_stall}


def run(quick: bool = False) -> List[Dict]:
    return [failure_run(k) for k in (KILL_AT_QUICK if quick else KILL_AT)]


def validate(rows: List[Dict]) -> List[str]:
    checks = []
    base = 50 * GB / (0.92 * 25e9)  # unimpeded transfer ~2.2 s
    for r in rows:
        k = r["kill_at"]
        if k >= base + 0.1:
            ok = r["b_stall_s"] <= base * 1.15
            checks.append(f"kill@{k}s after transfer done: B unaffected "
                          f"({r['b_stall_s']:.2f}s) -> {'OK' if ok else 'MISMATCH'}")
        else:
            # B re-reads from the trainer after the detection timeout
            ok = (
                r["b_stall_s"] >= k + DETECT - 0.2
                and r["b_stall_s"] < base + k + DETECT + 0.5
            )
            checks.append(f"kill@{k}s: B completes in {r['b_stall_s']:.2f}s "
                          f"(detection ~{DETECT:.0f}s) -> {'OK' if ok else 'MISMATCH'}")
    return checks


if __name__ == "__main__":
    harness.bench_main("micro_failure", run, validate)
