"""Networked control-plane overhead — RPC cost and the 10% makespan gate.

The tentpole moves the ``ReferenceServer`` behind a JSON-over-HTTP
transport. This bench quantifies what that costs on localhost, three
ways:

* **rpc_latency**: per-op round-trip of representative read ops through
  a real ``ControlServer`` socket vs the same call as a plain method
  call. The difference is the whole wire stack: JSON codec + HTTP +
  loopback TCP + dispatcher lock.
* **pull_makespan**: identical publish -> N x replicate runs where the
  *only* difference between arms is the control plane (the data plane is
  the in-process ``LocalTransport`` in both — same registry shape, same
  copies, same checksums). Gate: the networked arm's best-of-N makespan
  stays within 10% of in-process; control chatter must not tax pulls.

  Sizing note: units are 32 MB — the regime the paper's data plane
  actually moves (2 MB tiny-bucket floor, up to 1 GB chunks). Micro
  units (~4 MB) overstate the tax here for a reason that doesn't
  survive a real deployment: this bench hosts the HTTP controller in
  the *same* process as the puller, so the pure-Python HTTP work for
  each unit's control calls steals 5 ms GIL slices from the copy loop
  (~6 ms/unit flat). Separate processes — how the networked tier and
  production both run — don't share a GIL.
* **failover_recovery**: controller dies (HTTP stack torn down), a new
  incarnation is rebuilt from the WAL file and serves on a fresh port —
  wall time from kill to a digest-identical server answering pings.

CLI: PYTHONPATH=src python benchmarks/networked.py [--quick] [--json out]
(quick exits non-zero on MISMATCH; this is the CI networked-job gate).
"""

from __future__ import annotations

import os
import statistics
import tempfile
import time
from typing import Dict, List

import numpy as np

from repro.core import ReferenceServer, TensorHubClient
from repro.core import failover
from repro.core.oplog import OpLog
from repro.net.client import RemoteClient
from repro.net.httpd import ControlServer
from repro.net.service import ReferenceService

try:
    from benchmarks import harness
except ImportError:  # invoked directly: benchmarks/ itself is sys.path[0]
    import harness

RPC_OPS = ("latest", "num_shards", "availability", "metrics")
MAKESPAN_GATE_PCT = 10.0


def _weights(n_tensors: int, elems: int) -> Dict[str, np.ndarray]:
    rng = np.random.RandomState(0)
    return {
        f"w{i}": rng.randn(elems).astype(np.float32) for i in range(n_tensors)
    }


def _seed_model(server_like) -> TensorHubClient:
    """A hub with one published single-tensor model, for the RPC micro."""
    hub = TensorHubClient(server_like)
    pub = hub.open("m", "pub", 1, 0)
    pub.register({"w0": np.zeros(8, dtype=np.float32)})
    pub.publish(0)
    return hub


def _call_op(target, op: str):
    if op == "latest":
        return target.latest("m")
    if op == "num_shards":
        return target.num_shards("m")
    if op == "availability":
        return target.availability("m", 0)
    if op == "metrics":
        return target.metrics()
    raise AssertionError(op)


def _median_us(target, op: str, iters: int) -> float:
    _call_op(target, op)  # warm (connection, codec, caches)
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        _call_op(target, op)
        samples.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(samples)


def _networked_stack(server: ReferenceServer):
    """ControlServer on a real localhost socket + a connected client.
    No ticker: the bench never ticks, so heartbeat expiry is inert."""
    http = ControlServer(ReferenceService(server))
    http.start()
    return http, RemoteClient(http.address)


def bench_rpc_latency(iters: int) -> List[Dict]:
    direct = ReferenceServer()
    _seed_model(direct)
    net_server = ReferenceServer()
    http, rc = _networked_stack(net_server)
    try:
        _seed_model(rc)
        rows = []
        for op in RPC_OPS:
            inproc = _median_us(direct, op, iters)
            networked = _median_us(rc, op, iters)
            rows.append(
                {
                    "case": "rpc_latency",
                    "op": op,
                    "iters": iters,
                    "inproc_us": round(inproc, 1),
                    "networked_us": round(networked, 1),
                    "overhead_us": round(networked - inproc, 1),
                }
            )
        return rows
    finally:
        rc.close()
        http.shutdown()


def _publish_arm(server_like, w: Dict[str, np.ndarray]) -> TensorHubClient:
    hub = TensorHubClient(server_like)
    pub = hub.open("m", "pub", 1, 0)
    pub.register(w)
    pub.publish(0)
    return hub


def _timed_pull(hub: TensorHubClient, name: str, w) -> float:
    """One fresh reader replica replicating the published version; the
    reader is closed afterwards so iterations don't accumulate stores
    (a quarter-GB of retained readers skews later timings)."""
    rdr = hub.open("m", name, 1, 0)
    rdr.register({k: np.zeros_like(v) for k, v in w.items()})
    t0 = time.perf_counter()
    rdr.replicate(0)
    dt = time.perf_counter() - t0
    rdr.close()
    return dt


def bench_pull_makespan(n_tensors: int, elems: int, iters: int) -> List[Dict]:
    """Arms are *interleaved* (in-process pull, networked pull, repeat)
    and scored best-of-N: back-to-back arms hand the second one a
    polluted allocator and page cache, which reads as fake control-plane
    overhead. Best-of because noise only ever inflates a run."""
    w = _weights(n_tensors, elems)
    hub_in = _publish_arm(ReferenceServer(), w)
    net_server = ReferenceServer()
    http, rc = _networked_stack(net_server)
    try:
        hub_net = _publish_arm(rc, w)
        inproc_s = networked_s = float("inf")
        for it in range(iters):
            inproc_s = min(inproc_s, _timed_pull(hub_in, f"ri{it}", w))
            networked_s = min(networked_s, _timed_pull(hub_net, f"rn{it}", w))
    finally:
        rc.close()
        http.shutdown()
    payload_mb = n_tensors * elems * 4 / 2**20
    return [
        {
            "case": "pull_makespan",
            "payload_mb": round(payload_mb, 1),
            "units": n_tensors,
            "iters": iters,
            "inproc_ms": round(inproc_s * 1e3, 2),
            "networked_ms": round(networked_s * 1e3, 2),
            "overhead_pct": round(
                (networked_s - inproc_s) / inproc_s * 100.0, 2
            ),
        }
    ]


def bench_failover_recovery() -> List[Dict]:
    tmp = tempfile.mkdtemp(prefix="th-bench-net-")
    wal = os.path.join(tmp, "controller.wal")
    server = ReferenceServer(log=OpLog.open_path(wal))
    http, rc = _networked_stack(server)
    hub = _seed_model(rc)
    sub = hub.open("m", "sub", 1, 0)
    sub.register({"w0": np.zeros(8, dtype=np.float32)})
    sub.replicate(0)
    pre_kill_digest = failover.state_digest(server)
    ops_in_wal = server.seq
    rc.close()
    http.shutdown()  # the controller process "dies"; the WAL file remains

    t0 = time.perf_counter()
    recovered = failover.recover_path(wal)
    http2 = ControlServer(ReferenceService(recovered))
    http2.start()
    rc2 = RemoteClient(http2.address)
    ping = rc2.ping()
    recovery_s = time.perf_counter() - t0
    digest_match = failover.state_digest(recovered) == pre_kill_digest
    rc2.close()
    http2.shutdown()
    return [
        {
            "case": "failover_recovery",
            "ops_in_wal": ops_in_wal,
            "recovery_ms": round(recovery_s * 1e3, 2),
            "digest_match": bool(digest_match and not ping["crashed"]),
        }
    ]


def run(quick: bool = False) -> List[Dict]:
    rpc_iters = 50 if quick else 300
    if quick:
        n_tensors, elems, pull_iters = 4, 1 << 23, 3  # 128 MB, 4x32MB units
    else:
        n_tensors, elems, pull_iters = 8, 1 << 23, 4  # 256 MB, 8x32MB units
    rows: List[Dict] = []
    rows += bench_rpc_latency(rpc_iters)
    rows += bench_pull_makespan(n_tensors, elems, pull_iters)
    rows += bench_failover_recovery()
    return rows


def validate(rows: List[Dict]) -> List[str]:
    checks = []
    for r in rows:
        if r["case"] == "rpc_latency":
            # localhost HTTP round-trips are hundreds of us; anything in
            # the tens of ms means a stuck socket or per-call reconnects
            ok = r["networked_us"] < 50_000
            checks.append(
                f"rpc {r['op']}: {r['inproc_us']}us in-process vs "
                f"{r['networked_us']}us networked "
                f"(+{r['overhead_us']}us wire cost, sanity < 50ms) -> "
                f"{'OK' if ok else 'MISMATCH'}"
            )
        elif r["case"] == "pull_makespan":
            ok = r["overhead_pct"] <= MAKESPAN_GATE_PCT
            checks.append(
                f"pull makespan ({r['payload_mb']}MB, {r['units']} units): "
                f"networked {r['networked_ms']}ms vs in-process "
                f"{r['inproc_ms']}ms ({r['overhead_pct']:+.2f}%, required "
                f"<= {MAKESPAN_GATE_PCT:.0f}%) -> "
                f"{'OK' if ok else 'MISMATCH'}"
            )
        elif r["case"] == "failover_recovery":
            ok = r["digest_match"]
            checks.append(
                f"failover recovery: {r['recovery_ms']}ms from kill to a "
                f"serving controller rebuilt from {r['ops_in_wal']} WAL ops, "
                f"digest-identical -> {'OK' if ok else 'MISMATCH'}"
            )
    return checks


if __name__ == "__main__":
    harness.bench_main("networked", run, validate)
