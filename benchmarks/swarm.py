"""Swarm replication — destination count x spot-preemption rate sweep.

In-progress replicas serve their completed prefix as sources (the
unit-granular availability map), turning N-destination fan-out into
epidemic dissemination. This benchmark sweeps the two axes that stress
it — how many destinations pull one version at once, and what fraction
of them gets spot-preempted mid-pull — and compares against the
pre-swarm (PR 2) scheduler, reproduced exactly by ``swarm=False``.

Expected shape of the results:

* **Seeded pools (M >= 2 publishers)**: swarm wins outright — every
  reader blends published partitioning with peer prefixes, so aggregate
  bandwidth grows with the destination count instead of saturating at
  M uplinks.
* **Single seed (M = 1)**: swarm reproduces the pipeline-chain schedule
  bit-for-bit (the supply gate: a dedicated relay moves bytes
  link-disjointly at full rate; fanning a one-uplink pool would starve
  everyone in lockstep). This is a designed non-regression, not a
  missed optimization.
* **Preemption**: victims' replicate groups error, survivors always
  complete — the planner re-partitions only the unserved tail, and the
  eviction sweep proactively re-plans every reader that used the victim
  as a swarm source (blast-radius control).
"""

from __future__ import annotations

import random
from typing import Dict, List

from repro.transfer.simcluster import SimCluster

try:
    from benchmarks import harness
except ImportError:  # invoked directly: benchmarks/ itself is sys.path[0]
    import harness

GB = 1e9
SHARDS = 2
UNITS = [GB] * 16  # 16 GB/shard, fine-grained


def swarm_fanout(
    n_dest: int,
    m_src: int,
    preempt_frac: float,
    *,
    swarm: bool,
    seed: int = 7,
) -> Dict[str, object]:
    """M publishers hold v0 (one publishes, the rest replicate it up
    front); N spot destinations pull concurrently; ``preempt_frac`` of
    them is killed at deterministic (seeded) times mid-transfer. Returns
    the survivors' makespan and completion/quiescence checks."""
    cl = SimCluster(swarm=swarm)
    pubs = [
        cl.add_replica("m", f"pub{i}", SHARDS, unit_bytes=UNITS) for i in range(m_src)
    ]
    dests = [
        cl.add_replica("m", f"dst{i}", SHARDS, unit_bytes=UNITS, is_spot=True)
        for i in range(n_dest)
    ]
    for r in pubs + dests:
        r.open()
    cl.run()
    pubs[0].publish(0)
    cl.run()
    seeds = [p.replicate("latest") for p in pubs[1:]]
    cl.run()
    assert all(e.triggered and e.error is None for e in seeds)
    t0 = cl.env.now
    finish: Dict[str, float] = {}
    for d in dests:
        ev = d.replicate("latest")
        ev.add_callback(
            lambda e, name=d.name: (
                finish.setdefault(name, cl.env.now) if e.error is None else None
            )
        )
    rng = random.Random(seed)
    n_victims = int(round(n_dest * preempt_frac))
    victims = rng.sample([d.name for d in dests], n_victims)
    for v in victims:
        cl.env.schedule(rng.uniform(0.2, 1.2), lambda v=v: cl.kill_replica(v))
    cl.run(until=600.0)
    survivors = [d.name for d in dests if d.name not in victims]
    all_done = all(s in finish for s in survivors)
    parked = any(
        ev._waiters or ev._callbacks  # noqa: SLF001 - harness introspection
        for ev in cl.env._keyed.values()  # noqa: SLF001
    )
    makespan = max((finish[s] for s in survivors), default=0.0) - t0
    return {
        "makespan_s": makespan,
        "survivors_done": all_done,
        "quiesced": not all_done or not parked,
        "swarm_assignments": cl.server.stats["swarm_assignments"],
        "swarm_grows": cl.server.stats["swarm_grows"],
        "reassignments": cl.server.stats["reassignments"],
        "stall_parts": cl.stall_decomposition(survivors),
        "stall_total": cl.total_stall(survivors),
    }


def run(quick: bool = False) -> List[Dict]:
    rows: List[Dict] = []
    dest_counts = [4, 8] if quick else [2, 4, 8, 16]
    preempt_rates = [0.0, 0.25]
    for m_src in ([2] if quick else [1, 2]):
        for n in dest_counts:
            for frac in preempt_rates:
                for swarm in (False, True):
                    r = swarm_fanout(n, m_src, frac, swarm=swarm)
                    row = {
                        "scenario": f"{n}x{m_src}_p{int(frac * 100)}",
                        "swarm": swarm,
                        "n_dest": n,
                        "m_src": m_src,
                        "preempt_frac": frac,
                        "makespan_s": round(r["makespan_s"], 3),
                        "survivors_done": r["survivors_done"],
                        "quiesced": r["quiesced"],
                        "grows": r["swarm_grows"],
                        "reassigns": r["reassignments"],
                        "stall_total_s": round(r["stall_total"], 3),
                    }
                    row.update(harness.decomposition_cols(r["stall_parts"]))
                    rows.append(row)
    return rows


def _get(rows: List[Dict], scenario: str, swarm: bool) -> Dict:
    return next(
        r for r in rows if r["scenario"] == scenario and r["swarm"] is swarm
    )


def validate(rows: List[Dict]) -> List[str]:
    checks = []
    # every cell: survivors complete and the sim quiesces
    bad = [
        (r["scenario"], r["swarm"])
        for r in rows
        if not (r["survivors_done"] and r["quiesced"])
    ]
    checks.append(
        f"all cells complete + quiesce (no deadlocked waiters): "
        f"{'OK' if not bad else f'MISMATCH {bad}'}"
    )
    # seeded pool, no preemption: swarm beats the PR 2 scheduler and
    # scales more flatly with destination count
    have_8x2 = any(r["scenario"] == "8x2_p0" for r in rows)
    if have_8x2:
        pr2 = _get(rows, "8x2_p0", False)["makespan_s"]
        sw = _get(rows, "8x2_p0", True)["makespan_s"]
        gain = pr2 / sw
        checks.append(
            f"8 dests / 2 publishers: swarm {sw}s vs PR 2 {pr2}s "
            f"-> x{gain:.2f} (required >= 1.1) -> "
            f"{'OK' if gain >= 1.1 else 'MISMATCH'}"
        )
    lo, hi = ("4x2_p0", "8x2_p0") if have_8x2 else ("4x2_p0", "4x2_p0")
    if any(r["scenario"] == "16x2_p0" for r in rows):
        lo, hi = "2x2_p0", "16x2_p0"
        sw_ratio = (
            _get(rows, hi, True)["makespan_s"] / _get(rows, lo, True)["makespan_s"]
        )
        pr2_ratio = (
            _get(rows, hi, False)["makespan_s"] / _get(rows, lo, False)["makespan_s"]
        )
        checks.append(
            f"2 -> 16 dests (2 publishers): swarm scales x{sw_ratio:.2f} vs "
            f"PR 2 x{pr2_ratio:.2f} -> "
            f"{'OK' if sw_ratio <= pr2_ratio + 0.05 else 'MISMATCH'}"
        )
    # single seed: the supply gate keeps chain parity (designed)
    if any(r["scenario"] == "8x1_p0" for r in rows):
        pr2 = _get(rows, "8x1_p0", False)["makespan_s"]
        sw = _get(rows, "8x1_p0", True)["makespan_s"]
        dev = abs(sw - pr2) / pr2
        checks.append(
            f"single seed, 8 dests: swarm {sw}s vs chains {pr2}s "
            f"(supply gate: deviation {dev * 100:.1f}%, required < 5%) -> "
            f"{'OK' if dev < 0.05 else 'MISMATCH'}"
        )
    # stall decomposition tiles end-to-end stall on the busiest swarm cell
    big = max(
        (r for r in rows if r["swarm"] and r["preempt_frac"] == 0.0),
        key=lambda r: r["n_dest"],
    )
    checks.append(
        harness.check_decomposition(
            big["scenario"],
            {k: big[f"{k}_s"] for k in harness.STALL_COMPONENTS},
            big["stall_total_s"],
        )
    )
    return checks


if __name__ == "__main__":
    harness.bench_main("swarm", run, validate)
