"""Benchmark runner: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--json out.json]

Prints, per benchmark, a ``name,metric,value`` CSV block followed by the
claim-validation lines (paper number vs measured). ``--json`` also
writes the machine-readable aggregate — a list of per-benchmark dicts
(``harness.result_dict``: name, rows, checks, mismatches, elapsed_s) —
which the CI ``--quick`` job uploads as an artifact. ``--quick`` runs
each module's reduced grid and makes errors/mismatches fail the exit
code (the same contract as each module's own ``--quick`` CLI).
"""

from __future__ import annotations

import inspect
import sys
import time
import traceback

from benchmarks import (
    chaos,
    cross_dc,
    elastic,
    failover,
    fanout,
    harness,
    micro_bandwidth,
    micro_burst,
    micro_failure,
    obs_overhead,
    perf_transfer,
    reshard,
    roofline,
    standalone,
    swarm,
)

MODULES = [
    ("fig7a_bandwidth", micro_bandwidth),
    ("fig7b_burst", micro_burst),
    ("fig7c_failure", micro_failure),
    ("chaos_sweep", chaos),
    ("fanout_scheduler", fanout),
    ("swarm_replication", swarm),
    ("failover_control_plane", failover),
    ("telemetry_overhead", obs_overhead),
    ("fig9_standalone", standalone),
    ("fig11_elastic", elastic),
    ("fig12_cross_dc", cross_dc),
    ("reshard_codec", reshard),
    ("perf_transfer_iterations", perf_transfer),
    ("roofline_table", roofline),
]


def main(argv=None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    quick = "--quick" in args
    json_path = None
    if "--json" in args:
        i = args.index("--json")
        if i + 1 >= len(args):
            raise SystemExit("--json requires a path argument")
        json_path = args[i + 1]
    failures = 0
    mismatches = 0
    results = []
    for name, mod in MODULES:
        print(f"\n=== {name} ===")
        t0 = time.time()
        try:
            # the micro/roofline modules have no reduced grid to select
            takes_quick = "quick" in inspect.signature(mod.run).parameters
            rows = mod.run(quick=True) if quick and takes_quick else mod.run()
            checks = mod.validate(rows)
        except Exception:  # noqa: BLE001 - keep running remaining figures
            traceback.print_exc()
            failures += 1
            results.append(
                {
                    "name": name,
                    "error": traceback.format_exc(limit=3),
                    "elapsed_s": round(time.time() - t0, 2),
                }
            )
            continue
        for r in rows:
            items = ",".join(f"{k}={v}" for k, v in r.items())
            print(f"{name},{items}")
        for c in checks:
            print(f"  [check] {c}")
            if "MISMATCH" in c:
                mismatches += 1
        print(f"  ({time.time()-t0:.1f}s)")
        results.append(harness.result_dict(name, rows, checks, time.time() - t0))
    print(f"\nsummary: {len(MODULES)} benchmarks, {failures} errors, {mismatches} claim mismatches")
    if json_path:
        harness.write_json(json_path, results)
        print(f"wrote {json_path}")
    return 1 if failures or (quick and mismatches) else 0


if __name__ == "__main__":
    raise SystemExit(main())
