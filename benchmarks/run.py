"""Benchmark runner: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run

Prints, per benchmark, a ``name,metric,value`` CSV block followed by the
claim-validation lines (paper number vs measured).
"""

from __future__ import annotations

import time
import traceback

from benchmarks import (
    cross_dc,
    elastic,
    failover,
    fanout,
    micro_bandwidth,
    micro_burst,
    micro_failure,
    perf_transfer,
    roofline,
    standalone,
    swarm,
)

MODULES = [
    ("fig7a_bandwidth", micro_bandwidth),
    ("fig7b_burst", micro_burst),
    ("fig7c_failure", micro_failure),
    ("fanout_scheduler", fanout),
    ("swarm_replication", swarm),
    ("failover_control_plane", failover),
    ("fig9_standalone", standalone),
    ("fig11_elastic", elastic),
    ("fig12_cross_dc", cross_dc),
    ("perf_transfer_iterations", perf_transfer),
    ("roofline_table", roofline),
]


def main() -> int:
    failures = 0
    mismatches = 0
    for name, mod in MODULES:
        print(f"\n=== {name} ===")
        t0 = time.time()
        try:
            rows = mod.run()
            checks = mod.validate(rows)
        except Exception:  # noqa: BLE001 - keep running remaining figures
            traceback.print_exc()
            failures += 1
            continue
        for r in rows:
            items = ",".join(f"{k}={v}" for k, v in r.items())
            print(f"{name},{items}")
        for c in checks:
            print(f"  [check] {c}")
            if "MISMATCH" in c:
                mismatches += 1
        print(f"  ({time.time()-t0:.1f}s)")
    print(f"\nsummary: {len(MODULES)} benchmarks, {failures} errors, {mismatches} claim mismatches")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
