"""Telemetry-plane overhead — the recorder must be ~free when disabled.

The tentpole's contract is that the observability plane is *opt-in*: with
the shared ``DISABLED`` recorder (the default) every hot-path call site is
a single ``if rec.enabled`` branch, so the update path must stay within
2% of a build with no telemetry at all. This bench measures that three
ways:

* **recorder_ops**: per-op cost of ``span``/``event``/``counter_add`` on
  an enabled recorder, times the op count one real ``update("latest")``
  emits. This *projected* cost is the deterministic <2% CI gate — it is
  immune to scheduler noise.
* **threaded_update**: end-to-end warm ``update("latest")`` cycles with
  the recorder toggled per-cycle on a single rig, ABBA block schedule,
  median of paired block deltas. This validates the projection in situ,
  but on a shared box the residual noise floor is a few hundred us per
  16 ms op, so its gate is necessarily looser.
* **sim**: an identical ``SimCluster`` fan-out run with ``telemetry=True``
  vs off — spans ride every flow here, so this row bounds the *enabled*
  cost rather than the disabled one (context, loose gate).

Measurement notes (hard-won): twin disabled/enabled rigs are unusable —
within-cycle ordering alone swings the delta by +-4 ms (cache pollution
between back-to-back 12 MB updates). A single rig with a toggled
``rec.enabled`` still shows a ~1 ms period-2 sawtooth when payload
tensors are regenerated every cycle (allocator churn), hence the
pre-built ping-pong payloads. The ABBA schedule cancels linear drift
and any residual period-2 component within each block.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import ReferenceServer, TensorHubClient
from repro.obs import Recorder
from repro.transfer.simcluster import SimCluster

try:
    from benchmarks import harness
except ImportError:  # invoked directly: benchmarks/ itself is sys.path[0]
    import harness

N_TENSORS = 2
ELEMS = 1 << 19  # 2 MB per tensor, f32


class _UpdateRig:
    """One publisher/reader pair on its own server, cycled warm-update
    style: roll a version, time ``update("latest")`` only. The recorder
    stays attached for the rig's lifetime; cycles toggle ``enabled``."""

    def __init__(self) -> None:
        # window=1 / chunk_bytes=None pins the pull to the sequential
        # data plane (no worker threads): the windowed executor's 20 ms
        # condition-variable poll quantum swamps a sub-2% comparison
        # with scheduler noise, while the sequential path is
        # deterministic copy + checksum work
        self.rec = Recorder()
        hub = TensorHubClient(
            ReferenceServer(), recorder=self.rec, window=1, chunk_bytes=None
        )
        rng = np.random.RandomState(0)
        # two pre-built payload versions, ping-ponged between cycles:
        # regenerating tensors each cycle churns the allocator into a
        # period-2 latency sawtooth larger than the telemetry signal
        self.payloads = [
            {
                f"w{i}": rng.randn(ELEMS).astype(np.float32)
                for i in range(N_TENSORS)
            }
            for _ in range(2)
        ]
        self.pub = hub.open("m", "pub", 1, 0)
        self.pub.register(self.payloads[0])
        self.rdr = hub.open("m", "r", 1, 0)
        self.rdr.register(
            {f"w{i}": np.zeros(ELEMS, np.float32) for i in range(N_TENSORS)}
        )
        self.pub.publish(0)
        self.rdr.replicate(0)
        self.version = 0

    def cycle_us(self, enabled: bool) -> float:
        self.version += 1
        self.pub.unpublish()
        self.pub.store.register(self.payloads[self.version % 2])
        self.pub.publish(self.version)
        self.rec.enabled = enabled
        t0 = time.perf_counter()
        updated = self.rdr.update("latest")
        dt = time.perf_counter() - t0
        assert updated
        self.rec.enabled = True
        self.rec.clear()  # bound memory; keeps the recording cost live
        return dt * 1e6


def _abba_delta_us(rig: _UpdateRig, blocks: int) -> Dict[str, object]:
    """Median paired enabled-minus-disabled delta over ABBA blocks
    (disabled, enabled, enabled, disabled), plus the raw medians."""
    deltas: List[float] = []
    off: List[float] = []
    on: List[float] = []
    for _ in range(blocks):
        ts = []
        for flag in (False, True, True, False):
            ts.append(rig.cycle_us(flag))
        off.extend((ts[0], ts[3]))
        on.extend((ts[1], ts[2]))
        deltas.append((ts[1] + ts[2]) / 2 - (ts[0] + ts[3]) / 2)
    return {
        "delta_us": _median(deltas),
        "off_us": _median(off),
        "on_us": _median(on),
    }


def _recorder_op_ns(reps: int = 50_000) -> Dict[str, float]:
    """Per-op cost (ns) of the three hot recorder primitives."""
    rec = Recorder()
    best: Dict[str, float] = {}
    for _ in range(3):
        rec.clear()
        t0 = time.perf_counter()
        for _ in range(reps):
            with rec.span("s", track="t"):
                pass
        best["span_ns"] = min(
            best.get("span_ns", float("inf")),
            (time.perf_counter() - t0) / reps * 1e9,
        )
        rec.clear()
        t0 = time.perf_counter()
        for _ in range(reps):
            rec.event("e", track="t")
        best["event_ns"] = min(
            best.get("event_ns", float("inf")),
            (time.perf_counter() - t0) / reps * 1e9,
        )
        t0 = time.perf_counter()
        for _ in range(reps):
            rec.counter_add("c", 1.0)
        best["counter_ns"] = min(
            best.get("counter_ns", float("inf")),
            (time.perf_counter() - t0) / reps * 1e9,
        )
    rec.clear()
    return best


def _ops_per_update(rig: _UpdateRig) -> int:
    """Count recorder ops one enabled update emits: recorded events
    (spans + instants) plus counter_add calls (counted via a shadowing
    instance-attribute wrapper, removed afterwards)."""
    calls = [0]
    real = rig.rec.counter_add

    def counting(name, value):
        calls[0] += 1
        real(name, value)

    rig.rec.counter_add = counting  # type: ignore[method-assign]
    try:
        rig.rec.enabled = True
        rig.version += 1
        rig.pub.unpublish()
        rig.pub.store.register(rig.payloads[rig.version % 2])
        rig.pub.publish(rig.version)
        rig.rec.clear()
        assert rig.rdr.update("latest")
        n = len(rig.rec.events) + calls[0]
    finally:
        del rig.rec.counter_add
        rig.rec.clear()
    return n


def _sim_wall_s() -> float:
    """One deterministic fan-out run; wall time of the event loop. The
    grid is sized so the wall is tens of ms — small enough for a smoke
    job, large enough that scheduler jitter stays a small fraction."""
    t0 = time.perf_counter()
    cl = SimCluster(telemetry=getattr(_sim_wall_s, "telemetry", False))
    units = [1e9] * 32
    pubs = [cl.add_replica("m", f"pub{i}", 2, unit_bytes=units) for i in range(2)]
    dests = [cl.add_replica("m", f"dst{i}", 2, unit_bytes=units) for i in range(8)]
    for r in pubs + dests:
        r.open()
    cl.run()
    pubs[0].publish(0)
    cl.run()
    for p in pubs[1:]:
        p.replicate("latest")
    cl.run()
    for d in dests:
        d.replicate("latest")
    cl.run()
    return time.perf_counter() - t0


def _median(xs: List[float]) -> float:
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


def run(quick: bool = False) -> List[Dict]:
    blocks = 15 if quick else 40
    sim_repeats = 3 if quick else 5
    rig = _UpdateRig()
    for flag in (False, True, False, True, False, True):  # warm everything
        rig.cycle_us(flag)

    ops = _ops_per_update(rig)
    op_ns = _recorder_op_ns()
    # every op is at most a span (the priciest primitive), so ops *
    # span_ns upper-bounds the recording cost of one update
    projected_us = ops * op_ns["span_ns"] / 1e3

    abba = _abba_delta_us(rig, blocks)

    sim_runs: Dict[bool, List[float]] = {False: [], True: []}
    for _ in range(sim_repeats):
        for tel in (False, True):
            _sim_wall_s.telemetry = tel
            sim_runs[tel].append(_sim_wall_s())

    rows: List[Dict] = [
        {
            "bench": "recorder_ops",
            "span_ns": round(op_ns["span_ns"], 1),
            "event_ns": round(op_ns["event_ns"], 1),
            "counter_ns": round(op_ns["counter_ns"], 1),
            "ops_per_update": ops,
            "projected_add_us": round(projected_us, 2),
        },
        {
            "bench": "threaded_update",
            "variant": "disabled",
            "update_us": round(abba["off_us"], 1),
            "paired_delta_us": 0.0,
            "overhead_pct": 0.0,
        },
        {
            "bench": "threaded_update",
            "variant": "enabled",
            "update_us": round(abba["on_us"], 1),
            "paired_delta_us": round(abba["delta_us"], 1),
            # the paired estimate, not the ratio of independent medians:
            # the per-block delta cancels common-mode load
            "overhead_pct": round(100.0 * abba["delta_us"] / abba["off_us"], 2),
        },
    ]
    sim_off, sim_on = min(sim_runs[False]), min(sim_runs[True])
    rows.append(
        {
            "bench": "sim_fanout",
            "wall_off_ms": round(sim_off * 1e3, 1),
            "wall_on_ms": round(sim_on * 1e3, 1),
            "overhead_pct": round(100.0 * (sim_on / sim_off - 1.0), 2),
        }
    )
    return rows


def validate(rows: List[Dict]) -> List[str]:
    checks = []
    ops = next(r for r in rows if r["bench"] == "recorder_ops")
    by_var = {r["variant"]: r for r in rows if r["bench"] == "threaded_update"}
    base_us = by_var["disabled"]["update_us"]
    # the deterministic <2% gate: per-op recorder cost projected onto
    # the ops one real update emits, vs the measured update time
    proj_pct = 100.0 * ops["projected_add_us"] / base_us
    checks.append(
        f"recorder cost projected onto update path "
        f"{ops['projected_add_us']}us / {base_us}us = {proj_pct:.3f}% "
        f"({ops['ops_per_update']} ops @ {ops['span_ns']}ns; required < 2%) -> "
        f"{'OK' if proj_pct < 2.0 else 'MISMATCH'}"
    )
    en = by_var["enabled"]
    add_us = en["paired_delta_us"]
    # in-situ tripwire, not the <2% gate (that's the projection above):
    # the ABBA-paired noise floor on a shared box is still most of a ms
    # per ~16 ms op, so this only catches gross regressions — e.g. real
    # work accidentally landing on the disabled branch shows up as +ms
    ok = en["overhead_pct"] < 5.0 or add_us < 1500.0
    checks.append(
        f"recorder-enabled update path end-to-end {en['overhead_pct']}% "
        f"({add_us:+.1f}us/op paired; gross-regression tripwire, "
        f"required < 5% or < +1500us) -> {'OK' if ok else 'MISMATCH'}"
    )
    sim = next(r for r in rows if r["bench"] == "sim_fanout")
    checks.append(
        f"sim telemetry=True wall overhead {sim['overhead_pct']}% "
        f"(spans on every flow; required < 50%) -> "
        f"{'OK' if sim['overhead_pct'] < 50.0 else 'MISMATCH'}"
    )
    return checks


if __name__ == "__main__":
    harness.bench_main("obs_overhead", run, validate)
