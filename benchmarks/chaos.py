"""Chaos sweep — gray-failure resilience across both data planes.

Runs a deterministic, seeded fault schedule (``repro.transfer.faults``)
against the same trainer -> rollout-A -> rollout-B pipeline on the sim
plane (virtual time, fluid flows) and a publisher -> peer -> destination
pull on the threaded plane (real bytes through ``LocalTransport``), for
each gray-fault kind: straggler (slow source), flaky (transient read
errors), corrupt (byte flips caught by checksums), and hang (reads
stall until detection).

Validates the self-healing contract:

* every pull completes; on the threaded plane the delivered bytes are
  identical to the published tensors (the corruption oracle);
* single-source straggling inflates rollout-B's stall at most 2x over
  the fault-free single-source baseline (deadline detection + source
  quarantine re-route, not a full-transfer hang);
* transient-only schedules evict **zero** replicas — suspect sources
  are quarantined with probation, never removed;
* corrupt sources are quarantined on first checksum-verified evidence;
* identical seed => identical per-worker stall decomposition on the sim
  plane (the bit-for-bit replay the fault plan promises).
"""

from __future__ import annotations

import threading
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks import harness
from repro.core import ReferenceServer, TensorHubClient
from repro.obs import telemetry as obs
from repro.transfer.faults import (
    FaultPlan,
    FaultSpec,
    RetryPolicy,
    ThreadedFaultInjector,
)
from repro.transfer.simcluster import SimCluster

GB = 1e9
SEED = 7

#: sim-plane healing knobs, scaled to the scenario's ~0.04 s healthy
#: unit fetch: deadline a few fetches out, quick backoff, early hedging
SIM_POLICY = RetryPolicy(
    fail_detect=0.1,
    retry_limit=4,
    retry_backoff=0.02,
    hedge_threshold=3.0,
    hedge_min_samples=2,
)

#: threaded-plane knobs against the wall clock (reads are ~sub-ms)
THREADED_POLICY = RetryPolicy(
    fail_detect=0.25,
    retry_limit=5,
    retry_backoff=0.01,
    hedge_threshold=4.0,
    hedge_min_samples=2,
)

#: fault kind -> spec against the gray source ("ra" on the sim plane,
#: "peer" on the threaded plane). slow/hang degrade only the serving
#: ("up") direction on the sim plane so rollout-A's own warm-up pull
#: stays healthy — the gray node serves badly but reads fine.
SIM_FAULTS = {
    "baseline": (),
    "straggler": (FaultSpec("slow", "ra", severity=0.05, direction="up"),),
    "flaky": (FaultSpec("flaky", "ra", severity=0.25),),
    "corrupt": (FaultSpec("corrupt", "ra", severity=1.0),),
    "hang": (FaultSpec("hang", "ra", direction="up"),),
}

#: the threaded scheduler prefers the shallow publisher, so the gray
#: faults target "pub" — healing must quarantine it and re-route the
#: destination onto the healthy warmed-up peer
THREADED_FAULTS = {
    "baseline": (),
    "straggler": (FaultSpec("slow", "pub", stall=0.02),),
    "flaky": (FaultSpec("flaky", "pub", severity=0.65),),
    "corrupt": (FaultSpec("corrupt", "pub", severity=1.0),),
    "hang": (FaultSpec("hang", "pub", duration=1.0),),
}

SCENARIOS = ("baseline", "straggler", "flaky", "corrupt", "hang")


def _heal_counters(counters: Dict[str, float]) -> Dict[str, int]:
    return {
        "retries": int(counters.get(obs.CTR_RETRIES, 0)),
        "hedges": int(counters.get(obs.CTR_HEDGES, 0)),
        "corrupt_rejects": int(counters.get(obs.CTR_CORRUPT_REJECTS, 0)),
        "deadline_reports": int(counters.get(obs.CTR_DEADLINE_REPORTS, 0)),
    }


# -- sim plane ---------------------------------------------------------------


def _sim_once(
    kind: str, *, units_per_shard: int, max_sources: int
) -> Tuple[Dict[str, object], List]:
    """One seeded sim run; returns (row, per-worker stall decomposition)."""
    cl = SimCluster(
        retry_policy=SIM_POLICY,
        telemetry=True,
        max_sources=max_sources,
        quarantine_threshold=2,
        quarantine_probation=5.0,
    )
    units = [GB] * units_per_shard
    tr = cl.add_replica("m", "trainer", 2, unit_bytes=units)
    ra = cl.add_replica("m", "ra", 2, unit_bytes=units)
    rb = cl.add_replica("m", "rb", 2, unit_bytes=units)
    tr.open(), ra.open(), rb.open()
    cl.run()
    tr.publish(0)
    cl.run()
    cl.install_faults(FaultPlan(seed=SEED, faults=SIM_FAULTS[kind]))
    t0 = cl.env.now
    # A pulls from the trainer; B is scheduled onto A (pipeline), so B
    # reads through the gray source and must heal around it
    ra.replicate("latest")
    done_b = rb.replicate("latest")
    cl.run()
    completed = bool(done_b.triggered) and done_b.error is None
    decomp = [
        (wid, tuple(sorted(w.stall_parts.items())), round(w.total_stall, 12))
        for (wid, w) in sorted(
            ((f"{r}/{i}", w) for (r, i), w in cl._workers.items())  # noqa: SLF001
        )
    ]
    row = {
        "plane": "sim",
        "scenario": kind,
        "sources": max_sources,
        "completed": completed,
        "b_stall_s": round(max(s.worker.total_stall for s in rb.shards), 3),
        "wall_s": round(cl.env.now - t0, 3),
        "quarantines": cl.server.stats["quarantines"],
        "evictions": cl.server.stats["evictions"],
        **_heal_counters(cl.recorder.counters),
    }
    return row, decomp


def sim_scenario(
    kind: str, *, units_per_shard: int, max_sources: int = 4
) -> Dict[str, object]:
    """Run the scenario twice from the same seed; identical per-worker
    stall decomposition is the sim plane's determinism oracle."""
    row, decomp1 = _sim_once(
        kind, units_per_shard=units_per_shard, max_sources=max_sources
    )
    _, decomp2 = _sim_once(
        kind, units_per_shard=units_per_shard, max_sources=max_sources
    )
    row["deterministic"] = decomp1 == decomp2
    return row


# -- threaded plane ----------------------------------------------------------


def _run_group(handles, fn) -> None:
    errs: List[BaseException] = []

    def wrap(h):
        try:
            fn(h)
        except BaseException as e:  # noqa: BLE001
            errs.append(e)

    ts = [threading.Thread(target=wrap, args=(h,)) for h in handles]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
    if errs:
        raise errs[0]


def threaded_scenario(kind: str, *, n_tensors: int) -> Dict[str, object]:
    def mk(seed: float):
        return {
            f"w{i}": np.full((64, 32), seed + i, dtype=np.float32)
            for i in range(n_tensors)
        }

    server = ReferenceServer(quarantine_threshold=2, quarantine_probation=60.0)
    rec = obs.Recorder()
    inj = ThreadedFaultInjector(FaultPlan(seed=SEED, faults=THREADED_FAULTS[kind]))
    # the publisher and peer warm up through a fault-free transport; the
    # destination pulls through a second client (same worker registry)
    # whose transport carries the gray-fault injector
    clean = TensorHubClient(server)
    hub = TensorHubClient(
        server,
        registry=clean.registry,
        recorder=rec,
        retry_policy=THREADED_POLICY,
        faults=inj,
    )
    pubs = [clean.open("m", "pub", 2, i) for i in range(2)]
    for h in pubs:
        h.register(mk(3.0))
    _run_group(pubs, lambda h: h.publish(0))
    # healthy alternate: the peer replicates fault-free and stands by as
    # the re-route target once the gray publisher is quarantined
    peers = [clean.open("m", "peer", 2, i) for i in range(2)]
    for h in peers:
        h.register(mk(0.0))
    _run_group(peers, lambda h: h.replicate("latest"))
    dests = [hub.open("m", "dest", 2, i) for i in range(2)]
    for h in dests:
        h.register(mk(0.0))
    inj.arm()
    t0 = hub.clock()
    err: Optional[BaseException] = None
    try:
        _run_group(dests, lambda h: h.replicate("latest"))
    except BaseException as e:  # noqa: BLE001
        err = e
    wall = hub.clock() - t0
    inj.release()  # drain any reader threads still blocked in a hang
    want = mk(3.0)
    bytes_ok = err is None and all(
        np.array_equal(h.store.get(k), v) for h in dests for k, v in want.items()
    )
    return {
        "plane": "threaded",
        "scenario": kind,
        "completed": err is None,
        "bytes_ok": bytes_ok,
        "wall_s": round(wall, 3),
        "quarantines": server.stats["quarantines"],
        "evictions": server.stats["evictions"],
        **_heal_counters(rec.counters),
    }


# -- sweep -------------------------------------------------------------------


def run(quick: bool = False) -> List[Dict]:
    units = 4 if quick else 8
    n_tensors = 4 if quick else 6
    rows: List[Dict] = []
    for kind in SCENARIOS:
        rows.append(sim_scenario(kind, units_per_shard=units))
    # single-source straggler pair: quarantine re-route must bound the
    # stall at <=2x the fault-free single-source transfer
    rows.append(
        sim_scenario("baseline", units_per_shard=units, max_sources=1)
    )
    rows.append(
        sim_scenario("straggler", units_per_shard=units, max_sources=1)
    )
    for kind in SCENARIOS:
        rows.append(threaded_scenario(kind, n_tensors=n_tensors))
    return rows


def validate(rows: List[Dict]) -> List[str]:
    checks = []
    sim1 = {
        r["scenario"]: r
        for r in rows
        if r["plane"] == "sim" and r["sources"] == 1
    }
    thr = {r["scenario"]: r for r in rows if r["plane"] == "threaded"}

    done = all(r["completed"] for r in rows)
    checks.append(
        f"every pull completes under faults ({len(rows)} runs) "
        f"-> {'OK' if done else 'MISMATCH'}"
    )
    identical = all(r["bytes_ok"] for r in thr.values())
    checks.append(
        "threaded bytes identical to published (corruption oracle) "
        f"-> {'OK' if identical else 'MISMATCH'}"
    )
    ratio = sim1["straggler"]["b_stall_s"] / max(
        sim1["baseline"]["b_stall_s"], 1e-9
    )
    checks.append(
        f"single-source straggler stall x{ratio:.2f} of fault-free "
        f"(<=2x via quarantine re-route) -> {'OK' if ratio <= 2.0 else 'MISMATCH'}"
    )
    evict = sum(r["evictions"] for r in rows)
    checks.append(
        f"transient-only schedules evict zero replicas ({evict} evictions) "
        f"-> {'OK' if evict == 0 else 'MISMATCH'}"
    )
    quar = all(
        d["corrupt"]["quarantines"] >= 1 and d["corrupt"]["corrupt_rejects"] >= 1
        for d in ({r["scenario"]: r for r in rows if r["plane"] == "sim"}, thr)
    )
    checks.append(
        "corrupt source quarantined on checksum evidence (both planes) "
        f"-> {'OK' if quar else 'MISMATCH'}"
    )
    det = all(r["deterministic"] for r in rows if r["plane"] == "sim")
    checks.append(
        "identical seed => identical sim stall decomposition "
        f"-> {'OK' if det else 'MISMATCH'}"
    )
    healed = (
        thr["flaky"]["retries"] >= 1
        and {r["scenario"]: r for r in rows if r["plane"] == "sim"}["flaky"][
            "retries"
        ]
        >= 1
    )
    checks.append(
        f"flaky reads healed by bounded retries -> {'OK' if healed else 'MISMATCH'}"
    )
    return checks


if __name__ == "__main__":
    harness.bench_main("chaos", run, validate)
