"""Cross-layout resharding — rollout scale events that change TP degree.

Real RL fleets reshard on every transfer: training TP x PP rarely matches
inference TP, and elastic rollout pools re-spawn with whatever shard
count fits the freed GPUs (ROSE / MindSpeed-RL). This benchmark measures
the stall a rollout replica pays when it joins with a *different* shard
layout than the publisher, served by the striped interval reads of
``repro.resharding`` in the virtual-time simulator:

* TP-4 -> TP-2 (scale-down: fewer, fatter shards; dest NIC-bound)
* TP-2 -> TP-8 (scale-up: more, thinner shards; source NICs fan out)

Baseline: *gather-then-slice* — without a resharding planner the rollout
does what naive implementations do: every destination shard fetches the
full global state dict (all-gather) and slices its block locally. Each
source shard's NIC then ships its bytes to every destination shard
instead of exactly once, so the transfer serializes on source links as
the destination count grows.

Codec parity (``codec_parity``): the resharded interval path is now
codec-capable — the same two scale events run raw vs int8 on both data
planes. The threaded rows move REAL bytes (publish in dc0, reshard-pull
in dc1, per-link-class wire counters); the sim rows use the fluid
network's matching counters. The WAN byte-reduction ratio must agree
between the planes (< 2%), a forced-raw reshard must stay bit-exact with
the publisher, and the resharded int8 decode must be byte-identical to a
same-layout int8 pull of the same weights (row-aligned shard splits
share the quantization grid). ``fused_vs_staged`` times the fused
dequant+repack against the decode-trim-stage-repack pipeline over one
planned reshard and checks the fused path wins without exceeding the
HBM roofline.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.configs.paper_workloads import WORKLOADS
from repro.transfer.hardware import CLUSTER, TPU
from repro.transfer.simcluster import SimCluster

W = WORKLOADS["36B"]  # canonical 4-shard trainer layout
NUM_UNITS = 16

#: (name, publisher TP, rollout TP)
SCENARIOS = [
    ("TP-4 -> TP-2", 4, 2),
    ("TP-2 -> TP-8", 2, 8),
]


def _global_units() -> List[int]:
    return [b * W.num_shards for b in W.unit_bytes(NUM_UNITS)]


def tensorhub_reshard(src_tp: int, dst_tp: int) -> Dict[str, object]:
    """Publisher with ``src_tp`` shards, rollout joins with ``dst_tp``."""
    cl = SimCluster()
    units = _global_units()
    tr = cl.add_replica("m", "tr0", src_tp, global_unit_bytes=units)
    ro = cl.add_replica("m", "ro0", dst_tp, global_unit_bytes=units)
    tr.open()
    ro.open()
    cl.run()
    tr.publish(0)
    cl.run()
    ev = ro.replicate("latest")
    cl.run()
    assert ev.triggered and ev.error is None, ev.error
    per = [s.worker.total_stall for s in ro.shards]

    # striping evidence: per-dest-shard plan fan-out across source shards
    from repro.resharding import layout_from_manifests, plan_reshard

    src_layout = layout_from_manifests(
        {i: tr.manifest_for(i) for i in range(src_tp)}, src_tp
    )
    dst_layout = layout_from_manifests(
        {i: ro.manifest_for(i) for i in range(dst_tp)}, dst_tp
    )
    plan = plan_reshard(src_layout, dst_layout)
    fanout = [len(p.source_shards_used) for p in plan.shards]
    loads = [sum(p.bytes_per_source.get(j, 0) for p in plan.shards) for j in range(src_tp)]
    return {
        "mean_stall": sum(per) / len(per),
        "max_stall": max(per),
        "sources_per_dest_shard": fanout,
        "bytes_per_source_shard": loads,
    }


def naive_gather(src_tp: int, dst_tp: int) -> Dict[str, object]:
    """Gather-then-slice baseline: every dest shard all-gathers the full
    global model and slices locally. Source shard j's NIC ships its owned
    bytes ``dst_tp`` times; every dest NIC receives the full model."""
    hw = CLUSTER
    total = float(sum(_global_units()))
    owned = total / src_tp
    bw = hw.tensorhub_rdma_eff * hw.rdma_per_shard
    stall = max(dst_tp * owned / bw, total / bw) + hw.driver_rpc
    return {"mean_stall": stall, "max_stall": stall}


def _bench_tensors(rows: int = 16384) -> Dict[str, np.ndarray]:
    """Weights whose TP-{2,4,8} slices are whole multiples of the int8
    codec's 256-element row, so a resharded int8 decode is comparable
    bit-for-bit against a same-layout int8 pull."""
    rng = np.random.RandomState(7)
    return {
        "w": (rng.randn(rows, 64) * 2).astype(np.float32),
        "b": rng.randn(8192).astype(np.float32),
    }


def _threaded_reshard(
    tensors: Dict[str, np.ndarray], src_tp: int, dst_tp: int, wan_codec: str
):
    """Publish ``src_tp`` shards in dc0, reshard-pull ``dst_tp`` shards
    in dc1 on the threaded plane; returns (dest handles, WAN wire bytes,
    WAN decoded bytes)."""
    import threading

    from repro.core import ReferenceServer, TensorHubClient
    from repro.resharding import tp_shard

    hub = TensorHubClient(ReferenceServer(wan_codec=wan_codec))

    def group(name, tp, dc, zeros):
        hs = [hub.open("m", name, tp, i, datacenter=dc) for i in range(tp)]
        for h in hs:
            local, lay = tp_shard(tensors, h.shard_idx, tp)
            if zeros:
                local = {k: np.zeros_like(v) for k, v in local.items()}
            h.register(local, layout=lay)
        return hs

    def run_all(hs, fn):
        ts = [threading.Thread(target=fn, args=(h,)) for h in hs]
        for t in ts:
            t.start()
        for t in ts:
            t.join()

    run_all(group("pub", src_tp, "dc0", False), lambda h: h.publish(0))
    subs = group("sub", dst_tp, "dc1", True)
    run_all(subs, lambda h: h.replicate(0))
    return (
        subs,
        int(hub.transport.wire_bytes.get("vpc_up", 0)),
        int(hub.transport.decoded_bytes.get("vpc_up", 0)),
    )


def _sim_reshard(src_tp: int, dst_tp: int, wan_codec: str):
    """Same scale event in the virtual-time simulator; returns (WAN wire
    bytes, rollout stall decomposition)."""
    cl = SimCluster(wan_codec=wan_codec)
    units = _global_units()
    tr = cl.add_replica("m", "tr0", src_tp, global_unit_bytes=units)
    ro = cl.add_replica(
        "m", "ro0", dst_tp, datacenter="dc1", global_unit_bytes=units
    )
    tr.open()
    ro.open()
    cl.run()
    tr.publish(0)
    cl.run()
    ev = ro.replicate("latest")
    cl.run()
    assert ev.triggered and ev.error is None, ev.error
    return cl.link_class_bytes().get("vpc_up", 0.0), cl.stall_decomposition(["ro0"])


def codec_parity(src_tp: int, dst_tp: int, *, rows: int = 16384) -> Dict[str, object]:
    """Raw-vs-int8 wire bytes for one cross-DC reshard, on both planes."""
    from repro.resharding import tp_shard

    tensors = _bench_tensors(rows)
    total = sum(v.nbytes for v in tensors.values())
    moved: Dict[str, int] = {}
    raw_exact = int8_identical = False
    for codec in ("raw", "int8"):
        subs, wire, decoded = _threaded_reshard(tensors, src_tp, dst_tp, codec)
        moved[codec] = wire
        if codec == "raw":
            raw_exact = wire == decoded == total and all(
                np.array_equal(
                    h.store.get(k).view(np.uint8), v.view(np.uint8)
                )
                for h in subs
                for k, v in tp_shard(tensors, h.shard_idx, dst_tp)[0].items()
            )
        else:
            # byte identity vs a same-layout int8 pull of the same weights
            same, _, _ = _threaded_reshard(tensors, dst_tp, dst_tp, "int8")
            int8_identical = all(
                np.array_equal(
                    a.store.get(k).view(np.uint8),
                    b.store.get(k).view(np.uint8),
                )
                for a, b in zip(subs, same)
                for k in tensors
            )
    sim_raw, _ = _sim_reshard(src_tp, dst_tp, "raw")
    sim_int8, parts = _sim_reshard(src_tp, dst_tp, "int8")
    th_red = moved["raw"] / moved["int8"]
    sim_red = sim_raw / sim_int8
    stall = sum(parts.values())
    return {
        "system": f"codec-parity {SCENARIO_NAME[(src_tp, dst_tp)]}",
        "threaded_raw_mb": round(moved["raw"] / 1e6, 3),
        "threaded_int8_mb": round(moved["int8"] / 1e6, 3),
        "threaded_reduction_x": round(th_red, 3),
        "sim_reduction_x": round(sim_red, 3),
        "plane_ratio_gap_pct": round(abs(th_red - sim_red) / sim_red * 100, 3),
        "raw_bit_exact": raw_exact,
        "int8_matches_same_layout": int8_identical,
        "sim_decode_stall_pct": round(
            parts.get("decode", 0.0) / stall * 100 if stall else 0.0, 2
        ),
    }


def fused_vs_staged(*, mb: int = 48) -> Dict[str, object]:
    """Time fused dequant+repack against decode-trim-stage-repack over
    one planned TP-4 -> TP-2 int8 reshard (host path, best of 3)."""
    from repro.resharding import ReshardExecutor, layout_from_manifests, plan_shard
    from repro.transfer.codec import get_codec
    from repro.transfer.simcluster import make_layout_manifests

    # element counts not divisible by 256: shard boundaries land mid-row,
    # so plans carry real lead/tail widening like production layouts do
    sizes = [
        (mb * (1 << 20) * 2 // 3 // 4 + 129) * 4,
        (mb * (1 << 20) // 3 // 4 + 37) * 4,
    ]
    src_tp, dst_tp = 4, 2
    src = layout_from_manifests(
        dict(enumerate(make_layout_manifests(sizes, src_tp, dtype="float32"))),
        src_tp,
    )
    dst_manifests = make_layout_manifests(sizes, dst_tp, dtype="float32")
    dst = layout_from_manifests(dict(enumerate(dst_manifests)), dst_tp)
    c = get_codec("int8")
    rng = np.random.RandomState(11)
    work = []  # (executor, unit, placed, frames) per dest unit
    out_bytes = wire_bytes = 0
    for shard in range(dst_tp):
        plan = plan_shard(
            src, dst, shard,
            num_dest_units=dst_manifests[shard].num_units, codec="int8",
        )
        ex = ReshardExecutor(plan, dst_manifests[shard])
        for unit, placed in ex.unit_batches():
            frames = []
            for p in placed:
                iv = p.interval
                payload = (
                    rng.randn(iv.read_nbytes // 4).astype(np.float32)
                    .view(np.uint8).reshape(-1)
                )
                frames.append(c.encode(payload, "float32"))
            work.append((ex, unit, placed, frames))
            out_bytes += unit.nbytes
            wire_bytes += sum(f.nbytes for f in frames)

    def staged_pass():
        for ex, unit, placed, frames in work:
            staging = ex.make_staging(unit.index)
            for p, wire in zip(placed, frames):
                iv = p.interval
                staging[p.staging_offset : p.staging_offset + iv.nbytes] = (
                    c.decode(wire)[iv.lead : iv.lead + iv.nbytes]
                )
            ex.repack(unit.index, staging)

    def fused_pass():
        for ex, unit, placed, frames in work:
            ex.fused_repack(unit.index, frames)

    def best_of(fn, n=3):
        times = []
        for _ in range(n):
            t0 = time.perf_counter()
            fn()
            times.append(time.perf_counter() - t0)
        return min(times)

    staged_s = best_of(staged_pass)
    fused_s = best_of(fused_pass)
    # roofline floor: the decode must at least read the wire and write
    # the repacked unit once through HBM
    roofline_s = (wire_bytes + out_bytes) / TPU.hbm_bw
    return {
        "system": "fused-vs-staged dequant+repack (TP-4 -> TP-2, int8)",
        "payload_mb": round(out_bytes / 1e6, 1),
        "staged_gbps": round(out_bytes / staged_s / 1e9, 2),
        "fused_gbps": round(out_bytes / fused_s / 1e9, 2),
        "fused_speedup_x": round(staged_s / fused_s, 2),
        "roofline_headroom_x": round(fused_s / roofline_s, 1),
    }


SCENARIO_NAME = {(s, d): n for n, s, d in SCENARIOS}


def run(quick: bool = False) -> List[Dict]:
    rows = []
    for name, src_tp, dst_tp in SCENARIOS:
        th = tensorhub_reshard(src_tp, dst_tp)
        naive = naive_gather(src_tp, dst_tp)
        rows.append(
            {
                "scenario": name,
                "tensorhub_max_s": round(th["max_stall"], 2),
                "naive_max_s": round(naive["max_stall"], 2),
                "speedup": round(naive["max_stall"] / th["max_stall"], 1),
                "sources_per_dest_shard": th["sources_per_dest_shard"],
                "src_load_gb": [round(b / 1e9, 1) for b in th["bytes_per_source_shard"]],
            }
        )
    t_rows = 8192 if quick else 16384
    for _, src_tp, dst_tp in SCENARIOS:
        rows.append(codec_parity(src_tp, dst_tp, rows=t_rows))
    rows.append(fused_vs_staged(mb=16 if quick else 48))
    return rows


def reshard_source_failure() -> Dict[str, object]:
    """Kill the assigned source replica mid-reshard; the reader must
    re-plan against the surviving (differently-sharded!) replica and
    finish (4.5 re-routing + re-planning)."""
    cl = SimCluster()
    units = _global_units()
    tr = cl.add_replica("m", "tr0", 4, global_unit_bytes=units)
    sa = cl.add_replica("m", "sa0", 2, global_unit_bytes=units)
    ro = cl.add_replica("m", "ro0", 8, global_unit_bytes=units)
    for r in (tr, sa, ro):
        r.open()
    cl.run()
    tr.publish(0)
    cl.run()
    sa.replicate("latest")
    cl.run()  # sa now also holds v0 under a 2-shard layout
    ev = ro.replicate("latest")
    # ro is routed to the least-loaded source; kill the trainer mid-pull so
    # ro must re-plan against sa's 2-shard layout (or vice versa)
    cl.env.schedule(0.8, lambda: cl.kill_replica("tr0"))
    cl.run()
    return {
        "completed": bool(ev.triggered and ev.error is None),
        "stall": round(max(s.worker.total_stall for s in ro.shards), 2),
    }


def validate(rows: List[Dict]) -> List[str]:
    checks = []
    scale = [r for r in rows if "src_load_gb" in r]
    codec = [r for r in rows if "plane_ratio_gap_pct" in r]
    fused = [r for r in rows if "fused_speedup_x" in r]
    down = scale[0]  # TP-4 -> TP-2: each dest slice spans several src shards
    striped = all(n >= 2 for n in down["sources_per_dest_shard"])
    checks.append(
        f"{down['scenario']}: every dest shard stripes across >=2 source "
        f"shards {down['sources_per_dest_shard']} -> "
        f"{'OK' if striped else 'MISMATCH'}"
    )
    for r in scale:
        loads = r["src_load_gb"]
        balanced = max(loads) <= 1.5 * max(min(loads), 0.1)
        checks.append(
            f"{r['scenario']}: every source shard engaged, load balanced "
            f"{loads} GB -> {'OK' if balanced and min(loads) > 0 else 'MISMATCH'}"
        )
    for r in scale:
        checks.append(
            f"{r['scenario']} vs gather-then-slice: x{r['speedup']} "
            f"(naive {r['naive_max_s']}s vs striped {r['tensorhub_max_s']}s) "
            f"-> {'OK' if r['speedup'] >= 2.0 else 'MISMATCH'}"
        )
    for r in codec:
        checks.append(
            f"{r['system']}: raw reshard bit-exact with publisher -> "
            f"{'OK' if r['raw_bit_exact'] else 'MISMATCH'}"
        )
        checks.append(
            f"{r['system']}: int8 wire reduction x{r['threaded_reduction_x']} "
            f"(threaded, real bytes) -> "
            f"{'OK' if r['threaded_reduction_x'] >= 3.5 else 'MISMATCH'}"
        )
        checks.append(
            f"{r['system']}: resharded int8 decode byte-identical to "
            f"same-layout int8 pull -> "
            f"{'OK' if r['int8_matches_same_layout'] else 'MISMATCH'}"
        )
        checks.append(
            f"{r['system']}: sim-vs-threaded WAN byte-ratio gap "
            f"{r['plane_ratio_gap_pct']}% (sim x{r['sim_reduction_x']}) -> "
            f"{'OK' if r['plane_ratio_gap_pct'] < 2.0 else 'MISMATCH'}"
        )
        checks.append(
            f"{r['system']}: decode {r['sim_decode_stall_pct']}% of rollout "
            f"stall decomposition -> "
            f"{'OK' if r['sim_decode_stall_pct'] < 5.0 else 'MISMATCH'}"
        )
    for r in fused:
        ok = r["fused_speedup_x"] >= 1.0 and r["roofline_headroom_x"] >= 1.0
        checks.append(
            f"fused dequant+repack x{r['fused_speedup_x']} vs staged "
            f"({r['fused_gbps']} vs {r['staged_gbps']} GB/s, "
            f"{r['roofline_headroom_x']}x above the HBM roofline floor) -> "
            f"{'OK' if ok else 'MISMATCH'}"
        )
    rec = reshard_source_failure()
    checks.append(
        f"source killed mid-reshard: reader re-planned and completed "
        f"{rec['completed']} (stall {rec['stall']}s) -> "
        f"{'OK' if rec['completed'] else 'MISMATCH'}"
    )
    return checks


if __name__ == "__main__":
    from benchmarks import harness

    harness.bench_main("reshard", run, validate)
