"""Cross-layout resharding — rollout scale events that change TP degree.

Real RL fleets reshard on every transfer: training TP x PP rarely matches
inference TP, and elastic rollout pools re-spawn with whatever shard
count fits the freed GPUs (ROSE / MindSpeed-RL). This benchmark measures
the stall a rollout replica pays when it joins with a *different* shard
layout than the publisher, served by the striped interval reads of
``repro.resharding`` in the virtual-time simulator:

* TP-4 -> TP-2 (scale-down: fewer, fatter shards; dest NIC-bound)
* TP-2 -> TP-8 (scale-up: more, thinner shards; source NICs fan out)

Baseline: *gather-then-slice* — without a resharding planner the rollout
does what naive implementations do: every destination shard fetches the
full global state dict (all-gather) and slices its block locally. Each
source shard's NIC then ships its bytes to every destination shard
instead of exactly once, so the transfer serializes on source links as
the destination count grows.
"""

from __future__ import annotations

from typing import Dict, List

from repro.configs.paper_workloads import WORKLOADS
from repro.transfer.hardware import CLUSTER
from repro.transfer.simcluster import SimCluster

W = WORKLOADS["36B"]  # canonical 4-shard trainer layout
NUM_UNITS = 16

#: (name, publisher TP, rollout TP)
SCENARIOS = [
    ("TP-4 -> TP-2", 4, 2),
    ("TP-2 -> TP-8", 2, 8),
]


def _global_units() -> List[int]:
    return [b * W.num_shards for b in W.unit_bytes(NUM_UNITS)]


def tensorhub_reshard(src_tp: int, dst_tp: int) -> Dict[str, object]:
    """Publisher with ``src_tp`` shards, rollout joins with ``dst_tp``."""
    cl = SimCluster()
    units = _global_units()
    tr = cl.add_replica("m", "tr0", src_tp, global_unit_bytes=units)
    ro = cl.add_replica("m", "ro0", dst_tp, global_unit_bytes=units)
    tr.open()
    ro.open()
    cl.run()
    tr.publish(0)
    cl.run()
    ev = ro.replicate("latest")
    cl.run()
    assert ev.triggered and ev.error is None, ev.error
    per = [s.worker.total_stall for s in ro.shards]

    # striping evidence: per-dest-shard plan fan-out across source shards
    from repro.resharding import layout_from_manifests, plan_reshard

    src_layout = layout_from_manifests(
        {i: tr.manifest_for(i) for i in range(src_tp)}, src_tp
    )
    dst_layout = layout_from_manifests(
        {i: ro.manifest_for(i) for i in range(dst_tp)}, dst_tp
    )
    plan = plan_reshard(src_layout, dst_layout)
    fanout = [len(p.source_shards_used) for p in plan.shards]
    loads = [sum(p.bytes_per_source.get(j, 0) for p in plan.shards) for j in range(src_tp)]
    return {
        "mean_stall": sum(per) / len(per),
        "max_stall": max(per),
        "sources_per_dest_shard": fanout,
        "bytes_per_source_shard": loads,
    }


def naive_gather(src_tp: int, dst_tp: int) -> Dict[str, object]:
    """Gather-then-slice baseline: every dest shard all-gathers the full
    global model and slices locally. Source shard j's NIC ships its owned
    bytes ``dst_tp`` times; every dest NIC receives the full model."""
    hw = CLUSTER
    total = float(sum(_global_units()))
    owned = total / src_tp
    bw = hw.tensorhub_rdma_eff * hw.rdma_per_shard
    stall = max(dst_tp * owned / bw, total / bw) + hw.driver_rpc
    return {"mean_stall": stall, "max_stall": stall}


def run() -> List[Dict]:
    rows = []
    for name, src_tp, dst_tp in SCENARIOS:
        th = tensorhub_reshard(src_tp, dst_tp)
        naive = naive_gather(src_tp, dst_tp)
        rows.append(
            {
                "scenario": name,
                "tensorhub_max_s": round(th["max_stall"], 2),
                "naive_max_s": round(naive["max_stall"], 2),
                "speedup": round(naive["max_stall"] / th["max_stall"], 1),
                "sources_per_dest_shard": th["sources_per_dest_shard"],
                "src_load_gb": [round(b / 1e9, 1) for b in th["bytes_per_source_shard"]],
            }
        )
    return rows


def reshard_source_failure() -> Dict[str, object]:
    """Kill the assigned source replica mid-reshard; the reader must
    re-plan against the surviving (differently-sharded!) replica and
    finish (4.5 re-routing + re-planning)."""
    cl = SimCluster()
    units = _global_units()
    tr = cl.add_replica("m", "tr0", 4, global_unit_bytes=units)
    sa = cl.add_replica("m", "sa0", 2, global_unit_bytes=units)
    ro = cl.add_replica("m", "ro0", 8, global_unit_bytes=units)
    for r in (tr, sa, ro):
        r.open()
    cl.run()
    tr.publish(0)
    cl.run()
    sa.replicate("latest")
    cl.run()  # sa now also holds v0 under a 2-shard layout
    ev = ro.replicate("latest")
    # ro is routed to the least-loaded source; kill the trainer mid-pull so
    # ro must re-plan against sa's 2-shard layout (or vice versa)
    cl.env.schedule(0.8, lambda: cl.kill_replica("tr0"))
    cl.run()
    return {
        "completed": bool(ev.triggered and ev.error is None),
        "stall": round(max(s.worker.total_stall for s in ro.shards), 2),
    }


def validate(rows: List[Dict]) -> List[str]:
    checks = []
    down = rows[0]  # TP-4 -> TP-2: each dest slice spans several src shards
    striped = all(n >= 2 for n in down["sources_per_dest_shard"])
    checks.append(
        f"{down['scenario']}: every dest shard stripes across >=2 source "
        f"shards {down['sources_per_dest_shard']} -> "
        f"{'OK' if striped else 'MISMATCH'}"
    )
    for r in rows:
        loads = r["src_load_gb"]
        balanced = max(loads) <= 1.5 * max(min(loads), 0.1)
        checks.append(
            f"{r['scenario']}: every source shard engaged, load balanced "
            f"{loads} GB -> {'OK' if balanced and min(loads) > 0 else 'MISMATCH'}"
        )
    for r in rows:
        checks.append(
            f"{r['scenario']} vs gather-then-slice: x{r['speedup']} "
            f"(naive {r['naive_max_s']}s vs striped {r['tensorhub_max_s']}s) "
            f"-> {'OK' if r['speedup'] >= 2.0 else 'MISMATCH'}"
        )
    rec = reshard_source_failure()
    checks.append(
        f"source killed mid-reshard: reader re-planned and completed "
        f"{rec['completed']} (stall {rec['stall']}s) -> "
        f"{'OK' if rec['completed'] else 'MISMATCH'}"
    )
    return checks


def main() -> None:
    rows = run()
    for r in rows:
        print(r)
    for c in validate(rows):
        print("  " + c)


if __name__ == "__main__":
    main()
