"""Emit the EXPERIMENTS.md §Roofline markdown tables from results/dryrun.

    PYTHONPATH=src python -m benchmarks.make_roofline_table [results/dryrun]
"""

from __future__ import annotations

import glob
import json
import os
import sys


def fmt_s(x: float) -> str:
    return f"{x:.3f}" if x < 10 else f"{x:.1f}"


def load(results_dir: str, mesh: str):
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, f"*__{mesh}.json"))):
        rows.append(json.load(open(path)))
    return rows


def main() -> None:
    results_dir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    single = load(results_dir, "single")
    multi = {(r["arch"], r["shape"]): r for r in load(results_dir, "multi")}

    print("### Single-pod 16x16 (256 chips) — the roofline table\n")
    print("| arch | shape | kind | compute | memory | collective | bound | dominant | useful/HLO | roofline |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in single:
        if not r.get("ok"):
            print(f"| {r['arch']} | {r['shape']} | — | FAILED: {r.get('error','')[:40]} | | | | | | |")
            continue
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        print(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | {fmt_s(r['compute_s'])} s "
            f"| {fmt_s(r['memory_s'])} s | {fmt_s(r['collective_s'])} s | {fmt_s(bound)} s "
            f"| {r['dominant']} | {r['model_flops_fraction']:.2f} | {r['roofline_fraction']:.3f} |"
        )

    print("\n### Multi-pod 2x16x16 (512 chips) — sharding proof + scaling\n")
    print("| arch | shape | ok | compile | bytes/device (arg+tmp) | collective bytes/dev | bound vs single |")
    print("|---|---|---|---|---|---|---|")
    for r in single:
        key = (r["arch"], r["shape"])
        m = multi.get(key)
        if m is None:
            print(f"| {r['arch']} | {r['shape']} | MISSING | | | | |")
            continue
        if not m.get("ok"):
            print(f"| {m['arch']} | {m['shape']} | FAILED | {m.get('error','')[:40]} | | | |")
            continue
        ma = m.get("memory_analysis", {})
        dev_bytes = (ma.get("argument_bytes") or 0) + (ma.get("temp_bytes") or 0)
        sb = max(r["compute_s"], r["memory_s"], r["collective_s"]) if r.get("ok") else float("nan")
        mb = max(m["compute_s"], m["memory_s"], m["collective_s"])
        ratio = sb / mb if mb else float("nan")
        print(
            f"| {m['arch']} | {m['shape']} | ok | {m['compile_s']}s | {dev_bytes/1e9:.1f} GB "
            f"| {m['collective_bytes_per_device']/1e9:.1f} GB | x{ratio:.2f} |"
        )


if __name__ == "__main__":
    main()
