"""Multi-source fan-out — N standalone destinations pull one version from
M publisher replicas (4.3.3 "fully saturates RDMA bandwidth").

The multi-source transfer scheduler partitions each destination's
transfer-unit list across every published replica holding the version
(same-node > same-DC preference, least-loaded weighting), and the
windowed data plane keeps several unit flows in flight per shard,
splitting giant units into sub-unit chunks so one tensor can aggregate
every source uplink. This benchmark sweeps window depth, source count
and chunking, and reports aggregate delivered bandwidth against:

* ``pinned`` — the naive-broadcast baseline: every destination pinned to
  the same publisher, one whole-unit flow at a time (what a system with
  no load-aware scheduler does); it plateaus at a single uplink.
* ``legacy`` — the pre-scheduler TensorHub data plane (least-loaded
  single source, sequential unit flows), reproduced exactly by
  ``window=1, chunk_bytes=None, max_sources=1``; the recorded timings
  below were measured on the pre-scheduler implementation and the knobs
  must reproduce them within 5%.

The new path should approach ``min(M * src_uplink, N * dst_downlink)``
per shard column; with pipeline chains it can exceed the publisher-only
bound (in-progress replicas relay).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.transfer.hardware import CLUSTER
from repro.transfer.simcluster import SimCluster

try:
    from benchmarks import harness
except ImportError:  # invoked directly: benchmarks/ itself is sys.path[0]
    import harness

GB = 1e9
SHARDS = 2
UNIFORM_UNITS = [GB] * 16  # 16 GB/shard, chunk-free granularity
SKEWED_UNITS = [8 * GB] + [GB] * 8  # one giant tensor dominates the shard

#: makespans recorded on the pre-scheduler sequential data plane
#: (completion timestamps of the replicate group events). The
#: window=1/chunking-off/max_sources=1 configuration must reproduce
#: these within 5% — it runs the identical one-flow-at-a-time loop.
OLD_TIMINGS = {
    "fanout_8x4": 1.00315,
    "single_1x1": 0.69845,
    "fanout_4x2": 0.82904,
    "skew_8x4": 3.13323,
}


def fanout_makespan(
    n_dest: int,
    m_src: int,
    units: Sequence[float],
    *,
    window: int = 4,
    chunk_bytes: Optional[float] = None,
    max_sources: int = 4,
    scheduler: str = "least_loaded",
    pipeline: bool = True,
    swarm: bool = True,
) -> Dict[str, float]:
    """M publishers all hold v0 (one publishes, the rest replicate it up
    front); N destinations then pull concurrently. Returns the makespan
    (time until the last destination finished) and aggregate bandwidth."""
    cl = SimCluster(
        window=window,
        chunk_bytes=chunk_bytes,
        max_sources=max_sources,
        scheduler=scheduler,
        pipeline_replication=pipeline,
        swarm=swarm,
    )
    pubs = [
        cl.add_replica("m", f"pub{i}", SHARDS, unit_bytes=units) for i in range(m_src)
    ]
    dests = [
        cl.add_replica("m", f"dst{i}", SHARDS, unit_bytes=units) for i in range(n_dest)
    ]
    for r in pubs + dests:
        r.open()
    cl.run()
    pubs[0].publish(0)
    cl.run()
    seeds = [p.replicate("latest") for p in pubs[1:]]
    cl.run()
    assert all(e.triggered and e.error is None for e in seeds)
    t0 = cl.env.now
    finish: Dict[str, float] = {}
    for d in dests:
        ev = d.replicate("latest")
        ev.add_callback(
            lambda e, name=d.name: (
                finish.setdefault(name, cl.env.now) if e.error is None else None
            )
        )
    cl.run()
    assert len(finish) == n_dest, f"incomplete fan-out: {sorted(finish)}"
    makespan = max(finish.values()) - t0
    total_bytes = n_dest * sum(units) * SHARDS
    dest_names = [d.name for d in dests]
    return {
        "makespan_s": makespan,
        "agg_gbps": total_bytes / makespan / GB,
        "multi_assignments": cl.server.stats["multi_source_assignments"],
        "work_steals": cl.server.stats["work_steals"],
        "stall_parts": cl.stall_decomposition(dest_names),
        "stall_total": cl.total_stall(dest_names),
    }


def min_formula_gbps(n_dest: int, m_src: int) -> float:
    """min(M x src uplink, N x dst downlink), summed over shard columns."""
    per_column = min(m_src * CLUSTER.rdma_per_shard, n_dest * CLUSTER.rdma_per_shard)
    return per_column * SHARDS / GB


def run(quick: bool = False) -> List[Dict]:
    rows: List[Dict] = []

    def row(name, units, n, m, **kw) -> Dict:
        r = fanout_makespan(n, m, units, **kw)
        return {
            "scenario": name,
            "n_dest": n,
            "m_src": m,
            "makespan_s": round(r["makespan_s"], 3),
            "agg_gbps": round(r["agg_gbps"], 1),
            "multi": r["multi_assignments"],
            "steals": r["work_steals"],
            **{k: v for k, v in kw.items() if k in ("window", "max_sources")},
            "stall_total_s": round(r["stall_total"], 3),
            **harness.decomposition_cols(r["stall_parts"]),
        }

    # swarm=False everywhere legacy parity is asserted: these rows must
    # reproduce the recorded pre-scheduler timings bit-for-bit
    legacy = dict(window=1, chunk_bytes=None, max_sources=1, swarm=False)

    # headline: 8 destinations / 4 sources
    rows.append(row("pinned_8x4", UNIFORM_UNITS, 8, 4, scheduler="pinned",
                    pipeline=False, **legacy))
    rows.append(row("legacy_8x4", UNIFORM_UNITS, 8, 4, **legacy))
    rows.append(row("multi_8x4", UNIFORM_UNITS, 8, 4,
                    window=4, chunk_bytes=GB, max_sources=4, swarm=False))
    rows.append(row("swarm_8x4", UNIFORM_UNITS, 8, 4,
                    window=4, chunk_bytes=GB, max_sources=4))

    # parity scenarios: knobs-off must reproduce the old data plane
    for name, units, n, m in [
        ("single_1x1", UNIFORM_UNITS, 1, 1),
        ("fanout_4x2", UNIFORM_UNITS, 4, 2),
        ("skew_8x4", SKEWED_UNITS, 8, 4),
    ]:
        rows.append(row(f"parity_{name}", units, n, m, **legacy))

    # chunking: one giant tensor per shard, spread across source uplinks
    rows.append(row("skew_legacy", SKEWED_UNITS, 8, 4, **legacy))
    rows.append(row("skew_multi_chunk", SKEWED_UNITS, 8, 4,
                    window=4, chunk_bytes=GB, max_sources=4))
    rows.append(row("skew_multi_nochunk", SKEWED_UNITS, 8, 4,
                    window=4, chunk_bytes=None, max_sources=4))

    if not quick:
        for w in (1, 2, 4, 8):  # window sweep at 8x4
            rows.append(row(f"sweep_window_{w}", UNIFORM_UNITS, 8, 4,
                            window=w, chunk_bytes=GB, max_sources=4))
        for ms in (1, 2, 4):  # source-count sweep at window 4
            rows.append(row(f"sweep_sources_{ms}", UNIFORM_UNITS, 8, 4,
                            window=4, chunk_bytes=GB, max_sources=ms))
        # giant single tensor: chunking is the only way to split it
        rows.append(row("giant_legacy", [16 * GB], 2, 4, **legacy))
        rows.append(row("giant_multi", [16 * GB], 2, 4,
                        window=4, chunk_bytes=GB, max_sources=4))
    return rows


def _get(rows: List[Dict], scenario: str) -> Dict:
    return next(r for r in rows if r["scenario"] == scenario)


def validate(rows: List[Dict]) -> List[str]:
    checks = []
    pinned = _get(rows, "pinned_8x4")
    multi = _get(rows, "multi_8x4")
    speedup = pinned["makespan_s"] / multi["makespan_s"]
    checks.append(
        f"8 dests / 4 sources: multi-source {multi['agg_gbps']} GB/s vs "
        f"pinned single-source {pinned['agg_gbps']} GB/s -> x{speedup:.1f} "
        f"aggregate-bandwidth improvement (required >= 3x) -> "
        f"{'OK' if speedup >= 3.0 else 'MISMATCH'}"
    )
    bound = min_formula_gbps(8, 4)
    frac = multi["agg_gbps"] / bound
    checks.append(
        f"approaches min(M*src_uplink, N*dst_downlink) = {bound:.0f} GB/s: "
        f"measured {multi['agg_gbps']} GB/s ({frac*100:.0f}%) -> "
        f"{'OK' if frac >= 0.85 else 'MISMATCH'}"
    )
    swarm = _get(rows, "swarm_8x4")
    checks.append(
        f"swarm replication at 8x4: {swarm['makespan_s']}s vs PR 2 "
        f"multi-source {multi['makespan_s']}s (in-progress prefixes join "
        f"the pool) -> "
        f"{'OK' if swarm['makespan_s'] <= multi['makespan_s'] * 1.02 else 'MISMATCH'}"
    )
    parity_map = {
        "legacy_8x4": "fanout_8x4",
        "parity_single_1x1": "single_1x1",
        "parity_fanout_4x2": "fanout_4x2",
        "parity_skew_8x4": "skew_8x4",
    }
    worst = 0.0
    for scen, key in parity_map.items():
        got = _get(rows, scen)["makespan_s"]
        want = OLD_TIMINGS[key]
        worst = max(worst, abs(got - want) / want)
    checks.append(
        f"window=1/chunking-off reproduces the pre-scheduler timings: "
        f"max deviation {worst*100:.2f}% (required < 5%) -> "
        f"{'OK' if worst < 0.05 else 'MISMATCH'}"
    )
    skew_gain = (
        _get(rows, "skew_legacy")["makespan_s"]
        / _get(rows, "skew_multi_chunk")["makespan_s"]
    )
    checks.append(
        f"giant-unit shard: chunked multi-source x{skew_gain:.1f} faster than "
        f"the sequential chain -> {'OK' if skew_gain >= 1.5 else 'MISMATCH'}"
    )
    if any(r["scenario"] == "giant_multi" for r in rows):
        g = (
            _get(rows, "giant_legacy")["makespan_s"]
            / _get(rows, "giant_multi")["makespan_s"]
        )
        checks.append(
            f"single 16 GB tensor: sub-unit chunking x{g:.1f} faster "
            f"-> {'OK' if g >= 1.5 else 'MISMATCH'}"
        )
    for scen in ("multi_8x4", "swarm_8x4"):
        r = _get(rows, scen)
        checks.append(
            harness.check_decomposition(
                scen,
                {k: r[f"{k}_s"] for k in harness.STALL_COMPONENTS},
                r["stall_total_s"],
            )
        )
    return checks


if __name__ == "__main__":
    harness.bench_main("fanout", run, validate)
