"""Fig 11 — elastic rollouts on spot instances (260B workload): one stable
standalone replica + elastic replicas that join and get preempted; stall
time as the elastic count scales, TensorHub vs the UCX chain baseline.

Validates: TensorHub stall stays near-flat (~1.5 s for a 34 GB shard)
independent of elastic count (pipeline replication + server load
balancing), vs the UCX trainer->standalone->elastic chain whose last batch
waits ~7 s (stair-shaped CDF); update acceleration ~4.8x.

Swarm replication (in-progress replicas serve their completed prefix as
sources) drops the per-reader stall further — every reader blends the
published trainer pool with swarm peers, saturating its NIC with parallel
flows instead of one staggered relay link — and flattens the curve:
PR 2's chains pay one unit of hop lag per elastic replica, the swarm pays
none. ``swarm=False`` reproduces the PR 2 scheduler bit-for-bit (the
``PR2_BASELINE`` anchors below were measured at PR 2's HEAD).
"""

from __future__ import annotations

from typing import Dict, List

from repro.configs.paper_workloads import WORKLOADS
from repro.transfer.hardware import CLUSTER
from repro.transfer.simcluster import SimCluster

try:
    from benchmarks import harness
except ImportError:  # invoked directly: benchmarks/ itself is sys.path[0]
    import harness

W = WORKLOADS["260B"]
ELASTIC_COUNTS = [1, 2, 3, 6]

#: (mean, max) stall recorded on the PR 2 scheduler (pre-swarm HEAD);
#: ``swarm=False`` must reproduce these bit-for-bit (2-decimal rounding)
PR2_BASELINE = {
    1: (1.47, 1.57),
    2: (1.51, 1.59),
    3: (1.53, 1.61),
    6: (1.59, 1.68),
}


def tensorhub_elastic(n_elastic: int, *, swarm: bool = True) -> Dict[str, object]:
    cl = SimCluster(swarm=swarm)
    units = W.unit_bytes(64)
    trainers = [
        cl.add_replica("m", f"tr{i}", W.num_shards, unit_bytes=units)
        for i in range(W.num_trainer_replicas)
    ]
    standalone = cl.add_replica("m", "sa0", W.num_shards, unit_bytes=units)
    elastics = [
        cl.add_replica("m", f"el{i}", W.num_shards, unit_bytes=units, is_spot=True)
        for i in range(n_elastic)
    ]
    for r in trainers + [standalone] + elastics:
        r.open()
    cl.run()
    for t in trainers:
        t.publish(0)
    cl.run()
    t0 = cl.env.now
    standalone.replicate("latest")
    for e in elastics:
        e.replicate("latest")
    cl.run()
    names = ["sa0"] + [f"el{i}" for i in range(n_elastic)]
    per = cl.per_worker_stalls(names)
    return {
        "mean_stall": sum(per) / len(per),
        "max_stall": max(per),
        "cdf": sorted(round(p, 2) for p in per),
        "stall_parts": cl.stall_decomposition(names),
        "stall_total": sum(per),
    }


def ucx_elastic(n_elastic: int) -> Dict[str, object]:
    """UCX chain baseline (5.3): elastics wait for the standalone to pull
    from the trainers first, then are served from the standalone one send()
    at a time (blocking p2p) — the stair-shaped CDF of Fig 11b."""
    hw = CLUSTER
    wave = W.shard_bytes / (hw.ucx_eff * hw.rdma_per_shard)
    stage1 = wave + hw.driver_rpc
    per: List[float] = [stage1] * W.num_shards  # standalone GPUs
    for i in range(n_elastic):
        per.extend([stage1 + (i + 1) * wave] * W.num_shards)
    return {
        "mean_stall": sum(per) / len(per),
        "max_stall": max(per),
        "cdf": sorted(round(p, 2) for p in per),
    }


def run(quick: bool = False) -> List[Dict]:
    rows = []
    counts = [1, 6] if quick else ELASTIC_COUNTS
    for n in counts:
        th = tensorhub_elastic(n)
        pr2 = tensorhub_elastic(n, swarm=False)
        ucx = ucx_elastic(n)
        row = {
            "elastic_replicas": n,
            "tensorhub_mean_s": round(th["mean_stall"], 2),
            "tensorhub_max_s": round(th["max_stall"], 2),
            "pr2_mean_s": round(pr2["mean_stall"], 2),
            "pr2_max_s": round(pr2["max_stall"], 2),
            "ucx_mean_s": round(ucx["mean_stall"], 2),
            "ucx_max_s": round(ucx["max_stall"], 2),
            "speedup_mean": round(ucx["mean_stall"] / th["mean_stall"], 1),
            "stall_total_s": round(th["stall_total"], 3),
        }
        row.update(harness.decomposition_cols(th["stall_parts"]))
        rows.append(row)
    return rows


def dynamic_membership(steps: int = 6) -> Dict[str, object]:
    """Fig 11a: the elastic pool grows and shrinks ACROSS training steps
    (deterministic scale events standing in for the autoscaler, 5.3);
    per-step stall must stay flat regardless of the current pool size."""
    cl = SimCluster()
    units = W.unit_bytes(64)
    trainers = [
        cl.add_replica("m", f"tr{i}", W.num_shards, unit_bytes=units)
        for i in range(W.num_trainer_replicas)
    ]
    standalone = cl.add_replica("m", "sa0", W.num_shards, unit_bytes=units)
    for r in trainers + [standalone]:
        r.open()
    cl.run()
    pool: List = []  # (replica, joined_step)
    per_step_max: List[float] = []
    spawned = 0
    for step in range(steps):
        # scale events: +2 replicas at steps 1 and 2, preempt one at step 4
        if step in (1, 2):
            for _ in range(2):
                e = cl.add_replica(
                    "m", f"el{spawned}", W.num_shards, unit_bytes=units, is_spot=True
                )
                e.open()
                pool.append((e, step))
                spawned += 1
            cl.run()
        if step == 4:
            victim, _ = pool.pop(0)
            cl.kill_replica(victim.name)
            cl.run()
        for t in trainers:
            t.publish(step)
        cl.run()
        live = [standalone] + [e for e, _ in pool]
        before = {s.worker.worker_id: s.worker.total_stall for r in live for s in r.shards}
        if step == 0:
            standalone.replicate("latest")
        else:
            standalone.update("latest")
        for e, joined in pool:
            (e.replicate if joined == step else e.update)("latest")
        cl.run()
        stalls = [
            s.worker.total_stall - before[s.worker.worker_id]
            for r in live
            for s in r.shards
        ]
        per_step_max.append(max(stalls) if stalls else 0.0)
        for t in trainers:
            t.unpublish()
        cl.run()
    return {"per_step_max": [round(s, 2) for s in per_step_max]}


def preemption_recovery() -> Dict[str, object]:
    """Scale-down mid-replication: a random elastic replica is killed while
    pulling; remaining replicas must complete untouched (spot churn, 4.5)."""
    cl = SimCluster()
    units = W.unit_bytes(64)
    tr = cl.add_replica("m", "tr0", W.num_shards, unit_bytes=units)
    els = [
        cl.add_replica("m", f"el{i}", W.num_shards, unit_bytes=units, is_spot=True)
        for i in range(3)
    ]
    tr.open()
    for e in els:
        e.open()
    cl.run()
    tr.publish(0)
    cl.run()
    events = [e.replicate("latest") for e in els]
    cl.env.schedule(0.7, lambda: cl.kill_replica("el1"))
    cl.run()
    ok = [bool(ev.triggered and ev.error is None) for ev in events]
    return {"survivors_completed": [ok[0], ok[2]], "victim_errored": not ok[1]}


def validate(rows: List[Dict]) -> List[str]:
    checks = []
    flat = rows[-1]["tensorhub_max_s"] / rows[0]["tensorhub_max_s"]
    checks.append(
        f"TensorHub stall flat under elastic scale-out: x{flat:.2f} at "
        f"{rows[-1]['elastic_replicas']} elastics (~1.5s each) -> "
        f"{'OK' if flat <= 1.6 and rows[-1]['tensorhub_max_s'] <= 2.5 else 'MISMATCH'}"
    )
    by_n = {r["elastic_replicas"]: r for r in rows}
    r3 = by_n.get(3, rows[-1])  # 3 elastic machines, the paper's setup (5.3)
    sp = round(r3["ucx_max_s"] / r3["tensorhub_max_s"], 1)
    checks.append(
        f"weight-update speedup vs UCX at {r3['elastic_replicas']} elastics "
        f"(tail: last batch {r3['ucx_max_s']}s vs flat {r3['tensorhub_max_s']}s): "
        f"{sp}x (paper: 4.8x, last batch 7.2s) -> "
        f"{'OK' if 4.0 <= sp <= 12.0 else 'MISMATCH'}"
    )
    # swarm replication: beats the PR 2 scheduler at the largest pool and
    # swarm=False reproduces the recorded PR 2 numbers bit-for-bit
    last = rows[-1]
    checks.append(
        f"swarm beats the PR 2 scheduler at {last['elastic_replicas']} "
        f"elastics: mean {last['tensorhub_mean_s']}s vs {last['pr2_mean_s']}s -> "
        f"{'OK' if last['tensorhub_mean_s'] < last['pr2_mean_s'] else 'MISMATCH'}"
    )
    parity_bad = [
        n
        for n, r in by_n.items()
        if n in PR2_BASELINE
        and (r["pr2_mean_s"], r["pr2_max_s"]) != PR2_BASELINE[n]
    ]
    checks.append(
        "swarm=False reproduces PR 2 bit-for-bit: "
        f"{'OK' if not parity_bad else f'MISMATCH at {parity_bad}'}"
    )
    rec = preemption_recovery()
    checks.append(
        f"preemption mid-pull: survivors complete {rec['survivors_completed']}, "
        f"victim evicted {rec['victim_errored']} -> "
        f"{'OK' if all(rec['survivors_completed']) else 'MISMATCH'}"
    )
    dyn = dynamic_membership()
    flat = max(dyn["per_step_max"]) <= 2.5
    checks.append(
        f"dynamic membership (join x4, preempt x1 over 6 steps): per-step max "
        f"stall {dyn['per_step_max']} -> {'OK' if flat else 'MISMATCH'}"
    )
    last = rows[-1]
    checks.append(
        harness.check_decomposition(
            f"{last['elastic_replicas']} elastics",
            {k: last[f"{k}_s"] for k in harness.STALL_COMPONENTS},
            last["stall_total_s"],
        )
    )
    return checks


if __name__ == "__main__":
    harness.bench_main("elastic", run, validate)
