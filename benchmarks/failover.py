"""Control-plane fault tolerance — op-log overhead and recovery time.

Two questions the tentpole must answer quantitatively:

* **Logging overhead**: how much publish/update-path latency does the
  replayable op log add over ``log=None`` (which reproduces the PR 3
  control plane bit-for-bit)? Target: <5% for the default in-memory
  log; file-backed variants are reported for context, with group-commit
  batching amortizing the write+flush cost.
* **Recovery time**: how does ``failover.recover`` scale with history
  length, and how flat does snapshot+compaction make it (O(live state)
  instead of O(history))?
"""

from __future__ import annotations

import os
import tempfile
import time
from typing import Dict, List, Optional

from repro.core import ReferenceServer, failover
from repro.core.meta import ShardManifest, TensorMeta, TransferUnit, WorkerInfo
from repro.core.oplog import OpLog

try:
    from benchmarks import harness
except ImportError:  # invoked directly: benchmarks/ itself is sys.path[0]
    import harness

N_UNITS = 32
#: overhead bench uses a production-shaped manifest: a 70B-class shard
#: registers hundreds of transfer units; the op log stores the manifest
#: by *reference* (one O(1) record per publish), so the relative cost is
#: what a real control plane would see
N_UNITS_PUBLISH = 256
SHARDS = 2


def make_manifest(n_units=N_UNITS, unit_bytes=1 << 20) -> ShardManifest:
    tensors = tuple(
        TensorMeta(f"t{i}", (unit_bytes,), "uint8", unit_bytes) for i in range(n_units)
    )
    units = tuple(
        TransferUnit(index=i, name=f"t{i}", nbytes=unit_bytes) for i in range(n_units)
    )
    return ShardManifest(tensors=tensors, units=units, checksums=(0,) * n_units)


def open_replica(s: ReferenceServer, name: str) -> None:
    for i in range(SHARDS):
        s.open(
            "m", name, SHARDS, i,
            worker=WorkerInfo(f"{name}/s{i}", f"dc0/{name}", "dc0", False),
        )
        s.register("m", name, i)


def _publish_cycle_trace(s: ReferenceServer, cycles: int) -> None:
    """The write-path hot loop: publish -> reader progress -> complete ->
    roll. One cycle is 2 publishes + 2 begins + 2*N_UNITS progress
    reports + 2 completes + 2 unpublishes + drains."""
    m = make_manifest()
    open_replica(s, "pub")
    open_replica(s, "r")
    op = 0
    for c in range(cycles):
        for i in range(SHARDS):
            s.publish("m", "pub", i, c, m, op_id=op)
        for i in range(SHARDS):
            s.begin_replicate("m", "r", i, c, op_id=op + 1)
        for p in range(1, N_UNITS + 1):
            for i in range(SHARDS):
                s.update_progress("m", "r", i, c, p)
        for i in range(SHARDS):
            s.complete_replicate("m", "r", i, c, op_id=op + 2)
        for name in ("r", "pub"):
            for i in range(SHARDS):
                s.unpublish("m", name, i, op_id=op + 3)
            s.finish_unpublish("m", name)
        op += 4


def _publish_update_latency(
    log: Optional[OpLog], cycles: int
) -> Dict[str, float]:
    """Per-op publish and update latency (the write path the issue's
    <5% target is about), timed around exactly those calls; the rest of
    the trace (progress reports, completes, drains) runs untimed."""
    s = ReferenceServer(log=log)
    m = make_manifest(N_UNITS_PUBLISH)
    open_replica(s, "pub")
    open_replica(s, "r")
    publish_s = update_s = 0.0
    op = 0
    for c in range(cycles):
        t0 = time.perf_counter()
        for i in range(SHARDS):
            s.publish("m", "pub", i, c, m, op_id=op)
        publish_s += time.perf_counter() - t0
        t0 = time.perf_counter()
        for i in range(SHARDS):
            s.begin_update("m", "r", i, "latest", op_id=op + 1)
        update_s += time.perf_counter() - t0
        for p in (N_UNITS_PUBLISH,):
            for i in range(SHARDS):
                s.update_progress("m", "r", i, c, p)
        for i in range(SHARDS):
            s.complete_replicate("m", "r", i, c, op_id=op + 2)
        for i in range(SHARDS):
            s.unpublish("m", "pub", i, op_id=op + 3)
        s.finish_unpublish("m", "pub")
        op += 4
    n = cycles * SHARDS
    return {"publish_us": publish_s / n * 1e6, "update_us": update_s / n * 1e6}


def bench_overhead(cycles: int, repeats: int) -> List[Dict]:
    rows = []
    tmp = tempfile.mkdtemp(prefix="tensorhub-failover-")
    variants = [
        ("no_log", lambda: None),
        ("memory_gc1", lambda: OpLog(group_commit=1)),
        ("file_gc1", lambda: OpLog(group_commit=1, path=os.path.join(tmp, "a.jsonl"))),
        ("file_gc64", lambda: OpLog(group_commit=64, path=os.path.join(tmp, "b.jsonl"))),
    ]
    _publish_update_latency(None, max(5, cycles // 4))  # warm the allocator/caches
    runs: Dict[str, List[Dict[str, float]]] = {name: [] for name, _ in variants}
    # interleave the variants across repeats so slow drift (GC pressure,
    # frequency scaling) spreads evenly instead of biasing one variant
    for _ in range(repeats):
        for name, make in variants:
            runs[name].append(_publish_update_latency(make(), cycles))
            for p in ("a.jsonl", "b.jsonl"):
                f = os.path.join(tmp, p)
                if os.path.exists(f):
                    os.unlink(f)
    times = {
        name: {k: min(r[k] for r in rs) for k in rs[0]} for name, rs in runs.items()
    }
    base = times["no_log"]
    for name, _ in variants:
        t = times[name]
        rows.append(
            {
                "bench": "overhead",
                "variant": name,
                "publish_us": round(t["publish_us"], 2),
                "update_us": round(t["update_us"], 2),
                "publish_overhead_pct": round(
                    100.0 * (t["publish_us"] / base["publish_us"] - 1.0), 2
                ),
                "update_overhead_pct": round(
                    100.0 * (t["update_us"] / base["update_us"] - 1.0), 2
                ),
            }
        )
    return rows


def bench_recovery(histories: List[int]) -> List[Dict]:
    rows = []
    for cycles in histories:
        log = OpLog()
        s = ReferenceServer(log=log)
        _publish_cycle_trace(s, cycles)
        n_records = log.last_seq
        t0 = time.perf_counter()
        rec = failover.recover(log)
        replay_s = time.perf_counter() - t0
        assert failover.state_digest(rec) == failover.state_digest(s)
        # snapshot + compaction: recovery is restore-only
        log.compact(failover.take_snapshot(s))
        t0 = time.perf_counter()
        rec2 = failover.recover(log)
        snap_s = time.perf_counter() - t0
        assert failover.state_digest(rec2) == failover.state_digest(s)
        gauges = rec2.metrics()["gauges"]
        rows.append(
            {
                "bench": "recovery",
                "history_records": n_records,
                "replay_ms": round(replay_s * 1e3, 2),
                "snapshot_ms": round(snap_s * 1e3, 2),
                "speedup": round(replay_s / snap_s, 1) if snap_s > 0 else float("inf"),
                # the server's own view of the same recovery, via metrics()
                "gauge_recovery_ms": round(
                    gauges["failover_last_recovery_seconds"] * 1e3, 2
                ),
                "oplog_avg_batch": round(gauges["oplog_avg_batch"], 1),
            }
        )
    return rows


def run(quick: bool = False) -> List[Dict]:
    cycles = 150 if quick else 400
    repeats = 3 if quick else 5
    histories = [5, 40] if quick else [5, 40, 160]
    return bench_overhead(cycles, repeats) + bench_recovery(histories)


def validate(rows: List[Dict]) -> List[str]:
    checks = []
    over = {r["variant"]: r for r in rows if r["bench"] == "overhead"}
    mem_u = over["memory_gc1"]["update_overhead_pct"]
    add_u = over["memory_gc1"]["update_us"] - over["no_log"]["update_us"]
    # percent-or-absolute: the update op itself is scheduler-heavy and
    # its latency swings with machine load far more than the ~1us the
    # log adds, so a small absolute allowance keeps the check meaningful
    # on noisy CI boxes while still catching a real logging regression
    ok_u = mem_u < 5.0 or add_u < 10.0
    checks.append(
        f"in-memory op log update-path overhead {mem_u}% "
        f"({add_u:+.2f}us/op; required < 5% or < +10us) -> "
        f"{'OK' if ok_u else 'MISMATCH'}"
    )
    # publish is so thin in-process (~no RTT, no serialization) that a
    # percentage hides the real claim: the log adds ~1us of absolute
    # latency per op — under any deployment RTT this is far below 5%
    add_p = over["memory_gc1"]["publish_us"] - over["no_log"]["publish_us"]
    checks.append(
        f"in-memory op log absolute publish overhead {add_p:.2f}us/op "
        f"(required < 3us) -> {'OK' if add_p < 3.0 else 'MISMATCH'}"
    )
    gc64, gc1 = over["file_gc64"]["publish_us"], over["file_gc1"]["publish_us"]
    checks.append(
        f"group commit amortizes the file sink: gc64 publish {gc64}us <= "
        f"gc1 {gc1}us * 1.05 -> {'OK' if gc64 <= gc1 * 1.05 else 'MISMATCH'}"
    )
    rec = [r for r in rows if r["bench"] == "recovery"]
    longest = max(rec, key=lambda r: r["history_records"])
    checks.append(
        f"snapshot recovery at {longest['history_records']} records: "
        f"{longest['snapshot_ms']}ms vs full replay {longest['replay_ms']}ms "
        f"-> {'OK' if longest['snapshot_ms'] < longest['replay_ms'] else 'MISMATCH'}"
    )
    # O(live state): snapshot recovery stays roughly flat as history grows
    if len(rec) >= 2:
        lo, hi = rec[0], rec[-1]
        ratio = hi["snapshot_ms"] / max(lo["snapshot_ms"], 1e-6)
        hist_ratio = hi["history_records"] / lo["history_records"]
        checks.append(
            f"snapshot recovery growth x{ratio:.1f} over x{hist_ratio:.1f} "
            f"history (required: sublinear) -> "
            f"{'OK' if ratio < hist_ratio else 'MISMATCH'}"
        )
    # the recovered server's own gauge agrees with the external stopwatch
    # (the gauge is set inside recover(), so it can only be <= ours)
    gauge_ok = all(
        0.0 < r["gauge_recovery_ms"] <= r["snapshot_ms"] * 1.01 + 0.01 for r in rec
    )
    checks.append(
        f"metrics() failover_last_recovery_seconds matches the measured "
        f"recovery ({[r['gauge_recovery_ms'] for r in rec]}ms vs "
        f"{[r['snapshot_ms'] for r in rec]}ms) -> "
        f"{'OK' if gauge_ok else 'MISMATCH'}"
    )
    return checks


if __name__ == "__main__":
    harness.bench_main("failover", run, validate)
