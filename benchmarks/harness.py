"""Shared benchmark harness.

Every scenario benchmark (`elastic`, `cross_dc`, `swarm`, `fanout`,
`failover`, ...) exposes ``run(quick) -> rows`` and ``validate(rows) ->
checks``; this module owns the previously copy-pasted CLI entry, row /
check printing, machine-readable JSON emission, and the stall-time
decomposition reporting added by the telemetry plane.

CLI (per benchmark):

    PYTHONPATH=src python benchmarks/<name>.py [--quick] [--json out.json]

``--json`` writes ``{"name", "rows", "checks", "mismatches",
"elapsed_s"}`` — the same per-benchmark dict ``benchmarks/run.py --json``
aggregates for the CI artifact.
"""

from __future__ import annotations

import json
import sys
import time
from typing import Callable, Dict, List, Optional, Sequence

from repro.obs.telemetry import STALL_COMPONENTS


# -- stall decomposition reporting -----------------------------------------


def decomposition_cols(parts: Dict[str, float], *, digits: int = 3) -> Dict[str, float]:
    """Row columns for a stall decomposition: one ``<component>_s`` per
    canonical component, rounded for display."""
    return {f"{k}_s": round(parts.get(k, 0.0), digits) for k in STALL_COMPONENTS}


def check_decomposition(
    label: str, parts: Dict[str, float], total: float, *, tol: float = 0.05
) -> str:
    """OK/MISMATCH line asserting the five components tile the
    end-to-end stall within ``tol`` (relative)."""
    s = sum(parts.get(k, 0.0) for k in STALL_COMPONENTS)
    if total <= 0.0:
        ok = s <= 1e-9
        rel = 0.0
    else:
        rel = abs(s - total) / total
        ok = rel <= tol
    detail = " + ".join(
        f"{k}={parts.get(k, 0.0):.3f}" for k in STALL_COMPONENTS
    )
    return (
        f"stall decomposition ({label}): {detail} = {s:.3f}s vs "
        f"end-to-end {total:.3f}s ({rel * 100:.1f}% off, required <= "
        f"{tol * 100:.0f}%) -> {'OK' if ok else 'MISMATCH'}"
    )


# -- results emission -------------------------------------------------------


def _jsonable(v):
    try:
        json.dumps(v)
        return v
    except TypeError:
        if isinstance(v, (list, tuple)):
            return [_jsonable(x) for x in v]
        if isinstance(v, dict):
            return {str(k): _jsonable(x) for k, x in v.items()}
        try:
            return float(v)  # numpy scalars
        except (TypeError, ValueError):
            return str(v)


def result_dict(
    name: str, rows: List[Dict], checks: Sequence[str], elapsed_s: float
) -> Dict:
    return {
        "name": name,
        "rows": [_jsonable(r) for r in rows],
        "checks": list(checks),
        "mismatches": sum("MISMATCH" in c for c in checks),
        "elapsed_s": round(elapsed_s, 2),
    }


def write_json(path: str, payload) -> None:
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1)


# -- CLI entry (the formerly copy-pasted main()) -----------------------------


def bench_main(
    name: str,
    run: Callable[..., List[Dict]],
    validate: Callable[[List[Dict]], List[str]],
    argv: Optional[Sequence[str]] = None,
) -> None:
    args = list(sys.argv[1:] if argv is None else argv)
    quick = "--quick" in args
    json_path = None
    if "--json" in args:
        i = args.index("--json")
        if i + 1 >= len(args):
            raise SystemExit("--json requires a path argument")
        json_path = args[i + 1]
    t0 = time.time()
    rows = run(quick=quick)
    checks = validate(rows)
    for r in rows:
        print(r)
    bad = 0
    for c in checks:
        print("  " + c)
        bad += "MISMATCH" in c
    if json_path:
        write_json(json_path, result_dict(name, rows, checks, time.time() - t0))
    if quick:
        raise SystemExit(1 if bad else 0)
