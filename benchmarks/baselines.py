"""Calibrated baseline models: NCCL / UCX / Ray-object-store weight
transfer, as characterized in the paper (2.3, 5.1.1, 5.2).

These are *analytic* models driven by the same hardware constants as the
TensorHub simulator; their efficiencies are calibrated to the paper's own
measurements (Fig 7a: 18.8 / 18.1 GB/s; 2.3: 40 GB in 32 s; 5.2: global
barrier + straggler amplification ~ ln(N)).
"""

from __future__ import annotations

import math
from typing import Tuple

from repro.transfer.hardware import CLUSTER, ClusterHW


def nccl_transfer_time(shard_bytes: float, total_gpus: int, hw: ClusterHW = CLUSTER) -> float:
    """Broadcast stage time: ring broadcast runs at nccl_eff of link bw
    (pipelined, ~independent of destination count), plus the Ray-driver
    coordination RPC and the straggler tail of a global barrier over N
    workers (5.2)."""
    bw = hw.nccl_eff * hw.rdma_per_shard
    return shard_bytes / bw + hw.driver_rpc + hw.straggler_scale * math.log(max(total_gpus, 2))


def nccl_total_stall(shard_bytes: float, total_gpus: int, hw: ClusterHW = CLUSTER) -> float:
    """NCCL interrupts *every* worker for the weight-transfer stage."""
    return total_gpus * nccl_transfer_time(shard_bytes, total_gpus, hw)


def ucx_transfer_time(
    shard_bytes: float,
    *,
    fan_out: int = 1,
    total_gpus: int = 2,
    tcp: bool = False,
    hw: ClusterHW = CLUSTER,
) -> float:
    """P2P pull: fan_out receivers share the sender uplink (2.3 "senders
    serve requests independently, making their outbound bandwidth the
    bottleneck under fan-out"). Framework-level coordination still
    interrupts workers (driver RPC)."""
    link = hw.vpc_per_node if tcp else hw.rdma_per_shard
    bw = hw.ucx_eff * link / max(fan_out, 1)
    return shard_bytes / bw + hw.driver_rpc + hw.straggler_scale * math.log(max(total_gpus, 2))


def ucx_total_stall(
    shard_bytes: float, total_gpus: int, *, fan_out: int = 1, tcp: bool = False,
    hw: ClusterHW = CLUSTER,
) -> float:
    return total_gpus * ucx_transfer_time(
        shard_bytes, fan_out=fan_out, total_gpus=total_gpus, tcp=tcp, hw=hw
    )


def object_store_time(shard_bytes: float, hw: ClusterHW = CLUSTER) -> Tuple[float, bool]:
    """Push-then-pull through CPU object storage: GPU->CPU copy +
    (de)serialization at the measured effective bandwidth, twice. Returns
    (seconds, crashed): Ray OOM-crashes beyond ~35 GB per shard (5.1.1)."""
    crashed = shard_bytes > hw.object_store_max_shard
    return 2.0 * shard_bytes / hw.object_store_bw, crashed


def rdma_ideal_time(shard_bytes: float, hw: ClusterHW = CLUSTER) -> float:
    """The roofline: per-shard RDMA bandwidth fully saturated."""
    return shard_bytes / hw.rdma_per_shard
