"""Perf iterations on the paper-representative cell: the Fig 9 1T
weight-transfer workload (768 trainer GPUs -> 256 standalone GPUs, 66 GB
shards), measured against the RDMA roofline.

Iterations (EXPERIMENTS.md Perf):
  T0 baseline   — paper semantics: least-loaded scheduling, 64 transfer
                  units/shard (post tiny-tensor compaction).
  T1 units=256  — finer pipelining units: each chained reader lags its
                  source by one unit; smaller units cut the fill latency
                  of deep replication chains.
  T2 depth-aware scheduling (beyond-paper) — prefer shallow sources on
                  refcount ties: builds a balanced tree (depth ~log N)
                  instead of a chain (depth ~N).
  T3 = T1 + T2
"""

from __future__ import annotations

from typing import Dict, List

from repro.configs.paper_workloads import WORKLOADS
from repro.transfer.simcluster import SimCluster

W = WORKLOADS["1T"]


def one_step_stall(*, units: int, scheduler: str) -> Dict[str, float]:
    cl = SimCluster()
    cl.server._scheduler = scheduler  # harness hook
    unit_bytes = W.unit_bytes(units)
    trainers = [
        cl.add_replica("m", f"tr{i}", W.num_shards, unit_bytes=unit_bytes)
        for i in range(W.num_trainer_replicas)
    ]
    rollouts = [
        cl.add_replica("m", f"ro{i}", W.num_shards, unit_bytes=unit_bytes)
        for i in range(W.num_standalone_replicas)
    ]
    for r in trainers + rollouts:
        r.open()
    cl.run()
    for t in trainers:
        t.publish(0)
    cl.run()
    for r in rollouts:
        r.replicate("latest")
    cl.run()
    names = [f"ro{i}" for i in range(W.num_standalone_replicas)]
    per = cl.per_worker_stalls(names)
    ideal = W.shard_bytes / 25e9
    return {
        "total_stall_s": sum(per),
        "mean_s": sum(per) / len(per),
        "max_s": max(per),
        "roofline_frac": ideal * len(per) / sum(per),
    }


def run() -> List[Dict]:
    variants = [
        ("T0 baseline (units=64, least-loaded)", dict(units=64, scheduler="least_loaded")),
        ("T1 units=256", dict(units=256, scheduler="least_loaded")),
        ("T2 depth-aware", dict(units=64, scheduler="depth_aware")),
        ("T3 units=256 + depth-aware", dict(units=256, scheduler="depth_aware")),
    ]
    rows = []
    for name, kw in variants:
        r = one_step_stall(**kw)
        rows.append({"variant": name, **{k: round(v, 3) for k, v in r.items()}})
    return rows


def validate(rows: List[Dict]) -> List[str]:
    base = rows[0]
    best = max(rows, key=lambda r: r["roofline_frac"])
    checks = [
        f"baseline roofline fraction {base['roofline_frac']:.3f} "
        f"(paper-faithful; mean latency {base['mean_s']}s vs ideal 2.64s)",
        f"best variant: {best['variant']} -> {best['roofline_frac']:.3f} "
        f"({(best['roofline_frac']/base['roofline_frac']-1)*100:+.1f}% vs baseline)",
    ]
    return checks


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
