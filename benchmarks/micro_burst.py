"""Fig 7b — scaling with bursts: 1 trainer group, 1..8 rollout groups all
requesting the same 50 GB/shard version simultaneously; total GPU stall
with and without pipeline replication vs the RDMA-ideal reference.

Validates: with pipelining, per-group latency stays ~2.2 s independent of
group count (total stall grows linearly); without it, contention on the
trainer uplink makes stall grow quadratically.
"""

from __future__ import annotations

from typing import Dict, List

from benchmarks import harness
from repro.transfer.simcluster import SimCluster

GB = 1e9
GROUPS = [1, 2, 4, 8]
GROUPS_QUICK = [1, 2, 8]
SHARD_GB = 50


def burst_stall(n_groups: int, *, pipeline: bool) -> Dict[str, float]:
    cl = SimCluster(pipeline_replication=pipeline)
    units = [SHARD_GB * GB / 64] * 64
    tr = cl.add_replica("m", "trainer", 8, unit_bytes=units)
    ros = [cl.add_replica("m", f"ro{i}", 8, unit_bytes=units) for i in range(n_groups)]
    tr.open()
    for r in ros:
        r.open()
    cl.run()
    tr.publish(0)
    cl.run()
    for r in ros:
        r.replicate("latest")
    cl.run()
    names = [f"ro{i}" for i in range(n_groups)]
    per = cl.per_worker_stalls(names)
    return {"total": sum(per), "max": max(per), "mean": sum(per) / len(per)}


def run(quick: bool = False) -> List[Dict]:
    rows = []
    for n in (GROUPS_QUICK if quick else GROUPS):
        with_p = burst_stall(n, pipeline=True)
        without = burst_stall(n, pipeline=False)
        ideal = SHARD_GB * GB / 25e9 * n * 8
        rows.append(
            {
                "groups": n,
                "pipeline_total_stall_s": round(with_p["total"], 1),
                "pipeline_max_worker_s": round(with_p["max"], 2),
                "no_pipeline_total_stall_s": round(without["total"], 1),
                "no_pipeline_max_worker_s": round(without["max"], 2),
                "rdma_ideal_total_s": round(ideal, 1),
            }
        )
    return rows


def validate(rows: List[Dict]) -> List[str]:
    checks = []
    # pipeline: max-worker stall ~flat (last/first <= 1.6x)
    flat = rows[-1]["pipeline_max_worker_s"] / rows[0]["pipeline_max_worker_s"]
    checks.append(f"pipeline per-group latency flat: x{flat:.2f} at 8 groups "
                  f"-> {'OK' if flat <= 1.6 else 'MISMATCH'}")
    # pipeline total near ideal
    frac = rows[-1]["rdma_ideal_total_s"] / rows[-1]["pipeline_total_stall_s"]
    checks.append(f"pipeline total within ~90% of RDMA ideal: {frac*100:.0f}% "
                  f"-> {'OK' if frac >= 0.8 else 'MISMATCH'}")
    # no-pipeline: super-linear (quadratic-ish) growth of total stall
    g = (rows[-1]["no_pipeline_total_stall_s"] / rows[0]["no_pipeline_total_stall_s"])
    checks.append(f"no-pipeline total stall grows x{g:.1f} for 8x groups "
                  f"(quadratic ~64x) -> {'OK' if g >= 32 else 'MISMATCH'}")
    return checks


if __name__ == "__main__":
    harness.bench_main("micro_burst", run, validate)
