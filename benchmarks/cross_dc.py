"""Fig 12 — cross-datacenter rollouts (9B workload): trainers in dc0,
standalone rollouts in dc1, reachable only over the VPC NIC with
stream-limited WAN TCP.

The measured transition is the warm update path (the paper's steady
state): rollouts hold version v and poll ``update("latest")`` between
inference batches while trainers publish v+1 across the DC boundary.
TensorHub: exactly one *seeding* replica pays the 2.5 s TCP transfer;
smart skipping keeps the others inferring until the seed lands, then they
pull over local RDMA in ~0.45 s. Offload seeding moves the TCP fetch into
a background CPU buffer, removing even the seeder's stall.

Validates: per-GPU latency distribution (single 2.5 s tail, 0.45 s body),
~19x stall reduction vs UCX-over-TCP (with offload seeding, the abstract's
number), cross-DC traffic = 1 copy vs n copies.
"""

from __future__ import annotations

import sys
from typing import Dict, List

from repro.configs.paper_workloads import WORKLOADS
from repro.transfer.hardware import CLUSTER
from repro.transfer.simcluster import SimCluster

W = WORKLOADS["9B"]
N_STANDALONE = W.standalone_gpus // W.num_shards  # 4 replicas x 2 shards


def tensorhub_cross_dc(
    *,
    offload_seeding: bool,
    poll_period: float = 0.2,
    tcp_compression: float = 1.0,
    swarm: bool = True,
) -> Dict[str, object]:
    cl = SimCluster(tcp_compression=tcp_compression, swarm=swarm)
    units = W.unit_bytes(64)
    trainers = [
        cl.add_replica("m", f"tr{i}", W.num_shards, datacenter="dc0", unit_bytes=units)
        for i in range(W.num_trainer_replicas)
    ]
    rollouts = [
        cl.add_replica(
            "m", f"ro{i}", W.num_shards, datacenter="dc1", unit_bytes=units,
            offload_seeding=offload_seeding,
        )
        for i in range(N_STANDALONE)
    ]
    for r in trainers + rollouts:
        r.open()
    cl.run()
    # warm-up: v0 is distributed everywhere (not measured)
    for t in trainers:
        t.publish(0)
    cl.run()
    for r in rollouts:
        r.replicate("latest")
    cl.run()
    for t in trainers:
        t.unpublish()
    cl.run()
    # reset stall accounting; measure only the v0 -> v1 transition
    for r in rollouts:
        for s in r.shards:
            s.worker.total_stall = 0.0
    vpc_before = {k: v for k, v in cl.net.link_bytes.items()}
    for t in trainers:
        t.publish(1)
    cl.run()

    done = {r.name: False for r in rollouts}

    def poller(rep):
        def gen():
            while True:
                results = []
                for s in rep.shards:
                    res = yield from s.g_update("latest")
                    results.append(res)
                if results[0]:
                    done[rep.name] = True
                    return
                yield cl.env.timeout(poll_period)

        return gen

    for r in rollouts:
        cl.env.process(poller(r)())
    cl.run(until=120.0)
    assert all(done.values()), f"rollouts did not converge: {done}"
    names = [f"ro{i}" for i in range(N_STANDALONE)]
    per = cl.per_worker_stalls(names)
    vpc = sum(
        b - vpc_before.get(name, 0.0)
        for name, b in cl.net.link_bytes.items()
        if ":vpc_up" in name
    )
    return {
        "total_stall": sum(per),
        "per_gpu": sorted(round(p, 2) for p in per),
        "cross_dc_bytes": vpc,
    }


def swarm_cold_fanin(*, swarm: bool) -> Dict[str, object]:
    """Cold start: every dc1 rollout replicates v0 concurrently while the
    only copies live in dc0. Exactly one dc1 replica seeds over the WAN;
    the rest swarm off its completed prefix (and each other) over local
    RDMA — same-DC in-progress peers outrank cross-DC published sources,
    so the cross-DC link carries exactly ONE copy regardless of fan-out.
    ``swarm=False`` runs the PR 2 scheduler (pipeline chains off the
    seeder) for comparison; the WAN invariant must hold in both."""
    cl = SimCluster(swarm=swarm)
    units = W.unit_bytes(64)
    trainers = [
        cl.add_replica("m", f"tr{i}", W.num_shards, datacenter="dc0", unit_bytes=units)
        for i in range(W.num_trainer_replicas)
    ]
    rollouts = [
        cl.add_replica("m", f"ro{i}", W.num_shards, datacenter="dc1", unit_bytes=units)
        for i in range(N_STANDALONE)
    ]
    for r in trainers + rollouts:
        r.open()
    cl.run()
    for t in trainers:
        t.publish(0)
    cl.run()
    t0 = cl.env.now
    finish: Dict[str, float] = {}
    events = []
    for r in rollouts:
        ev = r.replicate("latest")
        ev.add_callback(
            lambda e, name=r.name: (
                finish.setdefault(name, cl.env.now) if e.error is None else None
            )
        )
        events.append(ev)
    cl.run(until=120.0)
    assert all(e.triggered and e.error is None for e in events)
    wan = sum(b for name, b in cl.net.link_bytes.items() if ":vpc_up" in name)
    return {
        "makespan_s": max(finish.values()) - t0,
        "cross_dc_bytes": wan,
        "one_copy_bytes": float(W.shard_bytes * W.num_shards),
    }


def ucx_cross_dc() -> Dict[str, object]:
    """Every replica pulls its shards over stream-limited WAN TCP
    (calibrated to the paper's 7.8 s per 10 GB shard)."""
    hw = CLUSTER
    t = W.shard_bytes / hw.ucx_tcp_stream + hw.driver_rpc
    per = [round(t, 2)] * W.standalone_gpus
    return {
        "total_stall": sum(per),
        "per_gpu": per,
        "cross_dc_bytes": float(W.shard_bytes * W.standalone_gpus),
    }


#: int8 + per-1024-element f32 scales vs bf16: (1 + 4/1024) / 2
INT8_RATIO = 0.502


def run(quick: bool = False) -> List[Dict]:
    """``quick`` drops the offload-seeding and int8 variants (the two
    extra warm-transition sims) — the smoke run keeps the headline
    seeding row, the UCX baseline and both cold fan-in WAN checks."""
    th = tensorhub_cross_dc(offload_seeding=False)
    ucx = ucx_cross_dc()
    rows = [
        {"system": "ucx-tcp", **_fmt(ucx)},
        {"system": "tensorhub", **_fmt(th)},
    ]
    if not quick:
        th_off = tensorhub_cross_dc(offload_seeding=True)
        th_q = tensorhub_cross_dc(offload_seeding=False, tcp_compression=INT8_RATIO)
        rows.append({"system": "tensorhub+offload-seeding", **_fmt(th_off)})
        rows.append({"system": "tensorhub+int8-seeding (beyond-paper)", **_fmt(th_q)})
    for swarm in (False, True):
        cold = swarm_cold_fanin(swarm=swarm)
        rows.append(
            {
                "system": f"cold-fanin ({'swarm' if swarm else 'pr2-chains'})",
                "makespan_s": round(cold["makespan_s"], 2),
                "cross_dc_gb": round(cold["cross_dc_bytes"] / 1e9, 2),
                "one_copy_gb": round(cold["one_copy_bytes"] / 1e9, 2),
            }
        )
    return rows


def _fmt(d: Dict) -> Dict:
    return {
        "total_stall_s": round(d["total_stall"], 2),
        "per_gpu_s": d["per_gpu"],
        "cross_dc_gb": round(d["cross_dc_bytes"] / 1e9, 1),
    }


def validate(rows: List[Dict]) -> List[str]:
    by_sys = {r["system"]: r for r in rows}
    ucx = by_sys["ucx-tcp"]
    th = by_sys["tensorhub"]
    th_off = by_sys.get("tensorhub+offload-seeding")
    th_q = by_sys.get("tensorhub+int8-seeding (beyond-paper)")
    checks = []
    # swarm replication: the cold fan-in moves exactly ONE copy across the
    # WAN (the seeder's), with the rest of dc1 fed from its prefix over
    # local RDMA — under both the swarm planner and the PR 2 chains
    for r in rows:
        if "cold-fanin" not in r["system"]:
            continue
        ok = abs(r["cross_dc_gb"] - r["one_copy_gb"]) < 0.05
        checks.append(
            f"{r['system']}: cross-DC traffic {r['cross_dc_gb']} GB == exactly "
            f"1 copy ({r['one_copy_gb']} GB), makespan {r['makespan_s']}s -> "
            f"{'OK' if ok else 'MISMATCH'}"
        )
    if th_q is not None:
        checks.append(
            f"int8 seeding (beyond-paper): seeder tail {th_q['per_gpu_s'][-1]}s vs "
            f"{th['per_gpu_s'][-1]}s bf16 -> "
            f"{'OK' if th_q['per_gpu_s'][-1] < th['per_gpu_s'][-1] * 0.65 else 'MISMATCH'}"
        )
    tail = th["per_gpu_s"]
    body_ok = tail[0] <= 0.7 and tail[-1] >= 2.0
    checks.append(
        f"single seeding tail (per-GPU {tail}; paper: seeder 2.5s, rest 0.45s) "
        f"-> {'OK' if body_ok else 'MISMATCH'}"
    )
    red_plain = ucx["total_stall_s"] / max(th["total_stall_s"], 1e-9)
    checks.append(
        f"stall reduction vs UCX-TCP (seeding only): {red_plain:.0f}x -> "
        f"{'OK' if red_plain >= 5 else 'MISMATCH'}"
    )
    if th_off is not None:
        red_off = ucx["total_stall_s"] / max(th_off["total_stall_s"], 1e-9)
        checks.append(
            f"stall reduction with offload seeding: {red_off:.0f}x (paper: 19x) -> "
            f"{'OK' if 12 <= red_off <= 30 else 'MISMATCH'}"
        )
    traffic = ucx["cross_dc_gb"] / max(th["cross_dc_gb"], 1e-9)
    checks.append(
        f"cross-DC traffic {th['cross_dc_gb']} GB vs UCX {ucx['cross_dc_gb']} GB "
        f"({traffic:.0f}x less) -> {'OK' if traffic >= 3.5 else 'MISMATCH'}"
    )
    return checks


def main() -> None:
    quick = "--quick" in sys.argv
    rows = run(quick=quick)
    for r in rows:
        print(r)
    bad = 0
    for c in validate(rows):
        print("  " + c)
        bad += "MISMATCH" in c
    if quick:
        raise SystemExit(1 if bad else 0)


if __name__ == "__main__":
    main()
