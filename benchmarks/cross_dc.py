"""Fig 12 — cross-datacenter rollouts (9B workload): trainers in dc0,
standalone rollouts in dc1, reachable only over the VPC NIC with
stream-limited WAN TCP.

The measured transition is the warm update path (the paper's steady
state): rollouts hold version v and poll ``update("latest")`` between
inference batches while trainers publish v+1 across the DC boundary.
TensorHub: exactly one *seeding* replica pays the 2.5 s TCP transfer;
smart skipping keeps the others inferring until the seed lands, then they
pull over local RDMA in ~0.45 s. Offload seeding moves the TCP fetch into
a background CPU buffer, removing even the seeder's stall.

Validates: per-GPU latency distribution (single 2.5 s tail, 0.45 s body),
~19x stall reduction vs UCX-over-TCP (with offload seeding, the abstract's
number), cross-DC traffic = 1 copy vs n copies, and the wire-codec rows
(beyond-paper): int8-quantized WAN transfer cuts wire bytes ~3.9x vs f32
(~2.0x vs bf16) at < 1% max relative weight error, measured both in the
fluid sim (codec-derived byte accounting) and on the threaded data plane
with real bytes (``codec_parity``); ``codec="raw"`` reproduces the
pre-codec byte counts bit-for-bit. On top of int8, the version-delta
codec (``delta:int8``) ships only the rows that changed between
correlated versions — sub-GB WAN per warm update vs int8's ~5.1 GB at
1/8 rows changed — with reconstruction gated on bit-parity against the
int8-decode baseline and byte-identical fallback when the destination's
base was evicted (``delta_parity``).
"""

from __future__ import annotations

import json
from typing import Dict, List

from repro.configs.paper_workloads import WORKLOADS
from repro.transfer.hardware import CLUSTER
from repro.transfer.simcluster import SimCluster

try:
    from benchmarks import harness
except ImportError:  # invoked directly: benchmarks/ itself is sys.path[0]
    import harness

#: Chrome trace-event JSON of one threaded cross-DC int8 pull
#: (chrome://tracing / https://ui.perfetto.dev); CI uploads it as an
#: artifact next to the ``--json`` results
TRACE_PATH = "cross_dc_trace.json"

W = WORKLOADS["9B"]
N_STANDALONE = W.standalone_gpus // W.num_shards  # 4 replicas x 2 shards


def tensorhub_cross_dc(
    *,
    offload_seeding: bool,
    poll_period: float = 0.2,
    wan_codec: str = "raw",
    wan_delta: bool = False,
    delta_kept_frac: float = 1.0,
    swarm: bool = True,
) -> Dict[str, object]:
    cl = SimCluster(
        wan_codec=wan_codec,
        wan_delta=wan_delta,
        delta_kept_frac=delta_kept_frac,
        swarm=swarm,
    )
    units = W.unit_bytes(64)
    trainers = [
        cl.add_replica("m", f"tr{i}", W.num_shards, datacenter="dc0", unit_bytes=units)
        for i in range(W.num_trainer_replicas)
    ]
    rollouts = [
        cl.add_replica(
            "m", f"ro{i}", W.num_shards, datacenter="dc1", unit_bytes=units,
            offload_seeding=offload_seeding,
        )
        for i in range(N_STANDALONE)
    ]
    for r in trainers + rollouts:
        r.open()
    cl.run()
    # warm-up: v0 is distributed everywhere (not measured)
    for t in trainers:
        t.publish(0)
    cl.run()
    for r in rollouts:
        r.replicate("latest")
    cl.run()
    for t in trainers:
        t.unpublish()
    cl.run()
    # reset stall accounting; measure only the v0 -> v1 transition
    for r in rollouts:
        for s in r.shards:
            s.worker.total_stall = 0.0
            s.worker.stall_parts.clear()
    vpc_before = cl.link_class_bytes().get("vpc_up", 0.0)
    for t in trainers:
        t.publish(1)
    cl.run()

    done = {r.name: False for r in rollouts}

    def poller(rep):
        def gen():
            while True:
                results = []
                for s in rep.shards:
                    res = yield from s.g_update("latest")
                    results.append(res)
                if results[0]:
                    done[rep.name] = True
                    return
                yield cl.env.timeout(poll_period)

        return gen

    for r in rollouts:
        cl.env.process(poller(r)())
    cl.run(until=120.0)
    assert all(done.values()), f"rollouts did not converge: {done}"
    names = [f"ro{i}" for i in range(N_STANDALONE)]
    per = cl.per_worker_stalls(names)
    vpc = cl.link_class_bytes().get("vpc_up", 0.0) - vpc_before
    return {
        "total_stall": sum(per),
        "per_gpu": sorted(round(p, 2) for p in per),
        "cross_dc_bytes": vpc,
        "stall_parts": cl.stall_decomposition(names),
    }


def swarm_cold_fanin(*, swarm: bool) -> Dict[str, object]:
    """Cold start: every dc1 rollout replicates v0 concurrently while the
    only copies live in dc0. Exactly one dc1 replica seeds over the WAN;
    the rest swarm off its completed prefix (and each other) over local
    RDMA — same-DC in-progress peers outrank cross-DC published sources,
    so the cross-DC link carries exactly ONE copy regardless of fan-out.
    ``swarm=False`` runs the PR 2 scheduler (pipeline chains off the
    seeder) for comparison; the WAN invariant must hold in both (measured
    with ``wan_codec="raw"`` so cross-DC bytes equal weight bytes)."""
    cl = SimCluster(swarm=swarm, wan_codec="raw")
    units = W.unit_bytes(64)
    trainers = [
        cl.add_replica("m", f"tr{i}", W.num_shards, datacenter="dc0", unit_bytes=units)
        for i in range(W.num_trainer_replicas)
    ]
    rollouts = [
        cl.add_replica("m", f"ro{i}", W.num_shards, datacenter="dc1", unit_bytes=units)
        for i in range(N_STANDALONE)
    ]
    for r in trainers + rollouts:
        r.open()
    cl.run()
    for t in trainers:
        t.publish(0)
    cl.run()
    t0 = cl.env.now
    finish: Dict[str, float] = {}
    events = []
    for r in rollouts:
        ev = r.replicate("latest")
        ev.add_callback(
            lambda e, name=r.name: (
                finish.setdefault(name, cl.env.now) if e.error is None else None
            )
        )
        events.append(ev)
    cl.run(until=120.0)
    assert all(e.triggered and e.error is None for e in events)
    wan = cl.link_class_bytes().get("vpc_up", 0.0)
    return {
        "makespan_s": max(finish.values()) - t0,
        "cross_dc_bytes": wan,
        "one_copy_bytes": float(W.shard_bytes * W.num_shards),
    }


def ucx_cross_dc() -> Dict[str, object]:
    """Every replica pulls its shards over stream-limited WAN TCP
    (calibrated to the paper's 7.8 s per 10 GB shard)."""
    hw = CLUSTER
    t = W.shard_bytes / hw.ucx_tcp_stream + hw.driver_rpc
    per = [round(t, 2)] * W.standalone_gpus
    return {
        "total_stall": sum(per),
        "per_gpu": per,
        "cross_dc_bytes": float(W.shard_bytes * W.standalone_gpus),
    }


def codec_parity() -> Dict[str, object]:
    """Threaded plane, REAL bytes: raw-vs-int8 wire byte counts for one
    cross-DC shard pull on bf16 and f32 weight sets, plus the decoded
    weight error. ``codec="raw"`` must reproduce today's transfer byte
    counts bit-for-bit (payload bytes == wire bytes == array bytes); the
    int8 wire must cut f32 bytes ~3.9x (bf16 ~2.0x) at < 1% max relative
    error, with end-to-end checksums verified over the decoded bytes
    (``verify_checksums`` stays on for every pull below)."""
    import ml_dtypes
    import numpy as np

    from repro.core import ReferenceServer, TensorHubClient

    row: Dict[str, object] = {"system": "codec-parity (threaded)"}
    for tag, np_dtype in (("f32", np.float32), ("bf16", ml_dtypes.bfloat16)):
        rng = np.random.RandomState(0)
        tensors = {
            f"w{i}": (rng.randn((1 << 20) + 999) * 2).astype(np_dtype)
            for i in range(2)
        }
        total = sum(v.nbytes for v in tensors.values())
        moved: Dict[str, int] = {}
        decoded: Dict[str, int] = {}
        classes: Dict[str, List[str]] = {}
        max_rel = 0.0
        raw_exact = False
        for codec in ("raw", "int8"):
            hub = TensorHubClient(ReferenceServer(wan_codec=codec))
            assert hub.transport.verify_checksums
            pub = hub.open("m", "pub", 1, 0, datacenter="dc0")
            pub.register(tensors)
            pub.publish(0)
            r = hub.open("m", "r", 1, 0, datacenter="dc1")
            r.register({k: np.zeros_like(v) for k, v in tensors.items()})
            r.replicate(0)
            # per-link-class byte counters, not hand-rolled arithmetic:
            # the cross-DC pull rides the WAN TCP slice ("vpc_up"),
            # wire bytes on the link vs bytes after decode
            moved[codec] = int(sum(hub.transport.wire_bytes.values()))
            decoded[codec] = int(sum(hub.transport.decoded_bytes.values()))
            classes[codec] = sorted(hub.transport.wire_bytes)
            assert hub.transport.bytes_moved == moved[codec]
            if codec == "raw":
                raw_exact = (
                    moved["raw"] == total
                    and decoded["raw"] == total
                    and all(
                        np.array_equal(r.store.get(k).view(np.uint8), v.view(np.uint8))
                        for k, v in tensors.items()
                    )
                )
            else:
                for k, v in tensors.items():
                    got = np.asarray(r.store.get(k), np.float32)
                    want = np.asarray(v, np.float32)
                    denom = max(float(np.max(np.abs(want))), 1e-12)
                    max_rel = max(
                        max_rel, float(np.max(np.abs(got - want))) / denom
                    )
        row[f"{tag}_raw_mb"] = round(moved["raw"] / 1e6, 3)
        row[f"{tag}_int8_mb"] = round(moved["int8"] / 1e6, 3)
        row[f"{tag}_reduction_x"] = round(moved["raw"] / moved["int8"], 2)
        row[f"{tag}_max_rel_err"] = round(max_rel, 5)
        row[f"{tag}_raw_bit_exact"] = raw_exact
        row[f"{tag}_wire_ratio"] = moved["int8"] / decoded["int8"]
        row[f"{tag}_link_classes"] = classes["int8"]
    return row


def delta_parity() -> Dict[str, object]:
    """Threaded plane, REAL bytes: the correlated warm update (v0 -> v1
    with 1/8 of the quant rows changed) with and without delta
    negotiation. Gates: the delta:int8 update ships strictly fewer WAN
    bytes than plain int8, reconstructs within int8's error bound with
    unchanged rows bit-identical to the int8-decode baseline, and a
    destination whose held base was evicted mid-plan falls back to plain
    int8 with a byte-identical final state."""
    import numpy as np

    from repro.core import ReferenceServer, TensorHubClient

    nrows = 8192
    changed = nrows // 8
    rng = np.random.default_rng(12)
    v1 = rng.standard_normal((nrows, 256)).astype(np.float32)
    v2 = v1.copy()
    v2[:changed] = v2[:changed] * 1.001 + 0.01

    def update_run(wan_delta: bool, scramble: bool = False):
        hub = TensorHubClient(
            ReferenceServer(wan_codec="int8", wan_delta=wan_delta)
        )
        pub = hub.open("m", "pub", 1, 0, datacenter="dc0")
        pub.register({"w": v1.copy()})
        pub.publish(0)
        r = hub.open("m", "r", 1, 0, datacenter="dc1")
        r.register({"w": np.zeros_like(v1)})
        r.replicate(0)
        pub.unpublish()
        pub.store.register({"w": v2.copy()})
        pub.publish(1)
        if scramble:
            r.store.get("w")[:] = 0.0  # held base evicted mid-plan
        before = hub.transport.bytes_moved
        assert r.update("latest")
        return hub.transport.bytes_moved - before, r.store.get("w").copy(), hub

    int8_wire, int8_out, _ = update_run(False)
    delta_wire, delta_out, _ = update_run(True)
    _, stale_out, stale_hub = update_run(True, scramble=True)
    denom = float(np.max(np.abs(v2)))
    return {
        "system": "delta-parity (threaded)",
        "int8_update_mb": round(int8_wire / 1e6, 3),
        "delta_update_mb": round(delta_wire / 1e6, 3),
        # unrounded twins for the sim-vs-threaded ratio parity check
        "int8_update_bytes": int(int8_wire),
        "delta_update_bytes": int(delta_wire),
        "reduction_x": round(int8_wire / delta_wire, 2),
        "max_rel_err": round(float(np.max(np.abs(delta_out - v2))) / denom, 5),
        # unchanged rows land bit-identical to the int8-decode baseline
        "base_byte_parity": bool(
            np.array_equal(delta_out[changed:], int8_out[changed:])
        ),
        "stale_fallback_identical": bool(
            stale_hub.transport.delta_stale_fallbacks >= 1
            and np.array_equal(stale_out, int8_out)
        ),
    }


def threaded_stall_demo(trace_path: str = TRACE_PATH) -> Dict[str, object]:
    """One real cross-DC int8 shard pull on the threaded data plane with
    the telemetry recorder on: the per-replica pull timeline goes out as
    Chrome trace-event JSON and the recorder's stall counters decompose
    the replicate() wall time into plan_wait / wire / decode / verify /
    control — the components must tile the end-to-end stall within 5%."""
    import numpy as np

    from repro.core import ReferenceServer, TensorHubClient
    from repro.obs import Recorder, stall_breakdown, write_chrome_trace

    rec = Recorder()
    hub = TensorHubClient(
        ReferenceServer(wan_codec="int8"), recorder=rec, window=1, chunk_bytes=None
    )
    rng = np.random.RandomState(1)
    tensors = {
        f"w{i}": (rng.randn(1 << 21) * 2).astype(np.float32) for i in range(4)
    }  # 4 x 8 MB units
    pub = hub.open("m", "pub", 1, 0, datacenter="dc0")
    pub.register(tensors)
    pub.publish(0)
    r = hub.open("m", "r", 1, 0, datacenter="dc1")
    r.register({k: np.zeros_like(v) for k, v in tensors.items()})
    rec.clear()  # measure the pull only, not registration/publish
    t0 = rec.clock()
    r.replicate(0)
    wall = rec.clock() - t0
    write_chrome_trace(rec, trace_path)
    row: Dict[str, object] = {
        "system": "threaded-stall-demo (int8 pull)",
        "wall_s": round(wall, 4),
        "spans": len(rec.events),
        "trace": trace_path,
    }
    row.update(harness.decomposition_cols(stall_breakdown(rec), digits=4))
    return row


def run(quick: bool = False) -> List[Dict]:
    """``quick`` drops the offload-seeding variant (one extra
    warm-transition sim) — the smoke run keeps the headline seeding row,
    the UCX baseline, the raw-vs-int8 wire comparison (sim + threaded
    codec parity) and both cold fan-in WAN checks."""
    th = tensorhub_cross_dc(offload_seeding=False)
    th_q = tensorhub_cross_dc(offload_seeding=False, wan_codec="int8")
    th_d = tensorhub_cross_dc(
        offload_seeding=False, wan_codec="int8", wan_delta=True,
        delta_kept_frac=0.125,
    )
    ucx = ucx_cross_dc()
    th_row = {"system": "tensorhub", **_fmt(th)}
    th_row["stall_total_s"] = round(th["total_stall"], 3)
    th_row.update(harness.decomposition_cols(th["stall_parts"]))
    rows = [
        {"system": "ucx-tcp", **_fmt(ucx)},
        th_row,
        {"system": "tensorhub+int8-wire (beyond-paper)", **_fmt(th_q)},
        {"system": "tensorhub+delta-wire (beyond-paper)", **_fmt(th_d)},
        codec_parity(),
        delta_parity(),
        threaded_stall_demo(),
    ]
    if not quick:
        th_off = tensorhub_cross_dc(offload_seeding=True)
        rows.append({"system": "tensorhub+offload-seeding", **_fmt(th_off)})
    for swarm in (False, True):
        cold = swarm_cold_fanin(swarm=swarm)
        rows.append(
            {
                "system": f"cold-fanin ({'swarm' if swarm else 'pr2-chains'})",
                "makespan_s": round(cold["makespan_s"], 2),
                "cross_dc_gb": round(cold["cross_dc_bytes"] / 1e9, 2),
                "one_copy_gb": round(cold["one_copy_bytes"] / 1e9, 2),
            }
        )
    return rows


def _fmt(d: Dict) -> Dict:
    return {
        "total_stall_s": round(d["total_stall"], 2),
        "per_gpu_s": d["per_gpu"],
        "cross_dc_gb": round(d["cross_dc_bytes"] / 1e9, 1),
        # unrounded twin of cross_dc_gb: the sim-vs-threaded codec-ratio
        # parity check needs more precision than the display column
        "cross_dc_bytes": d["cross_dc_bytes"],
    }


def validate(rows: List[Dict]) -> List[str]:
    by_sys = {r["system"]: r for r in rows}
    ucx = by_sys["ucx-tcp"]
    th = by_sys["tensorhub"]
    th_off = by_sys.get("tensorhub+offload-seeding")
    th_q = by_sys.get("tensorhub+int8-wire (beyond-paper)")
    parity = by_sys.get("codec-parity (threaded)")
    checks = []
    if th_q is not None:
        wan_red = th["cross_dc_gb"] / max(th_q["cross_dc_gb"], 1e-9)
        checks.append(
            f"int8 WAN wire bytes (sim): {th_q['cross_dc_gb']} GB vs "
            f"{th['cross_dc_gb']} GB raw = {wan_red:.2f}x less (int8 + "
            f"per-256 f32 scales vs f32: 3.94x) -> "
            f"{'OK' if 3.8 <= wan_red <= 4.0 else 'MISMATCH'}"
        )
    if parity is not None:
        ok = (
            parity["f32_raw_bit_exact"]
            and parity["bf16_raw_bit_exact"]
            and 3.8 <= parity["f32_reduction_x"] <= 4.0
            and 1.9 <= parity["bf16_reduction_x"] <= 2.1
            and parity["f32_max_rel_err"] < 0.01
            and parity["bf16_max_rel_err"] < 0.01
        )
        checks.append(
            "codec parity (threaded, real bytes): raw bit-exact="
            f"{parity['f32_raw_bit_exact'] and parity['bf16_raw_bit_exact']}, "
            f"int8 wire {parity['f32_reduction_x']}x (f32) / "
            f"{parity['bf16_reduction_x']}x (bf16) smaller, max rel err "
            f"{max(parity['f32_max_rel_err'], parity['bf16_max_rel_err'])} "
            f"(<1%) -> {'OK' if ok else 'MISMATCH'}"
        )
    # swarm replication: the cold fan-in moves exactly ONE copy across the
    # WAN (the seeder's), with the rest of dc1 fed from its prefix over
    # local RDMA — under both the swarm planner and the PR 2 chains
    for r in rows:
        if "cold-fanin" not in r["system"]:
            continue
        ok = abs(r["cross_dc_gb"] - r["one_copy_gb"]) < 0.05
        checks.append(
            f"{r['system']}: cross-DC traffic {r['cross_dc_gb']} GB == exactly "
            f"1 copy ({r['one_copy_gb']} GB), makespan {r['makespan_s']}s -> "
            f"{'OK' if ok else 'MISMATCH'}"
        )
    if th_q is not None:
        checks.append(
            f"int8 wire (beyond-paper): seeder tail {th_q['per_gpu_s'][-1]}s vs "
            f"{th['per_gpu_s'][-1]}s raw -> "
            f"{'OK' if th_q['per_gpu_s'][-1] < th['per_gpu_s'][-1] * 0.65 else 'MISMATCH'}"
        )
    tail = th["per_gpu_s"]
    body_ok = tail[0] <= 0.7 and tail[-1] >= 2.0
    checks.append(
        f"single seeding tail (per-GPU {tail}; paper: seeder 2.5s, rest 0.45s) "
        f"-> {'OK' if body_ok else 'MISMATCH'}"
    )
    red_plain = ucx["total_stall_s"] / max(th["total_stall_s"], 1e-9)
    checks.append(
        f"stall reduction vs UCX-TCP (seeding only): {red_plain:.0f}x -> "
        f"{'OK' if red_plain >= 5 else 'MISMATCH'}"
    )
    if th_off is not None:
        red_off = ucx["total_stall_s"] / max(th_off["total_stall_s"], 1e-9)
        checks.append(
            f"stall reduction with offload seeding: {red_off:.0f}x (paper: 19x) -> "
            f"{'OK' if 12 <= red_off <= 30 else 'MISMATCH'}"
        )
    traffic = ucx["cross_dc_gb"] / max(th["cross_dc_gb"], 1e-9)
    checks.append(
        f"cross-DC traffic {th['cross_dc_gb']} GB vs UCX {ucx['cross_dc_gb']} GB "
        f"({traffic:.0f}x less) -> {'OK' if traffic >= 3.5 else 'MISMATCH'}"
    )
    # stall-time decomposition tiles the end-to-end stall in BOTH planes
    checks.append(
        harness.check_decomposition(
            "sim warm transition",
            {k: th[f"{k}_s"] for k in harness.STALL_COMPONENTS},
            th["stall_total_s"],
        )
    )
    demo = by_sys.get("threaded-stall-demo (int8 pull)")
    if demo is not None:
        checks.append(
            harness.check_decomposition(
                "threaded int8 pull",
                {k: demo[f"{k}_s"] for k in harness.STALL_COMPONENTS},
                demo["wall_s"],
            )
        )
        checks.append(_check_trace(demo["trace"]))
    # delta wire codec: both planes must ship strictly fewer bytes than
    # plain int8, reconstruct within the int8 tolerance (unchanged rows
    # bit-identical to the int8-decode baseline), and survive a mid-plan
    # base eviction byte-identically — and the two planes must agree on
    # the delta/int8 wire ratio
    th_d = by_sys.get("tensorhub+delta-wire (beyond-paper)")
    dp = by_sys.get("delta-parity (threaded)")
    if th_d is not None and th_q is not None and dp is not None:
        ok = (
            th_d["cross_dc_bytes"] < th_q["cross_dc_bytes"]
            and dp["delta_update_bytes"] < dp["int8_update_bytes"]
            and dp["max_rel_err"] < 0.01
            and dp["base_byte_parity"]
            and dp["stale_fallback_identical"]
        )
        checks.append(
            f"delta wire (beyond-paper): {th_d['cross_dc_gb']} GB WAN/update "
            f"(sim, 1/8 rows changed) vs {th_q['cross_dc_gb']} GB int8; "
            f"threaded update {dp['delta_update_mb']} MB vs "
            f"{dp['int8_update_mb']} MB ({dp['reduction_x']}x), max rel err "
            f"{dp['max_rel_err']}, int8-baseline byte parity "
            f"{dp['base_byte_parity']}, evicted-base fallback byte-identical "
            f"{dp['stale_fallback_identical']} -> {'OK' if ok else 'MISMATCH'}"
        )
        sim_ratio = th_d["cross_dc_bytes"] / th_q["cross_dc_bytes"]
        thr_ratio = dp["delta_update_bytes"] / dp["int8_update_bytes"]
        dev = abs(thr_ratio - sim_ratio) / sim_ratio
        checks.append(
            f"sim-vs-threaded delta wire-byte parity: sim delta/int8 ratio "
            f"{sim_ratio:.4f} vs threaded {thr_ratio:.4f} "
            f"({dev * 100:.2f}% apart, required < 2%) -> "
            f"{'OK' if dev < 0.02 else 'MISMATCH'}"
        )
    # counter-based byte parity: the sim's codec-derived WAN reduction and
    # the threaded plane's real wire/decoded counter ratio agree
    if th_q is not None and parity is not None:
        sim_ratio = th_q["cross_dc_bytes"] / th["cross_dc_bytes"]
        thr_ratio = parity["f32_wire_ratio"]
        dev = abs(thr_ratio - sim_ratio) / sim_ratio
        cls_ok = parity["f32_link_classes"] == ["vpc_up"]
        checks.append(
            f"sim-vs-threaded int8 wire-byte parity: sim vpc_up ratio "
            f"{sim_ratio:.4f} vs threaded wire/decoded {thr_ratio:.4f} "
            f"({dev * 100:.2f}% apart, required < 2%; link classes "
            f"{parity['f32_link_classes']}) -> "
            f"{'OK' if dev < 0.02 and cls_ok else 'MISMATCH'}"
        )
    return checks


def _check_trace(path: str) -> str:
    """The exported trace must survive a json.loads round-trip with
    integer, monotonically ordered timestamps (Chrome trace-event)."""
    try:
        with open(path) as fh:
            data = json.loads(fh.read())
        xs = [e for e in data["traceEvents"] if e["ph"] == "X"]
        ok = (
            len(xs) > 0
            and all(isinstance(e["ts"], int) and isinstance(e["dur"], int) for e in xs)
            and all(a["ts"] <= b["ts"] for a, b in zip(xs, xs[1:]))
        )
        detail = f"{len(xs)} spans"
    except (OSError, KeyError, ValueError) as exc:
        ok, detail = False, f"unreadable: {exc}"
    return (
        f"chrome trace {path}: valid JSON, monotonic integer ts ({detail}) -> "
        f"{'OK' if ok else 'MISMATCH'}"
    )


if __name__ == "__main__":
    harness.bench_main("cross_dc", run, validate)
