"""Fig 12 — cross-datacenter rollouts (9B workload): trainers in dc0,
standalone rollouts in dc1, reachable only over the VPC NIC with
stream-limited WAN TCP.

The measured transition is the warm update path (the paper's steady
state): rollouts hold version v and poll ``update("latest")`` between
inference batches while trainers publish v+1 across the DC boundary.
TensorHub: exactly one *seeding* replica pays the 2.5 s TCP transfer;
smart skipping keeps the others inferring until the seed lands, then they
pull over local RDMA in ~0.45 s. Offload seeding moves the TCP fetch into
a background CPU buffer, removing even the seeder's stall.

Validates: per-GPU latency distribution (single 2.5 s tail, 0.45 s body),
~19x stall reduction vs UCX-over-TCP (with offload seeding, the abstract's
number), cross-DC traffic = 1 copy vs n copies.
"""

from __future__ import annotations

from typing import Dict, List

from repro.configs.paper_workloads import WORKLOADS
from repro.transfer.hardware import CLUSTER
from repro.transfer.simcluster import SimCluster

W = WORKLOADS["9B"]
N_STANDALONE = W.standalone_gpus // W.num_shards  # 4 replicas x 2 shards


def tensorhub_cross_dc(
    *, offload_seeding: bool, poll_period: float = 0.2, tcp_compression: float = 1.0
) -> Dict[str, object]:
    cl = SimCluster(tcp_compression=tcp_compression)
    units = W.unit_bytes(64)
    trainers = [
        cl.add_replica("m", f"tr{i}", W.num_shards, datacenter="dc0", unit_bytes=units)
        for i in range(W.num_trainer_replicas)
    ]
    rollouts = [
        cl.add_replica(
            "m", f"ro{i}", W.num_shards, datacenter="dc1", unit_bytes=units,
            offload_seeding=offload_seeding,
        )
        for i in range(N_STANDALONE)
    ]
    for r in trainers + rollouts:
        r.open()
    cl.run()
    # warm-up: v0 is distributed everywhere (not measured)
    for t in trainers:
        t.publish(0)
    cl.run()
    for r in rollouts:
        r.replicate("latest")
    cl.run()
    for t in trainers:
        t.unpublish()
    cl.run()
    # reset stall accounting; measure only the v0 -> v1 transition
    for r in rollouts:
        for s in r.shards:
            s.worker.total_stall = 0.0
    vpc_before = {k: v for k, v in cl.net.link_bytes.items()}
    for t in trainers:
        t.publish(1)
    cl.run()

    done = {r.name: False for r in rollouts}

    def poller(rep):
        def gen():
            while True:
                results = []
                for s in rep.shards:
                    res = yield from s.g_update("latest")
                    results.append(res)
                if results[0]:
                    done[rep.name] = True
                    return
                yield cl.env.timeout(poll_period)

        return gen

    for r in rollouts:
        cl.env.process(poller(r)())
    cl.run(until=120.0)
    assert all(done.values()), f"rollouts did not converge: {done}"
    names = [f"ro{i}" for i in range(N_STANDALONE)]
    per = cl.per_worker_stalls(names)
    vpc = sum(
        b - vpc_before.get(name, 0.0)
        for name, b in cl.net.link_bytes.items()
        if ":vpc_up" in name
    )
    return {
        "total_stall": sum(per),
        "per_gpu": sorted(round(p, 2) for p in per),
        "cross_dc_bytes": vpc,
    }


def ucx_cross_dc() -> Dict[str, object]:
    """Every replica pulls its shards over stream-limited WAN TCP
    (calibrated to the paper's 7.8 s per 10 GB shard)."""
    hw = CLUSTER
    t = W.shard_bytes / hw.ucx_tcp_stream + hw.driver_rpc
    per = [round(t, 2)] * W.standalone_gpus
    return {
        "total_stall": sum(per),
        "per_gpu": per,
        "cross_dc_bytes": float(W.shard_bytes * W.standalone_gpus),
    }


#: int8 + per-1024-element f32 scales vs bf16: (1 + 4/1024) / 2
INT8_RATIO = 0.502


def run() -> List[Dict]:
    th = tensorhub_cross_dc(offload_seeding=False)
    th_off = tensorhub_cross_dc(offload_seeding=True)
    th_q = tensorhub_cross_dc(offload_seeding=False, tcp_compression=INT8_RATIO)
    ucx = ucx_cross_dc()
    return [
        {"system": "ucx-tcp", **_fmt(ucx)},
        {"system": "tensorhub", **_fmt(th)},
        {"system": "tensorhub+offload-seeding", **_fmt(th_off)},
        {"system": "tensorhub+int8-seeding (beyond-paper)", **_fmt(th_q)},
    ]


def _fmt(d: Dict) -> Dict:
    return {
        "total_stall_s": round(d["total_stall"], 2),
        "per_gpu_s": d["per_gpu"],
        "cross_dc_gb": round(d["cross_dc_bytes"] / 1e9, 1),
    }


def validate(rows: List[Dict]) -> List[str]:
    ucx, th, th_off, th_q = rows
    checks = []
    checks.append(
        f"int8 seeding (beyond-paper): seeder tail {th_q['per_gpu_s'][-1]}s vs "
        f"{th['per_gpu_s'][-1]}s bf16 -> "
        f"{'OK' if th_q['per_gpu_s'][-1] < th['per_gpu_s'][-1] * 0.65 else 'MISMATCH'}"
    )
    tail = th["per_gpu_s"]
    body_ok = tail[0] <= 0.7 and tail[-1] >= 2.0
    checks.append(
        f"single seeding tail (per-GPU {tail}; paper: seeder 2.5s, rest 0.45s) "
        f"-> {'OK' if body_ok else 'MISMATCH'}"
    )
    red_plain = ucx["total_stall_s"] / max(th["total_stall_s"], 1e-9)
    checks.append(
        f"stall reduction vs UCX-TCP (seeding only): {red_plain:.0f}x -> "
        f"{'OK' if red_plain >= 5 else 'MISMATCH'}"
    )
    red_off = ucx["total_stall_s"] / max(th_off["total_stall_s"], 1e-9)
    checks.append(
        f"stall reduction with offload seeding: {red_off:.0f}x (paper: 19x) -> "
        f"{'OK' if 12 <= red_off <= 30 else 'MISMATCH'}"
    )
    traffic = ucx["cross_dc_gb"] / max(th["cross_dc_gb"], 1e-9)
    checks.append(
        f"cross-DC traffic {th['cross_dc_gb']} GB vs UCX {ucx['cross_dc_gb']} GB "
        f"({traffic:.0f}x less) -> {'OK' if traffic >= 3.5 else 'MISMATCH'}"
    )
    return checks


def main() -> None:
    rows = run()
    for r in rows:
        print(r)
    for c in validate(rows):
        print("  " + c)


if __name__ == "__main__":
    main()
