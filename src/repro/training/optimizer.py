"""AdamW, implemented directly on pytrees (no optax dependency).

Moments may be kept in bf16 (``state_dtype``) — at 671B-scale this halves
optimizer memory, the difference between fitting and not fitting a v5e pod
(EXPERIMENTS.md records both). Moment shardings mirror the parameter
shardings, so FSDP shards optimizer state exactly like ZeRO.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # scalar int32
    mu: Any  # first moment, like params
    nu: Any  # second moment, like params


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: Any = jnp.float32  # jnp.bfloat16 halves optimizer memory
    grad_clip: float = 1.0
    #: optional lr schedule step -> multiplier
    schedule: Optional[Callable[[jax.Array], jax.Array]] = None

    def init(self, params: Any) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, self.state_dtype)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def update(
        self, grads: Any, state: AdamWState, params: Any
    ) -> Tuple[Any, AdamWState]:
        step = state.step + 1
        if self.grad_clip > 0:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = jnp.asarray(self.lr, jnp.float32)
        if self.schedule is not None:
            lr = lr * self.schedule(step)

        def upd(p, g, mu, nu):
            g32 = g.astype(jnp.float32)
            mu_n = b1 * mu.astype(jnp.float32) + (1 - b1) * g32
            nu_n = b2 * nu.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            mu_hat = mu_n / c1
            nu_hat = nu_n / c2
            delta = mu_hat / (jnp.sqrt(nu_hat) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return p_new, mu_n.astype(self.state_dtype), nu_n.astype(self.state_dtype)

        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
        p_new = jax.tree.unflatten(treedef, [t[0] for t in flat])
        mu_new = jax.tree.unflatten(treedef, [t[1] for t in flat])
        nu_new = jax.tree.unflatten(treedef, [t[2] for t in flat])
        return p_new, AdamWState(step=step, mu=mu_new, nu=nu_new)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def cosine_schedule(warmup: int, total: int) -> Callable[[jax.Array], jax.Array]:
    def fn(step: jax.Array) -> jax.Array:
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / max(warmup, 1), 1.0)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        return warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))

    return fn
