"""Loss functions: LM cross-entropy (shifted), masked CE (encoder), and a
GRPO-style clipped policy-gradient objective for the RL loop."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def _log_softmax(logits: jax.Array) -> jax.Array:
    return jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)


def lm_cross_entropy(
    logits: jax.Array,  # [B, S, V]
    tokens: jax.Array,  # [B, S]
    *,
    text_offset: int = 0,  # VLM: logits include a patch prefix of this length
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token CE: logits[:, t] predicts tokens[:, t+1]."""
    lp = _log_softmax(logits[:, text_offset:-1])
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    acc = jnp.mean(jnp.argmax(lp, axis=-1) == tgt)
    return loss, {"loss": loss, "accuracy": acc}


def masked_cross_entropy(
    logits: jax.Array,  # [B, S, V]
    targets: jax.Array,  # [B, S]
    mask: jax.Array,  # [B, S] bool (True = scored position)
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    lp = _log_softmax(logits)
    nll = -jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    m = mask.astype(jnp.float32)
    denom = jnp.maximum(m.sum(), 1.0)
    loss = (nll * m).sum() / denom
    acc = ((jnp.argmax(lp, axis=-1) == targets) * m).sum() / denom
    return loss, {"loss": loss, "accuracy": acc}


def grpo_loss(
    logits: jax.Array,  # [B, S, V] current policy
    tokens: jax.Array,  # [B, S] sampled responses (incl. prompt prefix)
    behavior_logprobs: jax.Array,  # [B, S-1] logprobs under the sampling policy
    advantages: jax.Array,  # [B] group-relative advantages
    loss_mask: jax.Array,  # [B, S-1] True on response tokens
    *,
    clip_eps: float = 0.2,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Clipped token-level policy gradient with group-relative advantages
    (GRPO-style, the algorithm family the paper's workloads run: 2.1)."""
    lp = _log_softmax(logits[:, :-1])
    tok_lp = jnp.take_along_axis(lp, tokens[:, 1:][..., None], axis=-1)[..., 0]
    ratio = jnp.exp(tok_lp - behavior_logprobs)
    adv = advantages[:, None]
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv
    per_tok = -jnp.minimum(unclipped, clipped)
    m = loss_mask.astype(jnp.float32)
    loss = (per_tok * m).sum() / jnp.maximum(m.sum(), 1.0)
    return loss, {
        "loss": loss,
        "mean_ratio": (ratio * m).sum() / jnp.maximum(m.sum(), 1.0),
        "mean_advantage": jnp.mean(advantages),
    }


def group_relative_advantages(rewards: jax.Array, group_size: int) -> jax.Array:
    """GRPO advantage: reward minus its prompt-group mean, normalized by the
    group std. rewards: [B] with B = num_groups * group_size."""
    g = rewards.reshape(-1, group_size)
    mean = g.mean(axis=1, keepdims=True)
    std = g.std(axis=1, keepdims=True)
    return ((g - mean) / jnp.maximum(std, 1e-6)).reshape(-1)
