"""Step factories: train / prefill / decode, shared by examples, smoke
tests and the multi-pod dry-run.

The returned functions are pure (params, opt_state, batch) -> ... and are
jitted by the caller with in/out shardings from ``repro.sharding.rules``;
GSPMD propagates everything else.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AUDIO, VLM, ModelConfig
from repro.training import objectives
from repro.training.optimizer import AdamW, AdamWState


def make_loss_fn(model, cfg: ModelConfig) -> Callable:
    def loss_fn(params, batch):
        logits = model.forward(params, batch)
        if cfg.family == AUDIO:
            return objectives.masked_cross_entropy(logits, batch["targets"], batch["mask"])
        offset = cfg.num_patches if cfg.family == VLM else 0
        return objectives.lm_cross_entropy(logits, batch["tokens"], text_offset=offset)

    return loss_fn


def make_train_step(
    model,
    cfg: ModelConfig,
    opt: AdamW,
    *,
    accum: int = 1,
) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). ``accum > 1`` runs that many sequential microbatches (the
    leading batch dim must divide evenly) and averages gradients."""
    loss_fn = make_loss_fn(model, cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return grads, metrics

    def train_step(params, opt_state: AdamWState, batch: Dict[str, jax.Array]):
        if accum == 1:
            grads, metrics = single(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]), batch
            )

            def body(carry, mb):
                grads_acc = carry
                g, m = single(params, mb)
                return jax.tree.map(jnp.add, grads_acc, g), m

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, metrics = jax.lax.scan(body, zeros, micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        new_params, new_opt = opt.update(grads, opt_state, params)
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(model) -> Callable:
    def prefill_step(params, batch):
        logits, cache, cache_len = model.prefill(params, batch)
        return logits, cache, cache_len

    return prefill_step


def make_decode_step(model, *, ring: bool = False) -> Callable:
    """One serve_step: append one token to the KV/recurrent cache."""
    kwargs = {}
    if ring:
        kwargs["ring"] = True

    def decode_step(params, cache, tokens, cache_len):
        try:
            return model.decode(params, cache, tokens, cache_len, **kwargs)
        except TypeError:  # families without a ring-cache mode
            return model.decode(params, cache, tokens, cache_len)

    return decode_step


def make_grpo_step(model, cfg: ModelConfig, opt: AdamW) -> Callable:
    """RL training step: GRPO clipped policy gradient over sampled rollouts."""

    def loss_fn(params, batch):
        logits = model.forward(params, {"tokens": batch["tokens"]})
        return objectives.grpo_loss(
            logits,
            batch["tokens"],
            batch["behavior_logprobs"],
            batch["advantages"],
            batch["loss_mask"],
        )

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def rl_step(params, opt_state: AdamWState, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        new_params, new_opt = opt.update(grads, opt_state, params)
        return new_params, new_opt, metrics

    return rl_step
