from repro.training.objectives import (
    group_relative_advantages,
    grpo_loss,
    lm_cross_entropy,
    masked_cross_entropy,
)
from repro.training.optimizer import AdamW, AdamWState, cosine_schedule, global_norm
from repro.training.steps import (
    make_decode_step,
    make_grpo_step,
    make_loss_fn,
    make_prefill_step,
    make_train_step,
)

__all__ = [
    "AdamW",
    "AdamWState",
    "cosine_schedule",
    "global_norm",
    "group_relative_advantages",
    "grpo_loss",
    "lm_cross_entropy",
    "make_decode_step",
    "make_grpo_step",
    "make_loss_fn",
    "make_prefill_step",
    "make_train_step",
    "masked_cross_entropy",
]
