"""Plan-time quantization-row-grid alignment, shared by every chunker.

The int8 wire codec quantizes per row of ``INT8_ROW_LEN`` elements, so a
sub-unit range read reproduces the whole-unit encoding row-for-row only
when its byte offset is a multiple of :meth:`WireCodec.row_bytes` (a
partial tail row is legal only at the end of the unit payload — the
transport enforces exactly this). Two planners need that arithmetic:

* the client's task builder, splitting giant units into chunks
  (``core/client.py``) — chunk boundaries land on the row grid;
* the resharding planner (``planner.py``), striping byte intervals
  across source shards — each interval is *widened* to the enclosing
  row-grid range (``lead``/``tail`` bytes) so the source can encode it,
  and the destination trims the widening after decode (or the fused
  kernel gathers only the interior rows).

Keeping both on one module keeps the grid arithmetic from drifting
between the chunked same-layout path and the resharded path.
"""

from __future__ import annotations

from typing import Iterable, Optional, Tuple


def chunk_align(nbytes: int, align: int) -> int:
    """Round a chunk/stripe size up to the row grid (no-op for ``raw``'s
    1-byte granularity)."""
    if align <= 1 or nbytes <= 0:
        return nbytes
    return -(-nbytes // align) * align


def row_granularity(codec_names: Iterable[str], dtype: Optional[str]) -> int:
    """The coarsest row granularity any of ``codec_names`` needs for a
    payload of ``dtype`` — boundaries aligned to this are aligned for
    every codec in the set (the client aligns once for a whole plan)."""
    from repro.transfer.codec import get_codec

    return max(get_codec(name).row_bytes(dtype) for name in codec_names)


def snap(
    offset: int, nbytes: int, rb: int, unit_nbytes: int
) -> Tuple[int, int]:
    """Widen ``[offset, offset + nbytes)`` of a unit payload to the
    enclosing row-grid range: returns ``(lead, tail)`` byte counts such
    that ``[offset - lead, offset + nbytes + tail)`` starts on a row
    boundary and ends on a row boundary or at ``unit_nbytes`` (the one
    place a partial row is legal). ``(0, 0)`` for byte-granular codecs.
    """
    if rb <= 1 or nbytes <= 0:
        return 0, 0
    lead = offset % rb
    stop = offset + nbytes
    stop_aligned = -(-stop // rb) * rb
    if 0 < unit_nbytes < stop_aligned:
        stop_aligned = unit_nbytes
    return lead, stop_aligned - stop
