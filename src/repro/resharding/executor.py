"""Plan execution: staging assembly + repack into registered buffers.

The transport lands each :class:`ReadInterval`'s payload in a contiguous
*staging* buffer (the analogue of the RDMA landing zone — striped reads
arrive out of tensor order, from many source shards). Once every interval
of a destination transfer unit is in, ``repack`` gathers the staging
bytes into the unit's payload layout and the store absorbs it with the
ordinary ``write_unit`` path, so downstream machinery (progress counters,
pipelined readers, compact buckets) is unchanged.

Repack runs either as a NumPy scatter (the reference path the threaded
client uses by default) or through the Pallas gather kernel in
``repro.kernels.repack`` (``use_kernel=True``; parity is tested).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.errors import TensorHubError
from repro.core.meta import ShardManifest, TransferUnit
from repro.resharding.planner import ReadInterval, ShardPlan


@dataclasses.dataclass(frozen=True)
class PlacedInterval:
    """An interval plus where its payload lands in the unit's staging
    buffer and in the assembled unit payload."""

    interval: ReadInterval
    staging_offset: int
    unit_offset: int  # destination offset within the assembled unit payload


class ReshardExecutor:
    """Drives one destination shard's :class:`ShardPlan`."""

    def __init__(
        self,
        plan: ShardPlan,
        dest_manifest: ShardManifest,
        *,
        use_kernel: bool = False,
        interpret: Optional[bool] = None,
    ) -> None:
        self.plan = plan
        self.manifest = dest_manifest
        self.use_kernel = use_kernel
        #: None = auto: compiled on TPU, Pallas interpreter elsewhere
        #: (CPU/GPU backends cannot compile the TPU gather kernel)
        self.interpret = interpret
        self._units: Dict[int, List[PlacedInterval]] = {}
        self._staging_bytes: Dict[int, int] = {}
        by_unit = plan.intervals_by_unit()
        for u in dest_manifest.units:
            member_off = self._member_offsets(u)
            placed: List[PlacedInterval] = []
            pos = 0
            for iv in by_unit.get(u.index, []):
                if iv.tensor not in member_off:
                    raise TensorHubError(
                        f"plan interval for {iv.tensor!r} does not belong to "
                        f"dest unit {u.index} ({u.name})"
                    )
                placed.append(
                    PlacedInterval(
                        interval=iv,
                        staging_offset=pos,
                        unit_offset=member_off[iv.tensor] + iv.dst_offset,
                    )
                )
                pos += iv.nbytes
            self._units[u.index] = placed
            self._staging_bytes[u.index] = pos

    @staticmethod
    def _member_offsets(unit: TransferUnit) -> Dict[str, int]:
        if not unit.is_compact:
            return {unit.name: 0}
        return {name: off for name, off, _ in unit.layout}

    # -- iteration --------------------------------------------------------------

    @property
    def num_units(self) -> int:
        return len(self.manifest.units)

    def unit_batches(
        self, *, start_unit: int = 0
    ) -> Iterator[Tuple[TransferUnit, List[PlacedInterval]]]:
        """Destination units in progress order, with their placed
        intervals. ``start_unit`` skips units already completed (resume
        after a source failure re-plan)."""
        for u in self.manifest.units[start_unit:]:
            yield u, self._units[u.index]

    def staging_bytes(self, dest_unit: int) -> int:
        return self._staging_bytes[dest_unit]

    def make_staging(self, dest_unit: int) -> np.ndarray:
        return np.empty(self._staging_bytes[dest_unit], dtype=np.uint8)

    # -- repack -----------------------------------------------------------------

    def instructions(self, dest_unit: int) -> List[Tuple[int, int, int]]:
        """``(staging_offset, unit_offset, nbytes)`` gather triples."""
        return [
            (p.staging_offset, p.unit_offset, p.interval.nbytes)
            for p in self._units[dest_unit]
        ]

    def repack(self, dest_unit: int, staging: np.ndarray) -> np.ndarray:
        """Assemble the destination unit's payload from staging bytes."""
        unit = self.manifest.units[dest_unit]
        instrs = self.instructions(dest_unit)
        if self.use_kernel:
            import jax

            from repro.kernels.repack import repack_bytes

            interpret = self.interpret
            if interpret is None:
                interpret = jax.default_backend() != "tpu"
            return np.asarray(
                repack_bytes(staging, instrs, unit.nbytes, interpret=interpret)
            )
        return repack_np(staging, instrs, unit.nbytes)

    def fused_repack(
        self, dest_unit: int, frames: List[np.ndarray]
    ) -> np.ndarray:
        """Assemble the destination unit's payload straight from int8
        *wire frames* — one frame per placed interval, in plan order —
        via the fused dequant+gather path (``kernels/quant/fused``): no
        staging-buffer decode, and the row-grid ``lead``/``tail``
        widening is dropped instead of decoded-then-discarded.

        ``use_kernel`` dispatches exactly like :meth:`repack`: the Pallas
        kernel on device (or interpreter), the NumPy fusion otherwise.
        Both are bit-identical to decode-then-:meth:`repack`.
        """
        from repro.kernels.quant import fused as fused_lib
        from repro.transfer.codec import parse_int8_frame

        unit = self.manifest.units[dest_unit]
        placed = self._units[dest_unit]
        if len(frames) != len(placed):
            raise TensorHubError(
                f"dest unit {dest_unit}: {len(frames)} wire frames for "
                f"{len(placed)} placed intervals"
            )
        placements = []
        for p, wire in zip(placed, frames):
            iv = p.interval
            frame = parse_int8_frame(wire)
            if frame.nbytes != iv.read_nbytes:
                raise TensorHubError(
                    f"dest unit {dest_unit}: frame decodes {frame.nbytes}B "
                    f"but interval {iv.tensor}[{iv.src_offset}:"
                    f"{iv.src_stop}] read {iv.read_nbytes}B"
                )
            placements.append((frame, iv.lead, iv.nbytes, p.unit_offset))
        if self.use_kernel:
            import jax

            interpret = self.interpret
            if interpret is None:
                interpret = jax.default_backend() != "tpu"
            return fused_lib.fused_repack(
                placements, unit.nbytes, interpret=interpret
            )
        return fused_lib.fused_repack_np(placements, unit.nbytes)


def repack_np(
    staging: np.ndarray, instructions: List[Tuple[int, int, int]], out_nbytes: int
) -> np.ndarray:
    """Host reference repack: scatter staging runs into the unit payload."""
    out = np.zeros(out_nbytes, dtype=np.uint8)
    src = staging.view(np.uint8).reshape(-1)
    for s_off, d_off, nbytes in instructions:
        out[d_off : d_off + nbytes] = src[s_off : s_off + nbytes]
    return out
