"""Replica shard layouts, assembled from per-shard manifests.

A :class:`ReplicaLayout` is the planner's view of one replica: for every
tensor, the global shape plus each shard's slice (see the package
docstring for the descriptor format). It also records which transfer unit
carries the tensor in each shard's manifest — the planner annotates every
read interval with that unit index so pipelined readers can gate on the
source's per-unit progress counter.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.core.errors import ShardLayoutError
from repro.core.meta import ShardManifest, TensorMeta, dtype_from_str


def dtype_itemsize(name: str) -> int:
    """Itemsize of a numpy dtype string, including ml_dtypes extras."""
    return dtype_from_str(name).itemsize


@dataclasses.dataclass(frozen=True)
class ShardSlice:
    """One shard's block of one tensor, in global coordinates."""

    shard: int
    start: Tuple[int, ...]
    shape: Tuple[int, ...]
    #: index of the TransferUnit carrying this tensor in the shard manifest
    unit: int
    #: byte offset of this tensor's payload inside the carrying unit
    #: (0 for a plain unit; the member offset for a compacted bucket)
    unit_offset: int = 0
    #: total payload bytes of the carrying unit (0 when unknown)
    unit_nbytes: int = 0
    #: element dtype of the carrying unit's payload as seen by wire
    #: codecs (``None`` for mixed-dtype buckets — codecs pass through)
    unit_dtype: Optional[str] = None

    @property
    def stop(self) -> Tuple[int, ...]:
        return tuple(s + n for s, n in zip(self.start, self.shape))


@dataclasses.dataclass(frozen=True)
class TensorLayout:
    """All shards' slices of one tensor."""

    name: str
    dtype: str
    itemsize: int
    global_shape: Tuple[int, ...]
    slices: Tuple[ShardSlice, ...]

    @property
    def global_nbytes(self) -> int:
        n = self.itemsize
        for d in self.global_shape:
            n *= d
        return n

    def slice_for(self, shard: int) -> Optional[ShardSlice]:
        for s in self.slices:
            if s.shard == shard:
                return s
        return None


@dataclasses.dataclass(frozen=True)
class ReplicaLayout:
    """Planner's view of one replica: tensors in manifest order."""

    num_shards: int
    tensors: Tuple[TensorLayout, ...]

    def tensor(self, name: str) -> Optional[TensorLayout]:
        for t in self.tensors:
            if t.name == name:
                return t
        return None

    def names(self) -> List[str]:
        return [t.name for t in self.tensors]


def _unit_placement(
    manifest: ShardManifest, tensor: str
) -> Tuple[int, int, int]:
    """Where a tensor's bytes live in the shard's unit schema:
    ``(unit_index, byte_offset_in_unit, unit_nbytes)``."""
    for u in manifest.units:
        if u.name == tensor:
            return u.index, 0, u.nbytes
        if tensor in u.members:
            for name, off, _nb in u.layout:
                if name == tensor:
                    return u.index, off, u.nbytes
            raise ShardLayoutError(
                f"tensor {tensor!r}: compacted bucket {u.name!r} has no "
                "layout entry for it (cannot place unit-space reads)"
            )
    raise ShardLayoutError(f"tensor {tensor!r} not carried by any transfer unit")


def layout_from_manifests(
    manifests: Mapping[int, ShardManifest], num_shards: Optional[int] = None
) -> ReplicaLayout:
    """Assemble a :class:`ReplicaLayout` from per-shard manifests.

    ``manifests`` may be partial (a destination planning only its own
    shard passes just that one); ``num_shards`` defaults to the number of
    manifests provided.
    """
    from repro.transfer.codec import unit_wire_dtype

    if not manifests:
        raise ShardLayoutError("no manifests to build a layout from")
    n = len(manifests) if num_shards is None else num_shards
    by_name: Dict[str, List[ShardSlice]] = {}
    meta_by_name: Dict[str, TensorMeta] = {}
    order: List[str] = []
    for shard, manifest in sorted(manifests.items()):
        tensor_map = {t.name: t for t in manifest.tensors}
        unit_dtypes = {
            u.index: unit_wire_dtype(tensor_map, u) for u in manifest.units
        }
        for meta in manifest.tensors:
            gshape = meta.global_shape or meta.shape
            prev = meta_by_name.get(meta.name)
            if prev is None:
                meta_by_name[meta.name] = meta
                order.append(meta.name)
            else:
                prev_g = prev.global_shape or prev.shape
                if prev_g != gshape or prev.dtype != meta.dtype:
                    raise ShardLayoutError(
                        f"tensor {meta.name!r}: shards disagree on global "
                        f"shape/dtype ({prev_g}/{prev.dtype} vs "
                        f"{gshape}/{meta.dtype})"
                    )
            unit, unit_off, unit_nbytes = _unit_placement(manifest, meta.name)
            by_name[meta.name] = by_name.get(meta.name, [])
            by_name[meta.name].append(
                ShardSlice(
                    shard=shard,
                    start=meta.start,
                    shape=meta.shape,
                    unit=unit,
                    unit_offset=unit_off,
                    unit_nbytes=unit_nbytes,
                    unit_dtype=unit_dtypes[unit],
                )
            )
    tensors = tuple(
        TensorLayout(
            name=name,
            dtype=meta_by_name[name].dtype,
            itemsize=dtype_itemsize(meta_by_name[name].dtype),
            global_shape=meta_by_name[name].global_shape or meta_by_name[name].shape,
            slices=tuple(by_name[name]),
        )
        for name in order
    )
    return ReplicaLayout(num_shards=n, tensors=tensors)


# ---------------------------------------------------------------------------
# Tensor-parallel splitting helper (tests, examples, benchmarks)
# ---------------------------------------------------------------------------


def tp_axis_for(name: str, shape: Tuple[int, ...], num_shards: int) -> Optional[int]:
    """Default TP rule: shard the first dim divisible by ``num_shards``
    (row parallelism); tensors with no divisible dim stay replicated."""
    for axis, d in enumerate(shape):
        if d % num_shards == 0 and d >= num_shards:
            return axis
    return None


def tp_shard(
    global_tensors: Mapping[str, np.ndarray],
    shard_idx: int,
    num_shards: int,
    *,
    axis_overrides: Optional[Mapping[str, Optional[int]]] = None,
) -> Tuple[Dict[str, np.ndarray], Dict[str, Tuple[Tuple[int, ...], Tuple[int, ...]]]]:
    """Slice global tensors for one TP shard.

    Returns ``(local_tensors, layout)`` where ``layout`` maps tensor name
    to ``(global_shape, offset)`` — the arguments
    :meth:`repro.transfer.engine.WorkerStore.register` takes to stamp the
    layout descriptor onto the registered buffers. Tensors whose shard
    axis is ``None`` (no divisible dim, or overridden) are replicated.
    """
    locals_: Dict[str, np.ndarray] = {}
    layout: Dict[str, Tuple[Tuple[int, ...], Tuple[int, ...]]] = {}
    for name, arr in global_tensors.items():
        gshape = tuple(arr.shape)
        if axis_overrides is not None and name in axis_overrides:
            axis = axis_overrides[name]
        else:
            axis = tp_axis_for(name, gshape, num_shards)
        if axis is None:
            locals_[name] = np.ascontiguousarray(arr)
            layout[name] = (gshape, (0,) * arr.ndim)
            continue
        per = gshape[axis] // num_shards
        sel = [slice(None)] * arr.ndim
        sel[axis] = slice(shard_idx * per, (shard_idx + 1) * per)
        offset = [0] * arr.ndim
        offset[axis] = shard_idx * per
        locals_[name] = np.ascontiguousarray(arr[tuple(sel)])
        layout[name] = (gshape, tuple(offset))
    return locals_, layout
