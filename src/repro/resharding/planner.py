"""The resharding planner: mismatched layouts -> striped interval reads.

For every destination shard, the planner intersects its slice of each
tensor against every source shard's slice and emits an ordered list of
:class:`ReadInterval` — byte-range reads striped across *all* source
shards — that exactly tiles every destination tensor. See the package
docstring for the layout-descriptor format.

Algorithm (per destination shard, per tensor)
---------------------------------------------

1. Decompose each non-empty intersection ``dest_slice ∩ src_slice_j``
   into contiguous *runs*: byte ranges contiguous in BOTH the source
   shard's local buffer and the destination shard's local buffer
   (C-order rows along the last dim, merged when adjacent). Dim-0
   sharding — the common TP case — merges to a single run.
2. Sweep the destination's local byte space over run boundaries; every
   elementary segment is assigned to the least-loaded source shard that
   covers it (load = bytes already assigned to that source shard by this
   destination shard). Segments covered by several source shards
   (replicated tensors, overlapping slices) are additionally split into
   stripes so no single source serializes the read.
3. A segment no source covers means the layouts are not convertible:
   :class:`repro.core.errors.ShardLayoutError`.

Every interval is annotated with the source transfer unit that carries
its bytes (pipeline gating: the read may start once the source's progress
counter passes that unit) and the destination unit it lands in (progress
is published in completed destination units).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.errors import ShardLayoutError
from repro.resharding import rowgrid
from repro.resharding.layout import ReplicaLayout, ShardSlice, TensorLayout

#: segments covered by >1 source shard are split into stripes of at least
#: this many bytes (smaller segments are not worth fragmenting)
STRIPE_MIN_BYTES = 1 << 20


@dataclasses.dataclass(frozen=True)
class ReadInterval:
    """One striped read: a byte range of a source shard's local tensor
    buffer landing at a byte range of the destination's local buffer.

    Reads execute in *unit space*: ``src_unit_offset`` places the range
    inside the source TransferUnit's payload (tensor offset plus the
    member offset for compacted buckets), and ``lead``/``tail`` widen it
    to the quantization row grid of the plan's codec so the source can
    encode the range (``raw`` plans have zero widening). The transport
    reads ``[read_offset, read_offset + read_nbytes)`` of the unit; the
    destination trims ``lead``/``tail`` from the decoded bytes — or the
    fused dequant+gather kernel simply never gathers them.
    """

    tensor: str
    source_shard: int
    src_offset: int  # bytes, within the source shard's local tensor buffer
    dst_offset: int  # bytes, within the dest shard's local tensor buffer
    nbytes: int
    source_unit: int  # TransferUnit index carrying the bytes at the source
    dest_unit: int  # TransferUnit index the bytes land in at the dest
    #: byte offset of this range inside the source unit's payload
    #: (-1: unknown — legacy plans; treat as ``src_offset``)
    src_unit_offset: int = -1
    #: total payload bytes of the source unit (0 when unknown)
    src_unit_nbytes: int = 0
    #: row-grid widening in bytes before/after the range (0 for raw)
    lead: int = 0
    tail: int = 0

    @property
    def src_stop(self) -> int:
        return self.src_offset + self.nbytes

    @property
    def dst_stop(self) -> int:
        return self.dst_offset + self.nbytes

    @property
    def read_offset(self) -> int:
        """Unit-payload byte offset the transport actually reads from."""
        base = self.src_unit_offset if self.src_unit_offset >= 0 else self.src_offset
        return base - self.lead

    @property
    def read_nbytes(self) -> int:
        """Bytes the transport actually reads (row-grid widened)."""
        return self.lead + self.nbytes + self.tail


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """All interval reads for one destination shard, ordered by
    destination unit (then tensor, then destination offset) so that a
    prefix of completed units maps to a monotone progress counter."""

    dest_shard: int
    intervals: Tuple[ReadInterval, ...]
    num_dest_units: int
    total_bytes: int

    @property
    def bytes_per_source(self) -> Dict[int, int]:
        out: Dict[int, int] = {}
        for iv in self.intervals:
            out[iv.source_shard] = out.get(iv.source_shard, 0) + iv.nbytes
        return out

    @property
    def source_shards_used(self) -> Tuple[int, ...]:
        return tuple(sorted({iv.source_shard for iv in self.intervals}))

    def intervals_by_unit(self) -> Dict[int, List[ReadInterval]]:
        """Intervals bucketed by destination unit in plan order — one
        pass; callers iterating per unit use this instead of repeated
        linear scans."""
        out: Dict[int, List[ReadInterval]] = {}
        for iv in self.intervals:
            out.setdefault(iv.dest_unit, []).append(iv)
        return out

    def intervals_for_unit(self, dest_unit: int) -> List[ReadInterval]:
        return [iv for iv in self.intervals if iv.dest_unit == dest_unit]


@dataclasses.dataclass(frozen=True)
class ReshardPlan:
    """Per-destination-shard plans plus the layouts they were built from."""

    source: ReplicaLayout
    dest: ReplicaLayout
    shards: Tuple[ShardPlan, ...]

    def shard(self, dest_shard: int) -> ShardPlan:
        for p in self.shards:
            if p.dest_shard == dest_shard:
                return p
        raise KeyError(dest_shard)

    @property
    def total_bytes(self) -> int:
        return sum(p.total_bytes for p in self.shards)


# ---------------------------------------------------------------------------
# run decomposition
# ---------------------------------------------------------------------------


def _ravel(coord: Sequence[int], shape: Sequence[int]) -> int:
    off = 0
    for c, n in zip(coord, shape):
        off = off * n + c
    return off


def _intersection_runs(
    dest: ShardSlice, src: ShardSlice, itemsize: int
) -> List[Tuple[int, int, int]]:
    """Contiguous runs of ``dest ∩ src`` as ``(dst_off, src_off, nbytes)``
    byte triples, offsets local to each side's buffer. Empty when the
    slices don't overlap."""
    ndim = max(len(dest.shape), 1)
    d_start = dest.start or (0,)
    d_shape = dest.shape or (1,)
    s_start = src.start or (0,)
    s_shape = src.shape or (1,)
    lo = tuple(max(a, b) for a, b in zip(d_start, s_start))
    hi = tuple(
        min(a + n, b + m)
        for a, n, b, m in zip(d_start, d_shape, s_start, s_shape)
    )
    if any(h <= l for l, h in zip(lo, hi)):
        return []
    ext = tuple(h - l for l, h in zip(lo, hi))
    row_elems = ext[-1]
    runs: List[Tuple[int, int, int]] = []
    for lead in itertools.product(*(range(l, h) for l, h in zip(lo[:-1], hi[:-1]))):
        coord = (*lead, lo[-1])
        dst_off = _ravel(
            tuple(c - o for c, o in zip(coord, d_start)), d_shape
        ) * itemsize
        src_off = _ravel(
            tuple(c - o for c, o in zip(coord, s_start)), s_shape
        ) * itemsize
        nbytes = row_elems * itemsize
        if runs and runs[-1][0] + runs[-1][2] == dst_off and runs[-1][1] + runs[-1][2] == src_off:
            prev = runs[-1]
            runs[-1] = (prev[0], prev[1], prev[2] + nbytes)
        else:
            runs.append((dst_off, src_off, nbytes))
    del ndim
    return runs


# ---------------------------------------------------------------------------
# coverage sweep + load balancing
# ---------------------------------------------------------------------------


def _plan_tensor(
    tensor: TensorLayout,
    dest_slice: ShardSlice,
    load: Dict[int, int],
    *,
    stripe_min: int,
    codec: str = "raw",
) -> List[ReadInterval]:
    """Assign every byte of the destination slice to a source shard."""
    from repro.transfer.codec import get_codec

    local_bytes = tensor.itemsize
    for d in dest_slice.shape or (1,):
        local_bytes *= d
    if local_bytes == 0:
        return []
    # (dst_off, src_off, nbytes) runs per candidate source shard, plus
    # each candidate's unit placement and row-grid granularity
    runs: Dict[int, List[Tuple[int, int, int]]] = {}
    place: Dict[int, ShardSlice] = {}
    rb_of: Dict[int, int] = {}
    wire = get_codec(codec)
    for src_slice in tensor.slices:
        r = _intersection_runs(dest_slice, src_slice, tensor.itemsize)
        if r:
            runs[src_slice.shard] = r
            place[src_slice.shard] = src_slice
            rb_of[src_slice.shard] = wire.row_bytes(src_slice.unit_dtype)
    # sweep over the dest slice's local byte space
    cuts = {0, local_bytes}
    for rs in runs.values():
        for dst_off, _, nbytes in rs:
            cuts.add(dst_off)
            cuts.add(dst_off + nbytes)
    edges = sorted(c for c in cuts if 0 <= c <= local_bytes)
    intervals: List[ReadInterval] = []

    def emit(shard: int, dst_a: int, dst_b: int, src_off: int) -> None:
        p = place[shard]
        unit_off = p.unit_offset + src_off
        lead, tail = rowgrid.snap(
            unit_off, dst_b - dst_a, rb_of[shard], p.unit_nbytes
        )
        intervals.append(
            ReadInterval(
                tensor=tensor.name,
                source_shard=shard,
                src_offset=src_off,
                dst_offset=dst_a,
                nbytes=dst_b - dst_a,
                source_unit=p.unit,
                dest_unit=dest_slice.unit,
                src_unit_offset=unit_off,
                src_unit_nbytes=p.unit_nbytes,
                lead=lead,
                tail=tail,
            )
        )
        load[shard] = load.get(shard, 0) + (dst_b - dst_a)

    for a, b in zip(edges[:-1], edges[1:]):
        # candidates covering [a, b): (shard, src byte offset at a)
        cands: List[Tuple[int, int]] = []
        for shard, rs in runs.items():
            for dst_off, src_off, nbytes in rs:
                if dst_off <= a and b <= dst_off + nbytes:
                    cands.append((shard, src_off + (a - dst_off)))
                    break
        if not cands:
            raise ShardLayoutError(
                f"tensor {tensor.name!r}: destination bytes [{a}, {b}) of "
                f"shard {dest_slice.shard} are not covered by any source "
                "shard (layouts not convertible)"
            )
        if len(cands) == 1 or b - a < 2 * stripe_min:
            shard, src_off = min(
                cands, key=lambda c: (load.get(c[0], 0), c[0])
            )
            emit(shard, a, b, src_off)
            continue
        # replicated / overlapping region: stripe across the candidates.
        # Stripe size is rounded up to the coarsest candidate row grid so
        # interior boundaries stay row-aligned (zero widening) whenever
        # the region itself starts on a row boundary.
        n_stripes = min(len(cands), max(2, (b - a) // stripe_min))
        per = rowgrid.chunk_align(
            (b - a) // n_stripes, max(rb_of[s] for s, _ in cands)
        )
        pos = a
        order = sorted(cands, key=lambda c: (load.get(c[0], 0), c[0]))
        k = 0
        while pos < b:
            stop = b if k >= n_stripes - 1 else min(pos + per, b)
            shard, src_base = order[k % len(order)]
            emit(shard, pos, stop, src_base + (pos - a))
            pos = stop
            k += 1
    return intervals


def plan_shard(
    source: ReplicaLayout,
    dest: ReplicaLayout,
    dest_shard: int,
    *,
    stripe_min: int = STRIPE_MIN_BYTES,
    num_dest_units: Optional[int] = None,
    codec: str = "raw",
) -> ShardPlan:
    """Plan all interval reads for one destination shard.

    ``codec`` is the negotiated wire codec the reads will carry: interval
    boundaries are snapped to its quantization row grid (``lead``/``tail``
    widening) so every read is encodable at the source. ``raw`` plans
    have zero widening and are bit-identical to pre-codec plans.
    """
    _check_convertible(source, dest)
    load: Dict[int, int] = {}
    intervals: List[ReadInterval] = []
    max_unit = -1
    for tensor in dest.tensors:
        d_slice = tensor.slice_for(dest_shard)
        if d_slice is None:
            continue  # this shard holds no block of the tensor
        max_unit = max(max_unit, d_slice.unit)
        src_tensor = source.tensor(tensor.name)
        assert src_tensor is not None  # _check_convertible guarantees it
        intervals.extend(
            _plan_tensor(
                src_tensor, d_slice, load, stripe_min=stripe_min, codec=codec
            )
        )
    intervals.sort(key=lambda iv: (iv.dest_unit, iv.tensor, iv.dst_offset))
    plan = ShardPlan(
        dest_shard=dest_shard,
        intervals=tuple(intervals),
        num_dest_units=(max_unit + 1 if num_dest_units is None else num_dest_units),
        total_bytes=sum(iv.nbytes for iv in intervals),
    )
    validate_shard_plan(plan, dest, dest_shard)
    return plan


def plan_reshard(
    source: ReplicaLayout,
    dest: ReplicaLayout,
    *,
    stripe_min: int = STRIPE_MIN_BYTES,
    codec: str = "raw",
) -> ReshardPlan:
    """Plan every destination shard's reads from the source layout."""
    shards = sorted({s.shard for t in dest.tensors for s in t.slices})
    return ReshardPlan(
        source=source,
        dest=dest,
        shards=tuple(
            plan_shard(source, dest, d, stripe_min=stripe_min, codec=codec)
            for d in shards
        ),
    )


def _check_convertible(source: ReplicaLayout, dest: ReplicaLayout) -> None:
    src_names = set(source.names())
    dst_names = set(dest.names())
    if src_names != dst_names:
        missing = sorted(dst_names - src_names)
        extra = sorted(src_names - dst_names)
        raise ShardLayoutError(
            f"layouts not convertible: tensors missing at source {missing}, "
            f"extra at source {extra}"
        )
    for d_tensor in dest.tensors:
        s_tensor = source.tensor(d_tensor.name)
        assert s_tensor is not None
        if s_tensor.global_shape != d_tensor.global_shape:
            raise ShardLayoutError(
                f"tensor {d_tensor.name!r}: global shape mismatch "
                f"({s_tensor.global_shape} vs {d_tensor.global_shape})"
            )
        if s_tensor.dtype != d_tensor.dtype:
            raise ShardLayoutError(
                f"tensor {d_tensor.name!r}: dtype mismatch "
                f"({s_tensor.dtype} vs {d_tensor.dtype})"
            )


def validate_shard_plan(
    plan: ShardPlan, dest: ReplicaLayout, dest_shard: int
) -> None:
    """Exact-tiling invariant: the plan's destination byte ranges tile
    every destination tensor with no gaps and no overlaps, and every
    row-grid-widened read stays inside its source unit's payload."""
    by_tensor: Dict[str, List[ReadInterval]] = {}
    for iv in plan.intervals:
        by_tensor.setdefault(iv.tensor, []).append(iv)
        if iv.read_offset < 0 or (
            0 < iv.src_unit_nbytes < iv.read_offset + iv.read_nbytes
        ):
            raise ShardLayoutError(
                f"plan invalid: widened read [{iv.read_offset}, "
                f"{iv.read_offset + iv.read_nbytes}) of tensor "
                f"{iv.tensor!r} escapes source unit {iv.source_unit} "
                f"({iv.src_unit_nbytes}B) on source shard {iv.source_shard}"
            )
    for tensor in dest.tensors:
        d_slice = tensor.slice_for(dest_shard)
        if d_slice is None:
            continue
        local_bytes = tensor.itemsize
        for d in d_slice.shape or (1,):
            local_bytes *= d
        ivs = sorted(by_tensor.get(tensor.name, []), key=lambda i: i.dst_offset)
        pos = 0
        for iv in ivs:
            if iv.dst_offset != pos:
                kind = "overlap" if iv.dst_offset < pos else "gap"
                raise ShardLayoutError(
                    f"plan invalid: {kind} at byte {min(pos, iv.dst_offset)} "
                    f"of tensor {tensor.name!r} on dest shard {dest_shard}"
                )
            pos = iv.dst_stop
        if pos != local_bytes:
            raise ShardLayoutError(
                f"plan invalid: tensor {tensor.name!r} on dest shard "
                f"{dest_shard} covered to byte {pos} of {local_bytes}"
            )
