"""Cross-layout resharding: striped reads between mismatched shard layouts.

TensorHub's ROS abstraction promises "fetch any version on demand", but a
bare shard-to-shard pipe only serves reads between *identical* layouts.
Real RL fleets reshard on every transfer — training TP x PP rarely matches
inference TP — so this package turns the single-source pipeline into a
layout-translating data plane: a destination replica with an arbitrary
shard layout replicates from a source published under a different one,
striping byte-interval reads across *all* source shards.

Layout descriptor format
========================

Layout metadata rides on the existing control-plane types — no side
channel, no extra RPCs:

* ``repro.core.meta.TensorMeta`` carries two optional fields:

  - ``global_shape`` — the logical (unsharded) shape of the tensor.
  - ``offset`` — per-dim start of this shard's local block in global
    coordinates; the shard holds ``[offset[d], offset[d] + shape[d])``
    along every dim ``d`` (a dense hyper-rectangle, C-order contiguous
    in local memory).

  ``global_shape is None`` means "no layout metadata": the tensor is
  treated as layout-invariant (replicated), convertible only when the
  peer holds an identically-shaped block.

* A replica's *layout* is the collection of its per-shard manifests:
  :class:`ReplicaLayout` (``layout.py``) is built from
  ``{shard_idx: ShardManifest}`` and records, per tensor, the global
  shape, dtype, and every shard's slice plus the transfer unit that
  carries it (for pipeline-replication progress gating).

Two layouts are *convertible* when they agree on tensor names, dtypes and
global shapes, and the source slices jointly cover every destination
slice. Overlap (replication) is allowed and exploited for load balancing.

Planning
========

:func:`plan_reshard` intersects each destination shard's slice of each
tensor against every source shard's slice and emits a
:class:`ReshardPlan`: per destination shard, an ordered list of
:class:`ReadInterval` — ``(source_shard, src byte range) -> (dest tensor,
dst byte range)`` — that exactly tiles every destination tensor (no gaps,
no overlaps; validated). Regions available from several source shards
(replicated tensors, overlapping slices) are assigned greedily to the
least-loaded source shard, so bytes-per-source stays balanced and a
single hot shard never serializes the transfer.

Execution
=========

:class:`ReshardExecutor` (``executor.py``) drives a shard's plan:
intervals are pulled into a contiguous staging buffer (the RDMA-landing
analogue), and once a destination transfer unit's intervals are all in,
a *repack* step scatters staging bytes into the registered weight
buffers — either the NumPy reference path or the Pallas gather kernel in
``repro.kernels.repack``. Progress is counted in completed destination
units, so a resharded replica serves its prefix to downstream readers
exactly like a same-layout one (4.3.3 pipeline replication), and source
failure mid-plan re-plans against the replacement source (4.5).
"""

from repro.resharding.layout import (
    ReplicaLayout,
    TensorLayout,
    layout_from_manifests,
    tp_shard,
)
from repro.resharding.planner import (
    ReadInterval,
    ReshardPlan,
    ShardPlan,
    plan_reshard,
    plan_shard,
)
from repro.resharding.executor import ReshardExecutor

__all__ = [
    "ReadInterval",
    "ReplicaLayout",
    "ReshardExecutor",
    "ReshardPlan",
    "ShardPlan",
    "TensorLayout",
    "layout_from_manifests",
    "plan_reshard",
    "plan_shard",
    "tp_shard",
]
