"""Pure-jnp / NumPy oracles for the repack gather kernel."""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def gather_ref(staging: jax.Array, idx: jax.Array) -> jax.Array:
    """staging: uint8[S], idx: int32[N] -> uint8[N] = staging[idx]."""
    return jnp.take(staging, idx, axis=0)


def repack_ref(
    staging: np.ndarray,
    instructions: Sequence[Tuple[int, int, int]],
    out_nbytes: int,
) -> np.ndarray:
    """Instruction-level NumPy oracle: scatter each ``(staging_offset,
    out_offset, nbytes)`` run; uncovered output bytes are zero. Delegates
    to the production scatter path so the kernel parity tests validate
    the exact reference implementation the executor ships."""
    from repro.resharding.executor import repack_np

    return repack_np(np.asarray(staging, dtype=np.uint8), list(instructions), out_nbytes)


def random_instructions(
    rng: np.random.Generator, out_nbytes: int, max_runs: int = 12
) -> List[Tuple[int, int, int]]:
    """Random exact tiling of [0, out_nbytes) for parity tests: cut the
    output into runs, each sourced from a distinct staging range (staging
    is the runs concatenated in shuffled order)."""
    n_runs = int(rng.integers(1, max_runs + 1))
    cuts = sorted(
        set([0, out_nbytes]) | set(rng.integers(1, max(2, out_nbytes), n_runs))
    )
    runs = [(a, b - a) for a, b in zip(cuts[:-1], cuts[1:])]
    order = rng.permutation(len(runs))
    instructions = []
    pos = 0
    for k in order:
        d_off, nbytes = runs[k]
        instructions.append((pos, d_off, nbytes))
        pos += nbytes
    return instructions
