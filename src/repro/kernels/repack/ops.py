"""Host-facing repack entry points: gather-map building + kernel call."""

from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.kernels.repack.kernel import _LANES, gather_bytes


def build_gather_map(
    instructions: Sequence[Tuple[int, int, int]],
    out_nbytes: int,
    staging_nbytes: int,
) -> np.ndarray:
    """int32[out_nbytes] mapping every output byte to its staging byte.

    Output bytes no instruction covers point at ``staging_nbytes`` — the
    zero byte :func:`repack_bytes` appends — so they repack to 0, matching
    the NumPy reference.
    """
    idx = np.full(out_nbytes, staging_nbytes, dtype=np.int32)
    for s_off, d_off, nbytes in instructions:
        if d_off < 0 or d_off + nbytes > out_nbytes:
            raise ValueError(f"instruction out of range: {(s_off, d_off, nbytes)}")
        if s_off < 0 or s_off + nbytes > staging_nbytes:
            raise ValueError(f"staging read out of range: {(s_off, d_off, nbytes)}")
        idx[d_off : d_off + nbytes] = np.arange(
            s_off, s_off + nbytes, dtype=np.int32
        )
    return idx


def repack_bytes(
    staging: np.ndarray,
    instructions: Sequence[Tuple[int, int, int]],
    out_nbytes: int,
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """Device repack: assemble the destination unit payload (uint8
    [out_nbytes]) from the staging buffer via the Pallas gather kernel."""
    flat = np.asarray(staging, dtype=np.uint8).reshape(-1)
    idx = build_gather_map(instructions, out_nbytes, flat.shape[0])
    # append the zero byte uncovered positions index, then pad to lanes
    padded = np.concatenate([flat, np.zeros(1, np.uint8)])
    pad = (-padded.shape[0]) % _LANES
    if pad:
        padded = np.concatenate([padded, np.zeros(pad, np.uint8)])
    return gather_bytes(
        jnp.asarray(padded), jnp.asarray(idx), interpret=interpret
    )
