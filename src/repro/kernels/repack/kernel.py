"""Striped-read repack as a Pallas TPU gather kernel.

Cross-layout resharding (``repro.resharding``) lands interval payloads
from many source shards in a contiguous staging buffer; the repack step
permutes those bytes into the destination transfer unit's layout. On the
device this is a gather: a precomputed int32 index map (built on host
from the plan's instructions, ``ops.build_gather_map``) maps every output
byte to its staging position, and the kernel streams output blocks while
the whole staging buffer sits in VMEM (staging is one transfer unit,
bounded by the unit size / tiny-tensor bucket cap).

Blocks are (rows, 128) so the gather vectorizes across lanes; output
positions past the real payload (block padding) index a guaranteed zero
byte appended to staging. Byte-granularity gather is the general case —
intervals of bf16 tensors can land on 2-byte alignment, so a word-level
kernel cannot assume 4-byte-aligned runs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 128
#: output rows per grid step (32 KiB of uint8 per block)
BLOCK_ROWS = 256


def _repack_kernel(idx_ref, staging_ref, out_ref):
    flat = staging_ref[...].reshape(-1)  # full staging buffer in VMEM
    out_ref[...] = jnp.take(flat, idx_ref[...], axis=0)


def gather_bytes(
    staging: jax.Array, idx: jax.Array, *, interpret: bool = False
) -> jax.Array:
    """staging: uint8[S] (S a multiple of 128), idx: int32[N] with every
    entry in [0, S) -> uint8[N] = staging[idx]; N padded internally to a
    block multiple (callers slice back)."""
    n = idx.shape[0]
    block = BLOCK_ROWS * _LANES
    pad = (-n) % block
    if pad:
        idx = jnp.pad(idx, (0, pad))  # index 0 is always valid
    rows = idx.shape[0] // _LANES
    idx2d = idx.reshape(rows, _LANES)
    s_rows = staging.shape[0] // _LANES

    out = pl.pallas_call(
        _repack_kernel,
        grid=(rows // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, _LANES), lambda i: (i, 0)),
            pl.BlockSpec((s_rows, _LANES), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, _LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, _LANES), jnp.uint8),
        interpret=interpret,
    )(idx2d, staging.reshape(s_rows, _LANES))
    return out.reshape(-1)[:n]
