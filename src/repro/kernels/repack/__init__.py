"""Gather/repack kernel for cross-layout resharding (see kernel.py)."""

from repro.kernels.repack.kernel import gather_bytes
from repro.kernels.repack.ops import build_gather_map, repack_bytes
from repro.kernels.repack.ref import gather_ref, random_instructions, repack_ref

__all__ = [
    "build_gather_map",
    "gather_bytes",
    "gather_ref",
    "random_instructions",
    "repack_bytes",
    "repack_ref",
]
