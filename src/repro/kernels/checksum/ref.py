"""Pure-jnp oracle for the device checksum (matches
``repro.transfer.checksum.checksum`` bit for bit, mod-2^32 arithmetic)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def checksum_ref(words: jax.Array) -> jax.Array:
    """words: uint32[N]; returns uint32[2] = (s1, s2)."""
    w = words.astype(jnp.uint32)
    n = w.shape[0]
    idx = jnp.arange(n, dtype=jnp.uint32)
    weights = (idx & jnp.uint32(0xFFFF)) + jnp.uint32(1)
    s1 = jnp.sum(w, dtype=jnp.uint32)
    s2 = jnp.sum(w * weights, dtype=jnp.uint32)
    return jnp.stack([s1, s2])


def fold64(pair) -> int:
    """Combine (s1, s2) into the 64-bit value the transfer layer compares.

    A zero fold remaps to the transfer layer's ZERO_STANDIN: checksum 0
    is its "verification disabled" sentinel, so no real payload may
    produce it (mirrors ``repro.transfer.checksum.checksum``)."""
    from repro.transfer.checksum import ZERO_STANDIN

    s1, s2 = int(pair[0]), int(pair[1])
    return ((s2 << 32) | s1) or ZERO_STANDIN
