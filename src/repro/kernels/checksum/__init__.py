from repro.kernels.checksum.ops import tensor_checksum
from repro.kernels.checksum.ref import checksum_ref, fold64

__all__ = ["tensor_checksum", "checksum_ref", "fold64"]
