"""Jitted wrapper: checksum arbitrary tensors on-device."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.checksum.kernel import checksum_words


@functools.partial(jax.jit, static_argnames=("interpret",))
def tensor_checksum(x: jax.Array, *, interpret: bool = False) -> jax.Array:
    """Returns uint32[2] = (s1, s2) over the tensor's little-endian bytes,
    matching ``repro.transfer.checksum.checksum`` (fold64 combines them)."""
    raw = jax.lax.bitcast_convert_type(x.reshape(-1), jnp.uint8).reshape(-1)
    pad = (-raw.shape[0]) % 4
    if pad:
        raw = jnp.pad(raw, (0, pad))
    b = raw.reshape(-1, 4).astype(jnp.uint32)
    words = b[:, 0] | (b[:, 1] << 8) | (b[:, 2] << 16) | (b[:, 3] << 24)
    return checksum_words(words, interpret=interpret)


def host_equivalent(x) -> int:
    """Host-side value this kernel must match (for tests)."""
    from repro.transfer.checksum import checksum

    return checksum(np.asarray(x))
