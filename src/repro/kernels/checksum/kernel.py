"""End-to-end transfer checksum as a Pallas TPU kernel (paper 4.6).

The paper computes per-tensor checksums on the GPU, overlapped with the
RDMA transfer. TPU adaptation: a grid-sequential reduction over VMEM-sized
word blocks; the (s1, s2) accumulators live in the output block, which maps
to the same tile on every grid step (TPU grids execute sequentially, so
read-modify-write accumulation across steps is well-defined). All
arithmetic is uint32 with natural wraparound — bit-identical to the host
NumPy implementation in ``repro.transfer.checksum``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: words per grid step (1 MiB of uint32 per block)
BLOCK_WORDS = 256 * 1024
_LANES = 128


def _checksum_kernel(w_ref, out_ref, *, block_words: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    words = w_ref[...].astype(jnp.uint32)  # [block_words // 128, 128]
    base = jnp.uint32(i * block_words)
    rows, lanes = words.shape
    offs = (
        jax.lax.broadcasted_iota(jnp.uint32, (rows, lanes), 0) * jnp.uint32(lanes)
        + jax.lax.broadcasted_iota(jnp.uint32, (rows, lanes), 1)
    )
    idx = base + offs
    weights = (idx & jnp.uint32(0xFFFF)) + jnp.uint32(1)
    s1 = jnp.sum(words, dtype=jnp.uint32)
    s2 = jnp.sum(words * weights, dtype=jnp.uint32)
    acc = out_ref[0, :2]
    out_ref[0, :2] = acc + jnp.stack([s1, s2])


def checksum_words(words: jax.Array, *, interpret: bool = False) -> jax.Array:
    """words: uint32[N] -> uint32[2] (s1, s2); N padded to the block size
    with zeros (zero words are weight-invariant, so the result is exact)."""
    n = words.shape[0]
    block = min(BLOCK_WORDS, max(_LANES, ((n + _LANES - 1) // _LANES) * _LANES))
    pad = (-n) % block
    if pad:
        words = jnp.pad(words, (0, pad))
    nblocks = words.shape[0] // block
    w2d = words.reshape(nblocks * (block // _LANES), _LANES)
    rows_per_block = block // _LANES

    out = pl.pallas_call(
        functools.partial(_checksum_kernel, block_words=block),
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((rows_per_block, _LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, _LANES), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, _LANES), jnp.uint32),
        interpret=interpret,
    )(w2d)
    return out[0, :2]
