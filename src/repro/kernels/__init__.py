"""Pallas TPU kernels for the perf-critical compute layers.

* ``flash_attention`` — the serve/train attention hot path.
* ``checksum``        — end-to-end transfer integrity, overlappable with
  the weight transfer (paper 4.6).
* ``quant``           — int8 compression for cross-DC seeding and gradient
  transfer (beyond-paper optimization).

Each kernel ships ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jitted wrapper) and ``ref.py`` (pure-jnp oracle); tests sweep shapes and
dtypes against the oracle in interpret mode.
"""
