"""Jitted public wrapper for the flash-attention kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bh


@functools.partial(
    jax.jit, static_argnames=("causal", "softcap", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,  # [B, Hq, Sq, D]
    k: jax.Array,  # [B, Hkv, Sk, D]
    v: jax.Array,  # [B, Hkv, Sk, D]
    *,
    causal: bool = True,
    softcap: float = 0.0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    out = flash_attention_bh(
        q.reshape(b * hq, sq, d),
        k.reshape(b * hkv, sk, d),
        v.reshape(b * hkv, sk, d),
        causal=causal,
        softcap=softcap,
        block_q=block_q,
        block_k=block_k,
        interpret=interpret,
    )
    return out.reshape(b, hq, sq, d)
