"""Pure-jnp oracle for the flash-attention kernel (naive O(S^2) memory)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # [B, Hq, Sq, D]
    k: jax.Array,  # [B, Hkv, Sk, D]
    v: jax.Array,  # [B, Hkv, Sk, D]
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
) -> jax.Array:
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, sq, d)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.asarray(d, jnp.float32))
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(sq)
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, sq, d).astype(q.dtype)
