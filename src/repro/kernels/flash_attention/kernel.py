"""Flash attention as a Pallas TPU kernel.

TPU adaptation of the paper-era GPU flash attention: the online-softmax
accumulators live in VMEM scratch, the QK^T and PV matmuls hit the MXU with
f32 accumulation, and the KV sweep is the *innermost grid dimension* so the
q-block working set (q tile + m/l/acc scratch) stays resident in VMEM across
the whole sweep. Block shapes default to (128, head_dim) — MXU-aligned.

Layout: inputs are pre-flattened to [BH, S, D] by ``ops.flash_attention``;
GQA maps query-head row bh to kv row bh // group via the BlockSpec index
map, so no KV duplication is ever materialized.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # [1, bq, D]
    k_ref,  # [1, bk, D]
    v_ref,  # [1, bk, D]
    o_ref,  # [1, bq, D]
    m_scr,  # [bq, 128] f32
    l_scr,  # [bq, 128] f32
    acc_scr,  # [bq, D] f32
    *,
    causal: bool,
    softcap: float,
    block_q: int,
    block_k: int,
    kv_len: int,
    num_k_blocks: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k
    live = k_start < kv_len
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + block_q - 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        scale = 1.0 / math.sqrt(q.shape[-1])
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [bq, bk]
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < kv_len
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            mask = jnp.logical_and(mask, kpos <= qpos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, :1]  # [bq, 1]
        l_prev = l_scr[:, :1]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_scr[...] = jnp.broadcast_to(
            l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True), l_scr.shape
        )
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = jnp.broadcast_to(m_cur, m_scr.shape)

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = l_scr[:, :1]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_bh(
    q: jax.Array,  # [BHq, Sq, D]
    k: jax.Array,  # [BHkv, Sk, D]
    v: jax.Array,  # [BHkv, Sk, D]
    *,
    causal: bool = True,
    softcap: float = 0.0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Core pallas_call on pre-flattened [batch*heads, seq, dim] arrays."""
    bh, sq, d = q.shape
    bh_kv, sk, _ = k.shape
    group = bh // bh_kv
    kv_len = sk

    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pq = (-sq) % block_q
    pk = (-sk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    nq = (sq + pq) // block_q
    nk = (sk + pk) // block_k

    kernel = functools.partial(
        _flash_kernel,
        causal=causal,
        softcap=softcap,
        block_q=block_q,
        block_k=block_k,
        kv_len=kv_len,
        num_k_blocks=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq + pq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
