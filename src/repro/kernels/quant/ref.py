"""Pure-jnp oracle for int8 block quantization."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_ref(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-row int8: x [R, C] -> (q int8 [R, C], scale f32 [R])."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[:, None]), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_ref(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale[:, None]).astype(dtype)
