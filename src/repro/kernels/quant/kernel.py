"""Int8 symmetric quantization as a Pallas TPU kernel.

Beyond-paper optimization (EXPERIMENTS.md Perf): cross-datacenter seeding
sends 4x fewer bytes by transferring int8 + per-row scales instead of
bf16/f32 weights, and the same kernel compresses gradients for slow-link
data parallelism. Row blocks of 256 x C tile VMEM; absmax reduction and
rounding run on the VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BLOCK_ROWS = 256


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)  # [bR, C]
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)  # [bR, 1]
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = jnp.broadcast_to(scale, s_ref.shape).astype(jnp.float32)


def quantize_rows(
    x: jax.Array, *, block_rows: int = _BLOCK_ROWS, interpret: bool = False
):
    """x: [R, C] -> (q int8 [R, C], scales f32 [R])."""
    r, c = x.shape
    block_rows = min(block_rows, r)
    pad = (-r) % block_rows
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    rp = r + pad
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(rp // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, c), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 128), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp, c), jnp.int8),
            jax.ShapeDtypeStruct((rp, 128), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q[:r], s[:r, 0]
