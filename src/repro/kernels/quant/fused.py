"""Fused dequant + gather: int8 wire frames -> repacked unit payload.

The staged reshard decode path materializes every interval twice: decode
the int8 frame into a staging buffer, then repack (gather) staging bytes
into the destination unit's layout. This module fuses the two — a single
Pallas kernel reads the concatenated quantized values and per-row scales
of *all* frames of one destination unit and writes dequantized elements
directly at their repacked positions:

    out[i] = (q[qidx[i]] * scales[sidx[i]]).astype(out_dtype)

``qidx``/``sidx`` are precomputed int32 element maps (host-built from
the plan's placements, like ``repack.build_gather_map`` but in element
space); row-grid ``lead``/``tail`` widening is simply never mapped, so
the trimmed bytes are dropped for free instead of decoded-then-discarded.

The kernel path requires every quantized frame of the unit to share one
TPU-friendly element dtype (f32/bf16/f16) and element-aligned
placements; anything else — mixed dtypes, f64, passthrough-only units —
takes :func:`fused_repack_np`, the NumPy fusion of the same two passes
(decode rows straight into the output span, no staging buffer). Both
paths are bit-identical to staged decode-then-repack: the dequant math
is exactly ``Int8Codec.decode``'s (f32 multiply, round-to-nearest-even
downcast), and parity is pinned by tests in interpreter mode.

Frames arrive parsed (:func:`repro.transfer.codec.parse_int8_frame`), so
header/scale/shape validation happened exactly once, at the transport
boundary.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.meta import dtype_from_str

#: dtypes the device kernel handles (min-tile-friendly; f64 stays on host)
_KERNEL_DTYPES = ("float32", "bfloat16", "float16")

#: placement of one parsed frame in the destination unit payload:
#: (frame, lead, nbytes, unit_offset) — write frame bytes
#: [lead, lead + nbytes) at out[unit_offset : unit_offset + nbytes]
Placement = Tuple[object, int, int, int]


def _dequant_span(frame, lead: int, nbytes: int) -> np.ndarray:
    """Dequantize exactly the rows of ``frame`` that cover byte span
    [lead, lead + nbytes) and return those bytes — the NumPy half of the
    fusion (no whole-frame staging decode)."""
    npdtype = dtype_from_str(frame.dtype)
    isz = npdtype.itemsize
    rb = frame.row_len * isz
    r0 = lead // rb
    r1 = -(-(lead + nbytes) // rb)
    n = frame.nbytes // isz  # true element count of the frame
    e0 = r0 * frame.row_len
    e1 = min(r1 * frame.row_len, n)
    cnt = e1 - e0
    if cnt == (r1 - r0) * frame.row_len:
        qv = frame.q[e0:e1]  # full rows: no ragged-tail pad needed
    else:
        qv = np.zeros((r1 - r0) * frame.row_len, np.int8)
        qv[:cnt] = frame.q[e0:e1]
    x = qv.reshape(r1 - r0, frame.row_len).astype(np.float32)
    x *= frame.scales[r0:r1, None]  # in-place: same f32 multiply, one pass
    x = x.reshape(-1)[:cnt]
    if npdtype != np.float32:
        x = x.astype(npdtype)
    dec = np.ascontiguousarray(x).view(np.uint8)
    off = lead - r0 * rb
    return dec[off : off + nbytes]


def fused_repack_np(
    placements: Sequence[Placement], out_nbytes: int
) -> np.ndarray:
    """NumPy fused reference: each frame's covered rows dequantize
    straight into their repacked output span — one pass, no staging
    buffer, no decode-then-discard of the row-grid widening."""
    out = np.zeros(out_nbytes, dtype=np.uint8)
    for frame, lead, nbytes, uo in placements:
        if nbytes <= 0:
            continue
        if frame.is_passthrough:
            out[uo : uo + nbytes] = frame.passthrough[lead : lead + nbytes]
        else:
            out[uo : uo + nbytes] = _dequant_span(frame, lead, nbytes)
    return out


def kernel_dtype(placements: Sequence[Placement], out_nbytes: int) -> Optional[str]:
    """The single element dtype the device kernel would run at, or
    ``None`` when this unit must take the NumPy path (mixed/unsupported
    dtypes, element-misaligned placements, nothing quantized)."""
    dtype: Optional[str] = None
    for frame, lead, nbytes, uo in placements:
        if frame.is_passthrough:
            continue
        if frame.dtype not in _KERNEL_DTYPES:
            return None
        if dtype is None:
            dtype = frame.dtype
        elif frame.dtype != dtype:
            return None
        isz = dtype_from_str(dtype).itemsize
        if lead % isz or nbytes % isz or uo % isz:
            return None
    if dtype is not None and out_nbytes % dtype_from_str(dtype).itemsize:
        return None
    return dtype


def build_elem_maps(
    placements: Sequence[Placement], out_nbytes: int, dtype: str
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Host-side map building for the device kernel: concatenate every
    quantized frame's values/scales and map each output *element* to its
    (q, scale) position. Returns ``(qcat, scat, qidx, sidx)``; uncovered
    elements (gaps, passthrough spans overlaid later) point at the
    appended sentinel pair (q=0, scale=1.0) and decode to 0.0."""
    isz = dtype_from_str(dtype).itemsize
    n_elems = out_nbytes // isz
    q_parts: List[np.ndarray] = []
    s_parts: List[np.ndarray] = []
    qidx = np.empty(n_elems, np.int32)
    sidx = np.empty(n_elems, np.int32)
    covered = np.zeros(n_elems, bool)
    qoff = soff = 0
    for frame, lead, nbytes, uo in placements:
        if frame.is_passthrough or nbytes <= 0:
            continue
        oe0 = uo // isz
        cnt = nbytes // isz
        fe0 = lead // isz
        span = fe0 + np.arange(cnt, dtype=np.int32)
        qidx[oe0 : oe0 + cnt] = qoff + span
        sidx[oe0 : oe0 + cnt] = soff + span // frame.row_len
        covered[oe0 : oe0 + cnt] = True
        q_parts.append(frame.q)
        s_parts.append(frame.scales)
        qoff += frame.q.size
        soff += frame.scales.size
    q_parts.append(np.zeros(1, np.int8))  # the sentinel pair
    s_parts.append(np.ones(1, np.float32))
    qidx[~covered] = qoff
    sidx[~covered] = soff
    return np.concatenate(q_parts), np.concatenate(s_parts), qidx, sidx


def _pad_to(arr: np.ndarray, multiple: int) -> np.ndarray:
    pad = (-arr.shape[0]) % multiple
    if pad:
        arr = np.concatenate([arr, np.zeros(pad, arr.dtype)])
    return arr


def dequant_gather(
    q, scales, qidx, sidx, out_dtype, *, interpret: bool = False
):
    """The fused Pallas kernel: ``out[i] = (q[qidx[i]] * scales[sidx[i]])
    .astype(out_dtype)`` with q/scales fully in VMEM (one destination
    unit's frames, bounded like the repack staging buffer) and output
    element blocks streamed, mirroring ``repack.gather_bytes``."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    from repro.kernels.repack.kernel import _LANES, BLOCK_ROWS

    def _kernel(qidx_ref, sidx_ref, q_ref, s_ref, out_ref):
        qf = q_ref[...].reshape(-1)
        sf = s_ref[...].reshape(-1)
        vals = jnp.take(qf, qidx_ref[...], axis=0).astype(jnp.float32)
        scale = jnp.take(sf, sidx_ref[...], axis=0)
        out_ref[...] = (vals * scale).astype(out_ref.dtype)

    n = qidx.shape[0]
    block = BLOCK_ROWS * _LANES
    pad = (-n) % block
    qidx = jnp.asarray(qidx)
    sidx = jnp.asarray(sidx)
    if pad:
        qidx = jnp.pad(qidx, (0, pad))  # index 0 is always valid
        sidx = jnp.pad(sidx, (0, pad))
    rows = qidx.shape[0] // _LANES
    # int8 min tile is (32, 128), f32 (8, 128): pad the flat VMEM arrays
    q2 = jnp.asarray(_pad_to(np.asarray(q), 32 * _LANES)).reshape(-1, _LANES)
    s2 = jnp.asarray(_pad_to(np.asarray(scales), 8 * _LANES)).reshape(-1, _LANES)
    out = pl.pallas_call(
        _kernel,
        grid=(rows // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, _LANES), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, _LANES), lambda i: (i, 0)),
            pl.BlockSpec((q2.shape[0], _LANES), lambda i: (0, 0)),
            pl.BlockSpec((s2.shape[0], _LANES), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, _LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, _LANES), dtype_from_str(out_dtype)),
        interpret=interpret,
    )(
        qidx.reshape(rows, _LANES),
        sidx.reshape(rows, _LANES),
        q2,
        s2,
    )
    return out.reshape(-1)[:n]


def fused_repack(
    placements: Sequence[Placement],
    out_nbytes: int,
    *,
    interpret: bool = False,
) -> np.ndarray:
    """Device fused repack of one destination unit; falls back to
    :func:`fused_repack_np` when the unit's frames aren't kernel-shaped
    (mixed dtypes, f64, misalignment, passthrough-only)."""
    dtype = kernel_dtype(placements, out_nbytes)
    if dtype is None:
        return fused_repack_np(placements, out_nbytes)
    qcat, scat, qidx, sidx = build_elem_maps(placements, out_nbytes, dtype)
    dec = dequant_gather(qcat, scat, qidx, sidx, dtype, interpret=interpret)
    # copy: device arrays view as read-only, and passthrough overlays write
    out = np.asarray(dec).copy().view(np.uint8).reshape(-1)
    # passthrough frames (non-finite payloads, odd tails) overlay their
    # exact bytes after the kernel — byte-granular, like the NumPy path
    for frame, lead, nbytes, uo in placements:
        if frame.is_passthrough and nbytes > 0:
            out[uo : uo + nbytes] = frame.passthrough[lead : lead + nbytes]
    return out


__all__ = [
    "build_elem_maps",
    "dequant_gather",
    "fused_repack",
    "fused_repack_np",
    "kernel_dtype",
]
