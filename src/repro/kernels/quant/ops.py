"""Jitted wrappers: quantize/dequantize arbitrary tensors (compression for
cross-DC seeding and gradient all-reduce)."""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.quant.kernel import quantize_rows
from repro.kernels.quant.ref import dequantize_ref


@functools.partial(jax.jit, static_argnames=("row_len", "interpret"))
def _quantize_flat(x: jax.Array, *, row_len: int, interpret: bool):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % row_len
    if pad:
        flat = jnp.pad(flat, (0, pad))
    rows = flat.reshape(-1, row_len)
    return quantize_rows(rows, interpret=interpret)


def quantize(
    x: jax.Array, *, row_len: int = 1024, interpret: bool = False
) -> Tuple[jax.Array, jax.Array, Tuple[int, ...]]:
    """Flatten to rows of ``row_len`` and int8-quantize. Returns
    (q int8 [R, row_len], scales f32 [R], original shape)."""
    q, s = _quantize_flat(x, row_len=row_len, interpret=interpret)
    return q, s, tuple(x.shape)


@functools.partial(jax.jit, static_argnames=("shape", "dtype"))
def dequantize(q: jax.Array, scales: jax.Array, shape: Tuple[int, ...], dtype=jnp.float32) -> jax.Array:
    flat = dequantize_ref(q, scales, dtype).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compressed_bytes(
    q: jax.Array, scales: jax.Array, num_elements: Optional[int] = None
) -> int:
    """Wire size of a quantized tensor: q payload + scales.

    ``quantize`` zero-pads the flattened tensor to a multiple of
    ``row_len`` before reshaping into rows, so ``q.size`` over-counts
    tensors whose element count is not a row multiple — the padding is
    reconstructed from the header at decode time and never crosses the
    wire. Pass ``num_elements`` (``prod(shape)`` from ``quantize``'s
    returned shape) to clamp the count to the true payload.
    """
    n = q.size if num_elements is None else min(int(num_elements), q.size)
    return n * q.dtype.itemsize + scales.size * scales.dtype.itemsize
