from repro.kernels.quant.fused import fused_repack, fused_repack_np
from repro.kernels.quant.ops import compressed_bytes, dequantize, quantize
from repro.kernels.quant.ref import dequantize_ref, quantize_ref

__all__ = [
    "compressed_bytes",
    "dequantize",
    "quantize",
    "dequantize_ref",
    "quantize_ref",
    "fused_repack",
    "fused_repack_np",
]
