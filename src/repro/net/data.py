"""Socketed data plane: remote worker-to-worker byte movement.

``WorkerDataServer`` exposes one worker's registered stores over HTTP —
the reproduction's stand-in for the paper's one-sided RDMA reads. A
request names what to read (whole unit, or a row-grid-aligned chunk —
resharded interval reads arrive as widened unit chunks since the
row-grid planner) plus the negotiated codec; the response body is the
**wire frame** (codec-encoded at the source, exactly the bytes the NIC
would carry) and the ``X-TH-Checksum`` header carries the source's
read-time checksum over the *decoded* payload (over the wire frame
itself for ``raw_wire`` requests, where the caller decodes) — the same
end-to-end transit contract as
:class:`~repro.transfer.engine.LocalTransport`, with the verification
halves now genuinely on opposite ends of a socket.

``RemoteTransport`` extends ``LocalTransport``: a source that is
registered in this process is read through the inherited in-memory path,
anything else resolves to a peer address (via the controller's announce
directory) and is pulled over HTTP/1.1 keep-alive connections pooled per
``(host, port)`` — a windowed pull re-uses a handful of warm sockets
instead of paying connect + slow-start per read. Delta frames keep their
fallback semantics — the *destination* decodes against its own held
base, and a stale base triggers one re-request with ``no_base`` set,
mirroring the in-process transparent re-ship (both frames are accounted
as wire bytes).
"""

from __future__ import annotations

import http.client
import json
import socket
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, Optional, Callable

import numpy as np

from repro.core.errors import (
    ChecksumError,
    TensorHubError,
    TransportError,
)
from repro.core.meta import TransferUnit, from_wire, to_wire
from repro.net import protocol
from repro.net.httpd import split_address
from repro.obs import telemetry as obs
from repro.transfer import checksum as checksum_lib
from repro.transfer import codec as codec_lib
from repro.transfer.engine import LocalTransport, WorkerRegistry, WorkerStore

# codec failures must re-raise as themselves across the wire: the engine
# distinguishes CodecError (decode-failure healing) from ChecksumError
# (corruption evidence), and StaleBaseError drives the delta fallback
protocol.register_error(codec_lib.CodecError)
protocol.register_error(codec_lib.StaleBaseError)

DATA_PROTOCOL_VERSION = 1


# ---------------------------------------------------------------------------
# serving side
# ---------------------------------------------------------------------------


def _serve_read(registry: WorkerRegistry, req: Dict[str, Any]) -> tuple:
    """Execute one read request against the local registry.

    Returns ``(wire_bytes, checksum)`` where the checksum is folded over
    the decoded payload — or over the wire frame itself for ``raw_wire``
    requests, which the caller decodes (0 when verification is off — the
    disabled sentinel the checksum module reserves). Raises typed errors;
    the handler encodes them for the wire."""
    if req.get("v") != DATA_PROTOCOL_VERSION:
        raise protocol.ProtocolError(
            f"unsupported data protocol version {req.get('v')!r}"
        )
    kind = req.get("kind")
    replica = req["replica"]
    shard_idx = int(req["shard_idx"])
    codec = req.get("codec", "raw")
    verify = bool(req.get("verify", True))
    src = registry.get(replica, shard_idx)

    unit: TransferUnit = from_wire(req["unit"])
    full = src.read_unit(unit)
    if kind == "chunk":
        offset, nbytes = int(req["offset"]), int(req["nbytes"])
        if nbytes < 0 or offset < 0 or offset + nbytes > full.nbytes:
            raise TensorHubError(
                f"unit {unit.name}: chunk [{offset}, {offset + nbytes}) "
                f"exceeds unit of {full.nbytes}B"
            )
        view = full[offset : offset + nbytes]
    elif kind == "unit":
        offset, nbytes = 0, full.nbytes
        view = full
    else:
        raise protocol.ProtocolError(f"unknown data request kind {kind!r}")

    if codec == "raw":
        return view.tobytes(), (checksum_lib.checksum(view) if verify else 0)

    cdc = codec_lib.get_codec(codec)
    dtype = src.unit_dtype(unit)
    if kind == "chunk":
        rb = cdc.row_bytes(dtype)
        if offset % rb or (nbytes % rb and offset + nbytes != full.nbytes):
            raise codec_lib.CodecError(
                f"chunk {unit.name}[{offset}:{offset + nbytes}] not aligned "
                f"to the {codec} codec's {rb}B row granularity — the "
                "reassembled unit would diverge from an unchunked transfer"
            )
    if req.get("raw_wire", False):
        # the caller decodes (fused dequant+gather at the destination):
        # ship the frame and checksum the frame itself
        if getattr(cdc, "needs_base", False):
            raise codec_lib.CodecError(
                f"wire-frame reads cannot carry the base-referencing codec "
                f"{codec!r} (no destination base at frame granularity) — "
                "resolve the reshard codec first"
            )
        wire = cdc.encode(view, dtype)
        return wire.tobytes(), (checksum_lib.checksum(wire) if verify else 0)
    if getattr(cdc, "needs_base", False) and not req.get("no_base", False):
        base_full = src.base_unit(unit)
        base = (
            None
            if base_full is None
            else (base_full[offset : offset + nbytes] if kind == "chunk" else base_full)
        )
        wire = cdc.encode(view, dtype, base=base)
        # checksum over the decode against the SAME base the frame was
        # encoded against: any destination whose decode succeeds (its
        # base digest matched) reconstructs these exact bytes
        csum = checksum_lib.checksum(cdc.decode(wire, base=base)) if verify else 0
    else:
        wire = cdc.encode(view, dtype)
        csum = checksum_lib.checksum(cdc.decode(wire)) if verify else 0
    return wire.tobytes(), csum


class _DataHandler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server_version = "tensorhub-data/1"
    # buffer the response and disable Nagle: unbuffered header writes
    # plus delayed ACK otherwise cost ~40ms of idle per request
    wbufsize = -1
    disable_nagle_algorithm = True

    def log_message(self, format: str, *args) -> None:  # noqa: A002
        pass

    def do_POST(self) -> None:  # noqa: N802
        if self.path != "/data":
            self._fail(404, protocol.ProtocolError("not found"))
            return
        try:
            length = int(self.headers.get("Content-Length", "0"))
            req = json.loads(self.rfile.read(length).decode("utf-8"))
            if not isinstance(req, dict):
                raise protocol.ProtocolError("data request must be an object")
            body, csum = _serve_read(self.server.registry, req)  # type: ignore[attr-defined]
        except (TensorHubError, KeyError, ValueError, TypeError) as e:
            self._fail(500, e)
            return
        self.send_response(200)
        self.send_header("Content-Type", "application/octet-stream")
        self.send_header("Content-Length", str(len(body)))
        self.send_header("X-TH-Checksum", str(csum))
        self.end_headers()
        self.wfile.write(body)

    def _fail(self, status: int, exc: BaseException) -> None:
        err: Dict[str, Any] = {"kind": type(exc).__name__, "message": str(exc)}
        if isinstance(exc, TransportError):
            err["transient"] = bool(exc.transient)
        body = json.dumps(err).encode("utf-8")
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)


class WorkerDataServer:
    """Serve this process's :class:`WorkerRegistry` over HTTP."""

    def __init__(
        self, registry: WorkerRegistry, host: str = "127.0.0.1", port: int = 0
    ) -> None:
        self.registry = registry
        self._httpd = ThreadingHTTPServer((host, port), _DataHandler)
        self._httpd.daemon_threads = True
        self._httpd.registry = registry  # type: ignore[attr-defined]
        self._thread: Optional[threading.Thread] = None

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def address(self) -> str:
        host, port = self._httpd.server_address[:2]
        return f"{host}:{port}"

    def start(self) -> "WorkerDataServer":
        self._thread = threading.Thread(
            target=self._httpd.serve_forever,
            kwargs={"poll_interval": 0.05},
            name="tensorhub-data-http",
            daemon=True,
        )
        self._thread.start()
        return self

    def shutdown(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None


# ---------------------------------------------------------------------------
# pulling side
# ---------------------------------------------------------------------------


class RemoteTransport(LocalTransport):
    """A ``LocalTransport`` whose reads may cross the network.

    ``resolve(replica, shard_idx)`` maps a source the local registry does
    not hold to a peer data-server address (the controller's announce
    directory, via :meth:`RemoteClient.peer_addr`). An unresolved peer is
    a *transient* transport fault — after a controller restart the
    directory refills as workers re-announce, and the engine's retry
    policy rides through the race.
    """

    def __init__(
        self,
        registry: WorkerRegistry,
        resolve: Callable[[str, int], Optional[str]],
        *,
        timeout: float = 30.0,
        throttle_s: float = 0.0,
        pool_size: int = 4,
        **kw: Any,
    ) -> None:
        super().__init__(registry, **kw)
        self.resolve = resolve
        self.timeout = timeout
        #: test knob: stretch every remote unit pull so a subprocess test
        #: can land a controller SIGKILL mid-pull deterministically
        self.throttle_s = throttle_s
        self.remote_pulls = 0
        #: idle keep-alive connections retained per (host, port)
        self.pool_size = pool_size
        self._pool: Dict[tuple, list] = {}
        self._pool_lock = threading.Lock()
        self.conn_opens = 0
        self.conn_reuses = 0

    # -- plumbing --------------------------------------------------------------

    def _is_local(self, replica: str, shard_idx: int) -> bool:
        return self.registry.lookup(replica, shard_idx) is not None

    def _open_conn(self, host: str, port: int) -> http.client.HTTPConnection:
        conn = http.client.HTTPConnection(host, port, timeout=self.timeout)
        conn.connect()
        conn.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        with self._acct_lock:
            self.conn_opens += 1
        return conn

    def _checkout(self, host: str, port: int) -> tuple:
        """A connection to the peer: pooled keep-alive if one is idle
        (returns ``(conn, True)``), else a fresh connect."""
        with self._pool_lock:
            idle = self._pool.get((host, port))
            if idle:
                return idle.pop(), True
        return self._open_conn(host, port), False

    def _checkin(self, host: str, port: int, conn: http.client.HTTPConnection) -> None:
        with self._pool_lock:
            idle = self._pool.setdefault((host, port), [])
            if len(idle) < self.pool_size:
                idle.append(conn)
                return
        conn.close()

    def close_pool(self) -> None:
        """Drop every idle pooled connection (tests; graceful teardown)."""
        with self._pool_lock:
            pools, self._pool = list(self._pool.values()), {}
        for idle in pools:
            for conn in idle:
                conn.close()

    def _fetch(self, replica: str, shard_idx: int, req: Dict[str, Any]) -> tuple:
        """POST one read request to the peer serving ``replica/shard``;
        returns ``(payload_bytes, source_checksum)``.

        Connections are pooled per (host, port): HTTP/1.1 keep-alive lets
        a windowed pull re-use a handful of warm sockets instead of
        paying connect + slow-start per read. A pooled socket may have
        gone stale (peer restarted, idle timeout); a send/recv failure on
        a *re-used* connection retries once on a fresh connect before
        surfacing a transient fault."""
        addr = self.resolve(replica, shard_idx)
        if addr is None:
            raise TransportError(
                f"no announced data peer for {replica}/shard{shard_idx}",
                transient=True,
            )
        host, port = split_address(addr)
        body = json.dumps(
            {"v": DATA_PROTOCOL_VERSION, "replica": replica,
             "shard_idx": shard_idx, "verify": self.verify_checksums, **req}
        ).encode("utf-8")
        for attempt in (0, 1):
            try:
                if attempt == 0:
                    conn, reused = self._checkout(host, port)
                else:
                    conn, reused = self._open_conn(host, port), False
            except (ConnectionError, socket.timeout, http.client.HTTPException, OSError) as e:
                raise TransportError(
                    f"data pull from {replica}/shard{shard_idx} ({addr}) "
                    f"failed: {e}",
                    transient=True,
                ) from None
            try:
                conn.request(
                    "POST", "/data", body=body,
                    headers={"Content-Type": "application/json"},
                )
                resp = conn.getresponse()
                payload = resp.read()
            except (ConnectionError, socket.timeout, http.client.HTTPException, OSError) as e:
                conn.close()
                if reused:
                    continue  # stale keep-alive socket: one fresh retry
                raise TransportError(
                    f"data pull from {replica}/shard{shard_idx} ({addr}) "
                    f"failed: {e}",
                    transient=True,
                ) from None
            if reused:
                with self._acct_lock:
                    self.conn_reuses += 1
                rec = self.recorder
                if rec.enabled:
                    rec.counter_add(obs.CTR_CONN_REUSE, 1)
            if resp.will_close:
                conn.close()
            else:
                self._checkin(host, port, conn)
            if resp.status != 200:
                try:
                    err = json.loads(payload.decode("utf-8"))
                except (ValueError, UnicodeDecodeError):
                    err = {"kind": "TransportError", "message": payload[:200].decode("utf-8", "replace"), "transient": True}
                protocol.raise_from_error(err)
            csum = int(resp.getheader("X-TH-Checksum", "0"))
            return payload, csum
        raise AssertionError("unreachable")  # pragma: no cover

    @staticmethod
    def _verify(payload: np.ndarray, expected: int, what: str) -> None:
        got = checksum_lib.checksum(payload)
        if got != expected:
            raise ChecksumError(
                f"{what}: checksum {got:#x} != expected {expected:#x}"
            )

    # -- transport interface ---------------------------------------------------

    def pull_unit(
        self,
        src_replica: str,
        shard_idx: int,
        unit: TransferUnit,
        expected_checksum: int,
        dst_store: WorkerStore,
        codec: str = "raw",
        link_class: str = "rdma",
        track: Optional[str] = None,
    ) -> None:
        if self._is_local(src_replica, shard_idx):
            super().pull_unit(
                src_replica, shard_idx, unit, expected_checksum,
                dst_store, codec, link_class, track,
            )
            return
        self._fault_read(src_replica, shard_idx)
        if self.throttle_s:
            time.sleep(self.throttle_s)
        with self._acct_lock:
            self.remote_pulls += 1
        req = {"kind": "unit", "unit": to_wire(unit), "codec": codec}
        body, src_csum = self._fetch(src_replica, shard_idx, req)
        cdc = codec_lib.get_codec(codec)
        if codec == "raw":
            payload = np.frombuffer(body, dtype=np.uint8).copy()
            if self.verify_checksums and expected_checksum:
                self._verify(
                    payload, expected_checksum,
                    f"unit {unit.name} from {src_replica}/shard{shard_idx}",
                )
            dst_store.write_unit(unit, payload)
            self._account(link_class, unit.nbytes, unit.nbytes)
            return
        wire = np.frombuffer(body, dtype=np.uint8)
        wire_nbytes = wire.nbytes
        if getattr(cdc, "needs_base", False):
            try:
                payload = cdc.decode(wire, base=self._dest_base(dst_store, unit))
            except codec_lib.StaleBaseError:
                # the destination's base diverged from the source's — same
                # transparent re-ship as in-process, one extra round trip
                with self._acct_lock:
                    self.delta_stale_fallbacks += 1
                body, src_csum = self._fetch(
                    src_replica, shard_idx, {**req, "no_base": True}
                )
                wire = np.frombuffer(body, dtype=np.uint8)
                wire_nbytes += wire.nbytes
                payload = cdc.decode(wire)
        else:
            payload = cdc.decode(wire)
        if self.verify_checksums:
            self._verify(
                payload, src_csum,
                f"unit {unit.name} ({codec}) from {src_replica}/shard{shard_idx}",
            )
        dst_store.write_unit(unit, payload)
        self._account(link_class, wire_nbytes, unit.nbytes)

    def read_unit_range(
        self,
        src_replica: str,
        shard_idx: int,
        unit: TransferUnit,
        offset: int,
        nbytes: int,
        codec: str = "raw",
        link_class: str = "rdma",
        dest_base: Optional[np.ndarray] = None,
        decode: bool = True,
    ) -> np.ndarray:
        if self._is_local(src_replica, shard_idx):
            return super().read_unit_range(
                src_replica, shard_idx, unit, offset, nbytes,
                codec, link_class, dest_base, decode,
            )
        self._fault_read(src_replica, shard_idx)
        if self.throttle_s:
            time.sleep(self.throttle_s)
        req = {
            "kind": "chunk", "unit": to_wire(unit), "codec": codec,
            "offset": int(offset), "nbytes": int(nbytes),
        }
        if not decode and codec != "raw":
            if getattr(codec_lib.get_codec(codec), "needs_base", False):
                raise codec_lib.CodecError(
                    f"wire-frame reads cannot carry the base-referencing "
                    f"codec {codec!r} (no destination base at frame "
                    "granularity) — resolve the reshard codec first"
                )
            body, src_csum = self._fetch(
                src_replica, shard_idx, {**req, "raw_wire": True}
            )
            payload = np.frombuffer(body, dtype=np.uint8).copy()
            if self.verify_checksums:
                self._verify(
                    payload, src_csum,
                    f"chunk {unit.name}[{offset}:{offset + nbytes}] "
                    f"({codec} wire) from {src_replica}/shard{shard_idx}",
                )
            self._account(link_class, payload.nbytes, nbytes)
            return payload
        body, src_csum = self._fetch(src_replica, shard_idx, req)
        if codec == "raw":
            payload = np.frombuffer(body, dtype=np.uint8).copy()
            if self.verify_checksums:
                self._verify(
                    payload, src_csum,
                    f"chunk {unit.name}[{offset}:{offset + nbytes}] from "
                    f"{src_replica}/shard{shard_idx}",
                )
            self._account(link_class, nbytes, nbytes)
            return payload
        cdc = codec_lib.get_codec(codec)
        wire = np.frombuffer(body, dtype=np.uint8)
        wire_nbytes = wire.nbytes
        if getattr(cdc, "needs_base", False):
            try:
                payload = cdc.decode(wire, base=dest_base)
            except codec_lib.StaleBaseError:
                with self._acct_lock:
                    self.delta_stale_fallbacks += 1
                body, src_csum = self._fetch(
                    src_replica, shard_idx, {**req, "no_base": True}
                )
                wire = np.frombuffer(body, dtype=np.uint8)
                wire_nbytes += wire.nbytes
                payload = cdc.decode(wire)
        else:
            payload = cdc.decode(wire)
        if self.verify_checksums:
            self._verify(
                payload, src_csum,
                f"chunk {unit.name}[{offset}:{offset + nbytes}] ({codec}) from "
                f"{src_replica}/shard{shard_idx}",
            )
        self._account(link_class, wire_nbytes, nbytes)
        return payload

__all__ = [
    "DATA_PROTOCOL_VERSION",
    "RemoteTransport",
    "WorkerDataServer",
]
