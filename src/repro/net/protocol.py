"""Versioned JSON wire protocol for the networked control plane.

A request frame is one UTF-8 JSON document::

    {"v": 1, "op": "publish", "args": [<wire>...], "kw": {<name>: <wire>}}

and a response frame is either::

    {"v": 1, "ok": true,  "result": <wire>}
    {"v": 1, "ok": false, "error": {"kind": "StaleHandleError",
                                    "message": "...", ...}}

``<wire>`` values use the op log's generic codec
(:func:`repro.core.meta.to_wire` / ``from_wire``) — the WAL payload
schema in :data:`repro.core.oplog.OP_SCHEMAS` *is* the RPC schema for
every mutating op, and :data:`repro.core.server.READONLY_OPS` declares
the rest, so the wire format was fixed by PR 4 before any socket
existed.

Decoding is strict and total: anything malformed — truncated JSON,
non-UTF-8 bytes, unknown top-level fields, a missing or unsupported
``v`` — raises :class:`ProtocolError`, which the service turns into a
clean error frame instead of a hang or a stack-trace disconnect.

Typed errors travel by class name. Every error class in
``repro.core.errors`` (plus :class:`ProtocolError` and the codec errors
registered by :mod:`repro.net.data`) re-raises as itself on the client;
unknown kinds degrade to :class:`~repro.core.errors.TensorHubError` with
the kind folded into the message. ``ServerUnavailableError`` therefore
crosses the wire intact — a remote client parks on a crashed-but-
responsive controller exactly as the in-process client does.
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Tuple, Type

from repro.core import errors as errors_mod
from repro.core.errors import TensorHubError, TransportError
from repro.core.meta import from_wire, to_wire

#: bump when a frame field changes meaning; a decoder rejects frames it
#: does not speak rather than guessing
PROTOCOL_VERSION = 1

_REQUEST_FIELDS = {"v", "op", "args", "kw"}
_RESPONSE_FIELDS = {"v", "ok", "result", "error"}


class ProtocolError(TensorHubError):
    """A frame violated the wire protocol (malformed, truncated, wrong
    version, or an op outside the remotable surface)."""


#: error kinds that re-raise as their own class on the receiving side
ERROR_TYPES: Dict[str, Type[BaseException]] = {
    name: obj
    for name, obj in vars(errors_mod).items()
    if isinstance(obj, type) and issubclass(obj, TensorHubError)
}
ERROR_TYPES["ProtocolError"] = ProtocolError
# the server's argument validation surfaces these for bad op payloads
ERROR_TYPES["ValueError"] = ValueError
ERROR_TYPES["TypeError"] = TypeError
ERROR_TYPES["KeyError"] = KeyError


def register_error(cls: Type[BaseException]) -> Type[BaseException]:
    """Register an additional error class for faithful re-raise (usable
    as a decorator). Both peers must import the registering module."""
    ERROR_TYPES[cls.__name__] = cls
    return cls


# ---------------------------------------------------------------------------
# requests
# ---------------------------------------------------------------------------


def encode_request(op: str, args: tuple = (), kw: Dict[str, Any] | None = None) -> bytes:
    frame = {
        "v": PROTOCOL_VERSION,
        "op": op,
        "args": [to_wire(a) for a in args],
        "kw": {k: to_wire(v) for k, v in (kw or {}).items()},
    }
    return json.dumps(frame).encode("utf-8")


def _load_frame(data: bytes, allowed_fields: set) -> dict:
    if not isinstance(data, (bytes, bytearray)):
        raise ProtocolError(f"frame must be bytes, got {type(data).__name__}")
    try:
        obj = json.loads(data.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ProtocolError(f"unparseable frame: {e}") from None
    if not isinstance(obj, dict):
        raise ProtocolError(
            f"frame must be a JSON object, got {type(obj).__name__}"
        )
    unknown = set(obj) - allowed_fields
    if unknown:
        raise ProtocolError(f"unknown frame fields: {sorted(unknown)}")
    v = obj.get("v")
    if v != PROTOCOL_VERSION:
        raise ProtocolError(
            f"unsupported protocol version {v!r} (this peer speaks "
            f"{PROTOCOL_VERSION})"
        )
    return obj


def decode_request(data: bytes) -> Tuple[str, List[Any], Dict[str, Any]]:
    """Strictly decode a request frame into ``(op, args, kwargs)``.

    Raises :class:`ProtocolError` on any malformation; never raises
    anything else. Op *whitelisting* is the service's job — this layer
    only guarantees the frame is structurally sound."""
    obj = _load_frame(bytes(data), _REQUEST_FIELDS)
    op = obj.get("op")
    if not isinstance(op, str) or not op:
        raise ProtocolError(f"bad op field: {op!r}")
    raw_args = obj.get("args", [])
    raw_kw = obj.get("kw", {})
    if not isinstance(raw_args, list):
        raise ProtocolError("args must be a list")
    if not isinstance(raw_kw, dict) or any(not isinstance(k, str) for k in raw_kw):
        raise ProtocolError("kw must be a string-keyed object")
    try:
        args = [from_wire(a) for a in raw_args]
        kw = {k: from_wire(v) for k, v in raw_kw.items()}
    except (TypeError, KeyError, ValueError) as e:
        raise ProtocolError(f"undecodable argument payload: {e}") from None
    return op, args, kw


# ---------------------------------------------------------------------------
# responses
# ---------------------------------------------------------------------------


def encode_result(result: Any) -> bytes:
    return json.dumps(
        {"v": PROTOCOL_VERSION, "ok": True, "result": to_wire(result)}
    ).encode("utf-8")


def encode_error(exc: BaseException) -> bytes:
    err: Dict[str, Any] = {
        "kind": type(exc).__name__,
        "message": str(exc),
    }
    if isinstance(exc, TransportError):
        err["transient"] = bool(exc.transient)
    return json.dumps(
        {"v": PROTOCOL_VERSION, "ok": False, "error": err}
    ).encode("utf-8")


def raise_from_error(err: Dict[str, Any]) -> None:
    """Re-raise the typed error an error frame carries."""
    kind = err.get("kind", "TensorHubError")
    message = err.get("message", "")
    cls = ERROR_TYPES.get(kind)
    if cls is TransportError:
        raise TransportError(message, transient=bool(err.get("transient", False)))
    if cls is not None:
        raise cls(message)
    raise TensorHubError(f"{kind}: {message}")


def decode_response(data: bytes) -> Any:
    """Decode a response frame: return the result, or raise the typed
    error it carries. Malformed frames raise :class:`ProtocolError`."""
    obj = _load_frame(bytes(data), _RESPONSE_FIELDS)
    ok = obj.get("ok")
    if ok is True:
        try:
            return from_wire(obj.get("result"))
        except (TypeError, KeyError, ValueError) as e:
            raise ProtocolError(f"undecodable result payload: {e}") from None
    if ok is False:
        err = obj.get("error")
        if not isinstance(err, dict):
            raise ProtocolError(f"bad error payload: {err!r}")
        raise_from_error(err)
    raise ProtocolError(f"bad ok field: {ok!r}")


__all__ = [
    "ERROR_TYPES",
    "PROTOCOL_VERSION",
    "ProtocolError",
    "decode_request",
    "decode_response",
    "encode_error",
    "encode_request",
    "encode_result",
    "raise_from_error",
    "register_error",
]
