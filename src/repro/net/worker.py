"""One-call assembly of a networked worker process.

``NetWorker`` wires together everything a worker needs to participate in
the socketed deployment: a :class:`~repro.net.client.RemoteClient` to
the controller, a local :class:`~repro.transfer.engine.WorkerRegistry`
that *announces* every registered store to the controller's peer
directory, a :class:`~repro.net.data.WorkerDataServer` serving those
stores to other workers, a :class:`~repro.net.data.RemoteTransport`
resolving non-local sources through the directory, ambient wall-clock
heartbeats, and (optionally) an :class:`~repro.net.client
.AddressWatcher` that fails the whole stack over when the controller
restarts on a new port.

The resulting ``NetWorker.hub`` is a perfectly ordinary
:class:`~repro.core.client.TensorHubClient`; every test and example that
drives the in-process client drives this one unchanged.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

from repro.core.client import TensorHubClient
from repro.core.errors import ServerUnavailableError, TensorHubError, TransportError
from repro.net.client import AddressWatcher, RemoteClient, read_address
from repro.net.data import RemoteTransport, WorkerDataServer
from repro.transfer.engine import WorkerRegistry, WorkerStore


class _AnnouncingRegistry(WorkerRegistry):
    """A ``WorkerRegistry`` that mirrors membership into the controller's
    peer directory: ``add`` announces this worker's data address for the
    (replica, shard), ``remove`` retracts it. A briefly-unreachable
    controller is tolerated — the address watcher re-announces the full
    peer set on every failover."""

    def __init__(self, owner: "NetWorker") -> None:
        super().__init__()
        self._owner = owner

    def add(self, replica: str, shard_idx: int, store: WorkerStore) -> None:
        super().add(replica, shard_idx, store)
        try:
            self._owner.announce(replica, shard_idx)
        except (ServerUnavailableError, TensorHubError):
            pass

    def remove(self, replica: str, shard_idx: int) -> None:
        super().remove(replica, shard_idx)
        try:
            self._owner.remote().retract_peer(replica, shard_idx)
        except (ServerUnavailableError, TensorHubError):
            pass


class NetWorker:
    """A worker process's full networked stack around one hub client."""

    def __init__(
        self,
        worker_id: str,
        *,
        addr_file: Optional[str] = None,
        address: Optional[str] = None,
        heartbeat_interval: float = 0.5,
        watch_interval: float = 0.2,
        rpc_timeout: float = 10.0,
        throttle_s: float = 0.0,
        verify_checksums: bool = True,
        **client_kw: Any,
    ) -> None:
        if address is None:
            if addr_file is None:
                raise ValueError("need addr_file or address")
            deadline = time.monotonic() + rpc_timeout
            while (address := read_address(addr_file)) is None:
                if time.monotonic() >= deadline:
                    raise ServerUnavailableError(
                        f"no controller address in {addr_file!r}"
                    )
                time.sleep(0.05)
        self.worker_id = worker_id
        self.addr_file = addr_file
        self.registry = _AnnouncingRegistry(self)
        self.data_server = WorkerDataServer(self.registry).start()
        self.transport = RemoteTransport(
            self.registry,
            self._resolve,
            timeout=rpc_timeout,
            throttle_s=throttle_s,
            verify_checksums=verify_checksums,
        )
        self.hub = TensorHubClient(
            RemoteClient(address, timeout=rpc_timeout),
            registry=self.registry,
            transport=self.transport,
            clock=time.time,  # wall clock: shared axis with the controller
            **client_kw,
        )
        self.hub.start_heartbeats(heartbeat_interval)
        #: positive resolve cache: peer data addresses are stable for a
        #: worker's lifetime, and caching keeps the data plane off the
        #: controller mid-pull (a parked control plane then cannot stall
        #: an already-planned transfer's reads)
        self._peer_cache: Dict[Tuple[str, int], str] = {}
        self._cache_lock = threading.Lock()
        self.watcher: Optional[AddressWatcher] = None
        if addr_file is not None:
            self.watcher = AddressWatcher(
                self.hub,
                addr_file,
                poll_interval=watch_interval,
                peers=self._peer_list,
                timeout=rpc_timeout,
            ).start()

    # -- directory plumbing ----------------------------------------------------

    def remote(self) -> RemoteClient:
        """The hub's *current* controller proxy (changes on failover)."""
        return self.hub.server  # type: ignore[return-value]

    def announce(self, replica: str, shard_idx: int) -> None:
        self.remote().announce_peer(
            self.worker_id, replica, shard_idx, self.data_server.address
        )

    def _peer_list(self):
        return [
            (self.worker_id, replica, shard_idx, self.data_server.address)
            for (replica, shard_idx) in list(self.registry._stores)
        ]

    def _resolve(self, replica: str, shard_idx: int) -> Optional[str]:
        key = (replica, shard_idx)
        with self._cache_lock:
            cached = self._peer_cache.get(key)
        if cached is not None:
            return cached
        try:
            addr = self.remote().peer_addr(replica, shard_idx)
        except ServerUnavailableError as e:
            # directory briefly unreachable ≠ source dead: transient, the
            # engine's retry policy rides it out until failover completes
            raise TransportError(str(e), transient=True) from None
        if addr is not None:
            with self._cache_lock:
                self._peer_cache[key] = addr
        return addr

    # -- lifecycle -------------------------------------------------------------

    def open(self, *args: Any, **kw: Any):
        return self.hub.open(*args, **kw)

    def close(self) -> None:
        if self.watcher is not None:
            self.watcher.stop()
        self.hub.stop_heartbeats()
        self.data_server.shutdown()
        try:
            self.remote().close()
        except Exception:
            pass


__all__ = ["NetWorker"]
