"""Transport-agnostic control-plane service.

``ReferenceService`` is the seam the tentpole split introduces: all
server *logic* stays in :class:`repro.core.server.ReferenceServer`
(deterministic, single-threaded semantics, WAL-logged), and everything a
transport needs — frame decoding, op whitelisting, cross-thread
serialization, typed-error encoding, per-RPC latency stats, the worker
peer directory, and the heartbeat-expiry ticker — lives here, with no
socket in sight. The HTTP layer (:mod:`repro.net.httpd`) is a thin shim
over :meth:`handle_frame`; the protocol-fuzz tests drive the same entry
point in-process.

Idempotent redelivery comes for free: every mutating group op carries an
``op_id`` and the server's done-txn cache replays the cached result on
re-delivery, so a client may retry any request whose response was lost
to a dropped connection. The remaining mutating ops (progress reports,
heartbeats, manifest puts) are idempotent by construction.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.errors import TensorHubError
from repro.core.oplog import OP_SCHEMAS
from repro.core.server import CONTROL_OPS, ReferenceServer
from repro.net import protocol
from repro.net.protocol import ProtocolError
from repro.obs.rpc import RpcStats

#: service-level ops (not server methods, never WAL-logged): the peer
#: directory that stands in for the paper's RDMA address exchange, plus
#: introspection used by tests and the failover watcher.
SERVICE_OPS: Dict[str, tuple] = {
    "svc.ping": (),
    "svc.digest": (),
    "svc.metrics": (),
    "svc.announce": ("worker_id", "replica", "shard_idx", "address"),
    "svc.retract": ("replica", "shard_idx"),
    "svc.peer": ("replica", "shard_idx"),
    "svc.peers": (),
}


class ReferenceService:
    """One server, any number of transports.

    All dispatch is serialized on an internal lock: the server keeps its
    deterministic single-threaded semantics no matter how many transport
    threads (or in-process callers) push frames in.
    """

    def __init__(
        self,
        server: ReferenceServer,
        *,
        clock: Callable[[], float] = time.time,
        tick_interval: Optional[float] = None,
    ) -> None:
        self.server = server
        self.clock = clock
        self.rpc_stats = RpcStats()
        self._lock = threading.RLock()
        #: (replica, shard_idx) -> data-plane address ("host:port").
        #: Deliberately *not* part of the server's replayed state:
        #: addresses are ephemeral transport facts, so the service-wrapped
        #: server stays digest-identical to an in-process twin. After a
        #: controller restart the directory starts empty and workers
        #: re-announce (the address watcher does this before failing
        #: clients over); readers retry unresolved peers as transient.
        self._peers: Dict[Tuple[str, int], str] = {}
        self._peer_owner: Dict[Tuple[str, int], str] = {}
        self._started = clock()
        self._stop = threading.Event()
        self._ticker: Optional[threading.Thread] = None
        #: liveness monitoring: on when the ticker drives wall-clock
        #: expiry sweeps. The server seeds a fresh shard's last_heartbeat
        #: at 0.0 (virtual-time tests rely on that), which an epoch-clock
        #: tick would read as "stale since 1970" — so while monitoring,
        #: dispatch stamps a first heartbeat the instant an open lands,
        #: under the same lock (no tick can interleave).
        self._monitor = tick_interval is not None
        if tick_interval is not None:
            self.start_ticker(tick_interval)

    # -- dispatch --------------------------------------------------------------

    def dispatch(self, op: str, args: List[Any], kw: Dict[str, Any]) -> Any:
        """Invoke one whitelisted op; raises typed errors on failure."""
        if op.startswith("svc."):
            if op not in SERVICE_OPS:
                raise ProtocolError(f"unknown service op {op!r}")
            return self._service_op(op, args, kw)
        if op not in CONTROL_OPS:
            raise ProtocolError(f"op {op!r} is not a remotable control op")
        with self._lock:
            result = getattr(self.server, op)(*args, **kw)
            if op == "open" and self._monitor:
                merged = dict(zip(OP_SCHEMAS["open"], args))
                merged.update(kw)
                try:
                    self.server.heartbeat(
                        merged["model"], merged["replica"],
                        merged["shard_idx"], self.clock(),
                    )
                except (TensorHubError, KeyError):
                    pass
            return result

    def handle_frame(self, data: bytes) -> bytes:
        """Decode one request frame, dispatch it, encode the outcome.

        Total by construction: every failure — protocol violation, typed
        control-plane error, even an encoding bug — becomes a well-formed
        error frame. A transport never needs to disconnect on a bad
        request, and a fuzzer cannot make this raise."""
        t0 = time.perf_counter()
        op = "malformed"
        try:
            op, args, kw = protocol.decode_request(data)
            result = self.dispatch(op, args, kw)
            out = protocol.encode_result(result)
        except BaseException as e:  # noqa: BLE001 — the wire carries it
            self.rpc_stats.record(op, time.perf_counter() - t0, ok=False)
            return protocol.encode_error(e)
        self.rpc_stats.record(op, time.perf_counter() - t0)
        return out

    def call(self, op: str, *args: Any, **kw: Any) -> Any:
        """In-process convenience entry with the same validation path as
        a decoded frame (used by tests and the ticker)."""
        return self.dispatch(op, list(args), kw)

    # -- service ops -----------------------------------------------------------

    def _service_op(self, op: str, args: List[Any], kw: Dict[str, Any]) -> Any:
        try:
            if op == "svc.ping":
                return {
                    "service": "tensorhub-controller",
                    "protocol": protocol.PROTOCOL_VERSION,
                    "started": self._started,
                    "crashed": bool(self.server.is_crashed),
                }
            if op == "svc.digest":
                from repro.core import failover

                with self._lock:
                    return failover.state_digest(self.server)
            if op == "svc.metrics":
                return self.metrics()
            if op == "svc.announce":
                worker_id, replica, shard_idx, address = args
                with self._lock:
                    self._peers[(replica, int(shard_idx))] = str(address)
                    self._peer_owner[(replica, int(shard_idx))] = str(worker_id)
                return None
            if op == "svc.retract":
                replica, shard_idx = args
                with self._lock:
                    self._peers.pop((replica, int(shard_idx)), None)
                    self._peer_owner.pop((replica, int(shard_idx)), None)
                return None
            if op == "svc.peer":
                replica, shard_idx = args
                with self._lock:
                    return self._peers.get((replica, int(shard_idx)))
            if op == "svc.peers":
                with self._lock:
                    return {k: v for k, v in self._peers.items()}
        except (TypeError, ValueError) as e:
            raise ProtocolError(f"bad arguments for {op}: {e}") from None
        raise ProtocolError(f"unknown service op {op!r}")  # pragma: no cover

    # -- metrics ---------------------------------------------------------------

    def metrics(self) -> Dict[str, Dict[str, Any]]:
        """The server's metrics plus the transport's per-RPC section."""
        with self._lock:
            m = dict(self.server.metrics())
        m["rpc"] = self.rpc_stats.snapshot()
        return m

    def metrics_text(self) -> str:
        """One scrape body: server exposition + per-RPC series."""
        with self._lock:
            body = self.server.metrics_text()
        return body + self.rpc_stats.text()

    # -- heartbeat-expiry ticker ----------------------------------------------

    def start_ticker(self, interval: float) -> None:
        """Drive ``server.tick`` on the service clock so stale worker
        heartbeats expire (eviction + quarantine-probation lifts) without
        any client's help — the langport-controller shape. Each tick is a
        logged op with its explicit timestamp, so a WAL replay evicts the
        same replicas the live run did."""
        if self._ticker is not None:
            return
        self._monitor = True

        def loop() -> None:
            while not self._stop.wait(interval):
                try:
                    with self._lock:
                        if self.server.is_crashed:
                            continue
                        self.server.tick(self.clock())
                except TensorHubError:
                    continue

        self._ticker = threading.Thread(
            target=loop, name="tensorhub-ticker", daemon=True
        )
        self._ticker.start()

    def stop(self) -> None:
        self._stop.set()
        if self._ticker is not None:
            self._ticker.join(timeout=2.0)
            self._ticker = None


__all__ = ["ReferenceService", "SERVICE_OPS"]
