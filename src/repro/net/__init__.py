"""Networked control plane: the ReferenceServer as a multi-process service.

The in-process reproduction keeps the server, every worker, and both
data planes inside one Python interpreter; this package promotes the
same transport-agnostic server logic (``repro.core.server``) behind real
sockets, the deployment shape of the paper's production ROS:

* :mod:`repro.net.protocol` — versioned JSON wire frames over the op
  schemas the WAL already defines, plus the typed-error transport.
* :mod:`repro.net.service` — the transport-agnostic dispatcher: one
  ``ReferenceService`` wraps a ``ReferenceServer`` with frame decoding,
  op whitelisting, per-RPC latency stats, a worker peer directory, and
  the heartbeat-expiry ticker.
* :mod:`repro.net.httpd` — the thin HTTP transport (stdlib
  ``ThreadingHTTPServer``): POST /rpc frames, GET /metrics, /healthz.
* :mod:`repro.net.client` — ``RemoteClient``, a server-shaped proxy that
  drops into ``TensorHubClient.server`` unchanged, plus the address
  watcher that fails clients over to a restarted controller.
* :mod:`repro.net.data` — the socketed data plane: each worker serves
  its registered stores over HTTP and ``RemoteTransport`` pulls units /
  chunks / intervals from remote peers with the exact codec + checksum
  contract of the in-process transport.
* :mod:`repro.net.worker` — one-call worker-process assembly of all of
  the above.
* :mod:`repro.net.controller` — the controller process entry point
  (``python -m repro.net.controller``), WAL-backed and restartable.

The in-process path remains the default everywhere; nothing in
``repro.core`` depends on this package.
"""

from repro.net.protocol import PROTOCOL_VERSION, ProtocolError
from repro.net.service import ReferenceService
from repro.net.httpd import ControlServer
from repro.net.client import AddressWatcher, RemoteClient, read_address, write_address
from repro.net.data import RemoteTransport, WorkerDataServer
from repro.net.worker import NetWorker

__all__ = [
    "AddressWatcher",
    "ControlServer",
    "NetWorker",
    "PROTOCOL_VERSION",
    "ProtocolError",
    "ReferenceService",
    "RemoteClient",
    "RemoteTransport",
    "WorkerDataServer",
    "read_address",
    "write_address",
]
