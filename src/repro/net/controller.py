"""Controller process entry point: ``python -m repro.net.controller``.

Runs one WAL-backed :class:`~repro.core.server.ReferenceServer` behind
the HTTP control plane. On a fresh WAL the server starts empty and
writes its config header; when the WAL already carries history (the
process was SIGKILLed and restarted — possibly on a new port), recovery
replays it into a bit-identical server *first* and only then opens the
socket, so no client ever observes a half-recovered controller.

The controller publishes its address two ways: an atomically-replaced
address file (what workers' :class:`~repro.net.client.AddressWatcher`
polls to find a restarted controller) and a ``READY <host:port>`` line
on stdout (what the test harness waits for).

Deliberately jax-free: worker subprocess tests import nothing beyond the
core + net stack, keeping spawn time and memory at stdlib levels.
"""

from __future__ import annotations

import argparse
import signal
import sys
import threading
from typing import Optional

from repro.core.failover import recover
from repro.core.oplog import OpLog
from repro.core.server import ReferenceServer
from repro.net.client import write_address
from repro.net.httpd import ControlServer
from repro.net.service import ReferenceService


def build_server(
    wal: Optional[str], *, group_commit: int = 1, heartbeat_timeout: Optional[float]
) -> ReferenceServer:
    """Fresh server, or a WAL replay when ``wal`` carries history."""
    if wal is None:
        return ReferenceServer(heartbeat_timeout=heartbeat_timeout)
    log = OpLog.open_path(wal, group_commit=group_commit)
    if log.config is not None:
        # restart: the config header pins the knobs; CLI ones are ignored
        return recover(log)
    return ReferenceServer(heartbeat_timeout=heartbeat_timeout, log=log)


def main(argv: Optional[list] = None) -> int:
    p = argparse.ArgumentParser(description="TensorHub networked controller")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=0, help="0 picks a free port")
    p.add_argument("--addr-file", default=None,
                   help="publish host:port here (atomic replace)")
    p.add_argument("--wal", default=None,
                   help="op-log path; restarts recover from it")
    p.add_argument("--group-commit", type=int, default=1)
    p.add_argument("--heartbeat-timeout", type=float, default=None,
                   help="seconds without a shard heartbeat before eviction")
    p.add_argument("--tick-interval", type=float, default=0.25,
                   help="heartbeat-expiry sweep period (seconds)")
    args = p.parse_args(argv)

    server = build_server(
        args.wal,
        group_commit=args.group_commit,
        heartbeat_timeout=args.heartbeat_timeout,
    )
    service = ReferenceService(server, tick_interval=args.tick_interval)
    http = ControlServer(service, host=args.host, port=args.port).start()
    if args.addr_file:
        write_address(args.addr_file, http.address)

    stop = threading.Event()
    for sig in (signal.SIGINT, signal.SIGTERM):
        signal.signal(sig, lambda *_: stop.set())

    # the harness sentinel: everything before this line may be slow
    # (recovery of a long WAL), everything after is served
    print(f"READY {http.address}", flush=True)
    stop.wait()

    http.shutdown()
    if server.log is not None:
        server.log.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
