"""Worker-side proxy for the networked control plane.

``RemoteClient`` is shaped like a :class:`~repro.core.server
.ReferenceServer`: every remotable op is a method, typed errors re-raise
as themselves, and ``add_watcher`` exists — so it drops straight into
``TensorHubClient(server=...)`` and the entire client stack (parking,
two-phase reassert, retry policy) works over sockets unchanged.

Connection-level failures (refused, reset, timed out) surface as
:class:`~repro.core.errors.ServerUnavailableError` — indistinguishable
from a ``crash()``ed in-process server, which is exactly right: the
client parks and waits for ``failover()``. Whether a retry is safe is
the *server's* problem, and it already solved it: group ops are
idempotent via their op-id done-txn cache, everything else by
construction, so ``RemoteClient`` retries once on a stale kept-alive
connection before giving up.

``AddressWatcher`` closes the loop for controller restarts: it polls the
address file the controller publishes, and when a *new* address answers
``svc.ping`` it re-announces this worker's data-plane peers (the
directory is ephemeral) and fails the ``TensorHubClient`` over to a
fresh ``RemoteClient`` — parked ops then reassert and resume.
"""

from __future__ import annotations

import http.client
import os
import socket
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.errors import ServerUnavailableError
from repro.core.oplog import OP_SCHEMAS
from repro.core.server import CONTROL_OPS
from repro.net import protocol
from repro.net.httpd import split_address

#: network faults that mean "controller unreachable", not "op failed"
_CONN_ERRORS = (
    ConnectionError,
    socket.timeout,
    http.client.HTTPException,
    OSError,
)


class RemoteClient:
    """Server-shaped HTTP proxy speaking the versioned frame protocol.

    One persistent keep-alive connection, guarded by a lock so a single
    ``RemoteClient`` may be shared the way an in-process server is."""

    def __init__(self, address: str, *, timeout: float = 10.0) -> None:
        self.address = address
        self.host, self.port = split_address(address)
        self.timeout = timeout
        self._lock = threading.Lock()
        self._conn: Optional[http.client.HTTPConnection] = None
        self._watchers: List[Callable[[], None]] = []
        self._unavailable = False

    # -- transport -------------------------------------------------------------

    def _post(self, frame: bytes) -> bytes:
        """POST one frame, reusing the kept-alive connection; one silent
        retry on a fresh connection covers the server having closed the
        idle socket between ops."""
        with self._lock:
            for attempt in (0, 1):
                try:
                    if self._conn is None:
                        conn = http.client.HTTPConnection(
                            self.host, self.port, timeout=self.timeout
                        )
                        conn.connect()
                        # latency-bound request/response pairs: Nagle
                        # plus delayed ACK would idle ~40ms per op
                        conn.sock.setsockopt(
                            socket.IPPROTO_TCP, socket.TCP_NODELAY, 1
                        )
                        self._conn = conn
                    self._conn.request(
                        "POST",
                        "/rpc",
                        body=frame,
                        headers={"Content-Type": "application/json"},
                    )
                    resp = self._conn.getresponse()
                    return resp.read()
                except _CONN_ERRORS as e:
                    self._drop_conn()
                    if attempt == 1:
                        self._unavailable = True
                        raise ServerUnavailableError(
                            f"controller {self.address} unreachable: {e}"
                        ) from None
        raise AssertionError("unreachable")

    def _drop_conn(self) -> None:
        if self._conn is not None:
            try:
                self._conn.close()
            except Exception:
                pass
            self._conn = None

    def call(self, op: str, *args: Any, **kw: Any) -> Any:
        out = self._post(protocol.encode_request(op, args, kw))
        result = protocol.decode_response(out)
        if op in OP_SCHEMAS:
            # mirror the in-process server's _bump for self-induced
            # state changes: a mutating op just landed, so wake this
            # process's waiters immediately instead of letting them eat
            # a full re-poll quantum. Changes made by *other* processes
            # still surface on the poll cadence — same guarantee, just
            # slower, which is all a remote watcher can promise.
            for cb in list(self._watchers):
                try:
                    cb()
                except Exception:
                    pass
        return result

    def close(self, *args: Any, **kw: Any) -> Any:
        """The one name both surfaces claim: with arguments this proxies
        the server's ``close(model, replica, shard_idx)`` op; a bare
        ``close()`` tears down this client's connection."""
        if args or kw:
            return self.call("close", *args, **kw)
        with self._lock:
            self._drop_conn()

    # -- the server interface --------------------------------------------------

    def __getattr__(self, name: str) -> Callable[..., Any]:
        # only the declared remotable surface; anything else is a
        # programming error, same as a missing server method
        if name.startswith("_") or name not in CONTROL_OPS:
            raise AttributeError(name)

        def method(*args: Any, **kw: Any) -> Any:
            return self.call(name, *args, **kw)

        method.__name__ = name
        return method

    def add_watcher(self, cb: Callable[[], None]) -> None:
        # fired after this client's own mutating ops (see call()); for
        # changes originating elsewhere the waiters' re-poll cadence is
        # the wakeup, as with any remote watcher
        self._watchers.append(cb)

    @property
    def is_crashed(self) -> bool:
        if self._unavailable:
            return True
        try:
            return bool(self.ping().get("crashed"))
        except ServerUnavailableError:
            return True

    # -- service ops -----------------------------------------------------------

    def ping(self) -> Dict[str, Any]:
        return self.call("svc.ping")

    def digest(self) -> str:
        return self.call("svc.digest")

    def announce_peer(
        self, worker_id: str, replica: str, shard_idx: int, address: str
    ) -> None:
        self.call("svc.announce", worker_id, replica, shard_idx, address)

    def retract_peer(self, replica: str, shard_idx: int) -> None:
        self.call("svc.retract", replica, shard_idx)

    def peer_addr(self, replica: str, shard_idx: int) -> Optional[str]:
        return self.call("svc.peer", replica, shard_idx)

    def peers(self) -> Dict[Tuple[str, int], str]:
        return self.call("svc.peers")

    def service_metrics(self) -> Dict[str, Any]:
        return self.call("svc.metrics")


# ---------------------------------------------------------------------------
# controller address file + failover watcher
# ---------------------------------------------------------------------------


def write_address(path: str, address: str) -> None:
    """Atomically publish the controller's address (rename, so a reader
    never sees a torn write)."""
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w", encoding="utf-8") as fh:
        fh.write(address + "\n")
    os.replace(tmp, path)


def read_address(path: str) -> Optional[str]:
    try:
        with open(path, "r", encoding="utf-8") as fh:
            addr = fh.read().strip()
    except FileNotFoundError:
        return None
    return addr or None


class AddressWatcher:
    """Fail a ``TensorHubClient`` over when the controller moves.

    Polls ``addr_file``; when it names an address different from the one
    the hub client is currently wired to *and* that address answers
    ``svc.ping``, re-announces this worker's data-plane peers on the new
    controller (its directory starts empty after a restart) and calls
    ``hub_client.failover(RemoteClient(new_addr))`` — parked ops wake,
    reassert their session state, and resume."""

    def __init__(
        self,
        hub_client: Any,
        addr_file: str,
        *,
        poll_interval: float = 0.2,
        peers: Optional[Callable[[], List[Tuple[str, str, int, str]]]] = None,
        timeout: float = 10.0,
    ) -> None:
        self.hub_client = hub_client
        self.addr_file = addr_file
        self.poll_interval = poll_interval
        self._peers = peers
        self._timeout = timeout
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def current_address(self) -> Optional[str]:
        server = self.hub_client.server
        return getattr(server, "address", None)

    def check_once(self) -> bool:
        """One poll step; returns True when a failover happened."""
        addr = read_address(self.addr_file)
        if addr is None or addr == self.current_address():
            return False
        candidate = RemoteClient(addr, timeout=self._timeout)
        try:
            candidate.ping()
        except ServerUnavailableError:
            candidate.close()
            return False
        # announce before failover: by the time parked readers resume,
        # the new controller can already resolve this worker's stores
        if self._peers is not None:
            for worker_id, replica, shard_idx, peer_addr in self._peers():
                candidate.announce_peer(worker_id, replica, shard_idx, peer_addr)
        self.hub_client.failover(candidate)
        return True

    def start(self) -> "AddressWatcher":
        def loop() -> None:
            while not self._stop.wait(self.poll_interval):
                try:
                    self.check_once()
                except Exception:
                    # a torn file read or race with a dying controller
                    # must not kill the watcher; next poll retries
                    time.sleep(self.poll_interval)

        self._thread = threading.Thread(
            target=loop, name="tensorhub-addr-watcher", daemon=True
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None


__all__ = [
    "AddressWatcher",
    "RemoteClient",
    "read_address",
    "write_address",
]
