"""HTTP transport for the control plane.

The thinnest possible shim over :meth:`ReferenceService.handle_frame`:
one ``POST /rpc`` endpoint moving opaque frames, plus ``GET /metrics``
(Prometheus text exposition) and ``GET /healthz`` for scrapers and
process supervisors. HTTP/1.1 keep-alive lets a ``RemoteClient`` reuse
one connection for its whole op stream; ``ThreadingHTTPServer`` gives a
thread per connection and the service's lock restores the server's
single-threaded semantics behind them.
"""

from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Tuple

from repro.net.service import ReferenceService

#: sanity cap on frame size; control-plane frames are manifests at most
#: (a few MB), so anything larger is a protocol violation, not a payload
MAX_FRAME_BYTES = 64 * 1024 * 1024


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"  # keep-alive: one connection per client
    server_version = "tensorhub-control/1"
    # request/response frames are small and latency-bound: buffer the
    # response into one segment and disable Nagle, or the unbuffered
    # header/body writes interact with delayed ACK into ~40ms per RPC
    wbufsize = -1
    disable_nagle_algorithm = True

    def log_message(self, format: str, *args) -> None:  # noqa: A002
        pass  # stderr chatter would drown subprocess-test log tails

    def _send(self, status: int, body: bytes, ctype: str) -> None:
        self.send_response(status)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_POST(self) -> None:  # noqa: N802
        if self.path != "/rpc":
            self._send(404, b"not found", "text/plain")
            return
        try:
            length = int(self.headers.get("Content-Length", "0"))
        except ValueError:
            length = -1
        if length < 0 or length > MAX_FRAME_BYTES:
            self._send(413, b"bad frame length", "text/plain")
            return
        data = self.rfile.read(length)
        # handle_frame is total: any malformation comes back as a clean
        # error frame, so a fuzzer never sees a dropped connection
        out = self.server.service.handle_frame(data)  # type: ignore[attr-defined]
        self._send(200, out, "application/json")

    def do_GET(self) -> None:  # noqa: N802
        service: ReferenceService = self.server.service  # type: ignore[attr-defined]
        if self.path == "/healthz":
            self._send(200, b"ok\n", "text/plain")
        elif self.path == "/metrics":
            body = service.metrics_text().encode("utf-8")
            self._send(200, body, "text/plain; version=0.0.4")
        else:
            self._send(404, b"not found", "text/plain")


class ControlServer:
    """Serve a :class:`ReferenceService` over HTTP on ``host:port``
    (port 0 picks a free one). ``start()`` runs the accept loop on a
    daemon thread; ``shutdown()`` stops it and closes the socket."""

    def __init__(
        self, service: ReferenceService, host: str = "127.0.0.1", port: int = 0
    ) -> None:
        self.service = service
        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self._httpd.service = service  # type: ignore[attr-defined]
        self._thread: threading.Thread | None = None

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def address(self) -> str:
        host, port = self._httpd.server_address[:2]
        return f"{host}:{port}"

    def start(self) -> "ControlServer":
        self._thread = threading.Thread(
            target=self._httpd.serve_forever,
            kwargs={"poll_interval": 0.05},
            name="tensorhub-control-http",
            daemon=True,
        )
        self._thread.start()
        return self

    def shutdown(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None
        self.service.stop()


def split_address(address: str) -> Tuple[str, int]:
    """``"host:port"`` → ``(host, port)``; raises ValueError when malformed."""
    host, _, port = address.rpartition(":")
    if not host or not port.isdigit():
        raise ValueError(f"bad address {address!r}")
    return host, int(port)


__all__ = ["ControlServer", "MAX_FRAME_BYTES", "split_address"]
