from repro.sharding.rules import (
    LONG_SERVE_RULES,
    Rules,
    SERVE_RULES,
    TRAIN_RULES,
    constrain,
    rules_for,
    sharding_for,
    spec_for,
    tree_shardings,
)

__all__ = [
    "LONG_SERVE_RULES",
    "Rules",
    "SERVE_RULES",
    "TRAIN_RULES",
    "constrain",
    "rules_for",
    "sharding_for",
    "spec_for",
    "tree_shardings",
]
