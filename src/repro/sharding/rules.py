"""Logical-axis -> mesh-axis sharding rules (DESIGN.md 5).

Every parameter / activation / cache dimension carries a *logical* axis name
(``repro.models.params.ParamSpec.axes``). A :class:`Rules` table maps logical
names to (composite) mesh axes; :func:`spec_for` turns a concrete shape +
axes tuple into a ``PartitionSpec`` with two safety properties:

* **divisibility-aware**: a dim is only sharded if its size divides evenly
  over the mapped mesh axes (e.g. gemma2's 4 KV heads stay replicated on a
  16-way model axis; its fused kv projection of 1024 shards fine);
* **first-fit**: each mesh axis is used at most once per tensor; later dims
  that would reuse a taken axis stay unsharded. This resolves e.g.
  [experts, embed, expert_mlp] where both "experts" and "expert_mlp" map to
  "model": experts wins, expert_mlp replicates.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.models.params import ParamSpec

Composite = Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class Rules:
    mapping: Dict[str, Composite]

    def lookup(self, logical: Optional[str]) -> Composite:
        if logical is None:
            return ()
        return self.mapping.get(logical, ())


def _mesh_axis_sizes(mesh) -> Dict[str, int]:
    # works for both Mesh and AbstractMesh (tests use the latter: no need
    # for 256 real devices to check rule logic)
    return dict(mesh.shape)


def spec_for(shape: Tuple[int, ...], axes: Tuple[Optional[str], ...], rules: Rules, mesh: Mesh) -> PartitionSpec:
    sizes = _mesh_axis_sizes(mesh)
    used: set = set()
    dims = []
    for dim_size, logical in zip(shape, axes):
        cand = [a for a in rules.lookup(logical) if a in sizes and a not in used]
        # composite fallback: if the full product doesn't divide, retry with
        # trailing sub-tuples — e.g. experts->(data,model): 16 experts can't
        # split 256 ways, but they split the 16-way model axis fine.
        # (Without this, dbrx's expert stack was fully REPLICATED in serve
        # mode: 423 s of redundant compute per step in the dry-run table.)
        chosen: Tuple[str, ...] = ()
        for start in range(len(cand)):
            sub = cand[start:]
            total = 1
            for a in sub:
                total *= sizes[a]
            if total > 1 and dim_size % total == 0:
                chosen = tuple(sub)
                break
        if chosen:
            used.update(chosen)
            dims.append(chosen if len(chosen) > 1 else chosen[0])
        else:
            dims.append(None)
    return PartitionSpec(*dims)


def sharding_for(p: ParamSpec, rules: Rules, mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, spec_for(p.shape, p.axes, rules, mesh))


def tree_shardings(tree: Any, rules: Rules, mesh: Mesh) -> Any:
    """Map a ParamSpec tree to a NamedSharding tree."""
    return jax.tree.map(
        lambda p: sharding_for(p, rules, mesh),
        tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def constrain(x: jax.Array, axes: Tuple[Optional[str], ...], rules: Rules, mesh: Mesh) -> jax.Array:
    """In-graph sharding constraint from logical axes (activations)."""
    spec = spec_for(x.shape, axes, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Rule tables (DESIGN.md 5)
# ---------------------------------------------------------------------------

#: Training: FSDP over (pod, data) on the embed dim of params (ZeRO-3
#: analogue — jit inserts all-gathers at use sites), TP over model.
TRAIN_RULES = Rules(
    {
        # activations
        "batch": ("pod", "data"),
        "seq": (),
        "act_embed": (),
        # params
        "embed": ("pod", "data"),
        "q_heads": ("model",),
        "kv_heads": ("model",),
        "head_dim": ("model",),  # fallback when head dims don't divide
        "mlp": ("model",),
        "vocab": ("model",),
        "experts": ("model",),
        "expert_mlp": ("model",),
        "q_lora": (),
        "kv_lora": (),
        "ssm_inner": ("model",),
        "ssm_heads": ("model",),
        "ssm_state": (),
        "conv": (),
        "frames": (),
        "layers": (),
    }
)

#: Serving: weights stay TP-sharded (no FSDP — no per-step all-gathers);
#: huge MoE expert stacks additionally shard experts over data (pure EP
#: over the whole pod: deepseek-v3 fits this way).
SERVE_RULES = Rules(
    {
        "batch": ("pod", "data"),
        "seq": (),
        "act_embed": (),
        "embed": (),
        "q_heads": ("model",),
        "kv_heads": ("model",),
        "head_dim": ("model",),
        "mlp": ("model",),
        "vocab": ("model",),
        "experts": ("data", "model"),
        "expert_mlp": (),
        "q_lora": (),
        "kv_lora": (),
        "ssm_inner": ("model",),
        "ssm_heads": ("model",),
        "ssm_state": (),
        "conv": (),
        "frames": (),
        "layers": (),
    }
)

#: Long-context decode (batch=1): sequence-parallel KV/SSM caches — the
#: cache seq dim shards over data since batch can't.
LONG_SERVE_RULES = Rules(
    {
        **SERVE_RULES.mapping,
        "batch": (),
        "seq": ("pod", "data"),
    }
)


def rules_for(kind: str, *, global_batch: int = 0) -> Rules:
    if kind == "train":
        return TRAIN_RULES
    if kind in ("prefill", "decode"):
        return LONG_SERVE_RULES if global_batch == 1 else SERVE_RULES
    raise ValueError(f"unknown step kind {kind!r}")
