from repro.rl.loop import RLConfig, RolloutWorker, TrainerWorker, sample_responses

__all__ = ["RLConfig", "RolloutWorker", "TrainerWorker", "sample_responses"]
