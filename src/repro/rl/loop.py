"""RL training loop wired through TensorHub (paper Fig. 4).

``TrainerWorker`` follows Fig. 4a (publish -> rollout elsewhere -> unpublish
-> train -> publish next); ``RolloutWorker`` follows Fig. 4b (replicate the
initial weights, then poll ``update("latest")`` between inference batches).
Weight transfer between them is the *real* control+data plane: the
ReferenceServer routes, the LocalTransport moves actual bytes between the
workers' registered buffers, checksums verify end to end.

Workers run as threads in one process — the same topology the paper's
integration test rig uses (4.6: single-process multi-client simulation);
on a real cluster each worker is a JAX process and nothing here changes.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TensorHubClient
from repro.core.errors import StaleHandleError, TensorHubError
from repro.data.synthetic import PromptSet
from repro.models import build_model, named_tensors
from repro.training import (
    AdamW,
    group_relative_advantages,
    make_grpo_step,
)


@dataclasses.dataclass
class RLConfig:
    model_name: str = "actor"
    num_steps: int = 20
    prompt_len: int = 8
    response_len: int = 24
    num_prompts: int = 4
    group_size: int = 4  # responses per prompt (GRPO group)
    lr: float = 1e-3
    seed: int = 0
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 10


def sample_responses(
    model, params, prompts: jax.Array, response_len: int, key: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Autoregressive sampling; returns (sequences, per-token logprobs).

    Uses prefill + decode — the same serve path the big configs lower.
    """
    b, plen = prompts.shape
    total = plen + response_len
    logits, cache, cache_len = model.prefill(params, {"tokens": prompts}, max_len=total)

    def step(carry, k):
        cache, cache_len, logits, seq_pos, toks = carry
        lp = jax.nn.log_softmax(logits[:, -1].astype(jnp.float32), axis=-1)
        nxt = jax.random.categorical(k, lp, axis=-1)  # [B]
        chosen_lp = jnp.take_along_axis(lp, nxt[:, None], axis=-1)[:, 0]
        toks = jax.lax.dynamic_update_slice(toks, nxt[:, None].astype(jnp.int32), (0, seq_pos))
        new_logits, cache = model.decode(params, cache, nxt[:, None].astype(jnp.int32), cache_len)
        return (cache, cache_len + 1, new_logits, seq_pos + 1, toks), chosen_lp

    toks0 = jnp.concatenate(
        [prompts.astype(jnp.int32), jnp.zeros((b, response_len), jnp.int32)], axis=1
    )
    keys = jax.random.split(key, response_len)
    (cache, _, _, _, toks), lps = jax.lax.scan(
        step, (cache, cache_len, logits, plen, toks0), keys
    )
    return toks, lps.T  # [B, total], [B, response_len]


class RolloutWorker(threading.Thread):
    """Fig. 4b: standalone rollout pulling weights on demand."""

    def __init__(
        self,
        name: str,
        hub: TensorHubClient,
        cfg: RLConfig,
        model_cfg,
        prompts: PromptSet,
        out_queue: List,
        stop: threading.Event,
        *,
        datacenter: str = "dc0",
        is_spot: bool = False,
    ) -> None:
        super().__init__(name=name, daemon=True)
        self.hub = hub
        self.cfg = cfg
        self.model_cfg = model_cfg
        self.model = build_model(model_cfg)
        self.prompts = prompts
        self.out_queue = out_queue
        self.stop_event = stop
        self.datacenter = datacenter
        self.is_spot = is_spot
        self.replica_name = name
        self.steps_done = 0
        self.weights_version: Optional[int] = None
        self.error: Optional[BaseException] = None

    def run(self) -> None:  # pragma: no cover - exercised via integration tests
        try:
            self._run()
        except BaseException as e:  # surfaced by the driver
            self.error = e

    def _run(self) -> None:
        cfg = self.cfg
        params = self.model.init(jax.random.PRNGKey(0), jnp.float32)
        handle = self.hub.open(
            cfg.model_name,
            self.replica_name,
            num_shards=1,
            shard_idx=0,
            datacenter=self.datacenter,
            is_spot=self.is_spot,
        )
        buffers = {k: np.array(v) for k, v in named_tensors(params).items()}
        handle.register(buffers)
        self.weights_version = handle.replicate("latest")
        rollout_step = 0
        while not self.stop_event.is_set():
            params = self._params_from_buffers(params, buffers)
            prompts = jnp.asarray(
                self.prompts.sample(cfg.num_prompts * cfg.group_size, rollout_step)
            )
            key = jax.random.PRNGKey(hash((self.replica_name, rollout_step)) % (2**31))
            seqs, lps = sample_responses(self.model, params, prompts, cfg.response_len, key)
            rewards = self.prompts.reward(np.asarray(seqs), cfg.prompt_len)
            self.out_queue.append(
                {
                    "tokens": np.asarray(seqs),
                    "behavior_logprobs": np.asarray(lps),
                    "rewards": rewards,
                    "version": self.weights_version,
                    "worker": self.replica_name,
                }
            )
            self.steps_done += 1
            rollout_step += 1
            try:
                if handle.update("latest"):
                    self.weights_version = handle.current_version
            except (StaleHandleError, TensorHubError):
                break
        handle.close()

    def _params_from_buffers(self, params: Any, buffers: Dict[str, np.ndarray]) -> Any:
        flat = named_tensors(params)
        return jax.tree.unflatten(
            jax.tree.structure(params),
            [jnp.asarray(buffers[k]) for k in flat],
        )


class TrainerWorker:
    """Fig. 4a trainer side, driven synchronously by the example script."""

    def __init__(
        self,
        hub: TensorHubClient,
        cfg: RLConfig,
        model_cfg,
        rollout_queue: List,
        *,
        datacenter: str = "dc0",
    ) -> None:
        self.hub = hub
        self.cfg = cfg
        self.model_cfg = model_cfg
        self.model = build_model(model_cfg)
        self.queue = rollout_queue
        self.opt = AdamW(lr=cfg.lr, weight_decay=0.0)
        self.params = self.model.init(jax.random.PRNGKey(cfg.seed), jnp.float32)
        self.opt_state = self.opt.init(self.params)
        self.rl_step = jax.jit(make_grpo_step(self.model, model_cfg, self.opt))
        self.handle = hub.open(
            cfg.model_name, "trainer-0", num_shards=1, shard_idx=0,
            retain="latest", datacenter=datacenter,
        )
        self.version = 0
        self.metrics_log: List[Dict[str, float]] = []
        self._buffers = {k: np.array(v) for k, v in named_tensors(self.params).items()}
        self.handle.register(self._buffers)
        self._sync_buffers()
        self.handle.publish(self.version)

    def _sync_buffers(self) -> None:
        for k, v in named_tensors(self.params).items():
            np.copyto(self._buffers[k], np.asarray(v))

    def wait_for_rollouts(self, n: int, timeout: float = 120.0) -> List[Dict]:
        deadline = time.monotonic() + timeout
        while len(self.queue) < n:
            if time.monotonic() > deadline:
                raise TimeoutError("rollouts did not arrive in time")
            time.sleep(0.01)
        out = [self.queue.pop(0) for _ in range(n)]
        return out

    def train_on(self, rollouts: List[Dict]) -> Dict[str, float]:
        cfg = self.cfg
        tokens = np.concatenate([r["tokens"] for r in rollouts], axis=0)
        lps = np.concatenate([r["behavior_logprobs"] for r in rollouts], axis=0)
        rewards = np.concatenate([r["rewards"] for r in rollouts], axis=0)
        adv = group_relative_advantages(jnp.asarray(rewards), cfg.group_size)
        total = tokens.shape[1]
        # behavior logprobs cover response tokens only; align them into the
        # shifted [B, S-1] frame (position p-1 predicts token p)
        blp = np.zeros((tokens.shape[0], total - 1), np.float32)
        blp[:, cfg.prompt_len - 1 :] = lps
        loss_mask = np.zeros((tokens.shape[0], total - 1), bool)
        loss_mask[:, cfg.prompt_len - 1 :] = True
        batch = {
            "tokens": jnp.asarray(tokens),
            "behavior_logprobs": jnp.asarray(blp),
            "advantages": adv,
            "loss_mask": jnp.asarray(loss_mask),
        }
        # Fig. 4a: unpublish -> mutate -> publish the new version
        self.handle.unpublish()
        self.params, self.opt_state, metrics = self.rl_step(self.params, self.opt_state, batch)
        self._sync_buffers()
        self.version += 1
        self.handle.publish(self.version)
        out = {k: float(v) for k, v in metrics.items()}
        out["mean_reward"] = float(rewards.mean())
        out["version"] = self.version
        self.metrics_log.append(out)
        return out

    def close(self) -> None:
        self.handle.close()
