"""Trip-count-aware HLO cost analyzer.

``compiled.cost_analysis()`` visits every computation **once**, so anything
inside a ``while`` body (i.e. every scanned layer) is undercounted by its
trip count — for a 61-layer scan that is a 61x error. This module re-derives
the roofline terms from ``compiled.as_text()`` honestly:

* parses the post-optimization HLO into computations + instructions,
* recovers each while loop's trip count from its condition computation
  (``compare(counter, constant), direction=LT/GT``),
* walks the call graph from ENTRY, multiplying by enclosing trip counts:
  - **dot FLOPs**: 2 * numel(result) * prod(contracting dims)  (MXU term)
  - **HBM bytes**: operand + result bytes of every materializing top-level
    instruction (fusions read inputs / write outputs once; aliasing ops —
    bitcast, tuple, get-tuple-element, parameter — are free)
  - **collective bytes** by kind (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), counted once per start/done pair.

All quantities are per-device (the module is the per-device SPMD program).
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_CALL_ATTR_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_ATTR_RE = re.compile(r"condition=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COMPARE_RE = re.compile(r"compare\(([^)]*)\),?\s*direction=(\w+)")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_ALIAS_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "opt-barrier", "iota",
}


def _split_shape_op(rest: str) -> Tuple[str, str]:
    """Split '"shape op(operands...)"' — the shape may be a tuple containing
    '/*index=k*/' comments, so scan for the matching close paren instead of
    regexing."""
    rest = rest.lstrip()
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    shape_txt = rest[: i + 1]
                    tail = rest[i + 1 :].lstrip()
                    op = tail.split("(")[0].strip() if "(" in tail else tail.split()[0] if tail else "unknown"
                    return shape_txt, op
        return rest, "unknown"
    parts = rest.split(None, 1)
    shape_txt = parts[0] if parts else ""
    tail = parts[1] if len(parts) > 1 else ""
    op = tail.split("(")[0].strip() if "(" in tail else (tail.split()[0] if tail else "unknown")
    return shape_txt, op


def _parse_shapes(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dims = tuple(int(d) for d in m.group(2).split(",")) if m.group(2) else ()
        out.append((m.group(1), dims))
    return out


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _parse_shapes(text):
        total += math.prod(dims) * _DTYPE_BYTES[dt]
    return total


def _numel(text: str) -> int:
    return sum(math.prod(dims) for _, dims in _parse_shapes(text))


@dataclasses.dataclass
class Instr:
    name: str
    shape_txt: str  # result shape text
    op: str
    body: str  # full remainder (operands + attrs)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    symbols: Dict[str, str]  # instr name -> result shape text


def _header_name(line: str) -> Optional[str]:
    """Computation header: '[ENTRY] %name (params...) -> ret {'. Params may
    contain nested tuple parens, so take the first token, not a regex over
    the parameter list."""
    s = line.strip()
    if not s.endswith("{"):
        return None
    if s.startswith("ENTRY "):
        s = s[len("ENTRY ") :]
    if not s.startswith("%") and not s[:1].isalpha():
        return None
    tok = s.split(None, 1)[0]
    name = tok.lstrip("%")
    # instruction lines never reach here (they start with whitespace)
    if not name or "=" in name:
        return None
    return name.split("(")[0]


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            if line[:1].isspace():
                continue
            name = _header_name(line)
            if name:
                cur = Computation(name, [], {})
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        shape_txt, op = _split_shape_op(rest)
        instr = Instr(name, shape_txt, op, rest)
        cur.instrs.append(instr)
        cur.symbols[name] = shape_txt
    return comps


def _trip_count(cond: Computation, comps: Dict[str, Computation]) -> Optional[int]:
    """Trip count from a scan-style condition: compare(counter, const).

    The compare itself is often hidden in a fused computation
    (``ROOT ... = pred[] fusion(%counter, %constant), calls=...``), so we
    accept any constant that feeds a compare directly OR feeds the ROOT
    instruction of the condition."""
    consts: Dict[str, int] = {}
    for ins in cond.instrs:
        cm = _CONST_RE.search(ins.body)
        if cm and ins.op == "constant":
            consts[ins.name] = int(cm.group(1))
    for ins in cond.instrs:
        m = _COMPARE_RE.search(ins.body)
        if not m:
            continue
        ops = _OPERAND_RE.findall(m.group(1))
        for o in ops:
            if o in consts:
                return consts[o]
    if cond.instrs:
        root_ops = _OPERAND_RE.findall(cond.instrs[-1].body)
        for o in root_ops:
            if o in consts:
                return consts[o]
    if len(consts) == 1:
        return next(iter(consts.values()))
    return None


def _dot_flops(ins: Instr, comp: Computation) -> float:
    result_elems = _numel(ins.shape_txt)
    cm = _CONTRACT_RE.search(ins.body)
    contracting = [int(x) for x in cm.group(1).split(",")] if cm and cm.group(1) else []
    # lhs operand: first %ref inside the parens
    paren = ins.body[ins.body.index("(") + 1 :]
    ops = _OPERAND_RE.findall(paren)
    k = 1
    if ops:
        lhs_shape = comp.symbols.get(ops[0])
        if lhs_shape:
            shapes = _parse_shapes(lhs_shape)
            if shapes:
                dims = shapes[0][1]
                for c in contracting:
                    if c < len(dims):
                        k *= dims[c]
    return 2.0 * result_elems * k


@dataclasses.dataclass
class HloCosts:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES}
    )
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES}
    )
    unknown_trip_counts: int = 0

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(text: str, *, default_trip: int = 1) -> HloCosts:
    comps = parse_hlo(text)
    costs = HloCosts()
    entry = None
    for raw in text.splitlines():
        if raw.startswith("ENTRY"):
            m = _COMP_HEADER_RE.match(raw.strip())
            if m:
                entry = m.group(1)
            break
    if entry is None:  # fall back to the last computation
        entry = next(reversed(comps)) if comps else None
    if entry is None:
        return costs

    def walk(comp_name: str, mult: float, in_fusion: bool) -> None:
        comp = comps.get(comp_name)
        if comp is None:
            return
        for ins in comp.instrs:
            op = ins.op
            if op == "while":
                cond_m = _COND_ATTR_RE.search(ins.body)
                body_m = _CALL_ATTR_RE.search(ins.body)
                trip = None
                if cond_m and cond_m.group(1) in comps:
                    trip = _trip_count(comps[cond_m.group(1)], comps)
                if trip is None:
                    trip = default_trip
                    costs.unknown_trip_counts += 1
                if body_m:
                    walk(body_m.group(1), mult * trip, in_fusion)
                continue
            if op == "conditional":
                for called in _CALL_ATTR_RE.findall(ins.body):
                    walk(called, mult, in_fusion)
                continue
            if op in ("fusion", "call", "async-start"):
                cm = _CALL_ATTR_RE.search(ins.body)
                if cm:
                    walk(cm.group(1), mult, in_fusion=(op == "fusion") or in_fusion)
                if not in_fusion and op != "call":
                    costs.hbm_bytes += mult * _instr_bytes(ins, comp)
                continue
            if op == "dot":
                costs.dot_flops += mult * _dot_flops(ins, comp)
                if not in_fusion:
                    costs.hbm_bytes += mult * _instr_bytes(ins, comp)
                continue
            base = op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES:
                if op.endswith("-done"):
                    continue
                costs.collective_bytes[base] += mult * _shape_bytes(ins.shape_txt)
                costs.collective_counts[base] += mult
                if not in_fusion:
                    costs.hbm_bytes += mult * _instr_bytes(ins, comp)
                continue
            if not in_fusion and op not in _ALIAS_OPS:
                costs.hbm_bytes += mult * _instr_bytes(ins, comp)

    def _sliced_operand_bytes(called_name: str, param_idx: int, full: int) -> int:
        """If the fused computation consumes parameter `param_idx` ONLY
        through dynamic-slice / dynamic-update-slice, the real traffic is
        the slice, not the full (often loop-invariant, scan-xs) buffer.
        Without this, a 4096-step sLSTM scan was charged 4.3 GB x 4096
        per layer for reading one timestep per iteration."""
        called = comps.get(called_name)
        if called is None:
            return full
        params = [i for i in called.instrs if i.op == "parameter"]
        if param_idx >= len(params):
            return full
        aliases = {params[param_idx].name}
        _VIEW_OPS = {"bitcast", "reshape", "copy", "transpose", "convert"}
        slice_bytes = 0
        for i2 in called.instrs:
            if i2.op == "parameter":
                continue
            refs = _OPERAND_RE.findall(i2.body[i2.body.index("(") + 1 :]) if "(" in i2.body else []
            hit = [r for r in refs if r in aliases]
            if not hit:
                continue
            if i2.op == "dynamic-slice":
                slice_bytes += 2 * _shape_bytes(i2.shape_txt)
            elif i2.op == "dynamic-update-slice":
                upd = called.symbols.get(refs[1], i2.shape_txt) if len(refs) > 1 else i2.shape_txt
                slice_bytes += 2 * _shape_bytes(upd)
                aliases.add(i2.name)  # result aliases the buffer
            elif i2.op in _VIEW_OPS:
                aliases.add(i2.name)  # view: keep following
            else:
                return full  # real compute touches the whole buffer
        return min(slice_bytes, full) if slice_bytes else full

    def _instr_bytes(ins: Instr, comp: Computation) -> int:
        paren = ins.body[ins.body.index("(") + 1 :] if "(" in ins.body else ""
        # operands end at the matching close paren; regex over the segment
        # before attribute keywords is good enough for byte accounting
        seg = paren.split("), ")[0] if "), " in paren else paren
        names = _OPERAND_RE.findall(seg)
        operands = [comp.symbols.get(o) for o in names]
        if ins.op == "dynamic-update-slice":
            # in-place update: traffic is the slice (read+write), not the
            # full carried buffer XLA aliases
            upd = operands[1] if len(operands) > 1 and operands[1] else ins.shape_txt
            return 2 * _shape_bytes(upd)
        if ins.op == "dynamic-slice":
            return 2 * _shape_bytes(ins.shape_txt)
        total = _shape_bytes(ins.shape_txt)
        called_m = _CALL_ATTR_RE.search(ins.body) if ins.op == "fusion" else None
        for idx, s in enumerate(operands):
            if not s:
                continue
            b = _shape_bytes(s)
            if called_m is not None and b > 4 * _shape_bytes(ins.shape_txt):
                b = _sliced_operand_bytes(called_m.group(1), idx, b)
            total += b
        return total

    walk(entry, 1.0, in_fusion=False)
    return costs
