"""Extract roofline terms from a compiled dry-run artifact.

``cost_analysis()`` gives HLO FLOPs and bytes for the *per-device*
partitioned program. Collective bytes are not in cost_analysis: we parse
the post-optimization HLO text and sum the operand bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
instruction (per-device shard sizes, since the module is per-device).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.transfer.hardware import TPU

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

#: matches e.g. "bf16[16,512,128]{2,1,0}" or "f32[128]"
_SHAPE_RE = re.compile(r"\b(pred|[suf]\d+|bf16|f16|c64|c128)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"  # result shape (maybe a tuple)
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum per-device result-shape bytes of collective ops.

    ``-done`` ops are skipped so async (start/done) pairs count once.
    """
    bytes_by_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    count_by_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _INSTR_RE.search(line)
        if not m:
            continue
        shape_txt, kind = m.group(1), m.group(2)
        bytes_by_kind[kind] += _shape_bytes(shape_txt)
        count_by_kind[kind] += 1
    return CollectiveStats(bytes_by_kind, count_by_kind)


@dataclasses.dataclass
class Roofline:
    """Three-term roofline for one (arch x shape x mesh) cell.

    All terms are seconds for one step, computed from per-device quantities
    (equivalently: global quantity / (chips * per-chip rate))."""

    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    chips: int
    collectives: CollectiveStats
    peak_memory_per_device: Optional[float] = None

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / TPU.peak_flops_bf16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_device / TPU.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / TPU.ici_bw

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def model_flops_fraction(self, model_flops_global: float) -> float:
        """MODEL_FLOPS / HLO_FLOPs: how much compiled compute is useful."""
        hlo_global = self.flops_per_device * self.chips
        return model_flops_global / hlo_global if hlo_global else 0.0

    def roofline_fraction(self, model_flops_global: float) -> float:
        """Useful-compute time / achievable step time: the score we report."""
        useful_s = model_flops_global / (self.chips * TPU.peak_flops_bf16)
        return useful_s / self.bound_s if self.bound_s else 0.0


def roofline_from_compiled(compiled, chips: int) -> Roofline:
    """Derive the three terms from the compiled per-device program.

    Uses the trip-count-aware analyzer (``repro.launch.hlo_analyzer``):
    XLA's built-in ``cost_analysis()`` visits while bodies once, which
    undercounts every scanned layer by the layer count.
    """
    from repro.launch.hlo_analyzer import analyze

    text = compiled.as_text()
    costs = analyze(text)
    stats = CollectiveStats(
        bytes_by_kind={k: int(v) for k, v in costs.collective_bytes.items()},
        count_by_kind={k: int(v) for k, v in costs.collective_counts.items()},
    )
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = float(getattr(ma, "temp_size_in_bytes", 0) or 0) + float(
            getattr(ma, "argument_size_in_bytes", 0) or 0
        ) + float(getattr(ma, "output_size_in_bytes", 0) or 0)
    except Exception:  # noqa: BLE001 - memory analysis optional on CPU
        pass
    return Roofline(
        flops_per_device=costs.dot_flops,
        hbm_bytes_per_device=costs.hbm_bytes,
        collective_bytes_per_device=float(costs.total_collective_bytes),
        chips=chips,
        collectives=stats,
        peak_memory_per_device=mem,
    )


def model_flops(cfg, case, model=None) -> float:
    """MODEL_FLOPS: 6*N*D for train (N = active params, D = global tokens);
    2*N*D for forward-only prefill/decode."""
    from repro.models import active_param_count, build_model

    model = model or build_model(cfg)
    n_active = active_param_count(cfg, model)
    if case.kind == "train":
        tokens = case.global_batch * case.seq_len
        return 6.0 * n_active * tokens
    if case.kind == "prefill":
        tokens = case.global_batch * case.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * case.global_batch
