"""Serving driver: batched prefill + decode against a rollout-style worker.

Demonstrates the serve path (the rollout side of the paper's loop) with
real compute on a reduced config; weight versions can be pulled live from
a TensorHub reference server while requests are in flight (Fig. 4b).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --requests 8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.rl.loop import sample_responses


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3-8b")
    ap.add_argument("--requests", type=int, default=8, help="batch of requests")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if cfg.encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode path to serve")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed), jnp.float32)

    rng = np.random.default_rng(args.seed)
    for rnd in range(args.rounds):
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab, size=(args.requests, args.prompt_len)), jnp.int32
        )
        t0 = time.time()
        seqs, lps = sample_responses(
            model, params, prompts, args.gen_len, jax.random.PRNGKey(rnd)
        )
        dt = time.time() - t0
        toks = args.requests * args.gen_len
        print(
            f"round {rnd}: {args.requests} requests x {args.gen_len} new tokens "
            f"in {dt:.2f}s ({toks/dt:.1f} tok/s), mean logprob "
            f"{float(jnp.mean(lps)):.3f}"
        )


if __name__ == "__main__":
    main()
