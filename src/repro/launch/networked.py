"""Networked deployment demo: one controller + N worker processes.

``python -m repro.launch.networked`` spawns the real multi-process
topology over localhost sockets: a WAL-backed controller
(``repro.net.controller``) plus ``--workers`` worker processes. Worker
rank 0 publishes a deterministically-seeded model; every other rank
replicates it over the socketed data plane and prints a SHA-256 digest
of its received bytes — all ranks printing the same digest is the
demo's proof of byte-identical delivery.

This is the user-facing wrapper; the subprocess test tier
(``tests/test_networked.py``) drives the same processes directly through
``tests/procs.py`` with kill/restart choreography on top.
"""

from __future__ import annotations

import argparse
import hashlib
import os
import subprocess
import sys
import tempfile
import time
from typing import Optional


def _worker_main(args: argparse.Namespace) -> int:
    # imports deferred: the parent (spawner) role needs none of them
    import numpy as np

    from repro.net.worker import NetWorker

    rank = args.rank
    rng = np.random.default_rng(1234)  # same stream every rank: rank 0
    # publishes exactly what the others expect to receive
    weights = {
        f"layer{i}": rng.standard_normal(
            (args.dim, args.dim), dtype=np.float32
        )
        for i in range(args.tensors)
    }
    digest_src = hashlib.sha256(
        b"".join(weights[k].tobytes() for k in sorted(weights))
    ).hexdigest()

    worker = NetWorker(f"worker{rank}", addr_file=args.addr_file)
    try:
        if rank == 0:
            h = worker.open("demo", "trainer", 1, 0)
            h.register(weights)
            h.publish(0)
            print(f"rank0 published v0 digest={digest_src}", flush=True)
            time.sleep(args.linger)  # keep serving until readers finish
        else:
            zeros = {k: np.zeros_like(v) for k, v in weights.items()}
            h = worker.open("demo", f"rollout{rank}", 1, 0)
            h.register(zeros)
            h.replicate(0)
            got = hashlib.sha256(
                b"".join(h.store.get(k).tobytes() for k in sorted(weights))
            ).hexdigest()
            status = "MATCH" if got == digest_src else "MISMATCH"
            print(f"rank{rank} replicated v0 digest={got} {status}", flush=True)
            return 0 if got == digest_src else 1
    finally:
        worker.close()
    return 0


def main(argv: Optional[list] = None) -> int:
    p = argparse.ArgumentParser(description="TensorHub networked demo")
    p.add_argument("--workers", type=int, default=3,
                   help="total worker processes (rank 0 publishes)")
    p.add_argument("--tensors", type=int, default=4)
    p.add_argument("--dim", type=int, default=256)
    p.add_argument("--heartbeat-timeout", type=float, default=5.0)
    p.add_argument("--run-dir", default=None,
                   help="WAL + address file directory (default: a tempdir)")
    p.add_argument("--linger", type=float, default=20.0,
                   help="seconds rank 0 keeps serving after publishing")
    # internal: worker-role reentry
    p.add_argument("--role", choices=("spawner", "worker"), default="spawner")
    p.add_argument("--rank", type=int, default=0)
    p.add_argument("--addr-file", default=None)
    args = p.parse_args(argv)

    if args.role == "worker":
        return _worker_main(args)

    run_dir = args.run_dir or tempfile.mkdtemp(prefix="tensorhub-net-")
    addr_file = os.path.join(run_dir, "controller.addr")
    wal = os.path.join(run_dir, "controller.wal")
    controller = subprocess.Popen(
        [
            sys.executable, "-m", "repro.net.controller",
            "--addr-file", addr_file, "--wal", wal,
            "--heartbeat-timeout", str(args.heartbeat_timeout),
        ],
    )
    print(f"controller pid={controller.pid} run_dir={run_dir}", flush=True)
    workers = []
    try:
        common = [
            sys.executable, "-m", "repro.launch.networked",
            "--role", "worker", "--addr-file", addr_file,
            "--tensors", str(args.tensors), "--dim", str(args.dim),
            "--linger", str(args.linger),
        ]
        workers.append(subprocess.Popen(common + ["--rank", "0"]))
        time.sleep(0.5)  # let the publish land before readers race it
        for rank in range(1, args.workers):
            workers.append(subprocess.Popen(common + ["--rank", str(rank)]))
        rc = 0
        for w in workers[1:]:
            rc |= w.wait()
        workers[0].terminate()
        workers[0].wait()
        return rc
    finally:
        for w in workers:
            if w.poll() is None:
                w.kill()
        controller.terminate()
        controller.wait()


if __name__ == "__main__":
    sys.exit(main())
