"""Dry-run cell construction: (arch x shape x mesh) -> lowerable step fn +
fully-sharded abstract inputs.

Shared by ``dryrun.py`` (lower/compile proof + stats) and
``benchmarks/roofline.py`` (three-term analysis). No real arrays are ever
created here — everything is ShapeDtypeStructs + NamedShardings.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs import SHAPES, get_config
from repro.configs.base import AUDIO, HYBRID, SSM, VLM, ModelConfig, ShapeCase
from repro.models import abstract_tree, build_model
from repro.models.params import ParamSpec
from repro.sharding import Rules, rules_for, spec_for, tree_shardings
from repro.training import AdamW, AdamWState, make_decode_step, make_prefill_step, make_train_step

#: logical axes of the batch inputs, by key
_INPUT_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "targets": ("batch", "seq"),
    "mask": ("batch", "seq"),
    "frames": ("batch", "seq", None),
    "patches": ("batch", None, "act_embed"),
}


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    cfg: ModelConfig
    case: ShapeCase
    step_fn: Callable
    in_structs: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    kind: str  # "train" | "prefill" | "decode"
    opt: Dict[str, Any] = dataclasses.field(default_factory=dict)
    #: explicit output shardings: pins the propagation search, which
    #: otherwise can blow up on deeply-scanned cache outputs at 512 parts
    out_shardings: Any = None

    def lower(self, mesh: jax.sharding.Mesh):
        from repro.models import optim

        kw = {}
        if self.out_shardings is not None:
            kw["out_shardings"] = self.out_shardings
        jitted = jax.jit(self.step_fn, in_shardings=self.in_shardings, **kw)
        with mesh, optim.optimizations(mesh=mesh, **self.opt):
            return jitted.lower(*self.in_structs)


def _input_shardings(batch_structs: Dict[str, Any], rules: Rules, mesh) -> Dict[str, Any]:
    out = {}
    for name, st in batch_structs.items():
        axes = _INPUT_AXES[name]
        out[name] = NamedSharding(mesh, spec_for(st.shape, axes[: len(st.shape)], rules, mesh))
    return out


def _cache_dtype(cfg: ModelConfig) -> Any:
    # decoder KV caches in bf16; recurrent/SSM states stay f32
    return jnp.float32 if cfg.family in (HYBRID, SSM) else jnp.bfloat16


def build_cell(
    arch: str,
    shape: str,
    mesh: jax.sharding.Mesh,
    *,
    param_dtype: Any = jnp.bfloat16,
    opt_state_dtype: Any = jnp.float32,
    rules: Optional[Rules] = None,
    opt: Optional[Dict[str, Any]] = None,
) -> Cell:
    cfg = get_config(arch)
    case = SHAPES[shape]
    model = build_model(cfg)
    rules = rules or rules_for(case.kind, global_batch=case.global_batch)
    opt = opt or {}

    pspecs = model.param_specs()
    params_structs = abstract_tree(pspecs, param_dtype)
    params_sh = tree_shardings(pspecs, rules, mesh)
    batch_structs = model.input_specs(case)
    batch_sh = _input_shardings(batch_structs, rules, mesh)
    scalar_sh = NamedSharding(mesh, PartitionSpec())

    if case.kind == "train":
        optimizer = AdamW(state_dtype=opt_state_dtype)
        step = make_train_step(model, cfg, optimizer)
        opt_structs = AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            mu=abstract_tree(pspecs, opt_state_dtype),
            nu=abstract_tree(pspecs, opt_state_dtype),
        )
        opt_sh = AdamWState(
            step=scalar_sh,
            mu=tree_shardings(pspecs, rules, mesh),
            nu=tree_shardings(pspecs, rules, mesh),
        )
        return Cell(
            arch, shape, cfg, case, step,
            (params_structs, opt_structs, batch_structs),
            (params_sh, opt_sh, batch_sh),
            "train",
            opt=opt,
            out_shardings=(params_sh, opt_sh, scalar_sh),
        )

    def _logits_sharding(seq_dim: bool) -> NamedSharding:
        axes = ("batch", "seq" if seq_dim else None, "vocab")
        shape_ = (case.global_batch, case.seq_len if seq_dim else 1, cfg.vocab)
        return NamedSharding(mesh, spec_for(shape_, axes, rules, mesh))

    if case.kind == "prefill" or cfg.encoder_only:
        if cfg.encoder_only:
            # encoder "prefill" = full encode (logits only)
            def encode_step(params, batch):
                return model.forward(params, batch)

            return Cell(
                arch, shape, cfg, case, encode_step,
                (params_structs, batch_structs),
                (params_sh, batch_sh),
                "prefill",
                opt=opt,
                out_shardings=_logits_sharding(seq_dim=True),
            )
        step = make_prefill_step(model)
        prefill_cache_specs = model.cache_specs(case.global_batch, case.seq_len)
        prefill_cache_sh = tree_shardings(prefill_cache_specs, rules, mesh)
        return Cell(
            arch, shape, cfg, case, step,
            (params_structs, batch_structs),
            (params_sh, batch_sh),
            "prefill",
            opt=opt,
            out_shardings=(_logits_sharding(False), prefill_cache_sh, scalar_sh),
        )

    # decode: one new token against a cache of ~seq_len
    ring = case.name == "long_500k" and cfg.family == HYBRID
    cache_specs = model.cache_specs(case.global_batch, case.seq_len, ring=ring)
    cache_structs = abstract_tree(cache_specs, _cache_dtype(cfg))
    cache_sh = tree_shardings(cache_specs, rules, mesh)
    step = make_decode_step(model, ring=ring)
    tok_struct = jax.ShapeDtypeStruct((case.global_batch, 1), jnp.int32)
    tok_sh = NamedSharding(mesh, spec_for(tok_struct.shape, ("batch", None), rules, mesh))
    len_struct = jax.ShapeDtypeStruct((), jnp.int32)
    return Cell(
        arch, shape, cfg, case, step,
        (params_structs, cache_structs, tok_struct, len_struct),
        (params_sh, cache_sh, tok_sh, scalar_sh),
        "decode",
        opt=opt,
        out_shardings=(_logits_sharding(False), cache_sh),
    )


def live_cells() -> Tuple[Tuple[str, str], ...]:
    """All live (arch, shape) pairs per the DESIGN.md skip table."""
    from repro.configs import ARCH_IDS, live_shapes

    out = []
    for arch in ARCH_IDS:
        for shape in live_shapes(get_config(arch)):
            out.append((arch, shape))
    return tuple(out)
