"""Launchers: mesh construction, the multi-pod dry-run, and the real
train/serve drivers. ``dryrun`` must be the process entry point when used
(it fakes 512 host devices before jax initializes)."""

from repro.launch.mesh import make_production_mesh, make_smoke_mesh, mesh_num_devices

__all__ = ["make_production_mesh", "make_smoke_mesh", "mesh_num_devices"]
