"""Training driver: real compute on the available devices.

Runs an arch (reduced config by default — the full configs are exercised
via the dry-run) against the synthetic bigram stream, with checkpointing,
restart-recovery and optional TensorHub publishing of every step's weights
(the co-located Fig. 4a pattern).

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --steps 50
    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --steps 50 \
        --resume --ckpt-dir /tmp/ckpt   # restart from the latest checkpoint
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt_lib
from repro.configs import ARCH_IDS, get_config
from repro.configs.base import AUDIO
from repro.data.synthetic import BigramStream, audio_batch
from repro.models import build_model, named_tensors
from repro.training import AdamW, cosine_schedule, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3-8b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full published config (needs a real pod)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--publish", action="store_true",
                    help="publish every version into a local TensorHub")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.reduced()
    model = build_model(cfg)
    opt = AdamW(lr=args.lr, schedule=cosine_schedule(10, args.steps), weight_decay=0.01)
    train_step = jax.jit(make_train_step(model, cfg, opt, accum=args.accum))

    params = model.init(jax.random.PRNGKey(args.seed), jnp.float32)
    opt_state = opt.init(params)
    start_step = 0
    stream = BigramStream(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch, seed=args.seed)

    if args.resume and args.ckpt_dir:
        latest = ckpt_lib.latest_step(args.ckpt_dir)
        if latest is not None:
            (params, opt_state), start_step, meta = ckpt_lib.restore(
                args.ckpt_dir, (params, opt_state)
            )
            stream.offset = meta.get("stream_offset", start_step)
            print(f"resumed from step {start_step} (stream offset {stream.offset})")

    hub_handle = None
    if args.publish:
        from repro.core import ReferenceServer, TensorHubClient

        hub = TensorHubClient(ReferenceServer())
        hub_handle = hub.open("train-model", "trainer-0", num_shards=1, shard_idx=0,
                              retain="latest")
        buffers = {k: np.array(v) for k, v in named_tensors(params).items()}
        hub_handle.register(buffers)
        hub_handle.publish(start_step)

    t0 = time.time()
    for step in range(start_step, args.steps):
        if cfg.family == AUDIO:
            batch = {k: jnp.asarray(v) for k, v in audio_batch(
                args.batch, args.seq, cfg.frontend_dim, cfg.vocab, args.seed * 100_003 + step
            ).items()}
        else:
            b = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
            if cfg.frontend == "vision":
                b["patches"] = jnp.zeros((args.batch, cfg.num_patches, cfg.d_model), jnp.float32)
                b["tokens"] = b["tokens"][:, : args.seq - cfg.num_patches]
            batch = b
        params, opt_state, metrics = train_step(params, opt_state, batch)
        if hub_handle is not None:
            hub_handle.unpublish()
            for k, v in named_tensors(params).items():
                np.copyto(hub_handle.store.get(k), np.asarray(v))
            hub_handle.publish(step + 1)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"acc {float(metrics['accuracy']):.3f} "
                  f"({(time.time()-t0):.1f}s)")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            path = ckpt_lib.save(
                args.ckpt_dir, step + 1, (params, opt_state),
                metadata={"stream_offset": stream.offset},
            )
            print(f"checkpointed -> {path}")
    if hub_handle is not None:
        hub_handle.close()


if __name__ == "__main__":
    main()
