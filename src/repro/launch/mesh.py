"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state (device count is locked on first jax init, and only
``dryrun.py`` may set the 512-device XLA flag before that happens).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; the multi-pod mesh adds a leading 2-pod
    axis (2x16x16 = 512 chips). ``pod`` composes with ``data`` as the outer
    data-parallel/FSDP dimension (DESIGN.md 5)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh() -> jax.sharding.Mesh:
    """1x1 mesh over the single real device (smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_num_devices(mesh: jax.sharding.Mesh) -> int:
    return int(mesh.devices.size)
