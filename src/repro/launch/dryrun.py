import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input shape) cell lowers
AND compiles on the production meshes (16x16 single-pod, 2x16x16 multi-pod)
with coherent shardings — no real allocation, ShapeDtypeStructs only.

The two os.environ lines above MUST stay the first statements: jax locks
the device count on first init, and only this entry point may fake 512
host devices (smoke tests and benchmarks see the 1 real device).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config, live_shapes
from repro.launch.cells import build_cell, live_cells
from repro.launch.hlo_stats import model_flops, roofline_from_compiled
from repro.launch.mesh import make_production_mesh, mesh_num_devices


def run_cell(
    arch: str, shape: str, *, multi_pod: bool, verbose: bool = True, opt: dict | None = None
) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_num_devices(mesh)
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, opt=opt)
    lowered = cell.lower(mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    roof = roofline_from_compiled(compiled, chips)
    mf = model_flops(cell.cfg, cell.case)
    mem = compiled.memory_analysis()
    result = {
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "opt": opt or {},
        "kind": cell.kind,
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_per_device": roof.flops_per_device,
        "hbm_bytes_per_device": roof.hbm_bytes_per_device,
        "collective_bytes_per_device": roof.collective_bytes_per_device,
        "collective_counts": roof.collectives.count_by_kind,
        "collective_bytes": roof.collectives.bytes_by_kind,
        "compute_s": roof.compute_s,
        "memory_s": roof.memory_s,
        "collective_s": roof.collective_s,
        "dominant": roof.dominant,
        "model_flops": mf,
        "model_flops_fraction": roof.model_flops_fraction(mf),
        "roofline_fraction": roof.roofline_fraction(mf),
        "memory_analysis": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
    }
    if verbose:
        print(f"[{result['mesh']}] {arch} x {shape} ({cell.kind}): "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s")
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis: flops/dev={roof.flops_per_device:.3e} "
              f"hbm B/dev={roof.hbm_bytes_per_device:.3e} "
              f"collective B/dev={roof.collective_bytes_per_device:.3e}")
        print(f"  roofline: compute {roof.compute_s*1e3:.1f}ms | memory "
              f"{roof.memory_s*1e3:.1f}ms | collective {roof.collective_s*1e3:.1f}ms "
              f"-> {roof.dominant}-bound; useful/HLO flops "
              f"{result['model_flops_fraction']:.2f}; roofline fraction "
              f"{result['roofline_fraction']:.2f}")
    return result


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true", help="run every live cell")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--out", default=None, help="directory for per-cell JSON results")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--opt", action="append", default=[],
                    help="optimization flags, e.g. --opt shard_attn_heads")
    args = ap.parse_args()
    opt = {name: True for name in args.opt}

    if args.all:
        cells = live_cells()
    else:
        if not args.arch:
            ap.error("--arch required unless --all")
        shapes = [args.shape] if args.shape else live_shapes(get_config(args.arch))
        cells = tuple((args.arch, s) for s in shapes)

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = []
    for arch, shape in cells:
        for multi in meshes:
            tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
            if opt:
                tag += "__" + "_".join(sorted(opt))
            out_path = os.path.join(args.out, tag + ".json") if args.out else None
            if out_path and args.skip_existing and os.path.exists(out_path):
                print(f"skip {tag} (exists)")
                continue
            try:
                result = run_cell(arch, shape, multi_pod=multi, opt=opt)
            except Exception as e:  # noqa: BLE001 - report and continue
                traceback.print_exc()
                result = {
                    "arch": arch, "shape": shape,
                    "mesh": "2x16x16" if multi else "16x16",
                    "ok": False, "error": f"{type(e).__name__}: {e}",
                }
                failures.append(tag)
            if out_path:
                os.makedirs(args.out, exist_ok=True)
                with open(out_path, "w") as f:
                    json.dump(result, f, indent=1)
    if failures:
        print(f"\nFAILED cells: {failures}")
        return 1
    print("\nall requested cells lowered + compiled OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
