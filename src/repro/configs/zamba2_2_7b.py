"""zamba2-2.7b — Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf].

The hybrid family: 54 Mamba2 (SSD) blocks with one *shared* attention+MLP
transformer block applied every ``shared_block_every`` SSM blocks (Zamba2
reuses the shared block's weights across its invocation points; its
per-invocation LoRA deltas are omitted — noted in DESIGN.md).
"""

from repro.configs.base import HYBRID, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family=HYBRID,
    num_layers=54,
    d_model=2_560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10_240,
    vocab=32_000,
    sliding_window=4_096,  # used by the shared block in long_500k mode
    ssm=SSMConfig(
        d_state=64,
        d_conv=4,
        expand=2,
        head_dim=64,
        chunk=256,
        shared_block_every=6,
    ),
    source="arXiv:2411.15242; hf",
)
