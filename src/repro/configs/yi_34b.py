"""yi-34b — dense llama-arch GQA [arXiv:2403.04652; hf]."""

from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family=DENSE,
    num_layers=60,
    d_model=7_168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20_480,
    vocab=64_000,
    rope_theta=5_000_000.0,
    source="arXiv:2403.04652; hf",
)
