"""gemma2-2b — local+global alternating attention, logit softcaps
[arXiv:2408.00118; hf]."""

from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family=DENSE,
    num_layers=26,
    d_model=2_304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,  # gemma2: head_dim independent of d_model/heads
    d_ff=9_216,
    vocab=256_000,
    sliding_window=4_096,
    alt_local_global=True,  # even layers sliding-window, odd layers global
    attn_softcap=50.0,
    logit_softcap=30.0,
    tie_embeddings=True,
    source="arXiv:2408.00118; hf",
)
