"""deepseek-v3-671b — MLA, 1 shared + 256 routed experts top-8
[arXiv:2412.19437; hf].

MTP (multi-token prediction) is a training-objective detail orthogonal to
weight transfer and roofline fidelity; omitted and noted in DESIGN.md.
"""

from repro.configs.base import MOE, MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family=MOE,
    num_layers=61,
    d_model=7_168,
    num_heads=128,
    num_kv_heads=128,  # MLA: KV latent is shared; head count for Q/V heads
    d_ff=2_048,  # per routed expert (fine-grained)
    vocab=129_280,
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        d_expert=2_048,
        num_shared=1,
        first_dense=3,  # layers 0-2 use a dense FFN
        d_ff_dense=18_432,
    ),
    mla=MLAConfig(
        q_lora_rank=1_536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    source="arXiv:2412.19437; hf",
)
