"""hubert-xlarge — encoder-only audio transformer (w2v2 arch)
[arXiv:2106.07447; unverified].

Per the task spec the conv feature extractor is a stub: ``input_specs()``
provides precomputed frame embeddings; a linear projection maps them into
d_model. Encoder-only: no decode shapes. ``vocab``=504 is the masked-
prediction codebook size.
"""

from repro.configs.base import AUDIO, ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family=AUDIO,
    num_layers=48,
    d_model=1_280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5_120,
    vocab=504,
    encoder_only=True,
    frontend="audio",
    frontend_dim=512,  # conv feature-extractor output dim (stubbed)
    source="arXiv:2106.07447; unverified",
)
