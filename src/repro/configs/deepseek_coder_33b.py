"""deepseek-coder-33b — dense llama-arch [arXiv:2401.14196; hf]."""

from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family=DENSE,
    num_layers=62,
    d_model=7_168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=19_200,
    vocab=32_256,
    rope_theta=100_000.0,
    source="arXiv:2401.14196; hf",
)
