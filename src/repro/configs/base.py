"""Model configuration schema for the assigned architecture pool.

One ``ModelConfig`` fully determines a model: the family dispatches to the
right block implementation in ``repro.models``; the numeric fields are the
exact published configs (sources in each ``configs/<id>.py``).

``reduced()`` produces a tiny same-family config for CPU smoke tests; the
full configs are only ever lowered via ShapeDtypeStructs in the dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

# Families (dispatch keys for repro.models)
DENSE = "dense"
MOE = "moe"
HYBRID = "hybrid"  # Mamba2 + shared attention (zamba2)
SSM = "ssm"  # xLSTM
AUDIO = "audio"  # encoder-only transformer, audio frontend stub
VLM = "vlm"  # decoder LM + vision frontend stub


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int  # per-expert FFN hidden size
    num_shared: int = 0  # always-on shared experts (deepseek-v3: 1)
    #: layers [0, first_dense) use a dense FFN instead of MoE (deepseek-v3: 3)
    first_dense: int = 0
    d_ff_dense: int = 0  # hidden size of those dense layers
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (deepseek-v3, arXiv:2412.19437)."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block parameters."""

    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64  # SSD head size P
    chunk: int = 256  # SSD chunk length for the training-time scan
    #: hybrid (zamba2): apply the shared attention block every k SSM blocks
    shared_block_every: int = 6


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM (arXiv:2405.04517): alternating mLSTM / sLSTM blocks."""

    proj_factor: float = 2.0  # mLSTM up-projection factor
    slstm_every: int = 2  # every k-th block is sLSTM (rest mLSTM)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # attention extras
    rope_theta: float = 10000.0
    sliding_window: int = 0  # gemma2 local layers / zamba2 long mode
    alt_local_global: bool = False  # gemma2: even layers local, odd global
    attn_softcap: float = 0.0  # gemma2: 50.0
    logit_softcap: float = 0.0  # gemma2: 30.0
    encoder_only: bool = False  # hubert: bidirectional, no decode
    tie_embeddings: bool = False
    # frontend stubs (per task spec: modality frontends are precomputed)
    frontend: str = "none"  # "none" | "audio" | "vision"
    frontend_dim: int = 0  # audio frame feature dim
    num_patches: int = 0  # vision patch count prepended to the text seq
    # family sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # bookkeeping
    source: str = ""  # provenance tag from the assignment table

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    # -- parameter counting (for MODEL_FLOPS = 6*N*D roofline terms) --------

    def param_count(self) -> int:
        """Total parameters (embedding included)."""
        return _count_params(self, active_only=False)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: shared + top_k experts)."""
        return _count_params(self, active_only=True)

    # -- reductions for smoke tests -----------------------------------------

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config: small widths, few layers/experts, small
        vocab. Keeps every structural feature (GQA ratio, MoE, MLA, softcaps,
        alternating windows, SSM, frontend stubs) so the smoke test exercises
        the same code path as the full config."""
        heads = max(2, min(self.num_heads, 4))
        kv = max(1, min(self.num_kv_heads, heads))
        if heads % kv:
            kv = 1
        layers = min(self.num_layers, 4)
        if self.family == HYBRID and self.ssm is not None:
            # keep >= one shared-block hit
            layers = max(layers, min(self.ssm.shared_block_every + 1, 4))
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 8),
                top_k=min(self.moe.top_k, 2),
                d_expert=64,
                d_ff_dense=128 if self.moe.first_dense else 0,
                first_dense=min(self.moe.first_dense, 1),
            )
        mla = None
        if self.mla is not None:
            mla = MLAConfig(
                q_lora_rank=32,
                kv_lora_rank=16,
                qk_nope_head_dim=16,
                qk_rope_head_dim=8,
                v_head_dim=16,
            )
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk=16, shared_block_every=2
            )
        return dataclasses.replace(
            self,
            name=f"{self.name}-smoke",
            num_layers=layers,
            d_model=64,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=16 if self.head_dim else 0,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            sliding_window=8 if self.sliding_window else 0,
            frontend_dim=32 if self.frontend == "audio" else 0,
            num_patches=4 if self.frontend == "vision" else 0,
            moe=moe,
            mla=mla,
            ssm=ssm,
        )


def _count_params(cfg: ModelConfig, *, active_only: bool) -> int:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    n = 0
    # embeddings (+ untied output head)
    n += cfg.vocab * d
    if not cfg.encoder_only and not cfg.tie_embeddings:
        n += cfg.vocab * d
    if cfg.frontend == "audio":
        n += cfg.frontend_dim * d
    per_layer = 0
    # attention
    if cfg.mla is not None:
        m = cfg.mla
        qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
        per_attn = (
            d * m.q_lora_rank
            + m.q_lora_rank * cfg.num_heads * qk_head
            + d * (m.kv_lora_rank + m.qk_rope_head_dim)
            + m.kv_lora_rank * cfg.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
            + cfg.num_heads * m.v_head_dim * d
        )
    else:
        per_attn = (
            d * cfg.num_heads * hd
            + 2 * d * cfg.num_kv_heads * hd
            + cfg.num_heads * hd * d
        )
    # ffn
    def swiglu(h: int) -> int:
        return 3 * d * h

    if cfg.family == SSM and cfg.xlstm is not None:
        d_in = int(d * cfg.xlstm.proj_factor)
        # mLSTM block: up/gate/down projections + qkv + gates
        per_layer = 2 * d * d_in + d_in * d + 3 * d * d_in + 3 * d_in
        n += cfg.num_layers * per_layer
        return n
    if cfg.family == HYBRID and cfg.ssm is not None:
        s = cfg.ssm
        d_in = s.expand * d
        nheads = d_in // s.head_dim
        # in_proj produces (z, x, B, C, dt): d -> 2*d_in + 2*d_state + nheads
        per_ssm = d * (2 * d_in + 2 * s.d_state + nheads) + d_in * d + d_in * s.d_conv
        # one shared attention+MLP block, reused across the depth (zamba2)
        shared = per_attn + swiglu(cfg.d_ff)
        n += cfg.num_layers * per_ssm + shared
        return n
    ffn = 0
    if cfg.moe is not None:
        mo = cfg.moe
        router = d * mo.num_experts
        experts = mo.top_k if active_only else mo.num_experts
        moe_layers = cfg.num_layers - mo.first_dense
        n += moe_layers * (router + experts * swiglu(mo.d_expert) + mo.num_shared * swiglu(mo.d_expert))
        n += mo.first_dense * swiglu(mo.d_ff_dense)
        n += cfg.num_layers * per_attn
        return n
    ffn = swiglu(cfg.d_ff) if cfg.d_ff else 0
    if cfg.encoder_only:
        ffn = 2 * d * cfg.d_ff  # standard (non-gated) MLP in hubert/w2v2
    n += cfg.num_layers * (per_attn + ffn)
    return n


# ---------------------------------------------------------------------------
# Input-shape grid (LM-family: seq_len x global_batch)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCase] = {
    "train_4k": ShapeCase("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCase("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCase("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCase("long_500k", 524_288, 1, "decode"),
}

#: sub-quadratic families allowed to run long_500k (task spec)
LONG_OK_FAMILIES = (HYBRID, SSM)


def live_shapes(cfg: ModelConfig) -> Tuple[str, ...]:
    """The live cells of the 4-shape grid for one arch (skips per DESIGN.md
    4 'Shape-grid skips')."""
    out = ["train_4k", "prefill_32k"]
    if not cfg.encoder_only:
        out.append("decode_32k")
        if cfg.family in LONG_OK_FAMILIES:
            out.append("long_500k")
    return tuple(out)
