"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

d_ff=0 per the assignment: xLSTM blocks carry their own up/down projections
(proj_factor) instead of a separate FFN.
"""

from repro.configs.base import SSM, ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family=SSM,
    num_layers=24,
    d_model=1_024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab=50_304,
    xlstm=XLSTMConfig(proj_factor=2.0, slstm_every=2),
    source="arXiv:2405.04517; unverified",
)
