"""dbrx-132b — fine-grained MoE, 16 experts top-4
[hf:databricks/dbrx-base; unverified]."""

from repro.configs.base import MOE, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family=MOE,
    num_layers=40,
    d_model=6_144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10_752,  # per-expert FFN hidden
    vocab=100_352,
    rope_theta=500_000.0,
    moe=MoEConfig(num_experts=16, top_k=4, d_expert=10_752),
    source="hf:databricks/dbrx-base; unverified",
)
