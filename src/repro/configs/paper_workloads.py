"""Paper Table 3 — the weight-transfer training workloads.

These parameterize the benchmark harness (Fig 9/11/12): shard counts,
per-shard bytes, and GPU counts. The mocked 1T model duplicates the 260B
layout four times, exactly as the paper does (5.2).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List


@dataclasses.dataclass(frozen=True)
class TransferWorkload:
    name: str
    num_shards: int
    shard_gb: float
    trainer_gpus: int
    standalone_gpus: int

    @property
    def shard_bytes(self) -> int:
        return int(self.shard_gb * 1e9)

    def unit_bytes(self, num_units: int = 64) -> List[int]:
        """Split the shard into transfer units (post tiny-tensor compaction
        a real shard is a few dozen ~GB units)."""
        per = self.shard_bytes // num_units
        out = [per] * num_units
        out[-1] += self.shard_bytes - per * num_units
        return out

    @property
    def num_trainer_replicas(self) -> int:
        return self.trainer_gpus // self.num_shards

    @property
    def num_standalone_replicas(self) -> int:
        return self.standalone_gpus // self.num_shards


WORKLOADS: Dict[str, TransferWorkload] = {
    "9B": TransferWorkload("9B", num_shards=2, shard_gb=10.0, trainer_gpus=16, standalone_gpus=8),
    "36B": TransferWorkload("36B", num_shards=4, shard_gb=19.0, trainer_gpus=16, standalone_gpus=8),
    "260B": TransferWorkload("260B", num_shards=8, shard_gb=34.0, trainer_gpus=64, standalone_gpus=16),
    "1T": TransferWorkload("1T", num_shards=16, shard_gb=66.0, trainer_gpus=768, standalone_gpus=256),
}
