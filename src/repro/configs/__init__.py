"""Architecture registry: ``--arch <id>`` ids map to config modules
(dashes in public ids become underscores in module names)."""

from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.configs.base import (
    AUDIO,
    DENSE,
    HYBRID,
    LONG_OK_FAMILIES,
    MOE,
    SHAPES,
    SSM,
    VLM,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    ShapeCase,
    XLSTMConfig,
    live_shapes,
)

ARCH_IDS: Tuple[str, ...] = (
    "dbrx-132b",
    "deepseek-v3-671b",
    "llama3-8b",
    "deepseek-coder-33b",
    "gemma2-2b",
    "yi-34b",
    "internvl2-2b",
    "zamba2-2.7b",
    "xlstm-350m",
    "hubert-xlarge",
)

_cache: Dict[str, ModelConfig] = {}


def _module_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _cache:
        if arch_id not in ARCH_IDS:
            raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
        mod = importlib.import_module(f"repro.configs.{_module_name(arch_id)}")
        _cache[arch_id] = mod.CONFIG
    return _cache[arch_id]


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = [
    "ARCH_IDS",
    "AUDIO",
    "DENSE",
    "HYBRID",
    "LONG_OK_FAMILIES",
    "MLAConfig",
    "MOE",
    "ModelConfig",
    "MoEConfig",
    "SHAPES",
    "SSM",
    "SSMConfig",
    "ShapeCase",
    "VLM",
    "XLSTMConfig",
    "all_configs",
    "get_config",
    "live_shapes",
]
