"""internvl2-2b — InternViT + InternLM2 [arXiv:2404.16821; hf].

Per the task spec, the entry specifies the transformer BACKBONE only; the
vision frontend is a stub — ``input_specs()`` provides precomputed patch
embeddings that are prepended to the token sequence.
"""

from repro.configs.base import VLM, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family=VLM,
    num_layers=24,
    d_model=2_048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8_192,
    vocab=92_553,
    rope_theta=1_000_000.0,
    frontend="vision",
    num_patches=256,  # precomputed patch embeddings per image
    source="arXiv:2404.16821; hf",
)
