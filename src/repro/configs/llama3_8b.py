"""llama3-8b — dense GQA, 128k vocab [arXiv:2407.21783; unverified]."""

from repro.configs.base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family=DENSE,
    num_layers=32,
    d_model=4_096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab=128_256,
    rope_theta=500_000.0,
    source="arXiv:2407.21783; unverified",
)
