"""Export recorded telemetry: Chrome trace-event JSON and text timelines.

``write_chrome_trace`` emits the Trace Event Format (`"ph": "X"`
complete events, microsecond timestamps) understood by Perfetto
(https://ui.perfetto.dev) and chrome://tracing. ``render_timeline``
draws the same spans as an ASCII gantt for docs and terminals.
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.obs.telemetry import Recorder


def chrome_trace_events(recorder: Recorder, *, pid: str = "tensorhub") -> List[dict]:
    """Convert finished spans to Chrome trace events, sorted by ts.

    Timestamps are rebased to the earliest span so virtual-time and
    wall-clock traces both start near zero, then scaled to integer
    microseconds as the format requires.
    """
    spans = list(recorder.events)
    if not spans:
        return []
    origin = min(t0 for (_, _, t0, _, _, _) in spans)
    tids: Dict[str, int] = {}
    out: List[dict] = []
    for name, track, t0, t1, parent, attrs in spans:
        tid = tids.setdefault(track, len(tids) + 1)
        args = dict(attrs) if attrs else {}
        if parent is not None:
            args["parent"] = parent
        out.append({
            "ph": "X",
            "name": name,
            "pid": pid,
            "tid": tid,
            "ts": int(round((t0 - origin) * 1e6)),
            "dur": int(round((t1 - t0) * 1e6)),
            "args": args,
        })
    out.sort(key=lambda e: (e["ts"], e["dur"]))
    # Thread-name metadata first so viewers label tracks.
    meta = [
        {"ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
         "ts": 0, "args": {"name": track}}
        for track, tid in sorted(tids.items(), key=lambda kv: kv[1])
    ]
    return meta + out


def write_chrome_trace(recorder: Recorder, path: str, *, pid: str = "tensorhub") -> str:
    """Write a Perfetto-loadable trace file; returns ``path``."""
    doc = {
        "traceEvents": chrome_trace_events(recorder, pid=pid),
        "displayTimeUnit": "ms",
    }
    with open(path, "w") as fh:
        json.dump(doc, fh)
    return path


def render_timeline(recorder: Recorder, *, width: int = 64,
                    tracks: Optional[List[str]] = None) -> str:
    """ASCII gantt of recorded spans, one row per span, grouped by track.

    A screenshot-equivalent of the Perfetto view for docs/terminals:
    each row shows the span's extent within the trace window, its name
    and duration.
    """
    spans = list(recorder.events)
    if tracks is not None:
        keep = set(tracks)
        spans = [s for s in spans if s[1] in keep]
    if not spans:
        return "(no spans recorded)\n"
    t_lo = min(t0 for (_, _, t0, _, _, _) in spans)
    t_hi = max(t1 for (_, _, _, t1, _, _) in spans)
    extent = max(t_hi - t_lo, 1e-12)
    by_track: Dict[str, list] = {}
    for s in spans:
        by_track.setdefault(s[1], []).append(s)
    lines = []
    unit = "s" if extent >= 1e-3 else "us"
    scale = 1.0 if unit == "s" else 1e6
    lines.append(f"trace window: {extent * scale:.3f}{unit} "
                 f"({len(spans)} spans, {len(by_track)} tracks)")
    for track in sorted(by_track):
        lines.append(f"[{track}]")
        for name, _, t0, t1, parent, attrs in sorted(by_track[track], key=lambda s: (s[2], s[3])):
            lo = int((t0 - t_lo) / extent * width)
            hi = max(int((t1 - t_lo) / extent * width), lo + 1)
            bar = " " * lo + "#" * (hi - lo) + " " * (width - hi)
            label = name if parent is None else f"{parent}>{name}"
            dur = (t1 - t0) * scale
            detail = ""
            if attrs:
                keys = [k for k in ("source", "codec", "link_class", "bytes") if k in attrs]
                if keys:
                    detail = " " + ",".join(f"{k}={attrs[k]}" for k in keys)
            lines.append(f"  |{bar}| {label} {dur:.3f}{unit}{detail}")
    return "\n".join(lines) + "\n"
