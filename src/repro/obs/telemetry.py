"""Low-overhead telemetry recorder: spans, counters, histograms.

The recorder is clock-injected: pass ``time.monotonic`` (default) for
the threaded data plane or ``lambda: env.now`` for the simulator, and
the same instrumentation code produces wall-clock or virtual-time
spans with no other changes.

Design constraints (the update path must stay within 2% of the
uninstrumented baseline, and the *disabled* path must allocate
nothing):

- A disabled recorder's ``counter_add`` / ``observe`` / ``event``
  return before touching any container, and ``span()`` returns a
  shared no-op context-manager singleton. Hot call sites additionally
  guard with ``if rec.enabled:`` so keyword-argument dicts are never
  built on the disabled path.
- Finished spans are stored as flat tuples ``(name, track, t0, t1,
  parent, attrs)`` appended to one list — no per-span objects survive
  beyond their lifetime.
- A single lock guards the containers; it is only taken when enabled.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

# Canonical decomposition of destination stall time. Every benchmark
# reports these five components; they must (approximately) tile the
# end-to-end stall.
STALL_COMPONENTS = ("plan_wait", "wire", "decode", "verify", "control")

# Counter names the data planes feed and stall_breakdown() reads.
CTR_PLAN_WAIT = "stall/plan_wait"
CTR_WIRE = "stall/wire"  # gross time around transport calls
CTR_DECODE = "stall/decode"
CTR_VERIFY = "stall/verify"
CTR_CONTROL = "stall/control"

# Gray-failure self-healing counters (event counts, not seconds): each
# increment pairs with a span event of the same name carrying the
# source/unit involved.
CTR_RETRIES = "heal/retries"
CTR_HEDGES = "heal/hedges"
CTR_CORRUPT_REJECTS = "heal/corrupt_rejects"
CTR_DEADLINE_REPORTS = "heal/deadline_reports"

# Delta-transfer fallbacks: a delta frame met a stale/evicted base at
# the destination and the unit was transparently re-shipped through the
# base codec (event count; pairs with a "delta_stale_fallback" event).
CTR_DELTA_STALE = "heal/delta_stale_fallbacks"

# Networked data plane: reads served over a re-used keep-alive
# connection from the per-peer pool (event count; the complement of
# fresh TCP connects, which pay handshake + slow-start).
CTR_CONN_REUSE = "net/conn_reuses"


class _NullSpan:
    """Shared no-op span; returned by a disabled recorder."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self

    def end(self) -> None:
        return None


NULL_SPAN = _NullSpan()


class Span:
    """An open span. Close with ``end()`` or use as a context manager.

    Spans nest per ``track``: a span opened while another span on the
    same track is open records that span's name as its ``parent``.
    """

    __slots__ = ("_rec", "name", "track", "t0", "parent", "attrs")

    def __init__(self, rec: "Recorder", name: str, track: str,
                 t0: float, parent: Optional[str], attrs: Optional[dict]):
        self._rec = rec
        self.name = name
        self.track = track
        self.t0 = t0
        self.parent = parent
        self.attrs = attrs

    def set(self, **attrs) -> "Span":
        if self.attrs is None:
            self.attrs = attrs
        else:
            self.attrs.update(attrs)
        return self

    def end(self) -> None:
        rec = self._rec
        if rec is None:
            return
        self._rec = None
        rec._finish(self)

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc) -> bool:
        self.end()
        return False


class Recorder:
    """Collects spans, counters and histograms under an injected clock."""

    def __init__(self, clock: Callable[[], float] = time.monotonic, *,
                 enabled: bool = True):
        self.clock = clock
        self.enabled = enabled
        # Finished spans: (name, track, t0, t1, parent, attrs-or-None).
        self.events: List[Tuple[str, str, float, float, Optional[str], Optional[dict]]] = []
        self.counters: Dict[str, float] = {}
        self.histograms: Dict[str, List[float]] = {}
        self._lock = threading.Lock()
        # Open-span stacks keyed by track (for parent attribution).
        self._open: Dict[str, List[Span]] = {}

    # -- spans ---------------------------------------------------------

    def span(self, name: str, track: str = "main", **attrs):
        """Open a span; returns a context manager with an ``end()``."""
        if not self.enabled:
            return NULL_SPAN
        t0 = self.clock()
        with self._lock:
            stack = self._open.get(track)
            parent = stack[-1].name if stack else None
            sp = Span(self, name, track, t0, parent, attrs or None)
            if stack is None:
                self._open[track] = [sp]
            else:
                stack.append(sp)
        return sp

    def _finish(self, sp: Span) -> None:
        t1 = self.clock()
        with self._lock:
            stack = self._open.get(sp.track)
            if stack is not None and sp in stack:
                stack.remove(sp)
            self.events.append((sp.name, sp.track, sp.t0, t1, sp.parent, sp.attrs))

    def event(self, name: str, track: str = "main", **attrs) -> None:
        """Record an instantaneous (zero-duration) event."""
        if not self.enabled:
            return
        now = self.clock()
        with self._lock:
            stack = self._open.get(track)
            parent = stack[-1].name if stack else None
            self.events.append((name, track, now, now, parent, attrs or None))

    # -- counters / histograms ----------------------------------------

    def counter_add(self, name: str, value: float = 1.0) -> None:
        if not self.enabled:
            return
        with self._lock:
            self.counters[name] = self.counters.get(name, 0.0) + value

    def counter(self, name: str) -> float:
        return self.counters.get(name, 0.0)

    def observe(self, name: str, value: float) -> None:
        """Record one histogram sample."""
        if not self.enabled:
            return
        with self._lock:
            samples = self.histograms.get(name)
            if samples is None:
                self.histograms[name] = [value]
            else:
                samples.append(value)

    def histogram_summary(self, name: str) -> Dict[str, float]:
        samples = sorted(self.histograms.get(name, ()))
        if not samples:
            return {"count": 0, "sum": 0.0, "min": 0.0, "p50": 0.0, "max": 0.0}
        n = len(samples)
        return {
            "count": n,
            "sum": sum(samples),
            "min": samples[0],
            "p50": samples[n // 2],
            "max": samples[-1],
        }

    # -- lifecycle -----------------------------------------------------

    def clear(self) -> None:
        with self._lock:
            self.events.clear()
            self.counters.clear()
            self.histograms.clear()
            self._open.clear()

    def snapshot(self) -> Dict[str, object]:
        """Point-in-time copy of counters and histogram summaries."""
        with self._lock:
            counters = dict(self.counters)
            hist_names = list(self.histograms)
        return {
            "counters": counters,
            "histograms": {n: self.histogram_summary(n) for n in hist_names},
            "spans": len(self.events),
        }


#: Shared disabled recorder, used as the default everywhere a recorder
#: is optional. Never enable this instance — create your own instead.
DISABLED = Recorder(enabled=False)


def stall_breakdown(recorder: Recorder) -> Dict[str, float]:
    """Destination stall decomposition from a recorder's counters.

    ``stall/wire`` is gross time around transport calls; decode and
    checksum-verify time measured inside the transport is carved out
    of it so the five components tile rather than double-count.
    """
    c = recorder.counters
    decode = c.get(CTR_DECODE, 0.0)
    verify = c.get(CTR_VERIFY, 0.0)
    gross = c.get(CTR_WIRE, 0.0)
    return {
        "plan_wait": c.get(CTR_PLAN_WAIT, 0.0),
        "wire": max(0.0, gross - decode - verify),
        "decode": decode,
        "verify": verify,
        "control": c.get(CTR_CONTROL, 0.0),
    }
