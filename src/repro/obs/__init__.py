"""Transfer telemetry plane (observability).

``telemetry`` — the low-overhead recorder (spans / counters /
histograms), clock-injected so the same instrumentation runs under
``time.monotonic`` (threaded data plane) and ``SimEnv`` virtual time
(simulator). ``export`` — Chrome trace-event JSON (Perfetto-viewable)
and a textual timeline renderer.
"""

from repro.obs.telemetry import (
    DISABLED,
    STALL_COMPONENTS,
    Recorder,
    stall_breakdown,
)
from repro.obs.export import (
    chrome_trace_events,
    render_timeline,
    write_chrome_trace,
)
from repro.obs.rpc import RpcStats

__all__ = [
    "DISABLED",
    "STALL_COMPONENTS",
    "Recorder",
    "RpcStats",
    "chrome_trace_events",
    "render_timeline",
    "stall_breakdown",
    "write_chrome_trace",
]
