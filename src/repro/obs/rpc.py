"""Per-RPC latency accounting for the networked control plane.

The in-process client times its server calls through the telemetry
recorder (``CTR_CONTROL``); the networked service needs the same
visibility *per op* and server-side — which ops dominate, how long they
take, how many fail — without the recorder's span machinery. ``RpcStats``
is a tiny thread-safe accumulator the :class:`repro.net.service
.ReferenceService` wraps around every dispatched frame; its snapshot
rides the service's ``metrics()`` under a dedicated ``rpc`` section and
the Prometheus text exposition as ``tensorhub_rpc_*{op="..."}`` series.

Latencies are wall-clock and therefore live outside the replayed
counter-equality contract (same rule as the server's ``gauges`` section);
call *counts* are transport-level facts (retries count twice — that is
the point) and are not expected to match between a server and its
crash-recovered twin either.
"""

from __future__ import annotations

import threading
from typing import Dict


class RpcStats:
    """Thread-safe per-op RPC counters: calls, errors, total/max latency."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._calls: Dict[str, int] = {}
        self._errors: Dict[str, int] = {}
        self._total_s: Dict[str, float] = {}
        self._max_s: Dict[str, float] = {}

    def record(self, op: str, seconds: float, *, ok: bool = True) -> None:
        with self._lock:
            self._calls[op] = self._calls.get(op, 0) + 1
            if not ok:
                self._errors[op] = self._errors.get(op, 0) + 1
            self._total_s[op] = self._total_s.get(op, 0.0) + seconds
            if seconds > self._max_s.get(op, 0.0):
                self._max_s[op] = seconds

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        """``{op: {calls, errors, total_s, max_s, mean_us}}``."""
        with self._lock:
            out: Dict[str, Dict[str, float]] = {}
            for op, calls in self._calls.items():
                total = self._total_s.get(op, 0.0)
                out[op] = {
                    "calls": float(calls),
                    "errors": float(self._errors.get(op, 0)),
                    "total_s": total,
                    "max_s": self._max_s.get(op, 0.0),
                    "mean_us": (total / calls) * 1e6 if calls else 0.0,
                }
            return out

    def text(self) -> str:
        """Prometheus-style exposition lines (labelled by op), matching
        the server's ``metrics_text`` framing so the two concatenate into
        one scrape body."""
        snap = self.snapshot()
        lines = []
        for metric, ptype in (
            ("rpc_calls_total", "counter"),
            ("rpc_errors_total", "counter"),
            ("rpc_latency_seconds_total", "counter"),
            ("rpc_latency_seconds_max", "gauge"),
        ):
            lines.append(f"# TYPE tensorhub_{metric} {ptype}")
            key = {
                "rpc_calls_total": "calls",
                "rpc_errors_total": "errors",
                "rpc_latency_seconds_total": "total_s",
                "rpc_latency_seconds_max": "max_s",
            }[metric]
            for op in sorted(snap):
                val = snap[op][key]
                text = f"{val:.9f}".rstrip("0").rstrip(".") if val % 1 else str(int(val))
                lines.append(f'tensorhub_{metric}{{op="{op}"}} {text}')
        return "\n".join(lines) + "\n"


__all__ = ["RpcStats"]
