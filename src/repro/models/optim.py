"""Beyond-paper performance knobs (EXPERIMENTS.md Perf).

The dry-run roofline exposed concrete inefficiencies in the *baseline*
sharding; each knob here is one hypothesis->change cycle. Knobs are process
globals (set by the dry-run/launch entry points before tracing) so the
model code stays a pure function of (params, batch).

H1 ``shard_attn_heads``: with GQA, kv_heads often doesn't divide the model
axis (llama3: 8 kv heads on 16-way TP), and GSPMD then replicates the whole
attention einsum on every model rank — 16x redundant compute AND it
all-reduces the f32 score tensors. Fix: broadcast K/V to the full query
head count (a local gather: each rank materializes only its own 2 heads)
and constrain q/k/v/o to shard on the q-head axis, which IS divisible.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Iterator, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


@dataclasses.dataclass
class OptFlags:
    mesh: Optional[Mesh] = None
    #: H1: shard attention on (batch, q-heads) — see module docstring.
    #: IMPORTANT: the constraint must pin the batch axis too; a
    #: with_sharding_constraint is a FULL spec, and leaving batch as None
    #: pins it replicated (the first H1 attempt did exactly that and made
    #: things worse — recorded in EXPERIMENTS.md Perf).
    shard_attn_heads: bool = False
    #: name of the mesh axis used for tensor parallelism
    model_axis: str = "model"
    #: mesh axes carrying the batch (outer data parallel)
    batch_axes: tuple = ("pod", "data")
    #: H2: apply the RMS-norm scale in the residual dtype instead of
    #: materializing full f32 copies of the residual stream (the variance
    #: reduction stays f32). ~1/3 of llama3 train HBM traffic was f32
    #: residual copies.
    lowp_norm: bool = False
    #: H3: expert-parallel MoE via shard_map — per-device local dispatch
    #: (sort over LOCAL tokens only) + local expert matmuls + one psum over
    #: the model axis, instead of GSPMD's replicated global sort/scatter
    #: (which all-gathers the whole dispatch buffer on every device).
    shardmap_moe: bool = False


FLAGS = OptFlags()


@contextlib.contextmanager
def optimizations(**kw) -> Iterator[OptFlags]:
    global FLAGS
    prev = FLAGS
    FLAGS = dataclasses.replace(FLAGS, **kw)
    try:
        yield FLAGS
    finally:
        FLAGS = prev


def shard_attn(x: jax.Array, *, batch_axis: int = 0, head_axis: int = 1) -> jax.Array:
    """Constrain `x` to shard batch over the data axes AND heads over the
    model axis (when active, mesh known, and the dims divide)."""
    f = FLAGS
    if not f.shard_attn_heads or f.mesh is None:
        return x
    sizes = dict(f.mesh.shape)
    spec = [None] * x.ndim
    batch = tuple(a for a in f.batch_axes if a in sizes)
    bsz = 1
    for a in batch:
        bsz *= sizes[a]
    if batch and bsz > 1 and x.shape[batch_axis] % bsz == 0:
        spec[batch_axis] = batch if len(batch) > 1 else batch[0]
    n = sizes.get(f.model_axis, 1)
    if n > 1 and x.shape[head_axis] % n == 0:
        spec[head_axis] = f.model_axis
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(f.mesh, PartitionSpec(*spec))
    )


def broadcast_kv_active() -> bool:
    return FLAGS.shard_attn_heads and FLAGS.mesh is not None
