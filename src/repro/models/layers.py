"""Common transformer building blocks (pure JAX, jit/scan friendly).

Attention is implemented *chunked* (online-softmax scan over KV blocks) so
that 32k-prefill and 500k-context cells lower with bounded memory on any
backend. The Pallas TPU kernel (``repro.kernels.flash_attention``) computes
the same function for the TPU runtime hot path; tests assert equivalence.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    from repro.models import optim  # late import: layers <- optim <- (none)

    dt = x.dtype
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jax.lax.rsqrt(var + eps)
    if optim.FLAGS.lowp_norm and dt != jnp.float32:
        # H2: keep the reduction in f32 but scale in the residual dtype —
        # avoids materializing f32 copies of the whole residual stream
        return x * scale.astype(dt) * (1.0 + gamma.astype(jnp.float32)).astype(dt)
    xf = x.astype(jnp.float32)
    return ((xf * scale) * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return cap * jnp.tanh(x / cap) if cap > 0 else x


# -- rotary ------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, D] (D even); positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- chunked (flash-style) attention ------------------------------------------


def _block_mask(
    q_pos: jax.Array,
    k_pos: jax.Array,
    causal: bool,
    window: Optional[jax.Array],
    kv_len: Optional[jax.Array],
) -> jax.Array:
    """[Sq, Sk_block] boolean mask (True = attend).

    ``window`` may be a traced scalar (per-layer values under scan, e.g.
    gemma2's alternating local/global layers); window <= 0 means unlimited.
    """
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        w = jnp.asarray(window)
        m &= (w <= 0) | (k_pos[None, :] > q_pos[:, None] - w)
    if kv_len is not None:
        m &= k_pos[None, :] < kv_len
    return m


def chunked_attention(
    q: jax.Array,  # [B, Hq, Sq, D]
    k: jax.Array,  # [B, Hkv, Sk, D]
    v: jax.Array,  # [B, Hkv, Sk, D]
    *,
    causal: bool = True,
    window: Optional[jax.Array] = None,  # sliding window (<=0 / None = off)
    q_offset: jax.Array | int = 0,
    kv_len: Optional[jax.Array] = None,  # valid cache length (decode)
    attn_softcap: float = 0.0,
    block_k: int = 1024,
) -> jax.Array:
    """Online-softmax attention, scanning over KV blocks.

    GQA: Hq must be a multiple of Hkv. ``q_offset`` places the query block
    within the global sequence (prefill: 0; decode: current position).
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    dv = v.shape[-1]  # may differ from d (MLA: qk vs v head dims)
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, sq, d)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    block_k = min(block_k, sk)
    nblocks = (sk + block_k - 1) // block_k
    pad = nblocks * block_k - sk
    if pad:
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    else:
        kp, vp = k, v
    kb = kp.reshape(b, hkv, nblocks, block_k, d).transpose(2, 0, 1, 3, 4)
    vb = vp.reshape(b, hkv, nblocks, block_k, dv).transpose(2, 0, 1, 3, 4)

    q_pos = jnp.asarray(q_offset) + jnp.arange(sq)
    base_valid = sk if kv_len is None else kv_len

    def body(carry, inp):
        m_prev, l_prev, acc = carry
        blk_idx, kblk, vblk = inp
        k_pos = blk_idx * block_k + jnp.arange(block_k)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kblk.astype(jnp.float32)) * scale
        if attn_softcap > 0:
            s = softcap(s, attn_softcap)
        valid = _block_mask(q_pos, k_pos, causal, window, jnp.asarray(base_valid))
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_cur[..., None])
        alpha = jnp.exp(m_prev - m_cur)
        l_cur = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vblk.astype(jnp.float32)
        )
        return (m_cur, l_cur, acc), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, g, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (jnp.arange(nblocks), kb, vb)
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, sq, dv).astype(q.dtype)


def reference_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[jax.Array] = None,
    q_offset: jax.Array | int = 0,
    kv_len: Optional[jax.Array] = None,
    attn_softcap: float = 0.0,
) -> jax.Array:
    """Naive O(S^2)-memory oracle for tests."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    dv = v.shape[-1]
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, sq, d)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k.astype(jnp.float32)) / jnp.sqrt(
        jnp.asarray(d, jnp.float32)
    )
    if attn_softcap > 0:
        s = softcap(s, attn_softcap)
    q_pos = jnp.asarray(q_offset) + jnp.arange(sq)
    k_pos = jnp.arange(sk)
    valid = _block_mask(
        q_pos, k_pos, causal, window, None if kv_len is None else jnp.asarray(kv_len)
    )
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, sq, dv).astype(q.dtype)


# -- MLP -----------------------------------------------------------------------


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def gelu_mlp(x: jax.Array, w_up: jax.Array, b_up: jax.Array, w_down: jax.Array, b_down: jax.Array) -> jax.Array:
    return jax.nn.gelu(x @ w_up + b_up) @ w_down + b_down
