"""Mamba2 (SSD) block: chunked-parallel scan for training, single-step
recurrence for decode (arXiv:2405.21060, used by zamba2).

State-space recurrence per head h with head size P and state size N:

    S_t = exp(dt_t * A_h) * S_{t-1} + (dt_t * x_t) outer B_t      [P, N]
    y_t = S_t @ C_t + D_h * x_t

Training uses the chunked algorithm: within-chunk quadratic (attention-like
masked matmul), cross-chunk state propagation via a short lax.scan.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rms_norm
from repro.models.params import ParamSpec, spec

Params = Dict[str, Any]


def ssd_dims(cfg: ModelConfig) -> Tuple[int, int, int, int, int]:
    s = cfg.ssm
    assert s is not None
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.d_state
    return d_in, nheads, s.head_dim, s.d_state, conv_dim


def ssd_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    s = cfg.ssm
    assert s is not None
    d = cfg.d_model
    d_in, nheads, _, n, conv_dim = ssd_dims(cfg)
    return {
        "ln": spec((d,), ("act_embed",), init="zeros"),
        # in_proj -> [z (d_in), xBC (d_in + 2N), dt (nheads)]
        "w_in": spec((d, 2 * d_in + 2 * n + nheads), ("embed", "ssm_inner")),
        "conv_w": spec((s.d_conv, conv_dim), ("conv", "ssm_inner")),
        "conv_b": spec((conv_dim,), ("ssm_inner",), init="zeros"),
        "a_log": spec((nheads,), ("ssm_heads",), init="zeros"),
        "d_skip": spec((nheads,), ("ssm_heads",), init="ones"),
        "dt_bias": spec((nheads,), ("ssm_heads",), init="zeros"),
        "norm": spec((d_in,), ("ssm_inner",), init="zeros"),
        "w_out": spec((d_in, d), ("ssm_inner", "embed")),
    }


def _ssd_chunked(
    x: jax.Array,  # [B, T, H, P]
    dt: jax.Array,  # [B, T, H]  (post-softplus)
    a: jax.Array,  # [H]        (negative)
    bmat: jax.Array,  # [B, T, N]
    cmat: jax.Array,  # [B, T, N]
    chunk: int,
    init_state: Optional[jax.Array] = None,  # [B, H, P, N]
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [B,T,H,P], final_state [B,H,P,N])."""
    b, t, h, p = x.shape
    n = bmat.shape[-1]
    L = min(chunk, t)
    nc = (t + L - 1) // L
    pad = nc * L - t
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))

    xc = x.reshape(b, nc, L, h, p)
    dtc = dt.reshape(b, nc, L, h).astype(jnp.float32)
    bc = bmat.reshape(b, nc, L, n).astype(jnp.float32)
    cc = cmat.reshape(b, nc, L, n).astype(jnp.float32)

    log_a = dtc * a.astype(jnp.float32)  # [B,nc,L,H] (negative)
    cum = jnp.cumsum(log_a, axis=2)  # inclusive cumulative log decay
    total = cum[:, :, -1]  # [B,nc,H]

    dx = (dtc[..., None] * xc.astype(jnp.float32))  # [B,nc,L,H,P]

    # within-chunk (causal masked attention-like) term
    g = jnp.einsum("bcln,bcmn->bclm", cc, bc)  # [B,nc,L,L]
    li = cum[:, :, :, None, :]  # l index -> [B,nc,L,1,H]
    lj = cum[:, :, None, :, :]  # m index -> [B,nc,1,L,H]
    decay = jnp.exp(li - lj)  # [B,nc,L,L,H]
    causal = jnp.tril(jnp.ones((L, L), bool))
    w = jnp.where(causal[None, None, :, :, None], g[..., None] * decay, 0.0)
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", w, dx)

    # chunk-final states: S_c = sum_j exp(total - cum_j) dx_j outer b_j
    decay_to_end = jnp.exp(total[:, :, None] - cum)  # [B,nc,L,H]
    s_chunk = jnp.einsum("bclh,bclhp,bcln->bchpn", decay_to_end, dx, bc)

    # propagate chunk states: S_prev_{c} = exp(total_{c-1}) S_prev_{c-1} + S_{c-1}
    def scan_fn(s_prev, inp):
        tot_c, s_c = inp
        s_next = jnp.exp(tot_c)[:, :, None, None] * s_prev + s_c
        return s_next, s_prev

    s0 = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )
    final_state, s_prevs = jax.lax.scan(
        scan_fn,
        s0,
        (total.transpose(1, 0, 2), s_chunk.transpose(1, 0, 2, 3, 4)),
    )
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)  # [B,nc,H,P,N]

    # inter-chunk contribution: y_inter[i] = exp(cum_i) * C_i . S_prev
    y_inter = jnp.einsum("bclh,bcln,bchpn->bclhp", jnp.exp(cum), cc, s_prevs)

    y = (y_intra + y_inter).reshape(b, nc * L, h, p)[:, :t]
    return y.astype(x.dtype), final_state


def ssd_reference(x, dt, a, bmat, cmat, init_state=None):
    """Step-by-step oracle for tests."""
    b, t, h, p = x.shape
    n = bmat.shape[-1]
    s = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )
    ys = []
    for i in range(t):
        decay = jnp.exp(dt[:, i].astype(jnp.float32) * a)  # [B,H]
        dx = dt[:, i, :, None].astype(jnp.float32) * x[:, i].astype(jnp.float32)
        s = decay[:, :, None, None] * s + jnp.einsum("bhp,bn->bhpn", dx, bmat[:, i].astype(jnp.float32))
        ys.append(jnp.einsum("bhpn,bn->bhp", s, cmat[:, i].astype(jnp.float32)))
    return jnp.stack(ys, axis=1).astype(x.dtype), s


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time. xbc: [B, T, C]; w: [K, C]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(k):
        out = out + pad[:, i : i + xbc.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(xbc.dtype)


def ssd_block_apply(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,  # [B, S, D]
    *,
    cache: Optional[Dict[str, jax.Array]] = None,
    # cache: {"conv": [B, K-1, conv_dim], "state": [B, H, P, N]}
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    s = cfg.ssm
    assert s is not None
    d_in, nheads, hd, n, conv_dim = ssd_dims(cfg)
    bsz, seq, _ = x.shape

    h = rms_norm(x, p["ln"])
    proj = h @ p["w_in"]
    z, xbc, dt_raw = jnp.split(proj, [d_in, d_in + conv_dim], axis=-1)

    if cache is None:
        xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
        new_conv = None
    else:
        # decode: shift-register conv state
        window = jnp.concatenate([cache["conv"], xbc], axis=1)  # [B, K-1+S, C]
        out = jnp.zeros_like(xbc, dtype=jnp.float32)
        k = p["conv_w"].shape[0]
        for i in range(k):
            out = out + window[:, i : i + seq].astype(jnp.float32) * p["conv_w"][i].astype(jnp.float32)
        xbc = jax.nn.silu(out + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
        new_conv = window[:, -(k - 1) :]

    xs, bmat, cmat = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    xs = xs.reshape(bsz, seq, nheads, hd)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    init = None if cache is None else cache["state"]
    if cache is None or seq > 1:
        y, state = _ssd_chunked(xs, dt, a, bmat, cmat, s.chunk, init)
    else:
        # single-token recurrence
        decay = jnp.exp(dt[:, 0] * a)  # [B,H]
        dx = dt[:, 0, :, None] * xs[:, 0].astype(jnp.float32)
        state = decay[:, :, None, None] * init.astype(jnp.float32) + jnp.einsum(
            "bhp,bn->bhpn", dx, bmat[:, 0].astype(jnp.float32)
        )
        y = jnp.einsum("bhpn,bn->bhp", state, cmat[:, 0].astype(jnp.float32))[:, None]
        y = y.astype(x.dtype)

    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, seq, d_in)
    y = rms_norm((y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype), p["norm"])
    out = y @ p["w_out"]

    new_cache: Dict[str, jax.Array] = {"state": state.astype(jnp.float32)}
    if cache is None:
        k = p["conv_w"].shape[0]
        raw = h @ p["w_in"]
        xbc_raw = raw[..., d_in : d_in + conv_dim]
        tail = xbc_raw[:, -(k - 1) :] if seq >= k - 1 else jnp.pad(
            xbc_raw, ((0, 0), (k - 1 - seq, 0), (0, 0))
        )
        new_cache["conv"] = tail
    else:
        new_cache["conv"] = new_conv
    return x + out, new_cache
