"""Parameter specs: shapes + logical sharding axes, one source of truth.

Every model builds an *abstract* parameter tree of :class:`ParamSpec` leaves.
From it we derive, without ever materializing weights:

* ``jax.ShapeDtypeStruct`` trees for the dry-run (``.lower()`` inputs),
* ``NamedSharding`` trees via ``repro.sharding.rules``,
* real initialized parameters for smoke tests / examples,
* the named-tensor dict that TensorHub ``register()``/``publish()`` consume.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Axes = Tuple[Optional[str], ...]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Axes  # logical sharding axes, len == ndim
    init: str = "normal"  # "normal" | "zeros" | "ones"
    scale: float = 1.0  # stddev multiplier on fan-in init

    def __post_init__(self) -> None:
        if len(self.shape) != len(self.axes):
            raise ValueError(f"spec {self.shape} has {len(self.axes)} axes")

    def struct(self, dtype: Any) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, dtype)


def spec(shape: Tuple[int, ...], axes: Axes, *, init: str = "normal", scale: float = 1.0) -> ParamSpec:
    return ParamSpec(tuple(int(s) for s in shape), tuple(axes), init=init, scale=scale)


def stack_layers(tree: Any, num_layers: int) -> Any:
    """Prepend a scan-stacked 'layers' dimension to every spec in a tree."""

    def bump(p: ParamSpec) -> ParamSpec:
        return ParamSpec(
            (num_layers, *p.shape), ("layers", *p.axes), init=p.init, scale=p.scale
        )

    return jax.tree.map(bump, tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def abstract_tree(tree: Any, dtype: Any) -> Any:
    return jax.tree.map(
        lambda p: p.struct(dtype), tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


def init_tree(tree: Any, key: jax.Array, dtype: Any) -> Any:
    """Materialize real parameters (smoke tests / examples only)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))

    def make(p: ParamSpec, k: jax.Array) -> jax.Array:
        if p.init == "zeros":
            return jnp.zeros(p.shape, dtype)
        if p.init == "ones":
            return jnp.ones(p.shape, dtype)
        fan_in = p.shape[-2] if len(p.shape) >= 2 else max(p.shape[-1], 1)
        std = p.scale / np.sqrt(fan_in)
        return (jax.random.normal(k, p.shape, jnp.float32) * std).astype(dtype)

    return jax.tree.unflatten(treedef, [make(p, k) for p, k in zip(leaves, keys)])


def named_tensors(params: Any, prefix: str = "") -> Dict[str, Any]:
    """Flatten a param pytree into the named-tensor dict consumed by
    TensorHub register()/publish() (DESIGN.md 4)."""
    out: Dict[str, Any] = {}
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in flat:
        name = prefix + "/".join(_key_str(k) for k in path)
        out[name] = leaf
    return out


def _key_str(k: Any) -> str:
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    if isinstance(k, jax.tree_util.GetAttrKey):
        return str(k.name)
    return str(k)


def tree_size(tree: Any) -> int:
    """Total element count of a spec tree (for param-count cross-checks)."""
    total = 0
    for p in jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, ParamSpec)):
        n = 1
        for s in p.shape:
            n *= s
        total += n
    return total
