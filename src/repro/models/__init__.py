from repro.models.lm import (
    DecoderLM,
    EncoderLM,
    HybridLM,
    XLSTMLM,
    active_param_count,
    build_model,
    param_count,
)
from repro.models.params import (
    ParamSpec,
    abstract_tree,
    init_tree,
    named_tensors,
    spec,
    stack_layers,
    tree_size,
)

__all__ = [
    "DecoderLM",
    "EncoderLM",
    "HybridLM",
    "ParamSpec",
    "XLSTMLM",
    "abstract_tree",
    "active_param_count",
    "build_model",
    "init_tree",
    "named_tensors",
    "param_count",
    "spec",
    "stack_layers",
    "tree_size",
]
