"""Model assembly: the 10 assigned architectures behind one interface.

``build_model(cfg)`` returns a family-specific model object exposing:

* ``param_specs()``       — ParamSpec tree (shapes + logical sharding axes)
* ``init(key, dtype)``    — materialized params (smoke tests / examples)
* ``forward(params, batch)``            — teacher-forced logits [B,S,V]
* ``cache_specs(batch_size, max_len)``  — decode-cache schema
* ``prefill(params, batch)``            — logits + primed cache
* ``decode(params, cache, tokens, cache_len)`` — one decode step
* ``input_specs(shape)``  — ShapeDtypeStruct stand-ins for the dry-run

All families use scan-over-layers with remat; caches are scan-stacked.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (
    AUDIO,
    DENSE,
    HYBRID,
    MOE,
    SSM,
    VLM,
    ModelConfig,
    ShapeCase,
)
from repro.models import blocks, ssd, xlstm_blocks
from repro.models.layers import apply_rope, rms_norm, softcap
from repro.models.params import (
    ParamSpec,
    abstract_tree,
    init_tree,
    spec,
    stack_layers,
    tree_size,
)

Params = Any
Cache = Any


def _layer_windows(cfg: ModelConfig, *, long_mode: bool = False) -> jnp.ndarray:
    """Per-layer sliding-window sizes (0 = global attention)."""
    if cfg.alt_local_global:
        w = [cfg.sliding_window if i % 2 == 0 else 0 for i in range(cfg.num_layers)]
    elif long_mode and cfg.sliding_window:
        w = [cfg.sliding_window] * cfg.num_layers
    else:
        w = [0] * cfg.num_layers
    return jnp.asarray(w, jnp.int32)


def _ring_slot(cache_len: jax.Array, window: int) -> jax.Array:
    return jnp.mod(cache_len, window)


def _ring_attention_step(
    q: jax.Array,  # [B, Hq, 1, hd] (rope already applied at cache_len)
    k_cache: jax.Array,  # [B, Hkv, W, hd] (rope applied at absolute positions)
    v_cache: jax.Array,
    cache_len: jax.Array,
    attn_softcap_v: float,
) -> jax.Array:
    """Attention over a ring-buffer window cache (long-context decode)."""
    b, hq, _, hd = q.shape
    _, hkv, w, _ = k_cache.shape
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, 1, hd)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k_cache.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    if attn_softcap_v > 0:
        s = softcap(s, attn_softcap_v)
    # slot s holds absolute position p = cache_len - ((cache_len - s) mod W)
    slots = jnp.arange(w)
    pos = cache_len - jnp.mod(cache_len - slots, w)
    valid = pos >= 0
    s = jnp.where(valid[None, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, hq, 1, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decoder LM (dense / moe / vlm families)
# ---------------------------------------------------------------------------


class DecoderLM:
    """Causal decoder: dense GQA or MLA attention x (SwiGLU | MoE) FFN."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.is_moe = cfg.moe is not None
        self.is_mla = cfg.mla is not None
        self.n_prefix = cfg.moe.first_dense if self.is_moe else 0
        self.n_scan = cfg.num_layers - self.n_prefix

    # -- specs ---------------------------------------------------------------

    def _layer_specs(self, *, dense_ffn: bool) -> Dict[str, Any]:
        cfg = self.cfg
        attn = blocks.mla_specs(cfg) if self.is_mla else blocks.attn_specs(cfg)
        if dense_ffn:
            d_ff = cfg.moe.d_ff_dense if self.is_moe else cfg.d_ff
            ffn = blocks.mlp_specs(cfg, d_ff)
        else:
            ffn = blocks.moe_specs(cfg) if self.is_moe else blocks.mlp_specs(cfg)
        return {"attn": attn, "ffn": ffn}

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        tree: Dict[str, Any] = {
            "embed": spec((cfg.vocab, cfg.d_model), ("vocab", "embed")),
            "layers": stack_layers(self._layer_specs(dense_ffn=not self.is_moe), self.n_scan),
            "final_ln": spec((cfg.d_model,), ("act_embed",), init="zeros"),
        }
        if self.n_prefix:
            tree["prefix"] = [self._layer_specs(dense_ffn=True) for _ in range(self.n_prefix)]
        if not cfg.tie_embeddings:
            tree["head"] = spec((cfg.d_model, cfg.vocab), ("embed", "vocab"))
        return tree

    def init(self, key: jax.Array, dtype: Any = jnp.float32) -> Params:
        return init_tree(self.param_specs(), key, dtype)

    # -- embedding / head ------------------------------------------------------

    def _embed(self, params: Params, tokens: jax.Array) -> jax.Array:
        x = params["embed"][tokens]
        if self.cfg.tie_embeddings:  # gemma2 normalizes the embedding scale
            x = x * jnp.sqrt(jnp.asarray(self.cfg.d_model, jnp.float32)).astype(x.dtype)
        return x

    def _head(self, params: Params, x: jax.Array) -> jax.Array:
        x = rms_norm(x, params["final_ln"])
        w = params["embed"].T if self.cfg.tie_embeddings else params["head"]
        logits = (x @ w).astype(jnp.float32)
        if self.cfg.logit_softcap > 0:
            logits = softcap(logits, self.cfg.logit_softcap)
        return logits

    def _ffn_apply(self, p_ffn: Dict[str, Any], x: jax.Array, *, dense: bool) -> jax.Array:
        if dense or not self.is_moe:
            return blocks.mlp_apply(p_ffn, x)
        from repro.models import optim

        if optim.FLAGS.shardmap_moe and optim.FLAGS.mesh is not None:
            return blocks.moe_apply_shardmap(self.cfg, p_ffn, x)
        return blocks.moe_apply(self.cfg, p_ffn, x)

    def _attn(self, p, x, *, positions, window=None, cache=None, cache_len=None):
        if self.is_mla:
            return blocks.mla_apply(
                self.cfg, p, x, positions=positions, cache=cache, cache_len=cache_len
            )
        return blocks.attn_apply(
            self.cfg, p, x, positions=positions, causal=not self.cfg.encoder_only,
            window=window, cache=cache, cache_len=cache_len,
        )

    # -- forward (train) -------------------------------------------------------

    def forward(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        x, positions = self._inputs(params, batch)
        windows = _layer_windows(self.cfg)[self.n_prefix :]

        for i in range(self.n_prefix):
            p = params["prefix"][i]
            x, _ = self._attn(p["attn"], x, positions=positions)
            x = self._ffn_apply(p["ffn"], x, dense=True)

        @jax.checkpoint
        def body(h, xs):
            layer, w = xs
            h, _ = self._attn(layer["attn"], h, positions=positions, window=w)
            h = self._ffn_apply(layer["ffn"], h, dense=False)
            return h, None

        x, _ = jax.lax.scan(body, x, (params["layers"], windows))
        return self._head(params, x)

    def _inputs(self, params: Params, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, jax.Array]:
        tokens = batch["tokens"]
        x = self._embed(params, tokens)
        if self.cfg.family == VLM:
            patches = batch["patches"].astype(x.dtype)  # [B, P, D] precomputed stub
            x = jnp.concatenate([patches, x], axis=1)
        positions = jnp.arange(x.shape[1])
        return x, positions

    # -- caches ------------------------------------------------------------------

    def _attn_cache_spec(self, b: int, m: int) -> Dict[str, ParamSpec]:
        cfg = self.cfg
        if self.is_mla:
            ml = cfg.mla
            return {
                "ckv": spec((b, m, ml.kv_lora_rank), ("batch", "seq", "kv_lora")),
                "krope": spec((b, m, ml.qk_rope_head_dim), ("batch", "seq", None)),
            }
        hd = cfg.resolved_head_dim
        return {
            "k": spec((b, cfg.num_kv_heads, m, hd), ("batch", "kv_heads", "seq", "head_dim")),
            "v": spec((b, cfg.num_kv_heads, m, hd), ("batch", "kv_heads", "seq", "head_dim")),
        }

    def cache_specs(self, batch_size: int, max_len: int, *, ring: bool = False) -> Any:
        m = min(max_len, self.cfg.sliding_window) if ring and self.cfg.sliding_window else max_len
        tree: Dict[str, Any] = {
            "layers": stack_layers(self._attn_cache_spec(batch_size, m), self.n_scan)
        }
        if self.n_prefix:
            tree["prefix"] = [self._attn_cache_spec(batch_size, m) for _ in range(self.n_prefix)]
        return tree

    def init_cache(self, batch_size: int, max_len: int, dtype: Any, *, ring: bool = False) -> Cache:
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, dtype),
            self.cache_specs(batch_size, max_len, ring=ring),
            is_leaf=lambda x: isinstance(x, ParamSpec),
        )

    # -- prefill -------------------------------------------------------------------

    def prefill(
        self, params: Params, batch: Dict[str, jax.Array], *, max_len: Optional[int] = None
    ) -> Tuple[jax.Array, Cache, jax.Array]:
        """Full-sequence forward that also returns the primed KV cache and
        its length. Cache buffers sized max_len (default: seq length)."""
        x, positions = self._inputs(params, batch)
        s = x.shape[1]
        m = max_len or s
        windows = _layer_windows(self.cfg)[self.n_prefix :]

        def pad_cache(c: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
            out = {}
            for name, arr in c.items():
                seq_axis = 1 if self.is_mla else 2
                pad = [(0, 0)] * arr.ndim
                pad[seq_axis] = (0, m - arr.shape[seq_axis])
                out[name] = jnp.pad(arr, pad)
            return out

        prefix_caches = []
        for i in range(self.n_prefix):
            p = params["prefix"][i]
            x, kv = self._attn(p["attn"], x, positions=positions)
            x = self._ffn_apply(p["ffn"], x, dense=True)
            prefix_caches.append(pad_cache(kv))

        @jax.checkpoint
        def body(h, xs):
            layer, w = xs
            h, kv = self._attn(layer["attn"], h, positions=positions, window=w)
            h = self._ffn_apply(layer["ffn"], h, dense=False)
            return h, pad_cache(kv)

        x, stacked = jax.lax.scan(body, x, (params["layers"], windows))
        logits = self._head(params, x[:, -1:])
        cache: Dict[str, Any] = {"layers": stacked}
        if self.n_prefix:
            cache["prefix"] = prefix_caches
        return logits, cache, jnp.asarray(s, jnp.int32)

    # -- decode ------------------------------------------------------------------------

    def decode(
        self,
        params: Params,
        cache: Cache,
        tokens: jax.Array,  # [B, 1]
        cache_len: jax.Array,
    ) -> Tuple[jax.Array, Cache]:
        x = self._embed(params, tokens)
        positions = cache_len + jnp.arange(x.shape[1])
        windows = _layer_windows(self.cfg)[self.n_prefix :]

        new_prefix = []
        for i in range(self.n_prefix):
            p = params["prefix"][i]
            x, kv = self._attn(
                p["attn"], x, positions=positions, cache=cache["prefix"][i], cache_len=cache_len
            )
            x = self._ffn_apply(p["ffn"], x, dense=True)
            new_prefix.append(kv)

        def body(h, xs):
            layer, w, c = xs
            h, kv = self._attn(
                layer["attn"], h, positions=positions, window=w, cache=c, cache_len=cache_len
            )
            h = self._ffn_apply(layer["ffn"], h, dense=False)
            return h, kv

        x, stacked = jax.lax.scan(body, x, (params["layers"], windows, cache["layers"]))
        logits = self._head(params, x)
        new_cache: Dict[str, Any] = {"layers": stacked}
        if self.n_prefix:
            new_cache["prefix"] = new_prefix
        return logits, new_cache

    # -- dry-run inputs ------------------------------------------------------------------

    def input_specs(self, case: ShapeCase) -> Dict[str, jax.ShapeDtypeStruct]:
        b, s = case.global_batch, case.seq_len
        if self.cfg.family == VLM:
            p = self.cfg.num_patches
            toks = jax.ShapeDtypeStruct((b, s - p), jnp.int32)
            return {
                "tokens": toks,
                "patches": jax.ShapeDtypeStruct((b, p, self.cfg.d_model), jnp.bfloat16),
            }
        return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}


# ---------------------------------------------------------------------------
# Encoder LM (hubert)
# ---------------------------------------------------------------------------


class EncoderLM:
    """Bidirectional encoder over precomputed frame embeddings (audio stub)."""

    def __init__(self, cfg: ModelConfig):
        assert cfg.encoder_only
        self.cfg = cfg

    def _layer_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        d, f = cfg.d_model, cfg.d_ff
        return {
            "attn": blocks.attn_specs(cfg),
            "ffn": {
                "ln": spec((d,), ("act_embed",), init="zeros"),
                "w_up": spec((d, f), ("embed", "mlp")),
                "b_up": spec((f,), ("mlp",), init="zeros"),
                "w_down": spec((f, d), ("mlp", "embed")),
                "b_down": spec((d,), ("act_embed",), init="zeros"),
            },
        }

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "frame_proj": spec((cfg.frontend_dim, cfg.d_model), ("frames", "embed")),
            "layers": stack_layers(self._layer_specs(), cfg.num_layers),
            "final_ln": spec((cfg.d_model,), ("act_embed",), init="zeros"),
            "head": spec((cfg.d_model, cfg.vocab), ("embed", "vocab")),
        }

    def init(self, key: jax.Array, dtype: Any = jnp.float32) -> Params:
        return init_tree(self.param_specs(), key, dtype)

    def forward(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        x = batch["frames"].astype(params["frame_proj"].dtype) @ params["frame_proj"]
        positions = jnp.arange(x.shape[1])

        @jax.checkpoint
        def body(h, layer):
            h, _ = blocks.attn_apply(
                self.cfg, layer["attn"], h, positions=positions, causal=False
            )
            f = layer["ffn"]
            hn = rms_norm(h, f["ln"])
            h = h + (jax.nn.gelu(hn @ f["w_up"] + f["b_up"]) @ f["w_down"] + f["b_down"])
            return h, None

        x, _ = jax.lax.scan(body, x, params["layers"])
        x = rms_norm(x, params["final_ln"])
        return (x @ params["head"]).astype(jnp.float32)

    def input_specs(self, case: ShapeCase) -> Dict[str, jax.ShapeDtypeStruct]:
        b, s = case.global_batch, case.seq_len
        return {
            "frames": jax.ShapeDtypeStruct((b, s, self.cfg.frontend_dim), jnp.bfloat16),
            "targets": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "mask": jax.ShapeDtypeStruct((b, s), jnp.bool_),
        }


# ---------------------------------------------------------------------------
# Hybrid (zamba2): Mamba2 stack + shared attention block
# ---------------------------------------------------------------------------


class HybridLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        s = cfg.ssm
        assert s is not None
        self.every = s.shared_block_every
        if cfg.num_layers % self.every:
            raise ValueError("hybrid: num_layers must be a multiple of shared_block_every")
        self.groups = cfg.num_layers // self.every

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        inner = stack_layers(ssd.ssd_specs(cfg), self.every)
        return {
            "embed": spec((cfg.vocab, cfg.d_model), ("vocab", "embed")),
            "groups": stack_layers(inner, self.groups),  # [G, E, ...]
            "shared_attn": blocks.attn_specs(cfg),
            "shared_mlp": blocks.mlp_specs(cfg),
            "final_ln": spec((cfg.d_model,), ("act_embed",), init="zeros"),
            "head": spec((cfg.d_model, cfg.vocab), ("embed", "vocab")),
        }

    def init(self, key: jax.Array, dtype: Any = jnp.float32) -> Params:
        return init_tree(self.param_specs(), key, dtype)

    def _ssd_cache_spec(self, b: int) -> Dict[str, ParamSpec]:
        cfg = self.cfg
        d_in, nheads, hd, n, conv_dim = ssd.ssd_dims(cfg)
        k = cfg.ssm.d_conv
        return {
            "conv": spec((b, k - 1, conv_dim), ("batch", None, "ssm_inner")),
            "state": spec((b, nheads, hd, n), ("batch", "ssm_heads", None, "ssm_state")),
        }

    def cache_specs(self, batch_size: int, max_len: int, *, ring: bool = False) -> Any:
        cfg = self.cfg
        m = min(max_len, cfg.sliding_window) if ring and cfg.sliding_window else max_len
        hd = cfg.resolved_head_dim
        attn_c = {
            "k": spec((batch_size, cfg.num_kv_heads, m, hd), ("batch", "kv_heads", "seq", "head_dim")),
            "v": spec((batch_size, cfg.num_kv_heads, m, hd), ("batch", "kv_heads", "seq", "head_dim")),
        }
        return {
            "ssd": stack_layers(stack_layers(self._ssd_cache_spec(batch_size), self.every), self.groups),
            "attn": stack_layers(attn_c, self.groups),
        }

    def init_cache(self, batch_size: int, max_len: int, dtype: Any, *, ring: bool = False) -> Cache:
        del dtype  # SSM states and small window caches stay f32
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32),
            self.cache_specs(batch_size, max_len, ring=ring),
            is_leaf=lambda x: isinstance(x, ParamSpec),
        )

    def _shared_block(self, params, x, *, positions, window=None, cache=None, cache_len=None, ring=False):
        cfg = self.cfg
        if cache is not None and ring:
            # ring-buffer window attention (long-context decode)
            p = params["shared_attn"]
            h = rms_norm(x, p["ln"])
            q = blocks._split_heads(h @ p["wq"], cfg.num_heads)
            k = blocks._split_heads(h @ p["wk"], cfg.num_kv_heads)
            v = blocks._split_heads(h @ p["wv"], cfg.num_kv_heads)
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            w = cache["k"].shape[2]
            slot = _ring_slot(cache_len, w)
            k_all = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, slot, 0))
            v_all = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, slot, 0))
            out = _ring_attention_step(q, k_all, v_all, cache_len, cfg.attn_softcap)
            x = x + blocks._merge_heads(out) @ p["wo"]
            new_cache = {"k": k_all, "v": v_all}
        else:
            x, new_cache = blocks.attn_apply(
                self.cfg, params["shared_attn"], x, positions=positions,
                window=window, cache=cache, cache_len=cache_len,
            )
        x = blocks.mlp_apply(params["shared_mlp"], x)
        return x, new_cache

    def forward(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        x = params["embed"][batch["tokens"]]
        positions = jnp.arange(x.shape[1])

        def group_body(h, gparams):
            @jax.checkpoint
            def inner(h2, lparams):
                h2, _ = ssd.ssd_block_apply(self.cfg, lparams, h2)
                return h2, None

            h, _ = jax.lax.scan(inner, h, gparams)
            h, _ = self._shared_block(params, h, positions=positions)
            return h, None

        x, _ = jax.lax.scan(group_body, x, params["groups"])
        x = rms_norm(x, params["final_ln"])
        return (x @ params["head"]).astype(jnp.float32)

    def prefill(
        self, params: Params, batch: Dict[str, jax.Array], *, max_len: Optional[int] = None
    ) -> Tuple[jax.Array, Cache, jax.Array]:
        x = params["embed"][batch["tokens"]]
        s = x.shape[1]
        m = max_len or s
        positions = jnp.arange(s)

        def group_body(h, gparams):
            def inner(h2, lparams):
                h2, c = ssd.ssd_block_apply(self.cfg, lparams, h2)
                return h2, c

            h, ssd_c = jax.lax.scan(inner, h, gparams)
            h, kv = self._shared_block(params, h, positions=positions)
            kv = {
                name: jnp.pad(arr, [(0, 0), (0, 0), (0, m - arr.shape[2]), (0, 0)])
                for name, arr in kv.items()
            }
            return h, (ssd_c, kv)

        x, (ssd_caches, attn_caches) = jax.lax.scan(group_body, x, params["groups"])
        x = rms_norm(x, params["final_ln"])
        logits = (x[:, -1:] @ params["head"]).astype(jnp.float32)
        cache = {"ssd": ssd_caches, "attn": attn_caches}
        return logits, cache, jnp.asarray(s, jnp.int32)

    def decode(
        self,
        params: Params,
        cache: Cache,
        tokens: jax.Array,
        cache_len: jax.Array,
        *,
        ring: bool = False,
    ) -> Tuple[jax.Array, Cache]:
        x = params["embed"][tokens]
        positions = cache_len + jnp.arange(x.shape[1])

        def group_body(h, xs):
            gparams, g_ssd, g_attn = xs

            def inner(h2, xs2):
                lparams, c = xs2
                h2, c2 = ssd.ssd_block_apply(self.cfg, lparams, h2, cache=c)
                return h2, c2

            h, new_ssd = jax.lax.scan(inner, h, (gparams, g_ssd))
            h, new_kv = self._shared_block(
                params, h, positions=positions, cache=g_attn, cache_len=cache_len, ring=ring
            )
            return h, (new_ssd, new_kv)

        x, (ssd_caches, attn_caches) = jax.lax.scan(
            group_body, x, (params["groups"], cache["ssd"], cache["attn"])
        )
        x = rms_norm(x, params["final_ln"])
        logits = (x @ params["head"]).astype(jnp.float32)
        return logits, {"ssd": ssd_caches, "attn": attn_caches}

    def input_specs(self, case: ShapeCase) -> Dict[str, jax.ShapeDtypeStruct]:
        return {"tokens": jax.ShapeDtypeStruct((case.global_batch, case.seq_len), jnp.int32)}


# ---------------------------------------------------------------------------
# xLSTM
# ---------------------------------------------------------------------------


class XLSTMLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        x = cfg.xlstm
        assert x is not None
        self.every = x.slstm_every
        if cfg.num_layers % self.every:
            raise ValueError("xlstm: num_layers must be a multiple of slstm_every")
        self.pairs = cfg.num_layers // self.every
        self.n_mlstm_per_pair = self.every - 1

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        mspecs = stack_layers(xlstm_blocks.mlstm_specs(cfg), self.n_mlstm_per_pair)
        return {
            "embed": spec((cfg.vocab, cfg.d_model), ("vocab", "embed")),
            "pairs": {
                "mlstm": stack_layers(mspecs, self.pairs),
                "slstm": stack_layers(xlstm_blocks.slstm_specs(cfg), self.pairs),
            },
            "final_ln": spec((cfg.d_model,), ("act_embed",), init="zeros"),
            "head": spec((cfg.d_model, cfg.vocab), ("embed", "vocab")),
        }

    def init(self, key: jax.Array, dtype: Any = jnp.float32) -> Params:
        return init_tree(self.param_specs(), key, dtype)

    def _state_specs(self, b: int) -> Dict[str, Any]:
        cfg = self.cfg
        d_in, nh, dh = xlstm_blocks.mlstm_dims(cfg)
        dhs = cfg.d_model // cfg.num_heads
        m_state = {
            "c": spec((b, nh, dh, dh), ("batch", "ssm_heads", None, None), init="zeros"),
            "n": spec((b, nh, dh), ("batch", "ssm_heads", None), init="zeros"),
            "m": spec((b, nh), ("batch", "ssm_heads"), init="zeros"),
        }
        s_state = {
            "h": spec((b, nh, dhs), ("batch", "ssm_heads", None), init="zeros"),
            "c": spec((b, nh, dhs), ("batch", "ssm_heads", None), init="zeros"),
            "n": spec((b, nh, dhs), ("batch", "ssm_heads", None), init="zeros"),
            "m": spec((b, nh, dhs), ("batch", "ssm_heads", None), init="zeros"),
        }
        return {
            "mlstm": stack_layers(stack_layers(m_state, self.n_mlstm_per_pair), self.pairs),
            "slstm": stack_layers(s_state, self.pairs),
        }

    def cache_specs(self, batch_size: int, max_len: int, *, ring: bool = False) -> Any:
        del max_len, ring  # recurrent state is O(1) in sequence length
        return self._state_specs(batch_size)

    def init_cache(self, batch_size: int, max_len: int, dtype: Any, *, ring: bool = False) -> Cache:
        del dtype
        tree = self.cache_specs(batch_size, max_len, ring=ring)
        cache = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32),
            tree,
            is_leaf=lambda x: isinstance(x, ParamSpec),
        )
        # stabilizers start at -inf-ish
        cache["mlstm"]["m"] = jnp.full_like(cache["mlstm"]["m"], -1e30)
        cache["slstm"]["m"] = jnp.full_like(cache["slstm"]["m"], -1e30)
        return cache

    def _run(self, params, x, cache):
        cfg = self.cfg

        def pair_body(h, xs):
            pparams, pcache = xs

            def m_body(h2, xs2):
                lp, lc = xs2
                h2, st = xlstm_blocks.mlstm_block_apply(cfg, lp, h2, cache=lc)
                return h2, st

            h, m_states = jax.lax.scan(m_body, h, (pparams["mlstm"], pcache["mlstm"]))
            h, s_state = xlstm_blocks.slstm_block_apply(cfg, pparams["slstm"], h, cache=pcache["slstm"])
            return h, {"mlstm": m_states, "slstm": s_state}

        x, new_cache = jax.lax.scan(pair_body, x, (params["pairs"], cache))
        return x, new_cache

    def forward(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        x = params["embed"][batch["tokens"]]
        cache = self.init_cache(x.shape[0], 0, x.dtype)
        x, _ = self._run(params, x, cache)
        x = rms_norm(x, params["final_ln"])
        return (x @ params["head"]).astype(jnp.float32)

    def prefill(
        self, params: Params, batch: Dict[str, jax.Array], *, max_len: Optional[int] = None
    ) -> Tuple[jax.Array, Cache, jax.Array]:
        x = params["embed"][batch["tokens"]]
        s = x.shape[1]
        cache = self.init_cache(x.shape[0], 0, x.dtype)
        x, new_cache = self._run(params, x, cache)
        x = rms_norm(x, params["final_ln"])
        return (x[:, -1:] @ params["head"]).astype(jnp.float32), new_cache, jnp.asarray(s, jnp.int32)

    def decode(
        self, params: Params, cache: Cache, tokens: jax.Array, cache_len: jax.Array
    ) -> Tuple[jax.Array, Cache]:
        del cache_len  # recurrent: no positional cache index
        x = params["embed"][tokens]
        x, new_cache = self._run(params, x, cache)
        x = rms_norm(x, params["final_ln"])
        return (x @ params["head"]).astype(jnp.float32), new_cache

    def input_specs(self, case: ShapeCase) -> Dict[str, jax.ShapeDtypeStruct]:
        return {"tokens": jax.ShapeDtypeStruct((case.global_batch, case.seq_len), jnp.int32)}


# ---------------------------------------------------------------------------
# factory
# ---------------------------------------------------------------------------


def build_model(cfg: ModelConfig):
    if cfg.family in (DENSE, MOE, VLM):
        return DecoderLM(cfg)
    if cfg.family == AUDIO:
        return EncoderLM(cfg)
    if cfg.family == HYBRID:
        return HybridLM(cfg)
    if cfg.family == SSM:
        return XLSTMLM(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")


def param_count(model) -> int:
    return tree_size(model.param_specs())


def active_param_count(cfg: ModelConfig, model) -> int:
    """Exact active parameters per token: total minus the routed-expert
    fraction that top-k routing leaves idle."""
    total = tree_size(model.param_specs())
    if cfg.moe is None:
        return total
    mo = cfg.moe
    expert_elems = 3 * cfg.d_model * mo.d_expert * mo.num_experts * (cfg.num_layers - mo.first_dense)
    return int(total - expert_elems * (1.0 - mo.top_k / mo.num_experts))
