"""Decoder/encoder blocks: GQA attention, MLA, SwiGLU MLP, routed MoE.

Each block is a pair of functions:

* ``<block>_specs(cfg) -> dict[str, ParamSpec]`` — parameter schema with
  logical sharding axes;
* ``<block>_apply(...)`` — the forward computation (train/prefill form and,
  where applicable, a single-token decode form against a cache).

All matmuls run in the activation dtype (bf16 in production configs);
normalizations and softmax statistics accumulate in f32.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import optim
from repro.models.layers import apply_rope, chunked_attention, rms_norm, swiglu
from repro.models.params import ParamSpec, spec

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Dense GQA attention
# ---------------------------------------------------------------------------


def attn_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    out = {
        "ln": spec((d,), ("act_embed",), init="zeros"),
        "wq": spec((d, cfg.num_heads * hd), ("embed", "q_heads")),
        "wk": spec((d, cfg.num_kv_heads * hd), ("embed", "kv_heads")),
        "wv": spec((d, cfg.num_kv_heads * hd), ("embed", "kv_heads")),
        "wo": spec((cfg.num_heads * hd, d), ("q_heads", "embed")),
    }
    if cfg.attn_softcap > 0:  # gemma2 also post-norms the block output
        out["post_ln"] = spec((d,), ("act_embed",), init="zeros")
    return out


def _split_heads(x: jax.Array, n: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n, -1).transpose(0, 2, 1, 3)  # [B, H, S, hd]


def _merge_heads(x: jax.Array) -> jax.Array:
    b, h, s, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * hd)


def attn_apply(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,  # [B, S, D]
    *,
    positions: jax.Array,  # [S] (or broadcastable)
    causal: bool = True,
    window: Optional[jax.Array] = None,
    cache: Optional[Dict[str, jax.Array]] = None,  # decode: {"k","v"} [B,Hkv,Smax,hd]
    cache_len: Optional[jax.Array] = None,
    block_k: int = 1024,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Returns (block output incl. residual, updated cache or fresh K/V)."""
    h = rms_norm(x, p["ln"])
    q = _split_heads(h @ p["wq"], cfg.num_heads)
    k = _split_heads(h @ p["wk"], cfg.num_kv_heads)
    v = _split_heads(h @ p["wv"], cfg.num_kv_heads)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    # H1 (repro.models.optim): when kv_heads doesn't divide the TP axis,
    # broadcast K/V to the q-head count and shard everything on q-heads —
    # otherwise GSPMD replicates the whole attention on every model rank.
    ka, va = k, v
    if optim.broadcast_kv_active() and cache is None:
        g = cfg.num_heads // cfg.num_kv_heads
        if g > 1:
            ka = jnp.repeat(k, g, axis=1)
            va = jnp.repeat(v, g, axis=1)
        q = optim.shard_attn(q)
        ka = optim.shard_attn(ka)
        va = optim.shard_attn(va)

    if cache is None:
        out = chunked_attention(
            q, ka, va, causal=causal, window=window,
            attn_softcap=cfg.attn_softcap, block_k=block_k,
        )
        out = optim.shard_attn(out)
        new_cache = {"k": k, "v": v}
    else:
        assert cache_len is not None
        k_all = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, cache_len, 0)
        )
        v_all = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, cache_len, 0)
        )
        out = chunked_attention(
            q,
            k_all,
            v_all,
            causal=causal,
            window=window,
            q_offset=cache_len,
            kv_len=cache_len + q.shape[2],
            attn_softcap=cfg.attn_softcap,
            block_k=block_k,
        )
        new_cache = {"k": k_all, "v": v_all}
    proj = _merge_heads(out) @ p["wo"]
    if "post_ln" in p:
        proj = rms_norm(proj, p["post_ln"])
    return x + proj, new_cache


# ---------------------------------------------------------------------------
# Multi-head Latent Attention (deepseek-v3)
# ---------------------------------------------------------------------------


def mla_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    m = cfg.mla
    assert m is not None
    d, H = cfg.d_model, cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "ln": spec((d,), ("act_embed",), init="zeros"),
        "wq_a": spec((d, m.q_lora_rank), ("embed", "q_lora")),
        "q_ln": spec((m.q_lora_rank,), ("q_lora",), init="zeros"),
        "wq_b": spec((m.q_lora_rank, H * qk_head), ("q_lora", "q_heads")),
        "wkv_a": spec((d, m.kv_lora_rank + m.qk_rope_head_dim), ("embed", "kv_lora")),
        "kv_ln": spec((m.kv_lora_rank,), ("kv_lora",), init="zeros"),
        "wkv_b_k": spec((m.kv_lora_rank, H * m.qk_nope_head_dim), ("kv_lora", "q_heads")),
        "wkv_b_v": spec((m.kv_lora_rank, H * m.v_head_dim), ("kv_lora", "q_heads")),
        "wo": spec((H * m.v_head_dim, d), ("q_heads", "embed")),
    }


def mla_apply(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    *,
    positions: jax.Array,
    cache: Optional[Dict[str, jax.Array]] = None,  # {"ckv": [B,Smax,R], "krope": [B,Smax,rd]}
    cache_len: Optional[jax.Array] = None,
    block_k: int = 1024,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    m = cfg.mla
    assert m is not None
    b, s, _ = x.shape
    H = cfg.num_heads
    h = rms_norm(x, p["ln"])
    # queries through the low-rank path
    q_lat = rms_norm(h @ p["wq_a"], p["q_ln"])
    q = (q_lat @ p["wq_b"]).reshape(b, s, H, -1).transpose(0, 2, 1, 3)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    # kv latent + decoupled rope key
    kv_a = h @ p["wkv_a"]
    ckv, k_rope = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    ckv = rms_norm(ckv, p["kv_ln"])  # [B, S, R]
    k_rope = apply_rope(k_rope[:, None], positions, cfg.rope_theta)  # [B,1,S,rd]

    if cache is None:
        # expanded (train/prefill) form: materialize per-head K/V
        k_nope = (ckv @ p["wkv_b_k"]).reshape(b, s, H, -1).transpose(0, 2, 1, 3)
        v = (ckv @ p["wkv_b_v"]).reshape(b, s, H, -1).transpose(0, 2, 1, 3)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, H, s, m.qk_rope_head_dim))], axis=-1)
        qk = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = chunked_attention(qk, k, v, causal=True, block_k=block_k)
        proj = _merge_heads(out) @ p["wo"]
        return x + proj, {"ckv": ckv, "krope": k_rope[:, 0]}

    # absorbed (decode) form: score against the latent cache directly
    assert cache_len is not None
    ckv_all = jax.lax.dynamic_update_slice(
        cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, cache_len, 0)
    )
    krope_all = jax.lax.dynamic_update_slice(
        cache["krope"], k_rope[:, 0].astype(cache["krope"].dtype), (0, cache_len, 0)
    )
    # fold W^UK into the query: q_abs [B,H,S,R]
    wk = p["wkv_b_k"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_abs = jnp.einsum("bhsd,rhd->bhsr", q_nope, wk)
    scale = 1.0 / jnp.sqrt(jnp.asarray(m.qk_nope_head_dim + m.qk_rope_head_dim, jnp.float32))
    scores = (
        jnp.einsum("bhsr,btr->bhst", q_abs.astype(jnp.float32), ckv_all.astype(jnp.float32))
        + jnp.einsum("bhsd,btd->bhst", q_rope.astype(jnp.float32), krope_all.astype(jnp.float32))
    ) * scale
    t = ckv_all.shape[1]
    valid = jnp.arange(t)[None, None, None, :] < (cache_len + s)
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhst,btr->bhsr", probs, ckv_all.astype(jnp.float32))
    wv = p["wkv_b_v"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bhsr,rhd->bhsd", out_lat, wv).astype(x.dtype)
    proj = _merge_heads(out) @ p["wo"]
    return x + proj, {"ckv": ckv_all, "krope": krope_all}


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    f = cfg.d_ff if d_ff is None else d_ff
    out = {
        "ln": spec((d,), ("act_embed",), init="zeros"),
        "w_gate": spec((d, f), ("embed", "mlp")),
        "w_up": spec((d, f), ("embed", "mlp")),
        "w_down": spec((f, d), ("mlp", "embed")),
    }
    if cfg.attn_softcap > 0:
        out["post_ln"] = spec((d,), ("act_embed",), init="zeros")
    return out


def mlp_apply(p: Params, x: jax.Array) -> jax.Array:
    h = rms_norm(x, p["ln"])
    out = swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
    if "post_ln" in p:
        out = rms_norm(out, p["post_ln"])
    return x + out


# ---------------------------------------------------------------------------
# Routed MoE (sort-based capacity dispatch; EP via the "experts" axis)
# ---------------------------------------------------------------------------


def moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    mo = cfg.moe
    assert mo is not None
    d, E, fe = cfg.d_model, mo.num_experts, mo.d_expert
    out = {
        "ln": spec((d,), ("act_embed",), init="zeros"),
        "router": spec((d, E), ("embed", None)),
        "w_gate": spec((E, d, fe), ("experts", "embed", "expert_mlp")),
        "w_up": spec((E, d, fe), ("experts", "embed", "expert_mlp")),
        "w_down": spec((E, fe, d), ("experts", "expert_mlp", "embed")),
    }
    if mo.num_shared:
        fs = mo.d_expert * mo.num_shared
        out["shared_gate"] = spec((d, fs), ("embed", "mlp"))
        out["shared_up"] = spec((d, fs), ("embed", "mlp"))
        out["shared_down"] = spec((fs, d), ("mlp", "embed"))
    return out


def moe_apply(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    """Token-choice top-k with per-expert capacity.

    Dispatch is a sort + scatter (no one-hot einsum, no O(T*E*C) buffers):
    tokens are ordered by assigned expert, placed into a [E, C, D] buffer
    (overflow beyond capacity is dropped, standard for capacity routing),
    the grouped matmuls run expert-parallel, and results scatter back
    weighted by the router probabilities.
    """
    mo = cfg.moe
    assert mo is not None
    b, s, d = x.shape
    t = b * s
    k = mo.top_k
    E = mo.num_experts
    cap = max(int(t * k / E * mo.capacity_factor), 1)
    # round capacity to a lane-friendly multiple
    cap = (cap + 7) // 8 * 8

    h = rms_norm(x, p["ln"])
    flat = h.reshape(t, d)
    logits = (flat @ p["router"]).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # [T, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # sort the T*k (token, slot) pairs by expert id
    e_flat = top_e.reshape(-1)  # [T*k]
    order = jnp.argsort(e_flat)  # stable
    e_sorted = e_flat[order]
    tok_sorted = (order // k).astype(jnp.int32)
    # position of each entry within its expert group
    ar = jnp.arange(t * k, dtype=jnp.int32)
    group_start = jnp.searchsorted(e_sorted, jnp.arange(E, dtype=e_sorted.dtype))
    pos_in_e = ar - group_start[e_sorted]
    keep = pos_in_e < cap
    dest = jnp.where(keep, e_sorted * cap + pos_in_e, E * cap)  # overflow -> waste row

    buf = jnp.zeros((E * cap + 1, d), dtype=x.dtype)
    buf = buf.at[dest].set(flat[tok_sorted])
    grouped = buf[: E * cap].reshape(E, cap, d)

    # expert-parallel grouped SwiGLU
    g = jnp.einsum("ecd,edf->ecf", grouped, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", grouped, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["w_down"])

    # gather back + weighted combine
    y_flat = jnp.concatenate([y.reshape(E * cap, d), jnp.zeros((1, d), y.dtype)])
    contrib = y_flat[dest] * top_p.reshape(-1)[order][:, None].astype(y.dtype)
    combined = jnp.zeros((t, d), dtype=jnp.float32).at[tok_sorted].add(
        contrib.astype(jnp.float32)
    )
    out = combined.astype(x.dtype)

    if mo.num_shared:
        out = out + swiglu(h.reshape(t, d), p["shared_gate"], p["shared_up"], p["shared_down"])
    return x + out.reshape(b, s, d)


def moe_apply_shardmap(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    """H3 (repro.models.optim): expert parallelism via shard_map.

    Each device dispatches only its LOCAL tokens (batch-sharded), runs only
    its LOCAL experts (model-sharded), and the per-token combine is one
    psum over the model axis — the Megatron-style EP pattern. Falls back to
    :func:`moe_apply` when the mesh/shape doesn't fit the pattern.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.models import optim

    f = optim.FLAGS
    mo = cfg.moe
    mesh = f.mesh
    assert mo is not None
    sizes = dict(mesh.shape)
    tp = sizes.get(f.model_axis, 1)
    bdims = tuple(a for a in f.batch_axes if sizes.get(a, 1) > 1)
    bprod = 1
    for a in bdims:
        bprod *= sizes[a]
    E = mo.num_experts
    if tp <= 1 or E % tp or x.shape[0] % max(bprod, 1) or not bdims:
        return moe_apply(cfg, p, x)
    e_loc = E // tp
    k = mo.top_k

    h = rms_norm(x, p["ln"])

    def local_moe(h_loc, router_w, wg, wu, wd):
        b, s, d = h_loc.shape
        t = b * s
        flat = h_loc.reshape(t, d)
        logits = (flat @ router_w).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
        cap = max(int(t * k / E * mo.capacity_factor), 1)
        cap = (cap + 7) // 8 * 8

        e_flat = top_e.reshape(-1)
        order = jnp.argsort(e_flat)
        e_sorted = e_flat[order]
        tok_sorted = (order // k).astype(jnp.int32)
        ar = jnp.arange(t * k, dtype=jnp.int32)
        group_start = jnp.searchsorted(e_sorted, jnp.arange(E, dtype=e_sorted.dtype))
        pos_in_e = ar - group_start[e_sorted]
        e_lo = jax.lax.axis_index(f.model_axis).astype(e_sorted.dtype) * e_loc
        local = (e_sorted >= e_lo) & (e_sorted < e_lo + e_loc) & (pos_in_e < cap)
        dest = jnp.where(local, (e_sorted - e_lo) * cap + pos_in_e, e_loc * cap)

        buf = jnp.zeros((e_loc * cap + 1, d), dtype=h_loc.dtype)
        buf = buf.at[dest].set(flat[tok_sorted])
        grouped = buf[: e_loc * cap].reshape(e_loc, cap, d)
        g = jnp.einsum("ecd,edf->ecf", grouped, wg)
        u = jnp.einsum("ecd,edf->ecf", grouped, wu)
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd)
        y_flat = jnp.concatenate([y.reshape(e_loc * cap, d), jnp.zeros((1, d), y.dtype)])
        w_sorted = top_p.reshape(-1)[order]
        contrib = y_flat[dest] * w_sorted[:, None].astype(y.dtype)
        out = jnp.zeros((t, d), jnp.float32).at[tok_sorted].add(contrib.astype(jnp.float32))
        out = jax.lax.psum(out, f.model_axis)
        return out.astype(h_loc.dtype).reshape(b, s, d)

    bspec = bdims if len(bdims) > 1 else bdims[0]
    out = shard_map(
        local_moe,
        mesh=mesh,
        in_specs=(
            P(bspec, None, None),
            P(None, None),
            P(f.model_axis, None, None),
            P(f.model_axis, None, None),
            P(f.model_axis, None, None),
        ),
        out_specs=P(bspec, None, None),
        check_rep=False,
    )(h, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    if mo.num_shared:
        b, s, d = x.shape
        out = out + swiglu(
            h.reshape(-1, d), p["shared_gate"], p["shared_up"], p["shared_down"]
        ).reshape(b, s, d)
    return x + out


def moe_dense_ref(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    """O(T*E) oracle: every expert runs on every token, masked combine.
    Used by tests on tiny configs to validate the dispatch path."""
    mo = cfg.moe
    assert mo is not None
    b, s, d = x.shape
    h = rms_norm(x, p["ln"])
    flat = h.reshape(-1, d)
    probs = jax.nn.softmax((flat @ p["router"]).astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, mo.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    gates = jnp.zeros_like(probs).at[jnp.arange(flat.shape[0])[:, None], top_e].set(top_p)
    g = jnp.einsum("td,edf->tef", flat, p["w_gate"])
    u = jnp.einsum("td,edf->tef", flat, p["w_up"])
    y = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * u, p["w_down"])
    out = jnp.einsum("ted,te->td", y.astype(jnp.float32), gates).astype(x.dtype)
    if mo.num_shared:
        out = out + swiglu(flat, p["shared_gate"], p["shared_up"], p["shared_down"])
    return x + out.reshape(b, s, d)
