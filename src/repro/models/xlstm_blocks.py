"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, strictly sequential).

Both carry a stabilizer state m so the exponential gating stays finite; the
parallel (training) and recurrent (decode) forms are algebraically
identical and the tests assert so.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rms_norm
from repro.models.params import ParamSpec, spec

Params = Dict[str, Any]


def mlstm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    x = cfg.xlstm
    assert x is not None
    d_in = int(cfg.d_model * x.proj_factor)
    heads = cfg.num_heads
    return d_in, heads, d_in // heads


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    d_in, h, _ = mlstm_dims(cfg)
    return {
        "ln": spec((d,), ("act_embed",), init="zeros"),
        "w_up": spec((d, 2 * d_in), ("embed", "ssm_inner")),
        "wq": spec((d_in, d_in), ("ssm_inner", None)),
        "wk": spec((d_in, d_in), ("ssm_inner", None)),
        "wv": spec((d_in, d_in), ("ssm_inner", None)),
        "w_if": spec((d_in, 2 * h), ("ssm_inner", "ssm_heads")),
        "b_if": spec((2 * h,), ("ssm_heads",), init="zeros"),
        "w_down": spec((d_in, d), ("ssm_inner", "embed")),
    }


def _mlstm_parallel(
    q: jax.Array, k: jax.Array, v: jax.Array, i_raw: jax.Array, f_raw: jax.Array
) -> jax.Array:
    """q,k,v: [B,H,T,Dh]; i_raw,f_raw: [B,H,T]. Returns [B,H,T,Dh]."""
    dh = q.shape[-1]
    log_f = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))
    cum = jnp.cumsum(log_f, axis=-1)  # F_t
    # d[t,s] = F_t - F_s + i_s   (s <= t)
    dmat = cum[..., :, None] - cum[..., None, :] + i_raw.astype(jnp.float32)[..., None, :]
    t = q.shape[2]
    causal = jnp.tril(jnp.ones((t, t), bool))
    dmat = jnp.where(causal, dmat, -jnp.inf)
    m = jnp.max(dmat, axis=-1)  # [B,H,T] running max
    dstab = jnp.exp(dmat - m[..., None])
    scores = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.asarray(dh, jnp.float32)) * dstab
    b = jnp.sum(scores, axis=-1)  # [B,H,T]
    denom = jnp.maximum(jnp.abs(b), jnp.exp(-m))
    out = jnp.einsum("bhts,bhsd->bhtd", scores, v.astype(jnp.float32)) / denom[..., None]
    return out.astype(q.dtype)


def _mlstm_chunked(
    q: jax.Array,  # [B,H,T,Dh]
    k: jax.Array,
    v: jax.Array,
    i_raw: jax.Array,  # [B,H,T]
    f_raw: jax.Array,
    *,
    chunk: int = 256,
    init: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Chunked mLSTM: within-chunk parallel (O(L^2)), cross-chunk recurrent
    matrix state — linear in T, the form that makes xLSTM usable at 32k/500k
    context. Algebraically identical to :func:`_mlstm_parallel` (tests).

    State is tracked stabilized: C_hat = C*exp(-m), n_hat = n*exp(-m).
    """
    b, h, t, dh = q.shape
    L = min(chunk, t)
    nc = (t + L - 1) // L
    pad = nc * L - t
    if pad:
        zq = ((0, 0), (0, 0), (0, pad), (0, 0))
        q, k, v = jnp.pad(q, zq), jnp.pad(k, zq), jnp.pad(v, zq)
        # padded steps: forget-gate 'keep everything' (log_f=0 via +inf raw),
        # input-gate 'add nothing' (i -> -inf)
        i_raw = jnp.pad(i_raw, ((0, 0), (0, 0), (0, pad)), constant_values=-1e30)
        f_raw = jnp.pad(f_raw, ((0, 0), (0, 0), (0, pad)), constant_values=1e30)

    qc = q.reshape(b, h, nc, L, dh).transpose(2, 0, 1, 3, 4).astype(jnp.float32)
    kc = k.reshape(b, h, nc, L, dh).transpose(2, 0, 1, 3, 4).astype(jnp.float32)
    vc = v.reshape(b, h, nc, L, dh).transpose(2, 0, 1, 3, 4).astype(jnp.float32)
    ic = i_raw.reshape(b, h, nc, L).transpose(2, 0, 1, 3).astype(jnp.float32)
    fc = f_raw.reshape(b, h, nc, L).transpose(2, 0, 1, 3).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    causal = jnp.tril(jnp.ones((L, L), bool))

    if init is None:
        state0 = {
            "c": jnp.zeros((b, h, dh, dh), jnp.float32),
            "n": jnp.zeros((b, h, dh), jnp.float32),
            "m": jnp.full((b, h), -1e30, jnp.float32),
        }
    else:
        state0 = {k2: init[k2].astype(jnp.float32) for k2 in ("c", "n", "m")}

    def body(state, inp):
        qq, kk, vv, ii, ff = inp  # [B,H,L,(Dh)]
        c_hat, n_hat, m_prev = state["c"], state["n"], state["m"]
        log_f = jax.nn.log_sigmoid(ff)
        cum = jnp.cumsum(log_f, axis=-1)  # F_t within chunk
        # local pairwise weights d[t,s] = F_t - F_s + i_s (s <= t)
        dmat = cum[..., :, None] - cum[..., None, :] + ii[..., None, :]
        dmat = jnp.where(causal, dmat, -jnp.inf)
        m_local = jnp.max(dmat, axis=-1)  # [B,H,L]
        m_inter = cum + m_prev[..., None]  # state contribution weight F_t + m_prev
        m_t = jnp.maximum(m_local, m_inter)
        dstab = jnp.exp(dmat - m_t[..., None])
        scores = jnp.einsum("bhtd,bhsd->bhts", qq, kk) * scale * dstab
        inter_w = jnp.exp(m_inter - m_t)  # [B,H,L]
        q_c = jnp.einsum("bhtd,bhde->bhte", qq, c_hat) * scale
        q_n = jnp.einsum("bhtd,bhd->bht", qq, n_hat) * scale
        num = jnp.einsum("bhts,bhsd->bhtd", scores, vv) + inter_w[..., None] * q_c
        den = jnp.sum(scores, axis=-1) + inter_w * q_n
        out = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # end-of-chunk state fold
        total = cum[..., -1:]  # F_L
        w = total - cum + ii  # contribution of step s to the final state
        m_new = jnp.maximum(total[..., 0] + m_prev, jnp.max(w, axis=-1))
        ws = jnp.exp(w - m_new[..., None])
        carry_scale = jnp.exp(total[..., 0] + m_prev - m_new)
        c_new = carry_scale[..., None, None] * c_hat + jnp.einsum(
            "bhs,bhsd,bhse->bhde", ws, kk, vv
        )
        n_new = carry_scale[..., None] * n_hat + jnp.einsum("bhs,bhsd->bhd", ws, kk)
        return {"c": c_new, "n": n_new, "m": m_new}, out

    state, outs = jax.lax.scan(body, state0, (qc, kc, vc, ic, fc))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, h, nc * L, dh)[:, :, :t]
    return out.astype(q.dtype), state


def _mlstm_step(
    state: Dict[str, jax.Array],
    q: jax.Array,  # [B,H,Dh]
    k: jax.Array,
    v: jax.Array,
    i_raw: jax.Array,  # [B,H]
    f_raw: jax.Array,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    dh = q.shape[-1]
    c, n, m = state["c"], state["n"], state["m"]  # [B,H,Dh,Dh], [B,H,Dh], [B,H]
    log_f = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))
    i32 = i_raw.astype(jnp.float32)
    m_new = jnp.maximum(log_f + m, i32)
    f_s = jnp.exp(log_f + m - m_new)
    i_s = jnp.exp(i32 - m_new)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    c = f_s[..., None, None] * c + i_s[..., None, None] * (kf[..., :, None] * vf[..., None, :])
    n = f_s[..., None] * n + i_s[..., None] * kf
    qf = q.astype(jnp.float32) / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    b = jnp.einsum("bhd,bhd->bh", qf, n)
    denom = jnp.maximum(jnp.abs(b), jnp.exp(-m_new))
    h = jnp.einsum("bhd,bhde->bhe", qf, c) / denom[..., None]
    return h.astype(q.dtype), {"c": c, "n": n, "m": m_new}


def mlstm_block_apply(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,  # [B,S,D]
    *,
    cache: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    d_in, nh, dh = mlstm_dims(cfg)
    bsz, seq, _ = x.shape
    h = rms_norm(x, p["ln"])
    up = h @ p["w_up"]
    xm, z = jnp.split(up, 2, axis=-1)

    def heads(t: jax.Array) -> jax.Array:
        return t.reshape(bsz, seq, nh, dh).transpose(0, 2, 1, 3)

    q, k, v = heads(xm @ p["wq"]), heads(xm @ p["wk"]), heads(xm @ p["wv"])
    gates = xm @ p["w_if"] + p["b_if"]
    i_raw, f_raw = jnp.split(gates.reshape(bsz, seq, 2, nh).transpose(0, 3, 1, 2), 2, axis=-1)
    i_raw, f_raw = i_raw[..., 0], f_raw[..., 0]  # [B,H,T]

    if seq > 1:
        # chunked: O(T) memory — the form that scales to 32k/500k context
        out, state = _mlstm_chunked(q, k, v, i_raw, f_raw, init=cache)
    else:
        state = cache if cache is not None else _mlstm_zero_state(bsz, nh, dh)
        outs = []
        for t in range(seq):
            o, state = _mlstm_step(state, q[:, :, t], k[:, :, t], v[:, :, t], i_raw[:, :, t], f_raw[:, :, t])
            outs.append(o)
        out = jnp.stack(outs, axis=2)
    merged = out.transpose(0, 2, 1, 3).reshape(bsz, seq, d_in)
    y = merged * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return x + y @ p["w_down"], state


def _mlstm_zero_state(b: int, h: int, dh: int) -> Dict[str, jax.Array]:
    return {
        "c": jnp.zeros((b, h, dh, dh), jnp.float32),
        "n": jnp.zeros((b, h, dh), jnp.float32),
        "m": jnp.full((b, h), -1e30, jnp.float32),
    }


def _mlstm_fold_state(q, k, v, i_raw, f_raw) -> Dict[str, jax.Array]:
    """Final (C, n, m) after consuming the whole sequence (prefill)."""
    log_f = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))
    cum = jnp.cumsum(log_f, axis=-1)
    total = cum[..., -1:]
    w = total - cum + i_raw.astype(jnp.float32)  # log-weight of step s in final state
    m = jnp.max(w, axis=-1)  # [B,H]
    ws = jnp.exp(w - m[..., None])
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    c = jnp.einsum("bhs,bhsd,bhse->bhde", ws, kf, vf)
    n = jnp.einsum("bhs,bhsd->bhd", ws, kf)
    return {"c": c, "n": n, "m": m}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    nh = cfg.num_heads
    dh = d // nh
    return {
        "ln": spec((d,), ("act_embed",), init="zeros"),
        "w_gates": spec((d, 4 * d), ("embed", "ssm_inner")),  # z,i,f,o
        "b_gates": spec((4 * d,), ("ssm_inner",), init="zeros"),
        "r_gates": spec((4, nh, dh, dh), (None, "ssm_heads", None, None), scale=0.5),
        "w_out": spec((d, d), ("ssm_inner", "embed")),
    }


def slstm_block_apply(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    *,
    cache: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    d = cfg.d_model
    nh = cfg.num_heads
    dh = d // nh
    bsz, seq, _ = x.shape
    inp = rms_norm(x, p["ln"])
    gates_x = (inp @ p["w_gates"] + p["b_gates"]).reshape(bsz, seq, 4, nh, dh)

    state = cache if cache is not None else {
        "h": jnp.zeros((bsz, nh, dh), jnp.float32),
        "c": jnp.zeros((bsz, nh, dh), jnp.float32),
        "n": jnp.zeros((bsz, nh, dh), jnp.float32),
        "m": jnp.full((bsz, nh, dh), -1e30, jnp.float32),
    }

    r = p["r_gates"].astype(jnp.float32)  # [4, H, dh, dh]

    def step(st, gx):
        h_prev, c_prev, n_prev, m_prev = st["h"], st["c"], st["n"], st["m"]
        rec = jnp.einsum("ghde,bhd->gbhe", r, h_prev)  # [4,B,H,dh]
        gz = gx[:, 0].astype(jnp.float32) + rec[0]
        gi = gx[:, 1].astype(jnp.float32) + rec[1]
        gf = gx[:, 2].astype(jnp.float32) + rec[2]
        go = gx[:, 3].astype(jnp.float32) + rec[3]
        z = jnp.tanh(gz)
        log_f = jax.nn.log_sigmoid(gf)
        m_new = jnp.maximum(log_f + m_prev, gi)
        i_s = jnp.exp(gi - m_new)
        f_s = jnp.exp(log_f + m_prev - m_new)
        c = f_s * c_prev + i_s * z
        n = f_s * n_prev + i_s
        h = jax.nn.sigmoid(go) * (c / jnp.maximum(n, 1e-6))
        return {"h": h, "c": c, "n": n, "m": m_new}, h

    new_state, hs = jax.lax.scan(
        step, state, gates_x.transpose(1, 0, 2, 3, 4)
    )
    out = hs.transpose(1, 0, 2, 3).reshape(bsz, seq, d).astype(x.dtype)
    return x + out @ p["w_out"], new_state
