"""Shared metadata types for the ROS control plane and the transfer engine.

The reference server never touches weight bytes; it moves only the
lightweight descriptors defined here (3.1: "The server only operates on
lightweight references").
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

#: Tensors smaller than this are compacted into contiguous buffers before
#: registration/transfer (4.3.2 "Tiny-Tensor Optimization").
TINY_TENSOR_BYTES = 2 * 1024 * 1024

#: Data-plane defaults shared by the threaded client, the simulator and
#: the server's scheduler: up to ``DEFAULT_WINDOW`` unit flows in flight
#: per destination shard (windowed pipelining), and units larger than
#: ``DEFAULT_CHUNK_BYTES`` split into byte-range reads. The chunk
#: threshold doubles as the scheduler's "giant unit" hint: workloads
#: whose units exceed it replicate badly over store-and-forward pipeline
#: chains (a relay can only serve *completed* units), so the scheduler
#: prefers partitioning them across fully-published replicas.
DEFAULT_WINDOW = 4
DEFAULT_CHUNK_BYTES = 1024 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class TensorMeta:
    """Descriptor of one named weight tensor held by a shard.

    ``shape`` is the *local* shape of the block this shard holds. The
    optional layout descriptor (``global_shape`` + ``offset``) places the
    local block inside the logical global tensor, enabling cross-layout
    resharding (``repro.resharding``): a destination sharded differently
    from the source intersects its slice against every source shard's
    slice and stripes byte-interval reads across them.

    * ``global_shape is None`` — no layout metadata: the tensor is treated
      as unsharded/identical across layouts (convertible only if the peer
      holds a block of the same local shape).
    * ``offset`` — per-dim start of the local block in global coordinates;
      the slice held is ``[offset[d], offset[d] + shape[d])`` per dim d.
    """

    name: str
    shape: Tuple[int, ...]
    dtype: str  # numpy dtype string, e.g. "bfloat16", "float32"
    nbytes: int
    global_shape: Optional[Tuple[int, ...]] = None
    offset: Optional[Tuple[int, ...]] = None

    def __post_init__(self) -> None:
        if self.nbytes < 0:
            raise ValueError(f"tensor {self.name}: negative nbytes")
        if self.global_shape is not None:
            off = self.offset or (0,) * len(self.global_shape)
            if len(off) != len(self.global_shape) or len(self.shape) != len(
                self.global_shape
            ):
                raise ValueError(f"tensor {self.name}: rank mismatch in layout")
            for o, n, g in zip(off, self.shape, self.global_shape):
                if o < 0 or o + n > g:
                    raise ValueError(
                        f"tensor {self.name}: slice [{o}, {o + n}) exceeds "
                        f"global dim {g}"
                    )

    @property
    def start(self) -> Tuple[int, ...]:
        """Slice start in global coordinates (zeros when unspecified)."""
        if self.offset is not None:
            return self.offset
        return (0,) * len(self.shape)

    @property
    def is_sharded(self) -> bool:
        return self.global_shape is not None and self.global_shape != self.shape


@dataclasses.dataclass(frozen=True)
class TransferUnit:
    """One unit of the data plane: a large tensor or a compacted bucket.

    The per-shard *progress counter* of pipeline replication (4.3.3) counts
    completed TransferUnits, in the deterministic order below. A partially
    replicated shard may serve exactly its prefix of units.
    """

    index: int
    name: str  # tensor name, or "__compact__/<i>" for a bucket
    nbytes: int
    #: member tensor names for a compacted bucket (empty for a plain tensor)
    members: Tuple[str, ...] = ()
    #: (name, offset, nbytes) layout of members inside the bucket
    layout: Tuple[Tuple[str, int, int], ...] = ()

    @property
    def is_compact(self) -> bool:
        return bool(self.members)


@dataclasses.dataclass(frozen=True)
class ShardManifest:
    """Everything a reader needs to pull one shard: ordered transfer units
    plus per-unit checksums. Attached to a publish() and stored (by
    reference) at the server."""

    tensors: Tuple[TensorMeta, ...]
    units: Tuple[TransferUnit, ...]
    checksums: Tuple[int, ...]  # per-unit; 0 when checksums disabled

    @property
    def total_bytes(self) -> int:
        return sum(u.nbytes for u in self.units)

    @property
    def num_units(self) -> int:
        return len(self.units)

    def validate_against(self, other: "ShardManifest") -> bool:
        """Shard-layout compatibility: same unit schema (names+sizes)."""
        if len(self.units) != len(other.units):
            return False
        return all(
            a.name == b.name and a.nbytes == b.nbytes and a.members == b.members
            for a, b in zip(self.units, other.units)
        )

    def same_layout(self, other: "ShardManifest") -> bool:
        """True when both shards hold byte-identical slices: same tensors,
        dtypes, local shapes AND layout descriptors. Two manifests can
        share a unit schema (validate_against) yet slice the global
        tensors along different axes — unit-for-unit copying between them
        would silently scramble weights; this is the check that gates the
        same-layout fast path."""
        theirs = {t.name: t for t in other.tensors}
        if len(self.tensors) != len(theirs):
            return False
        for a in self.tensors:
            b = theirs.get(a.name)
            if b is None:
                return False
            if (
                a.shape != b.shape
                or a.dtype != b.dtype
                or (a.global_shape or a.shape) != (b.global_shape or b.shape)
                or a.start != b.start
            ):
                return False
        return True


# ---------------------------------------------------------------------------
# Wire serialization (control-plane fault tolerance)
# ---------------------------------------------------------------------------
#
# The replayable op log and the failover snapshots need every control-plane
# record — op payloads (manifests, worker infos, version specs) and the
# server's own state dataclasses — in a JSON-able form. Rather than one
# hand-written encoder per type, a small generic codec walks registered
# dataclasses and the containers they nest (tuples, sets, dicts with tuple
# keys) and tags each non-JSON shape so the inverse is exact: a round trip
# through ``to_wire``/``from_wire`` reconstructs equal objects, and two
# equal object graphs encode to equal wire trees (the property the
# replay-equivalence tests compare on).

_WIRE_TYPES: Dict[str, type] = {}


def register_wire(cls: type) -> type:
    """Register a dataclass for wire encoding (usable as a decorator)."""
    _WIRE_TYPES[cls.__name__] = cls
    return cls


def to_wire(obj):
    """Encode ``obj`` into a JSON-able tree of dicts/lists/scalars."""
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    name = type(obj).__name__
    if dataclasses.is_dataclass(obj) and name in _WIRE_TYPES:
        return {
            "__dc__": name,
            "f": {
                f.name: to_wire(getattr(obj, f.name))
                for f in dataclasses.fields(obj)
            },
        }
    if isinstance(obj, tuple):
        return {"__tuple__": [to_wire(x) for x in obj]}
    if isinstance(obj, list):
        return [to_wire(x) for x in obj]
    if isinstance(obj, (set, frozenset)):
        # canonical order so equal sets encode identically
        return {"__set__": sorted((to_wire(x) for x in obj), key=repr)}
    if isinstance(obj, dict):
        # pair list: keys may be tuples (layout families, txn keys)
        return {"__map__": [[to_wire(k), to_wire(v)] for k, v in obj.items()]}
    raise TypeError(f"not wire-serializable: {name}")


def from_wire(w):
    """Inverse of :func:`to_wire`."""
    if w is None or isinstance(w, (bool, int, float, str)):
        return w
    if isinstance(w, list):
        return [from_wire(x) for x in w]
    if "__dc__" in w:
        cls = _WIRE_TYPES.get(w["__dc__"])
        if cls is None:
            raise TypeError(f"unknown wire type {w['__dc__']!r}")
        return cls(**{k: from_wire(v) for k, v in w["f"].items()})
    if "__tuple__" in w:
        return tuple(from_wire(x) for x in w["__tuple__"])
    if "__set__" in w:
        return {from_wire(x) for x in w["__set__"]}
    if "__map__" in w:
        out = {}
        for k, v in w["__map__"]:
            key = from_wire(k)
            out[tuple(key) if isinstance(key, list) else key] = from_wire(v)
        return out
    raise TypeError(f"malformed wire value: {w!r}")


def dtype_from_str(name: str):
    """numpy dtype from its string name, including ml_dtypes extras
    (bfloat16 etc.). Shared by the client and the resharding layer."""
    import numpy as np

    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # registers bfloat16 etc.

        return np.dtype(getattr(ml_dtypes, name))


def build_units(
    tensors: Sequence[TensorMeta],
    *,
    tiny_bytes: int = TINY_TENSOR_BYTES,
) -> List[TransferUnit]:
    """Compute the transfer-unit schedule for a shard.

    Large tensors become one unit each (registered directly with the NIC in
    RDMA-direct mode); tiny tensors are packed into contiguous buckets of up
    to ``tiny_bytes`` so that registration cost and per-message overhead are
    amortized. Order is registration order, which both sides share.
    """
    units: List[TransferUnit] = []
    bucket: List[TensorMeta] = []
    bucket_bytes = 0

    def flush_bucket() -> None:
        nonlocal bucket, bucket_bytes
        if not bucket:
            return
        layout = []
        off = 0
        for t in bucket:
            layout.append((t.name, off, t.nbytes))
            off += t.nbytes
        units.append(
            TransferUnit(
                index=len(units),
                name=f"__compact__/{len(units)}",
                nbytes=off,
                members=tuple(t.name for t in bucket),
                layout=tuple(layout),
            )
        )
        bucket = []
        bucket_bytes = 0

    for t in tensors:
        if t.nbytes < tiny_bytes:
            if bucket_bytes + t.nbytes > tiny_bytes and bucket:
                flush_bucket()
            bucket.append(t)
            bucket_bytes += t.nbytes
        else:
            units.append(TransferUnit(index=len(units), name=t.name, nbytes=t.nbytes))
    flush_bucket()
    # re-number: buckets were appended with provisional indices
    return [dataclasses.replace(u, index=i) for i, u in enumerate(units)]


@dataclasses.dataclass(frozen=True)
class WorkerInfo:
    """Placement of one shard-owning worker, used for topology-aware
    scheduling (4.3.1) and NIC affinity."""

    worker_id: str
    node: str
    datacenter: str
    is_spot: bool = False


# ---------------------------------------------------------------------------
# Read-plan metadata (shared by the server's scheduler and both data planes)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SourceSlice:
    """One source replica's share of a destination's transfer-unit list.

    The multi-source scheduler partitions the destination's units
    ``[start_unit, stop_unit)`` across all eligible replicas holding the
    version; a ``stop_unit`` of ``-1`` means "through the last unit"
    (emitted when the server does not know the destination's unit count).

    ``ceiling`` is the source's *progress ceiling* at plan time (swarm
    replication): the number of units of its completed prefix, i.e. the
    most a reader may pull from it without re-checking progress. ``-1``
    means the source was fully published when the plan was built. A
    partial (in-progress) source serves exactly ``[0, ceiling)``; reads
    beyond it must first await the source's live progress counter — the
    never-read-past-source-prefix contract both data planes enforce.

    ``codec`` is the wire codec the server negotiated for this link
    (``repro.transfer.codec``): WAN-crossing slices default to ``int8``,
    intra-DC (and all resharded interval reads) stay ``raw``. Both data
    planes honor it — the threaded transport encodes/decodes real bytes,
    the simulator derives fluid wire bytes from the codec's ratio."""

    source: str
    source_kind: str
    transport: str  # "rdma" | "tcp"
    start_unit: int
    stop_unit: int
    seeding: bool = False
    source_shards: int = 0
    ceiling: int = -1
    codec: str = "raw"

    def serves_whole_range(self) -> bool:
        """True when the plan-time prefix already covers the assigned
        range (no progress gating needed for any unit in it)."""
        return self.ceiling < 0 or self.stop_unit <= self.ceiling


for _cls in (TensorMeta, TransferUnit, ShardManifest, WorkerInfo, SourceSlice):
    register_wire(_cls)


@register_wire
@dataclasses.dataclass(frozen=True)
class Assignment:
    """Where a shard should pull its data from.

    ``source_shards``/``dest_shards`` carry the two replicas' shard
    layouts; when they differ the destination runs the cross-layout
    resharding path (``repro.resharding``): every destination shard
    stripes byte-interval reads across *all* source shards instead of the
    shard-to-shard unit pipe. Zero means "unknown" (legacy constructors)
    and is treated as same-layout.

    ``sources`` is the multi-source read plan: per-source unit ranges
    partitioned over every eligible replica holding the version —
    including, under swarm replication, *in-progress* replicas serving
    their completed prefix (each slice's ``ceiling``). The legacy
    single-source fields (``source``/``transport``/...) always describe
    the *primary* source — ``sources[0]`` when a plan exists. ``epoch``
    identifies the plan revision; the server bumps it on re-partitioning
    (source failure, work stealing, swarm growth) and readers compare it
    against ``ReferenceServer.assignment_epoch`` to pick up the new plan
    mid-transfer.
    """

    version: int
    source: str
    source_kind: str
    transport: str  # "rdma" | "tcp"
    seeding: bool = False  # dest becomes its DC's seeding replica
    source_shards: int = 0
    dest_shards: int = 0
    sources: Tuple[SourceSlice, ...] = ()
    epoch: int = 0
    #: wire codec of the *primary* source link (``sources[0].codec`` when
    #: a plan exists); legacy single-source pulls read it directly
    codec: str = "raw"

    @property
    def resharded(self) -> bool:
        return (
            self.source_shards > 0
            and self.dest_shards > 0
            and self.source_shards != self.dest_shards
        )

    @property
    def multi_source(self) -> bool:
        return len(self.sources) > 1

    @property
    def swarm(self) -> bool:
        """True when any plan member was serving a partial prefix."""
        return any(s.ceiling >= 0 for s in self.sources)

    def slices(self, num_units: int) -> List[SourceSlice]:
        """Normalized per-source unit ranges: legacy single-source
        assignments expand to one slice spanning every unit, and
        open-ended ranges are clamped to ``num_units``."""
        if self.sources:
            return [
                dataclasses.replace(
                    s,
                    stop_unit=num_units if s.stop_unit < 0 else min(s.stop_unit, num_units),
                )
                for s in self.sources
            ]
        return [
            SourceSlice(
                source=self.source,
                source_kind=self.source_kind,
                transport=self.transport,
                start_unit=0,
                stop_unit=num_units,
                seeding=self.seeding,
                source_shards=self.source_shards,
                codec=self.codec,
            )
        ]
