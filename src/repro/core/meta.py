"""Shared metadata types for the ROS control plane and the transfer engine.

The reference server never touches weight bytes; it moves only the
lightweight descriptors defined here (3.1: "The server only operates on
lightweight references").
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

#: Tensors smaller than this are compacted into contiguous buffers before
#: registration/transfer (4.3.2 "Tiny-Tensor Optimization").
TINY_TENSOR_BYTES = 2 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class TensorMeta:
    """Descriptor of one named weight tensor held by a shard."""

    name: str
    shape: Tuple[int, ...]
    dtype: str  # numpy dtype string, e.g. "bfloat16", "float32"
    nbytes: int

    def __post_init__(self) -> None:
        if self.nbytes < 0:
            raise ValueError(f"tensor {self.name}: negative nbytes")


@dataclasses.dataclass(frozen=True)
class TransferUnit:
    """One unit of the data plane: a large tensor or a compacted bucket.

    The per-shard *progress counter* of pipeline replication (4.3.3) counts
    completed TransferUnits, in the deterministic order below. A partially
    replicated shard may serve exactly its prefix of units.
    """

    index: int
    name: str  # tensor name, or "__compact__/<i>" for a bucket
    nbytes: int
    #: member tensor names for a compacted bucket (empty for a plain tensor)
    members: Tuple[str, ...] = ()
    #: (name, offset, nbytes) layout of members inside the bucket
    layout: Tuple[Tuple[str, int, int], ...] = ()

    @property
    def is_compact(self) -> bool:
        return bool(self.members)


@dataclasses.dataclass(frozen=True)
class ShardManifest:
    """Everything a reader needs to pull one shard: ordered transfer units
    plus per-unit checksums. Attached to a publish() and stored (by
    reference) at the server."""

    tensors: Tuple[TensorMeta, ...]
    units: Tuple[TransferUnit, ...]
    checksums: Tuple[int, ...]  # per-unit; 0 when checksums disabled

    @property
    def total_bytes(self) -> int:
        return sum(u.nbytes for u in self.units)

    @property
    def num_units(self) -> int:
        return len(self.units)

    def validate_against(self, other: "ShardManifest") -> bool:
        """Shard-layout compatibility: same unit schema (names+sizes)."""
        if len(self.units) != len(other.units):
            return False
        return all(
            a.name == b.name and a.nbytes == b.nbytes and a.members == b.members
            for a, b in zip(self.units, other.units)
        )


def build_units(
    tensors: Sequence[TensorMeta],
    *,
    tiny_bytes: int = TINY_TENSOR_BYTES,
) -> List[TransferUnit]:
    """Compute the transfer-unit schedule for a shard.

    Large tensors become one unit each (registered directly with the NIC in
    RDMA-direct mode); tiny tensors are packed into contiguous buckets of up
    to ``tiny_bytes`` so that registration cost and per-message overhead are
    amortized. Order is registration order, which both sides share.
    """
    units: List[TransferUnit] = []
    bucket: List[TensorMeta] = []
    bucket_bytes = 0

    def flush_bucket() -> None:
        nonlocal bucket, bucket_bytes
        if not bucket:
            return
        layout = []
        off = 0
        for t in bucket:
            layout.append((t.name, off, t.nbytes))
            off += t.nbytes
        units.append(
            TransferUnit(
                index=len(units),
                name=f"__compact__/{len(units)}",
                nbytes=off,
                members=tuple(t.name for t in bucket),
                layout=tuple(layout),
            )
        )
        bucket = []
        bucket_bytes = 0

    for t in tensors:
        if t.nbytes < tiny_bytes:
            if bucket_bytes + t.nbytes > tiny_bytes and bucket:
                flush_bucket()
            bucket.append(t)
            bucket_bytes += t.nbytes
        else:
            units.append(TransferUnit(index=len(units), name=t.name, nbytes=t.nbytes))
    flush_bucket()
    # re-number: buckets were appended with provisional indices
    return [dataclasses.replace(u, index=i) for i, u in enumerate(units)]


@dataclasses.dataclass(frozen=True)
class WorkerInfo:
    """Placement of one shard-owning worker, used for topology-aware
    scheduling (4.3.1) and NIC affinity."""

    worker_id: str
    node: str
    datacenter: str
    is_spot: bool = False
