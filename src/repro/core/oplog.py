"""Replayable op log for the control plane (fault tolerance).

Every state-mutating :class:`~repro.core.server.ReferenceServer` entry
point appends one :class:`OpRecord` — op name, logical sequence number,
and the call's arguments as a serializable payload — *before* executing.
Because the server is deterministic (no wall clock, no RNG; time enters
only as explicit ``now`` arguments), replaying the records in order
rebuilds a bit-identical server: that is what ``repro.core.failover``
does after a controller crash.

Durability model
----------------
``append`` buffers records in an in-memory *tail*; ``flush`` moves the
tail to the *committed* region (and, when a ``path`` is configured,
writes JSONL lines through to the file). ``group_commit=N`` auto-flushes
every N records — the classic group-commit batch that amortizes the
sync cost across concurrent writers. A crash loses the unflushed tail
(:meth:`lose_tail` simulates exactly that); recovery replays the
committed region only, and clients re-assert whatever the tail carried
(their registration, published version, and in-flight progress — see
``ShardHandle.reassert``).

Compaction
----------
:meth:`compact` installs a :class:`Snapshot` (a full serialized server
state at some sequence number, built by ``failover.take_snapshot``) and
drops every record it covers, making recovery O(live state) instead of
O(history).
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, Dict, Iterator, List, NamedTuple, Optional

from repro.core.errors import TensorHubError
from repro.core.meta import ShardManifest, from_wire, to_wire


#: per-op argument schemas, in ReferenceServer method signature order.
#: The hot path logs a bare positional tuple (building one kwargs dict
#: per mutation would already cost a measurable fraction of an in-process
#: publish); names are reattached lazily at replay/serialization time —
#: exactly how a real RPC layer's fixed wire schema works.
OP_SCHEMAS: Dict[str, tuple] = {
    "open": ("model", "replica", "num_shards", "shard_idx", "worker", "retain"),
    "register": ("model", "replica", "shard_idx"),
    "unregister": ("model", "replica", "shard_idx"),
    "close": ("model", "replica", "shard_idx"),
    "heartbeat": ("model", "replica", "shard_idx", "now"),
    "tick": ("now",),
    "fail_replica": ("model", "replica", "reason"),
    # evidence/now were appended for gray-failure classification; records
    # logged before that carry 3 args and replay with the server defaults
    # (zip() in kwargs() stops at the shorter tuple)
    "report_transfer_failure": (
        "model", "dest_replica", "source_replica", "evidence", "now",
    ),
    "publish": ("model", "replica", "shard_idx", "version", "manifest", "op_id"),
    "publish_offload": (
        "model", "replica", "shard_idx", "version", "manifest", "op_id",
    ),
    "unpublish": ("model", "replica", "shard_idx", "op_id"),
    "finish_unpublish": ("model", "replica"),
    "begin_replicate": ("model", "replica", "shard_idx", "spec", "op_id"),
    "begin_update": (
        "model", "replica", "shard_idx", "spec", "op_id", "offload_seeding",
    ),
    "update_progress": ("model", "replica", "shard_idx", "version", "progress"),
    "complete_replicate": ("model", "replica", "shard_idx", "version", "op_id"),
    "put_manifest": ("model", "replica", "shard_idx", "version", "manifest"),
    "poll_events": ("worker_id",),
}


class OpRecord(NamedTuple):
    """One logged control-plane mutation. ``args`` is positional, in
    ``OP_SCHEMAS[op]`` order; :meth:`kwargs` reattaches the names."""

    seq: int
    op: str  # ReferenceServer method name
    args: tuple

    def kwargs(self) -> Dict[str, object]:
        return dict(zip(OP_SCHEMAS[self.op], self.args))


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """Serialized full server state as of (and including) record ``seq``."""

    seq: int
    state: dict  # wire-encoded (JSON-able) — see failover.take_snapshot


class OpLog:
    """Append-only op log with group-commit batching and compaction."""

    def __init__(
        self, *, group_commit: int = 1, path: Optional[str] = None
    ) -> None:
        if group_commit < 1:
            raise ValueError(f"group_commit must be >= 1, got {group_commit}")
        self.group_commit = group_commit
        self.path = path
        #: server construction knobs, written once by the first server
        #: attached to this log; recovery rebuilds the server from them
        self.config: Optional[Dict[str, object]] = None
        self.snapshot: Optional[Snapshot] = None
        #: raw (seq, op, args) tuples — OpRecord views are materialized
        #: lazily by committed() / the on_append hook, off the hot path
        self._committed: List[tuple] = []
        self._tail: List[tuple] = []
        self._seq = 0
        #: test/benchmark hook: called after every append (and after the
        #: auto-flush it may trigger) with the new record — the crash
        #: sweeps use it to kill the controller at exact op boundaries
        self.on_append: Optional[Callable[[OpRecord], None]] = None
        self.flushes = 0
        self._fp = None
        #: file-sink manifest interning: a ShardManifest is encoded once
        #: as a "blob" line and later records reference it by key — the
        #: log moves lightweight references, like the server itself
        self._blob_ids: Dict[int, int] = {}
        #: blob-key base for the file sink. Seeded past the keys already
        #: present when a log file is reopened (:meth:`open_path`) so a
        #: restarted controller never reuses a live key — readers resolve
        #: blob references in file order, but distinct keys keep the file
        #: greppable and compaction-safe regardless of interleaving.
        self._blob_base = 0
        if path is not None:
            self._fp = open(path, "a", encoding="utf-8")
        #: direct mode: memory sink with group_commit=1 — every append is
        #: instantly durable, so records skip the tail/flush machinery
        #: entirely (this is the control plane's hot path)
        self._direct = path is None and group_commit == 1

    # -- write path -----------------------------------------------------------

    def set_config(self, config: Dict[str, object]) -> None:
        """First attached server wins; a conflicting re-attach is a bug."""
        if self.config is None:
            self.config = dict(config)
            if self._fp is not None:
                self._fp.write(
                    json.dumps({"kind": "config", "config": self.config}) + "\n"
                )
        elif self.config != config:
            raise TensorHubError(
                "op log already carries a different server config; recover "
                "through repro.core.failover instead of re-initializing"
            )

    def append(self, op: str, args: tuple = ()) -> None:
        # hot path: one bare tuple per mutation, args stored by reference
        # (frozen metadata records or scalars) — never copied or encoded
        # here. The file sink encodes lazily at flush, amortized by the
        # group commit; to_jsonl encodes on demand.
        self._seq += 1
        if self._direct:
            self._committed.append((self._seq, op, args))
        else:
            self._tail.append((self._seq, op, args))
            if len(self._tail) >= self.group_commit:
                self.flush()
        cb = self.on_append
        if cb is not None:
            cb(OpRecord(self._seq, op, args))

    def _encode_into(
        self,
        records,
        blob_ids: Dict[int, int],
        lines: List[str],
        *,
        base: int = 0,
    ) -> None:
        """Encode records as JSONL, interning each distinct manifest as a
        one-time "blob" line that later records reference by key (allocated
        from ``base + 1`` upward)."""
        for seq, op, args in records:
            enc = []
            for a in args:
                if isinstance(a, ShardManifest):
                    key = blob_ids.get(id(a))
                    if key is None:
                        key = base + len(blob_ids) + 1
                        blob_ids[id(a)] = key
                        lines.append(
                            json.dumps(
                                {"kind": "blob", "key": key, "value": to_wire(a)}
                            )
                        )
                    enc.append({"__blob__": key})
                else:
                    enc.append(to_wire(a))
            lines.append(
                json.dumps({"kind": "op", "seq": seq, "op": op, "args": enc})
            )

    def flush(self) -> None:
        """Commit the tail (group commit): the records become durable."""
        if not self._tail:
            return
        if self._fp is not None:
            lines: List[str] = []
            self._encode_into(self._tail, self._blob_ids, lines, base=self._blob_base)
            self._fp.write("\n".join(lines) + "\n")
            self._fp.flush()
        self._committed.extend(self._tail)
        self._tail.clear()
        self.flushes += 1

    def lose_tail(self) -> int:
        """Crash simulation: drop the unflushed tail; returns the count.

        Sequence numbers are not reused — replay tolerates gaps because
        every op is idempotent under re-delivery."""
        n = len(self._tail)
        self._tail = []
        return n

    def compact(self, snapshot: Snapshot) -> None:
        """Install a snapshot and drop the records it covers."""
        self.flush()
        self.snapshot = snapshot
        self._committed = [r for r in self._committed if r[0] > snapshot.seq]
        if self._fp is not None:  # rewrite: snapshot line + surviving suffix
            # crash-safe: build the compacted image in a temp file and
            # atomically rename it over the log — truncating in place
            # would destroy the whole durable history on a crash mid-write
            self._fp.close()
            tmp_path = self.path + ".compact"
            self._blob_ids = {}  # fresh file: re-intern on demand
            self._blob_base = 0
            lines: List[str] = []
            if self.config is not None:
                lines.append(json.dumps({"kind": "config", "config": self.config}))
            lines.append(
                json.dumps(
                    {"kind": "snapshot", "seq": snapshot.seq, "state": snapshot.state}
                )
            )
            self._encode_into(self._committed, self._blob_ids, lines)
            with open(tmp_path, "w", encoding="utf-8") as tmp:
                if lines:
                    tmp.write("\n".join(lines) + "\n")
                tmp.flush()
                os.fsync(tmp.fileno())
            os.replace(tmp_path, self.path)
            self._fp = open(self.path, "a", encoding="utf-8")

    def close(self) -> None:
        self.flush()
        if self._fp is not None:
            self._fp.close()
            self._fp = None

    # -- read path ------------------------------------------------------------

    @property
    def last_seq(self) -> int:
        """Sequence number of the last appended (not necessarily
        committed) record."""
        return self._seq

    @property
    def committed_seq(self) -> int:
        return self._committed[-1][0] if self._committed else (
            self.snapshot.seq if self.snapshot is not None else 0
        )

    def committed(self, after: int = 0) -> Iterator[OpRecord]:
        """Durable records with seq > ``after``, in order."""
        for seq, op, args in self._committed:
            if seq > after:
                yield OpRecord(seq, op, args)

    def __len__(self) -> int:
        return len(self._committed) + len(self._tail)

    # -- serialization --------------------------------------------------------

    def to_jsonl(self) -> str:
        """Durable content (config + snapshot + committed records) as
        JSONL — what a crash leaves on disk."""
        lines: List[str] = []
        if self.config is not None:
            lines.append(json.dumps({"kind": "config", "config": self.config}))
        if self.snapshot is not None:
            lines.append(
                json.dumps(
                    {
                        "kind": "snapshot",
                        "seq": self.snapshot.seq,
                        "state": self.snapshot.state,
                    }
                )
            )
        self._encode_into(self._committed, {}, lines)
        return "\n".join(lines) + ("\n" if lines else "")

    @classmethod
    def from_jsonl(cls, text: str, *, group_commit: int = 1) -> "OpLog":
        log = cls(group_commit=group_commit)
        blobs: Dict[int, object] = {}

        def arg(a):
            if isinstance(a, dict) and "__blob__" in a:
                return blobs[a["__blob__"]]
            return from_wire(a)

        for line in text.splitlines():
            line = line.strip()
            if not line:
                continue
            obj = json.loads(line)
            kind = obj.get("kind")
            if kind == "config":
                log.config = obj["config"]
            elif kind == "snapshot":
                log.snapshot = Snapshot(seq=obj["seq"], state=obj["state"])
            elif kind == "blob":
                blobs[obj["key"]] = from_wire(obj["value"])
                log._blob_base = max(log._blob_base, int(obj["key"]))
            elif kind == "op":
                rec = (obj["seq"], obj["op"], tuple(arg(a) for a in obj["args"]))
                log._committed.append(rec)
                log._seq = max(log._seq, rec[0])
            else:
                raise TensorHubError(f"bad op-log line kind: {kind!r}")
        if log.snapshot is not None:
            log._seq = max(log._seq, log.snapshot.seq)
        return log

    @classmethod
    def open_path(
        cls, path: str, *, group_commit: int = 1
    ) -> "OpLog":
        """Reopen a file-backed log in place: parse the durable content
        that a crash (or clean shutdown) left at ``path``, then continue
        appending to the same file.

        This is the networked controller's restart path —
        ``repro.core.failover.recover_path`` feeds the parsed records to
        replay and hands the still-attached log back to the rebuilt
        server, so the WAL keeps growing where the dead process stopped.
        Sequence numbers continue past the parsed maximum and blob keys
        are allocated past any key already present in the file; replay
        resolves blob references in file order either way."""
        try:
            with open(path, "r", encoding="utf-8") as fh:
                text = fh.read()
        except FileNotFoundError:
            text = ""
        log = cls.from_jsonl(text, group_commit=group_commit)
        log.path = path
        log._fp = open(path, "a", encoding="utf-8")
        log._direct = False  # records must reach the file sink via flush
        return log


