"""The ROS reference server (3, 4).

The server is the *control plane only*: it tracks which workers hold which
versions of the model weights and routes read requests to the least-loaded,
topology-closest source. It never stores or forwards weight bytes.

Design notes
------------
* **Deterministic, single-threaded semantics.** Every public method mutates
  state atomically and returns immediately (no blocking inside the server).
  Blocking client semantics (replicate waits for a version, unpublish drains)
  are built from the pending-ticket / event machinery here. Concurrency
  wrappers (threads in the real client, virtual time in the simulator) live
  outside. This is what makes FoundationDB-style deterministic interleaving
  tests possible (4.6).
* **Transactions per model-parallel group** (4.4): each replica's shards
  issue an identical op sequence (SPMD); ops carry ``op_id``. The first
  shard's arrival executes the op on behalf of the group and caches the
  result; later shards consume the cached result, so the whole group
  observes one consistent snapshot regardless of interleaving.
* **Soft state** (4.5): everything here can be lost; a backup server is
  repopulated by the next round of publishes.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

from repro.core import meta as meta_defaults
from repro.core import versions as version_lib
from repro.core.errors import (
    ConsistencyError,
    MutabilityViolationError,
    ServerUnavailableError,
    ShardLayoutError,
    StaleHandleError,
    TensorHubError,
    VersionUnavailableError,
)
from repro.core.meta import Assignment, ShardManifest, SourceSlice, WorkerInfo
from repro.core.oplog import OP_SCHEMAS, OpLog

logger = logging.getLogger(__name__)

# ---------------------------------------------------------------------------
# The remotable control-plane surface
# ---------------------------------------------------------------------------
#
# ``ReferenceServer`` is transport-agnostic: every public method is a plain
# call that mutates atomically and returns immediately, so any transport —
# the in-process client, or the networked ``repro.net`` service — is a thin
# dispatcher over this table. Mutating ops carry their wire schema in
# ``oplog.OP_SCHEMAS`` (the WAL payload IS the RPC payload); read-only ops
# declare theirs here. Anything not in ``CONTROL_OPS`` is not remotable:
# the networked service refuses it, so a forged frame can never reach
# private state (``_record``, ``__class__``, ...).

#: read-only ops: name -> positional argument names (keyword-only arguments
#: ride the request's ``kw`` map and are listed after a ``*`` marker).
READONLY_OPS: Dict[str, tuple] = {
    "get_assignment": ("model", "replica"),
    "assignment_epoch": ("model", "replica", "version"),
    "redeem": ("model", "replica", "*", "op_id"),
    "source_progress": ("model", "source_replica", "version"),
    "shard_progress": ("model", "source_replica", "version", "shard_idx"),
    "list_versions": ("model",),
    "latest": ("model",),
    "num_shards": ("model",),
    "replica_version": ("model", "replica"),
    "manifest": ("model", "version", "shard_idx", "*", "num_shards"),
    "replica_manifest": ("model", "version", "replica", "shard_idx"),
    "replica_datacenter": ("model", "replica"),
    "availability": ("model", "version"),
    "metrics": (),
    "metrics_text": (),
    "config": (),
    # administrative: kill this controller (tests / chaos drills); not
    # logged — a crash is precisely the thing the WAL does not record
    "crash": (),
}

#: every server method a transport may dispatch to, mutating or not
CONTROL_OPS: Set[str] = set(OP_SCHEMAS) | set(READONLY_OPS)

# ---------------------------------------------------------------------------
# Replica/version state
# ---------------------------------------------------------------------------

IN_PROGRESS = "in_progress"
PUBLISHED = "published"
DRAINING = "draining"

KIND_GPU = "gpu"
KIND_OFFLOAD = "offload"


@dataclasses.dataclass
class ReplicaVersionState:
    """One replica's relationship to one version."""

    replica: str
    version: int
    kind: str = KIND_GPU
    status: str = PUBLISHED
    #: per-shard count of transfer units received (pipeline progress, 4.3.3)
    progress: Dict[int, int] = dataclasses.field(default_factory=dict)
    #: replication sessions this replica currently serves (load balancing)
    refcount: int = 0
    #: for in-progress replicas: the source replica currently assigned
    source: Optional[str] = None
    #: True while this replica fetches over the slow cross-DC link (4.3.4)
    seeding: bool = False
    #: pipeline-chain depth from the original publisher (0 = published
    #: directly). Used by the beyond-paper "depth_aware" scheduler: a
    #: shallow replication tree cuts the pipeline fill latency that a pure
    #: least-loaded policy (which degenerates into a chain) pays.
    depth: int = 0
    #: offload replica created for *cross-DC seeding* (released once a local
    #: GPU replica has consumed it), vs a retention offload (released once it
    #: is no longer the last copy / no longer retained)
    seed_cache: bool = False
    #: shards that called complete_replicate
    completed_shards: Set[int] = dataclasses.field(default_factory=set)
    #: for in-progress replicas: the multi-source read plan as ordered
    #: (source replica, start_unit, stop_unit) ranges; ``source`` above is
    #: always the plan's primary (first) entry
    plan: List[Tuple[str, int, int]] = dataclasses.field(default_factory=list)
    #: bumped whenever the plan is (re)partitioned; readers poll it
    assign_epoch: int = 0
    #: this replica *as a source*: per-shard count of active readers
    shard_readers: Dict[int, int] = dataclasses.field(default_factory=dict)
    #: snapshot of the version's source generation when the plan was built
    #: (work stealing: a reader's progress report re-partitions only when a
    #: source arrived since — an O(1) check on the hot path)
    plan_gen: int = 0
    #: swarm replication: set once this in-progress replica's completed
    #: prefix (min over shards) first becomes non-empty — the moment it
    #: enters the unit-granular availability map as a servable source.
    #: The announcement bumps the version's source generation exactly
    #: once, so readers re-scan the pool without per-report churn.
    swarm_announced: bool = False

    def is_source_candidate(self) -> bool:
        return self.status in (PUBLISHED, IN_PROGRESS)


@dataclasses.dataclass
class ReplicaInfo:
    """An open replica (model-parallel group) and its placement."""

    name: str
    num_shards: int
    datacenter: str
    is_spot: bool
    kind: str = KIND_GPU
    #: retention lag: keep versions [latest-retain .. latest] available (3.3)
    retain: Optional[int] = None
    workers: Dict[int, WorkerInfo] = dataclasses.field(default_factory=dict)
    open_shards: Set[int] = dataclasses.field(default_factory=set)
    last_heartbeat: Dict[int, float] = dataclasses.field(default_factory=dict)
    #: version currently held (published/in-progress), None if unpublished
    current_version: Optional[int] = None
    #: old versions awaiting drain (refcount->0) and/or offload completion;
    #: maps version -> offload_pending
    draining: Dict[int, bool] = dataclasses.field(default_factory=dict)
    registered: Set[int] = dataclasses.field(default_factory=set)
    failed: bool = False
    #: gray-failure classifier state (4.5 extension): strikes accumulate
    #: from transient/corrupt transfer-failure evidence; at the quarantine
    #: threshold the replica is benched as a *source* (still alive, still
    #: registered, still a pull destination) until the probation deadline.
    #: Wire-registered dataclass fields, so the op log digest and failover
    #: replay carry them automatically.
    suspect_strikes: int = 0
    quarantined_until: Optional[float] = None
    #: delta-transfer bookkeeping: the most recent version this replica
    #: fully held before retiring it (set at unpublish / update start).
    #: A destination with ``prior_version == v`` still holds v's bytes
    #: (and its store snapshotted them), so a source whose own
    #: ``prior_version`` matches can serve int8 residuals instead of the
    #: full payload. Wire-registered, so failover replay carries it.
    prior_version: Optional[int] = None


@dataclasses.dataclass
class _Txn:
    op: str
    args_repr: str
    result: Any
    arrived: Set[int]
    on_last: Optional[Callable[[], None]] = None


#: retired group ops remembered per replica for idempotent re-delivery: a
#: client retrying after a controller failover (at-most-once ambiguity —
#: the crash may have landed between execute and reply) re-sends its most
#: recent ops; the memory hands back the cached result instead of
#: re-running them. Shards issue ops in lockstep program order, so a
#: retry is always among the last few op ids.
DONE_TXN_MEMORY = 8


@dataclasses.dataclass
class _PendingReplicate:
    """A replicate() group parked until its version spec resolves."""

    replica: str
    op_id: int
    spec: version_lib.VersionSpec
    assignment: Optional["Assignment"] = None


@dataclasses.dataclass
class ModelState:
    name: str
    num_shards: Optional[int] = None
    latest: Optional[int] = None
    replicas: Dict[str, ReplicaInfo] = dataclasses.field(default_factory=dict)
    #: version -> replica name -> state
    versions: Dict[int, Dict[str, ReplicaVersionState]] = dataclasses.field(
        default_factory=dict
    )
    #: per-shard manifests per version, keyed by (layout, shard_idx) where
    #: *layout* is the replica's shard count — replicas with different
    #: layouts register their own manifest families, and cross-layout
    #: readers fetch the source family to build a reshard plan. The
    #: family holds the FIRST layout registered at that shard count;
    #: a same-count replica sharded along different axes is tracked only
    #: in ``replica_manifests`` (below), which is alias-free.
    manifests: Dict[int, Dict[Tuple[int, int], ShardManifest]] = dataclasses.field(
        default_factory=dict
    )
    #: exact per-replica manifests, keyed by (replica_name, shard_idx).
    #: Readers resolve their assigned *source replica* here (falling back
    #: to its count family), so two same-count layouts can coexist on one
    #: version without unit pulls silently crossing layouts.
    replica_manifests: Dict[int, Dict[Tuple[str, int], ShardManifest]] = (
        dataclasses.field(default_factory=dict)
    )
    txns: Dict[Tuple[str, int], _Txn] = dataclasses.field(default_factory=dict)
    #: retired group ops, (replica, op_id) -> completed txn (result cached,
    #: on_last dropped); bounded to DONE_TXN_MEMORY per replica
    done_txns: Dict[Tuple[str, int], _Txn] = dataclasses.field(default_factory=dict)
    pending: List[_PendingReplicate] = dataclasses.field(default_factory=list)
    #: per-version source generation: bumped whenever a replica finishes
    #: holding the version (publish of the last shard / completed
    #: replication) — i.e. whenever the multi-source candidate pool grew
    source_gen: Dict[int, int] = dataclasses.field(default_factory=dict)


# ---------------------------------------------------------------------------
# Results returned to clients
# ---------------------------------------------------------------------------


# ``SourceSlice`` and ``Assignment`` live in ``repro.core.meta`` (they are
# plan *metadata*, shared by both data planes); re-exported here for the
# historical import path. ``SourceSlice.ceiling`` carries each source's
# progress ceiling — the swarm-replication contract that lets in-progress
# replicas serve exactly their completed prefix.


@dataclasses.dataclass(frozen=True)
class PublishResult:
    version: int


@dataclasses.dataclass(frozen=True)
class UnpublishResult:
    #: client must first offload its shard to CPU and publish_offload (3.3)
    offload_required: bool
    offload_version: Optional[int] = None
    #: True once the replica is hidden and drained; if False the client must
    #: poll wait_drained() before mutating buffers (3.2 mutability contract)
    drained: bool = True


@dataclasses.dataclass(frozen=True)
class UpdateDecision:
    updated: bool
    reason: str = ""
    version: Optional[int] = None
    assignment: Optional[Assignment] = None
    #: retention: offload the *current* version before reusing buffers
    offload_required: bool = False
    offload_version: Optional[int] = None
    drained: bool = True
    #: offload seeding (4.3.4): this caller must run the background fetch
    seed_started: bool = False
    seed_version: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class Event:
    kind: str  # "offload_release" | "evicted"
    model: str
    replica: str
    version: Optional[int] = None
    reason: str = ""


class ReferenceServer:
    """Centralized reference server. See module docstring."""

    def __init__(
        self,
        *,
        heartbeat_timeout: Optional[float] = None,
        pipeline_replication: bool = True,
        smart_skipping: bool = True,
        scheduler: str = "least_loaded",  # paper 4.3.1 | "depth_aware" (beyond-paper)
        # "pinned" pins every reader to the first candidate by name — the
        # naive-broadcast baseline benchmarks compare against
        max_sources: int = 4,
        work_stealing: bool = True,
        chunk_hint: Optional[float] = None,
        swarm: bool = True,
        wan_codec: str = "int8",
        wan_delta: bool = True,
        quarantine_threshold: int = 3,
        quarantine_probation: float = 30.0,
        log: Optional[OpLog] = None,
    ) -> None:
        self._models: Dict[str, ModelState] = {}
        self._heartbeat_timeout = heartbeat_timeout
        self._pipeline = pipeline_replication
        self._smart_skipping = smart_skipping
        self._scheduler = scheduler
        #: max replicas a multi-source assignment partitions units across;
        #: 1 disables multi-source planning entirely (legacy single source)
        self._max_sources = max(1, max_sources)
        self._work_stealing = work_stealing
        #: the data plane's sub-unit chunk threshold, used as the "giant
        #: unit" hint when choosing between pipeline chaining and
        #: published-pool partitioning (see _plan_assignment)
        self._chunk_hint = (
            meta_defaults.DEFAULT_CHUNK_BYTES if chunk_hint is None else chunk_hint
        )
        #: wire codec negotiated for WAN-crossing (cross-DC) slices; the
        #: resolve validates the name at construction so a bad knob fails
        #: here, not mid-transfer. Intra-DC links and resharded interval
        #: reads always negotiate "raw" (see _make_assignment). Imported
        #: lazily: repro.transfer.codec depends on repro.core.meta, so a
        #: module-level import would close an import cycle.
        from repro.transfer.codec import get_codec

        get_codec(wan_codec)
        self._wan_codec = wan_codec
        #: delta negotiation: when a WAN-crossing slice's source and
        #: destination both retired the same prior version (tracked on
        #: ``ReplicaInfo.prior_version``), negotiate
        #: ``delta:<wan_codec>`` — the source ships int8 residuals
        #: against the base both sides still hold. Only meaningful for
        #: base codecs the delta framing supports; other wan_codecs
        #: (e.g. ``fixed:<r>``) silently negotiate plain.
        self._wan_delta = bool(wan_delta)
        if self._wan_delta and wan_codec in ("raw", "int8"):
            get_codec(f"delta:{wan_codec}")
        #: swarm replication: admit *in-progress* replicas into the
        #: multi-source pool for the prefix of units they have completed
        #: (unit-granular availability map). ``swarm=False`` reproduces
        #: the pre-swarm (PR 2) scheduler bit-for-bit — the knob the
        #: benchmarks use for before/after parity. Swarm planning also
        #: requires pipeline replication (a partial replica serving its
        #: prefix *is* a pipeline relay) and ``max_sources > 1``.
        self._swarm = swarm
        #: gray-failure classifier: transient evidence accumulates strikes
        #: per source; at the threshold the source is quarantined (benched
        #: from planning, not evicted) for the probation window. Corrupt
        #: evidence quarantines immediately. See report_transfer_failure.
        self._quarantine_threshold = max(1, quarantine_threshold)
        self._quarantine_probation = quarantine_probation
        self._events: Dict[str, List[Event]] = {}
        self._watchers: List[Callable[[], None]] = []
        self._seq = 0
        self.stats: Dict[str, int] = {
            "publishes": 0,
            "replications_started": 0,
            "replications_completed": 0,
            "offloads": 0,
            "offload_releases": 0,
            "reassignments": 0,
            "evictions": 0,
            "smart_skips": 0,
            "multi_source_assignments": 0,
            "work_steals": 0,
            "swarm_assignments": 0,
            "swarm_grows": 0,
            "transient_reports": 0,
            "corrupt_reports": 0,
            "quarantines": 0,
            "probation_lifts": 0,
            # delta negotiation: logged assignments that carried at least
            # one delta slice / degraded a would-be-lossy cross-layout
            # slice to raw at plan time because the source payload is
            # wholly non-quantizable (quantizing would frame everything
            # passthrough for zero wire gain)
            "delta_assignments": 0,
            "codec_degrades": 0,
        }
        #: wall-clock duration of the last failover recovery that built
        #: this server (set by ``repro.core.failover.recover``; 0.0 for a
        #: server that never went through recovery). Exposed as a metrics
        #: *gauge*: wall-clock values are intentionally outside the
        #: replayed state digest / counter-equality contract.
        self.last_recovery_s = 0.0
        #: fault tolerance: replayable op log (None = PR 3 behavior,
        #: bit-for-bit — nothing is recorded, nothing can be recovered)
        self._dead = False
        self._log: Optional[OpLog] = None
        if log is not None:
            log.set_config(self.config())
            self._log = log

    # -- fault tolerance: op logging, crash, recovery hooks -------------------

    def config(self) -> Dict[str, Any]:
        """The construction knobs (resolved), as recorded in the op log —
        recovery rebuilds the server from exactly these."""
        return {
            "heartbeat_timeout": self._heartbeat_timeout,
            "pipeline_replication": self._pipeline,
            "smart_skipping": self._smart_skipping,
            "scheduler": self._scheduler,
            "max_sources": self._max_sources,
            "work_stealing": self._work_stealing,
            "chunk_hint": self._chunk_hint,
            "swarm": self._swarm,
            "wan_codec": self._wan_codec,
            "wan_delta": self._wan_delta,
            "quarantine_threshold": self._quarantine_threshold,
            "quarantine_probation": self._quarantine_probation,
        }

    @property
    def log(self) -> Optional[OpLog]:
        return self._log

    def attach_log(self, log: Optional[OpLog]) -> None:
        """Attach (or detach) the op log without writing a config header —
        used by recovery after replaying, so subsequent ops keep
        appending where the crashed server left off."""
        self._log = log

    def crash(self) -> None:
        """Kill the controller: every subsequent call raises
        :class:`ServerUnavailableError` until clients fail over to a
        recovered server (``repro.core.failover.recover``). In-flight
        calls that already passed the liveness check complete against the
        dead server's (discarded) state — the at-most-once ambiguity the
        idempotent op layer absorbs on retry."""
        self._dead = True

    @property
    def is_crashed(self) -> bool:
        return self._dead

    def _check_alive(self) -> None:
        if self._dead:
            raise ServerUnavailableError(
                "reference server is down; fail over to the recovered server"
            )

    def _record(self, op: str, *args: Any) -> None:
        """WAL-style intent record: appended before the mutation runs, so
        a mid-op crash replays the op to completion (never a torn state).
        Args are positional, in ``oplog.OP_SCHEMAS[op]`` order (= the
        method signature) — the hot path never builds a kwargs dict."""
        log = self._log
        if log is not None:
            log.append(op, args)

    # -- notification plumbing ------------------------------------------------

    def add_watcher(self, cb: Callable[[], None]) -> None:
        """cb() fires after every state mutation (used to wake waiters)."""
        self._watchers.append(cb)

    @property
    def seq(self) -> int:
        return self._seq

    def _bump(self) -> None:
        self._seq += 1
        for cb in self._watchers:
            cb()

    def _emit(self, worker_id: str, ev: Event) -> None:
        self._events.setdefault(worker_id, []).append(ev)

    def poll_events(self, worker_id: str) -> List[Event]:
        self._check_alive()
        # logged only when the pop actually mutates — clients poll this
        # after every op, and recording empty polls would bloat the log
        # with no-ops (skipping them is trivially replay-equivalent).
        # Replay then drains the same queues. A recovered-from-older-log
        # server may re-deliver events a client already saw; client-side
        # handling is idempotent (regression-tested), so re-delivery is
        # a no-op, not a bug.
        if worker_id in self._events:
            self._record("poll_events", worker_id)
        return self._events.pop(worker_id, [])

    # -- lifecycle ------------------------------------------------------------

    def open(
        self,
        model: str,
        replica: str,
        num_shards: int,
        shard_idx: int,
        *,
        worker: WorkerInfo,
        retain: Optional[version_lib.VersionSpec] = None,
    ) -> None:
        self._check_alive()
        self._record("open", model, replica, num_shards, shard_idx, worker, retain)
        st = self._models.setdefault(model, ModelState(name=model))
        if st.num_shards is None:
            # canonical layout = the first opener's; replicas with other
            # shard counts are welcome — mismatched-but-convertible layouts
            # are served by the resharding data plane (repro.resharding),
            # with convertibility checked against layout descriptors at
            # replicate time rather than rejected here.
            st.num_shards = num_shards
        info = st.replicas.get(replica)
        if info is None or info.failed:
            retain_lag = (
                None if retain is None else version_lib.parse_relative(str(retain))
                if isinstance(retain, str)
                else int(retain)
            )
            info = ReplicaInfo(
                name=replica,
                num_shards=num_shards,
                datacenter=worker.datacenter,
                is_spot=worker.is_spot,
                retain=retain_lag,
            )
            st.replicas[replica] = info
        if worker.datacenter != info.datacenter:
            raise ShardLayoutError(
                f"replica {replica!r} spans datacenters "
                f"({info.datacenter} vs {worker.datacenter})"
            )
        if shard_idx in info.open_shards:
            raise ConsistencyError(f"{replica}/shard{shard_idx} opened twice")
        info.workers[shard_idx] = worker
        info.open_shards.add(shard_idx)
        info.last_heartbeat[shard_idx] = 0.0
        self._bump()

    def register(self, model: str, replica: str, shard_idx: int) -> None:
        self._check_alive()
        self._record("register", model, replica, shard_idx)
        info = self._replica(model, replica)
        info.registered.add(shard_idx)
        self._bump()

    def unregister(self, model: str, replica: str, shard_idx: int) -> None:
        self._check_alive()
        self._record("unregister", model, replica, shard_idx)
        info = self._replica(model, replica)
        if info.current_version is not None:
            raise MutabilityViolationError(
                f"{replica}: unregister while version "
                f"{info.current_version} is still published"
            )
        info.registered.discard(shard_idx)
        self._bump()

    def close(self, model: str, replica: str, shard_idx: int) -> None:
        self._check_alive()
        self._record("close", model, replica, shard_idx)
        st = self._model(model)
        info = st.replicas.get(replica)
        if info is None:
            return
        info.open_shards.discard(shard_idx)
        if not info.open_shards:
            self._remove_replica(st, replica, reason="closed")
        self._bump()

    # -- heartbeats / failure detection (4.5) ----------------------------------

    def heartbeat(self, model: str, replica: str, shard_idx: int, now: float) -> None:
        self._check_alive()
        # logged (time enters as an explicit argument, so replay is
        # deterministic): unlogged heartbeats would make replayed tick()
        # evict different replicas than the live run did
        self._record("heartbeat", model, replica, shard_idx, now)
        st = self._models.get(model)
        if st is None:
            return
        info = st.replicas.get(replica)
        if info is None or info.failed:
            raise StaleHandleError(f"{replica} was evicted")
        info.last_heartbeat[shard_idx] = now

    def tick(self, now: float) -> List[str]:
        """Expire heartbeats and lift expired quarantines; returns names
        of replicas evicted this tick."""
        self._check_alive()
        self._record("tick", now)
        evicted: List[str] = []
        lifted = False
        for st in self._models.values():
            # probation: an expired quarantine rejoins the source pools one
            # strike short of the threshold — a single further transient
            # report re-quarantines it (probation, not a clean slate)
            for info in st.replicas.values():
                if info.quarantined_until is not None and now >= info.quarantined_until:
                    info.quarantined_until = None
                    info.suspect_strikes = self._quarantine_threshold - 1
                    self.stats["probation_lifts"] += 1
                    lifted = True
            if self._heartbeat_timeout is None:
                continue
            for name, info in list(st.replicas.items()):
                if info.failed or not info.open_shards:
                    continue
                stale = any(
                    now - info.last_heartbeat.get(s, 0.0) > self._heartbeat_timeout
                    for s in info.open_shards
                )
                if stale:
                    self._fail_replica(st, name, reason="heartbeat timeout")
                    evicted.append(name)
        if evicted or lifted:
            self._bump()
        return evicted

    def fail_replica(self, model: str, replica: str, reason: str = "injected") -> None:
        """Administrative/forced eviction (spot preemption, tests)."""
        self._check_alive()
        self._record("fail_replica", model, replica, reason)
        st = self._model(model)
        if replica in st.replicas:
            self._fail_replica(st, replica, reason=reason)
            self._bump()

    def report_transfer_failure(
        self,
        model: str,
        dest_replica: str,
        source_replica: str,
        evidence: str = "fatal",
        now: float = 0.0,
    ) -> None:
        """A reader reported trouble with its source mid-transfer (4.5).

        ``evidence`` classifies the report instead of treating every one
        as a death sentence:

        * ``"fatal"`` — the source is gone (dead store, stale handle):
          evict and reassign, the original fail-stop behavior.
        * ``"transient"`` — the read flaked or timed out: one strike.
          At ``quarantine_threshold`` strikes the source is *quarantined*
          — benched from source planning for ``quarantine_probation``
          seconds but neither evicted nor unregistered, so a gray-but-
          alive replica keeps its data and its pull-destination role.
        * ``"corrupt"`` — checksum-rejected bytes: quarantined
          immediately (a full threshold of strikes at once).

        The reader resumes from its progress either way; ``_reassign``
        re-plans any in-progress pull whose plan touches the suspect."""
        self._check_alive()
        self._record(
            "report_transfer_failure", model, dest_replica, source_replica,
            evidence, now,
        )
        st = self._model(model)
        info = st.replicas.get(source_replica)
        if evidence == "fatal":
            if info is not None and not info.failed:
                self._fail_replica(st, source_replica, reason="reported by reader")
        elif info is not None and not info.failed:
            if evidence == "corrupt":
                self.stats["corrupt_reports"] += 1
                info.suspect_strikes += self._quarantine_threshold
            else:
                self.stats["transient_reports"] += 1
                info.suspect_strikes += 1
            if info.suspect_strikes >= self._quarantine_threshold:
                until = now + self._quarantine_probation
                if info.quarantined_until is None:
                    self.stats["quarantines"] += 1
                    info.quarantined_until = until
                else:
                    info.quarantined_until = max(info.quarantined_until, until)
        self._reassign(st, dest_replica)
        self._bump()

    def get_assignment(self, model: str, replica: str) -> Optional[Assignment]:
        """Current source assignment for an in-progress replica (may have
        been re-routed after a failure). Works for GPU replicas and offload
        seeding twins alike."""
        self._check_alive()
        st = self._model(model)
        info = st.replicas.get(replica)
        if info is None or info.failed:
            raise StaleHandleError(f"{replica} was evicted")
        for vmap in st.versions.values():
            rv = vmap.get(replica)
            if rv is None or rv.status != IN_PROGRESS or rv.source is None:
                continue
            src_state = vmap.get(rv.source)
            if src_state is None:
                return None  # source died; awaiting _reassign
            return self._make_assignment(
                st, rv.version, src_state, dest=info,
                plan=rv.plan or None, epoch=rv.assign_epoch,
            )
        return None

    def assignment_epoch(self, model: str, replica: str, version: int) -> int:
        """Current plan revision of an in-progress replica. Readers compare
        this against their Assignment's epoch between unit flows: a bump
        means the plan was re-partitioned (source death, work stealing) and
        the reader should re-fetch its assignment."""
        self._check_alive()
        st = self._model(model)
        rv = st.versions.get(version, {}).get(replica)
        if rv is None:
            raise StaleHandleError(f"{replica} no longer replicating v{version}")
        return rv.assign_epoch

    # -- write path -----------------------------------------------------------

    def publish(
        self,
        model: str,
        replica: str,
        shard_idx: int,
        version: int,
        manifest: ShardManifest,
        *,
        op_id: int,
    ) -> PublishResult:
        self._check_alive()
        self._record("publish", model, replica, shard_idx, version, manifest, op_id)
        st = self._model(model)
        info = self._replica(model, replica)
        if shard_idx not in info.registered:
            raise MutabilityViolationError(
                f"{replica}/shard{shard_idx}: publish before register"
            )

        def on_first() -> PublishResult:
            if info.current_version is not None:
                raise MutabilityViolationError(
                    f"{replica}: publish({version}) while version "
                    f"{info.current_version} is still published; unpublish first"
                )
            self._install_replica_version(
                st, info, version, status=PUBLISHED, kind=info.kind
            )
            self.stats["publishes"] += 1
            self._advance_latest(st, version)
            return PublishResult(version=version)

        res = self._group_op(
            st, info, shard_idx, op_id, "publish", repr(version), on_first
        )
        # per-shard manifest registration (data-plane visibility); written
        # to be idempotent — a retried publish (controller failover
        # at-most-once ambiguity) must not bump the source generation or
        # resurrect a since-dropped version
        rv = st.versions.get(version, {}).get(replica)
        if rv is not None:
            self._set_manifest(
                st, version, replica, info.num_shards, shard_idx, manifest
            )
            was_full = len(rv.progress) >= info.num_shards
            rv.progress[shard_idx] = manifest.num_units
            if not was_full and len(rv.progress) >= info.num_shards:
                # fully published: the multi-source candidate pool grew
                st.source_gen[version] = st.source_gen.get(version, 0) + 1
        self._service_pending(st)
        self._bump()
        return res

    def publish_offload(
        self,
        model: str,
        replica: str,
        shard_idx: int,
        version: int,
        manifest: ShardManifest,
        *,
        op_id: int,
    ) -> PublishResult:
        """Publish the CPU offload copy created by the retention protocol or
        by offload seeding (3.3, 4.3.4)."""
        self._check_alive()
        self._record(
            "publish_offload", model, replica, shard_idx, version, manifest, op_id
        )
        st = self._model(model)
        info = self._replica(model, replica)
        off_name = offload_name(replica)

        def on_first() -> PublishResult:
            offinfo = st.replicas.get(off_name)
            if offinfo is None:
                offinfo = ReplicaInfo(
                    name=off_name,
                    num_shards=info.num_shards,
                    datacenter=info.datacenter,
                    is_spot=info.is_spot,
                    kind=KIND_OFFLOAD,
                    workers=dict(info.workers),
                    open_shards=set(info.open_shards),
                )
                st.replicas[off_name] = offinfo
            self._install_replica_version(
                st, offinfo, version, status=PUBLISHED, kind=KIND_OFFLOAD
            )
            self.stats["offloads"] += 1
            return PublishResult(version=version)

        res = self._group_op(
            st, info, shard_idx, op_id, "publish_offload", repr(version), on_first
        )
        off_rv = st.versions.get(version, {}).get(off_name)
        if off_rv is not None:  # tolerate re-delivery after the copy dropped
            self._set_manifest(
                st, version, off_name, info.num_shards, shard_idx, manifest
            )
            off_rv.progress[shard_idx] = manifest.num_units
        if info.draining.get(version):
            info.draining[version] = False  # retention satisfied by the offload copy
        self._service_pending(st)
        self._bump()
        return res

    def unpublish(
        self, model: str, replica: str, shard_idx: int, *, op_id: int
    ) -> UnpublishResult:
        self._check_alive()
        self._record("unpublish", model, replica, shard_idx, op_id)
        st = self._model(model)
        info = self._replica(model, replica)

        def on_first() -> UnpublishResult:
            return self._begin_unpublish(st, info)

        res = self._group_op(
            st, info, shard_idx, op_id, "unpublish", "", on_first
        )
        self._bump()
        return res

    def finish_unpublish(self, model: str, replica: str) -> bool:
        """Poll step after unpublish: returns True once every draining
        version of this replica has (a) zero in-flight readers and (b) its
        required offload published. Only then may the client reuse the
        weight buffers (3.2 mutability contract)."""
        self._check_alive()
        st = self._model(model)
        info = self._replica(model, replica)
        # logged only when a drain is actually outstanding: this is a
        # 20ms polling call (clients spin on it while readers drain),
        # and with nothing draining it provably mutates nothing — the
        # log records state changes, not poll frequency
        if info.draining:
            self._record("finish_unpublish", model, replica)
        for v in list(info.draining.keys()):
            offload_pending = info.draining[v]
            rv = st.versions.get(v, {}).get(replica)
            if rv is None:
                if not offload_pending:
                    del info.draining[v]
                continue
            if rv.refcount == 0 and not offload_pending:
                self._drop_replica_version(st, replica, v)
                del info.draining[v]
        done = not info.draining
        if done:
            self._bump()
        return done

    # -- read path ------------------------------------------------------------

    def begin_replicate(
        self,
        model: str,
        replica: str,
        shard_idx: int,
        spec: version_lib.VersionSpec,
        *,
        op_id: int,
    ) -> Optional[Assignment]:
        """Start (or park) a blocking replicate(). Returns the group's
        Assignment, or None if the version does not exist yet — in which
        case the group is parked and must poll :meth:`redeem`."""
        self._check_alive()
        self._record("begin_replicate", model, replica, shard_idx, spec, op_id)
        st = self._model(model)
        info = self._replica(model, replica)

        def on_first() -> Optional[Assignment]:
            if info.current_version is not None:
                raise MutabilityViolationError(
                    f"{replica}: replicate while holding version "
                    f"{info.current_version}; use update() or unpublish first"
                )
            v = version_lib.resolve(spec, st.latest)
            if v is not None and self._find_source(st, v, info) is not None:
                return self._assign(st, info, v)
            pend = _PendingReplicate(replica=replica, op_id=op_id, spec=spec)
            st.pending.append(pend)
            return None

        res = self._group_op(
            st, info, shard_idx, op_id, "replicate", repr(spec), on_first
        )
        self._bump()
        return res

    def redeem(self, model: str, replica: str, *, op_id: int) -> Optional[Assignment]:
        """Check whether a parked replicate() has been assigned."""
        self._check_alive()
        st = self._model(model)
        info = st.replicas.get(replica)
        if info is None or info.failed:
            raise StaleHandleError(f"{replica} was evicted")
        for p in st.pending:
            if p.replica == replica and p.op_id == op_id:
                return p.assignment  # still parked (None) — keep waiting
        # no longer parked: assignment was delivered through txn state
        txn = st.txns.get((replica, op_id))
        if txn is not None and isinstance(txn.result, Assignment):
            return txn.result
        cur = self._current_state(st, replica)
        if cur is not None and cur.status == IN_PROGRESS and cur.source:
            src = st.versions[cur.version].get(cur.source)
            if src is not None:
                return self._make_assignment(
                    st, cur.version, src, dest=info,
                    plan=cur.plan or None, epoch=cur.assign_epoch,
                )
        return None

    def begin_update(
        self,
        model: str,
        replica: str,
        shard_idx: int,
        spec: version_lib.VersionSpec,
        *,
        op_id: int,
        offload_seeding: bool = False,
    ) -> UpdateDecision:
        """Atomic check-and-transition to a newer version (Table 2 update)."""
        self._check_alive()
        self._record(
            "begin_update", model, replica, shard_idx, spec, op_id, offload_seeding
        )
        st = self._model(model)
        info = self._replica(model, replica)

        def on_first() -> UpdateDecision:
            v = version_lib.resolve(spec, st.latest)
            if v is None:
                return UpdateDecision(updated=False, reason="no such version")
            if info.current_version == v:
                return UpdateDecision(updated=False, reason="already current")
            src = self._find_source(st, v, info)
            if src is None:
                return UpdateDecision(updated=False, reason="no live source")
            # Smart skipping (4.3.4): if the only local path to v is a replica
            # still seeding over TCP, treat v as temporarily unavailable.
            if self._smart_skipping and self._only_seeding_sources(st, v, info):
                self.stats["smart_skips"] += 1
                started = offload_seeding and self._ensure_offload_seed(st, v, info)
                return UpdateDecision(
                    updated=False,
                    reason="seeding in progress",
                    seed_started=started,
                    seed_version=v if started else None,
                )
            if (
                offload_seeding
                and src.kind != KIND_OFFLOAD
                and self._cross_dc(st, src, info)
            ):
                # No local source at all: seed through a CPU buffer in the
                # background instead of stalling the accelerator (4.3.4).
                started = self._ensure_offload_seed(st, v, info)
                return UpdateDecision(
                    updated=False,
                    reason="offload seeding started"
                    if started
                    else "offload seeding in progress",
                    seed_started=started,
                    seed_version=v if started else None,
                )
            # commit: unpublish current (retention-aware), then assign.
            unpub = UnpublishResult(offload_required=False)
            if info.current_version is not None:
                unpub = self._begin_unpublish(st, info)
            assignment = self._assign(st, info, v)
            return UpdateDecision(
                updated=True,
                version=v,
                assignment=assignment,
                offload_required=unpub.offload_required,
                offload_version=unpub.offload_version,
                drained=unpub.drained,
            )

        res = self._group_op(
            st, info, shard_idx, op_id, "update", repr(spec), on_first
        )
        self._bump()
        return res

    def source_progress(self, model: str, source_replica: str, version: int) -> int:
        """Min over shards of the source's progress counter. Readers poll
        this (in the real system it is a one-sided read on the source)."""
        self._check_alive()
        st = self._model(model)
        vmap = st.versions.get(version, {})
        rv = vmap.get(source_replica)
        if rv is None:
            raise StaleHandleError(f"source {source_replica} no longer holds v{version}")
        if not rv.progress:
            return 0
        return min(rv.progress.values())

    def shard_progress(self, model: str, source_replica: str, version: int, shard_idx: int) -> int:
        self._check_alive()
        st = self._model(model)
        rv = st.versions.get(version, {}).get(source_replica)
        if rv is None:
            raise StaleHandleError(f"source {source_replica} no longer holds v{version}")
        return rv.progress.get(shard_idx, 0)

    def update_progress(
        self, model: str, replica: str, shard_idx: int, version: int, progress: int
    ) -> None:
        self._check_alive()
        self._record("update_progress", model, replica, shard_idx, version, progress)
        st = self._model(model)
        rv = st.versions.get(version, {}).get(replica)
        if rv is None:
            raise StaleHandleError(f"{replica} no longer replicating v{version}")
        rv.progress[shard_idx] = max(rv.progress.get(shard_idx, 0), progress)
        # swarm announcement: the first time this puller's completed prefix
        # (min over shards) becomes non-empty it joins the availability map
        # as a servable source; bump the source generation once so other
        # readers' progress reports re-scan the pool and grow their plans.
        if (
            self._swarm
            and rv.status == IN_PROGRESS
            and not rv.swarm_announced
            and rv.progress
            and min(rv.progress.values()) >= 1
        ):
            rv.swarm_announced = True
            st.source_gen[version] = st.source_gen.get(version, 0) + 1
        # work stealing (driven by reader progress reports): a source that
        # arrived after this plan was built gets a share of the remaining
        # units. The generation check keeps the hot path O(1).
        if (
            self._work_stealing
            and rv.status == IN_PROGRESS
            and rv.plan
            and rv.plan_gen != st.source_gen.get(version, 0)
        ):
            self._steal_work(st, version, rv)
        self._bump()

    def complete_replicate(
        self, model: str, replica: str, shard_idx: int, version: int, *, op_id: int
    ) -> None:
        self._check_alive()
        self._record("complete_replicate", model, replica, shard_idx, version, op_id)
        st = self._model(model)
        info = self._replica(model, replica)
        rv = st.versions.get(version, {}).get(replica)
        if rv is None:
            if (info.name, op_id) in st.done_txns:
                # the whole group already completed and the version has
                # since been dropped: a re-delivered complete is a no-op
                self._group_op(
                    st, info, shard_idx, op_id, "complete", repr(version),
                    lambda: None,
                )
                return
            raise StaleHandleError(f"{replica} lost its in-progress state for v{version}")
        rv.completed_shards.add(shard_idx)
        self._group_op(
            st,
            info,
            shard_idx,
            op_id,
            "complete",
            repr(version),
            lambda: None,
            self._complete_on_last(st, version, replica),
        )
        self._bump()

    def _complete_on_last(
        self, st: ModelState, version: int, replica: str
    ) -> Callable[[], None]:
        """The group-completion action of complete_replicate, as a factory
        so snapshot restore can rebuild the callback for an open txn (the
        closure binds only replayable state, never the live objects).
        Idempotent: a re-run against an already-published replica (dup
        delivery after failover) changes nothing."""

        def on_last() -> None:
            rv = st.versions.get(version, {}).get(replica)
            if rv is None or rv.status != IN_PROGRESS:
                return  # already completed (or dropped): nothing to do
            rv.status = PUBLISHED
            rv.seeding = False
            self._release_sources(st.versions.get(version, {}), rv)
            self.stats["replications_completed"] += 1
            # this replica is now a fully-held copy: late readers steal from it
            st.source_gen[version] = st.source_gen.get(version, 0) + 1
            self._maybe_release_offloads(st, version)
            self._service_pending(st)

        return on_last

    # -- queries (Table 2: list / wait) ----------------------------------------

    def list_versions(self, model: str) -> Dict[int, Set[str]]:
        self._check_alive()
        st = self._models.get(model)
        if st is None:
            return {}
        out: Dict[int, Set[str]] = {}
        for v, vmap in st.versions.items():
            names = {
                r.replica
                for r in vmap.values()
                if r.status == PUBLISHED or (r.status == IN_PROGRESS)
            }
            if names:
                out[v] = names
        return out

    def latest(self, model: str) -> Optional[int]:
        self._check_alive()
        st = self._models.get(model)
        return None if st is None else st.latest

    def num_shards(self, model: str) -> Optional[int]:
        self._check_alive()
        st = self._models.get(model)
        return None if st is None else st.num_shards

    def replica_version(self, model: str, replica: str) -> Optional[int]:
        """The version a replica currently holds (published or in
        progress), or None for an unknown/evicted/idle replica. Clients
        re-asserting state after a controller failover compare this
        against their local view to decide what to re-issue."""
        self._check_alive()
        st = self._models.get(model)
        if st is None:
            return None
        info = st.replicas.get(replica)
        if info is None or info.failed:
            return None
        return info.current_version

    def manifest(
        self,
        model: str,
        version: int,
        shard_idx: int,
        *,
        num_shards: Optional[int] = None,
    ) -> Optional[ShardManifest]:
        """Manifest of one shard of one layout family; ``num_shards``
        defaults to the model's canonical (first-opened) layout."""
        self._check_alive()
        st = self._model(model)
        layout = st.num_shards if num_shards is None else num_shards
        return st.manifests.get(version, {}).get((layout, shard_idx))

    def put_manifest(
        self,
        model: str,
        replica: str,
        shard_idx: int,
        version: int,
        manifest: ShardManifest,
    ) -> None:
        """Register a manifest for a replica's own layout family.

        Called by cross-layout readers at replicate start so that (a) the
        planner's inputs are server-visible and (b) downstream readers
        with the *same* non-canonical layout can pipeline plain unit
        pulls off this replica's progress counter."""
        self._check_alive()
        self._record("put_manifest", model, replica, shard_idx, version, manifest)
        st = self._model(model)
        info = self._replica(model, replica)
        self._set_manifest(st, version, replica, info.num_shards, shard_idx, manifest)
        self._bump()

    def replica_manifest(
        self, model: str, version: int, replica: str, shard_idx: int
    ) -> Optional[ShardManifest]:
        """The exact manifest a given replica's shard holds for a version,
        falling back to its shard-count family (publishers and same-layout
        chains never diverge from their family). Readers resolve their
        assigned source through this — not through the count family — so
        two same-count layouts on one version cannot alias."""
        self._check_alive()
        return self._replica_manifest(self._model(model), version, replica, shard_idx)

    def _replica_manifest(
        self, st: ModelState, version: int, replica: str, shard_idx: int
    ) -> Optional[ShardManifest]:
        """Unguarded internal lookup: scheduler internals must not trip
        the public liveness check — a crashed server's in-flight op runs
        to completion against its (discarded) state."""
        m = st.replica_manifests.get(version, {}).get((replica, shard_idx))
        if m is not None:
            return m
        info = st.replicas.get(replica)
        layout = st.num_shards if info is None else info.num_shards
        return st.manifests.get(version, {}).get((layout, shard_idx))

    def replica_datacenter(self, model: str, replica: str) -> str:
        self._check_alive()
        return self._replica(model, replica).datacenter

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------

    def _model(self, model: str) -> ModelState:
        st = self._models.get(model)
        if st is None:
            raise TensorHubError(f"unknown model {model!r}")
        return st

    def _replica(self, model: str, replica: str) -> ReplicaInfo:
        st = self._model(model)
        info = st.replicas.get(replica)
        if info is None:
            raise TensorHubError(f"unknown replica {replica!r}")
        if info.failed:
            raise StaleHandleError(f"{replica} was evicted")
        return info

    def _group_op(
        self,
        st: ModelState,
        info: ReplicaInfo,
        shard_idx: int,
        op_id: int,
        op: str,
        args_repr: str,
        on_first: Callable[[], Any],
        on_last: Optional[Callable[[], None]] = None,
    ) -> Any:
        """Transactional group op (4.4). First arrival executes; all shards
        consume the same cached result; optional on_last runs when the whole
        group arrived.

        Re-delivery is a no-op: a shard retrying an op after a controller
        failover (the crash may have landed between execute and reply)
        gets the cached result back — from the open txn if the group is
        still gathering, or from the bounded done-txn memory after it
        retired. Only a *divergent* op (same id, different kind or args)
        still raises: that is an SPMD framework bug, not a retry."""
        key = (info.name, op_id)
        done = st.done_txns.get(key)
        if done is not None:
            if done.op != op or done.args_repr != args_repr:
                raise ConsistencyError(
                    f"{info.name} op#{op_id}: shard{shard_idx} issued "
                    f"{op}({args_repr}) but group ran {done.op}({done.args_repr})"
                )
            if isinstance(done.result, TensorHubError):
                raise done.result
            return done.result
        txn = st.txns.get(key)
        if txn is None:
            result = on_first()
            txn = _Txn(
                op=op, args_repr=args_repr, result=result, arrived=set(), on_last=on_last
            )
            st.txns[key] = txn
        else:
            if txn.op != op or txn.args_repr != args_repr:
                raise ConsistencyError(
                    f"{info.name} op#{op_id}: shard{shard_idx} issued "
                    f"{op}({args_repr}) but group ran {txn.op}({txn.args_repr})"
                )
        if shard_idx not in txn.arrived:
            txn.arrived.add(shard_idx)
            if len(txn.arrived) == info.num_shards:
                if txn.on_last is not None:
                    txn.on_last()
                del st.txns[key]
                self._retire_txn(st, info.name, key, txn)
        if isinstance(txn.result, TensorHubError):
            raise txn.result
        return txn.result

    def _retire_txn(
        self, st: ModelState, replica: str, key: Tuple[str, int], txn: _Txn
    ) -> None:
        """Remember a completed group op for idempotent re-delivery,
        pruned to the DONE_TXN_MEMORY most recent op ids per replica
        (shards issue ops in lockstep, so retries are always recent)."""
        st.done_txns[key] = _Txn(
            op=txn.op, args_repr=txn.args_repr, result=txn.result,
            arrived=set(txn.arrived),
        )
        # prune by insertion recency (dicts are insertion-ordered), NOT by
        # op-id magnitude: reassert ops use high-base ids (2M+/3M+) that
        # would otherwise squat the cache forever and evict genuinely
        # recent ops
        mine = [k for k in st.done_txns if k[0] == replica]
        for k in mine[: max(0, len(mine) - DONE_TXN_MEMORY)]:
            del st.done_txns[k]

    # -- publish/unpublish helpers ---------------------------------------------

    def _install_replica_version(
        self,
        st: ModelState,
        info: ReplicaInfo,
        version: int,
        *,
        status: str,
        kind: str,
        source: Optional[str] = None,
        seeding: bool = False,
    ) -> ReplicaVersionState:
        if kind == KIND_GPU:
            if info.current_version is not None:
                raise MutabilityViolationError(
                    f"{info.name} already holds v{info.current_version}"
                )
            info.current_version = version
        rv = ReplicaVersionState(
            replica=info.name,
            version=version,
            kind=kind,
            status=status,
            source=source,
            seeding=seeding,
        )
        st.versions.setdefault(version, {})[info.name] = rv
        return rv

    def _advance_latest(self, st: ModelState, version: int) -> None:
        if st.latest is None or version > st.latest:
            st.latest = version
            # A new latest shifts every retain window: offload replicas pinned
            # only by retention may now be released (3.3).
            for v in list(st.versions.keys()):
                self._maybe_release_offloads(st, v)
            self._gc_versions(st)

    def _retained_versions(self, st: ModelState) -> Set[int]:
        if st.latest is None:
            return set()
        out: Set[int] = set()
        for info in st.replicas.values():
            if info.failed or info.retain is None:
                continue
            for k in range(info.retain + 1):
                v = st.latest - k
                if v >= 0:
                    out.add(v)
        return out

    def _live_copies(self, st: ModelState, version: int, *, exclude: str) -> int:
        """Replicas (any kind) that can keep the version alive; spot-hosted
        replicas do not count toward retention (4.5)."""
        n = 0
        for rv in st.versions.get(version, {}).values():
            if rv.replica == exclude or rv.status != PUBLISHED:
                continue
            if st.replicas[rv.replica].is_spot:
                continue
            n += 1
        return n

    def _begin_unpublish(self, st: ModelState, info: ReplicaInfo) -> UnpublishResult:
        v = info.current_version
        if v is None:
            raise MutabilityViolationError(f"{info.name}: unpublish with nothing published")
        rv = st.versions[v][info.name]
        offload_required = (
            v in self._retained_versions(st)
            and not info.is_spot
            and self._live_copies(st, v, exclude=info.name) == 0
        )
        # hide from the scheduler immediately; mutation must wait for drain
        rv.status = DRAINING
        info.current_version = None
        # delta bookkeeping: this replica just retired v and its store
        # snapshots the bytes — it can serve/receive residuals against v
        # until it next completes a different version
        info.prior_version = v
        if rv.refcount == 0 and not offload_required:
            self._drop_replica_version(st, info.name, v)
            return UnpublishResult(offload_required=False, drained=True)
        # If an offload is required the client performs it *before* reusing
        # buffers; the GPU entry is dropped after offload + drain.
        info.draining[v] = offload_required
        return UnpublishResult(
            offload_required=offload_required,
            offload_version=v if offload_required else None,
            drained=False,
        )

    def _drop_replica_version(self, st: ModelState, replica: str, version: int) -> None:
        vmap = st.versions.get(version)
        if not vmap:
            return
        rv = vmap.pop(replica, None)
        if rv is not None:
            self._release_sources(vmap, rv)
        rep_map = st.replica_manifests.get(version)
        if rep_map:
            for key in [k for k in rep_map if k[0] == replica]:
                del rep_map[key]
        if not vmap:
            del st.versions[version]
            st.manifests.pop(version, None)
            st.replica_manifests.pop(version, None)
            st.source_gen.pop(version, None)
        self._gc_versions(st)

    def _gc_versions(self, st: ModelState) -> None:
        for v in list(st.versions.keys()):
            if not st.versions[v]:
                del st.versions[v]
                st.manifests.pop(v, None)
                st.replica_manifests.pop(v, None)
                st.source_gen.pop(v, None)

    def _maybe_release_offloads(self, st: ModelState, version: int) -> None:
        """Release offload replicas that outlived their purpose (3.3, 4.3.4):

        * retention offloads — once no longer the last copy, or no longer
          retained;
        * seed caches — once a same-DC GPU replica holds the version (it has
          been consumed locally), or a newer version superseded it.
        """
        vmap = st.versions.get(version)
        if not vmap:
            return
        retained = self._retained_versions(st)
        for name, rv in list(vmap.items()):
            if rv.kind != KIND_OFFLOAD or rv.status != PUBLISHED:
                continue
            if rv.refcount > 0:
                continue
            info = st.replicas.get(name)
            if info is None:
                continue
            if rv.seed_cache:
                consumed = any(
                    o.kind == KIND_GPU
                    and o.status == PUBLISHED
                    and st.replicas[o.replica].datacenter == info.datacenter
                    for o in vmap.values()
                )
                superseded = st.latest is not None and version < st.latest
                release = consumed or superseded
            else:
                others = self._live_copies(st, version, exclude=name)
                release = version not in retained or others > 0
            if release:
                self._drop_replica_version(st, name, version)
                self.stats["offload_releases"] += 1
                for w in info.workers.values():
                    self._emit(
                        w.worker_id,
                        Event(
                            kind="offload_release",
                            model=st.name,
                            replica=name,
                            version=version,
                        ),
                    )

    def _set_manifest(
        self,
        st: ModelState,
        version: int,
        replica: str,
        layout: int,
        shard_idx: int,
        manifest: ShardManifest,
    ) -> None:
        # exact per-replica entry first (alias-free; readers resolve their
        # assigned source here)
        rep_map = st.replica_manifests.setdefault(version, {})
        rprev = rep_map.get((replica, shard_idx))
        if rprev is None or (
            all(c == 0 for c in rprev.checksums) and any(manifest.checksums)
        ):
            rep_map[(replica, shard_idx)] = manifest
        # count-keyed family: first layout at this count wins; a same-count
        # manifest slicing along other axes is NOT an error (it lives in
        # the replica map), but a conflicting unit schema for the *same*
        # layout is.
        shard_map = st.manifests.setdefault(version, {})
        prev = shard_map.get((layout, shard_idx))
        if prev is not None:
            if prev.same_layout(manifest):
                if not prev.validate_against(manifest):
                    raise ShardLayoutError(
                        f"shard {shard_idx} of v{version}: manifest mismatch "
                        f"with the {layout}-shard layout family already "
                        "registered"
                    )
                # checksum upgrade: a resharding reader registers with zero
                # checksums (its buffers are pre-pull garbage) and re-puts
                # real ones once the pull completes, restoring end-to-end
                # verification for downstream same-layout readers
                if all(c == 0 for c in prev.checksums) and any(manifest.checksums):
                    shard_map[(layout, shard_idx)] = manifest
            return
        shard_map[(layout, shard_idx)] = manifest

    # -- scheduling (4.3.1) -----------------------------------------------------

    def _is_quarantined(self, info: Optional[ReplicaInfo]) -> bool:
        return info is not None and info.quarantined_until is not None

    def _source_candidates(
        self,
        st: ModelState,
        version: int,
        dest: ReplicaInfo,
        *,
        include_quarantined: bool = False,
    ) -> List[ReplicaVersionState]:
        vmap = st.versions.get(version, {})
        out = []
        for rv in vmap.values():
            if rv.replica == dest.name:
                continue
            if not rv.is_source_candidate():
                continue
            if rv.status == IN_PROGRESS and not self._pipeline:
                continue
            info = st.replicas.get(rv.replica)
            if info is None or info.failed:
                continue
            if not include_quarantined and self._is_quarantined(info):
                continue
            if rv.status == IN_PROGRESS and self._chain_reaches(
                vmap, rv.replica, dest.name
            ):
                # an in-progress candidate whose own source chain passes
                # through the destination would close a read cycle: each
                # end serves only its completed prefix and both tails gate
                # on the other forever. Reachable since re-partitioning
                # re-plans several readers at the same instant (a shared
                # swarm source dying); never valid, so never a candidate.
                continue
            out.append(rv)
        return out

    def _find_source(
        self, st: ModelState, version: int, dest: ReplicaInfo
    ) -> Optional[ReplicaVersionState]:
        cands = self._source_candidates(st, version, dest)
        if not cands:
            # every live candidate is quarantined: a suspect source still
            # beats no source — without the fallback a transient-only
            # fault schedule could starve readers of their only replica
            cands = self._source_candidates(
                st, version, dest, include_quarantined=True
            )
        if not cands:
            return None
        local = [c for c in cands if st.replicas[c.replica].datacenter == dest.datacenter]
        pool = local or cands

        def layout_penalty(c: ReplicaVersionState) -> int:
            # prefer same-layout sources: plain unit pulls beat the
            # reshard path (no repack) when both are available
            return 0 if st.replicas[c.replica].num_shards == dest.num_shards else 1

        if self._scheduler == "depth_aware":
            # prefer shallow sources, then least-loaded: builds a balanced
            # replication tree instead of a chain (EXPERIMENTS.md Perf)
            return min(pool, key=lambda c: (layout_penalty(c), c.refcount, c.depth, c.replica))
        if self._scheduler == "pinned":
            # naive-broadcast baseline: every reader hits the same (first
            # by name) source regardless of load — the behavior the
            # fan-out benchmark quantifies multi-source gains against
            return min(pool, key=lambda c: (layout_penalty(c), c.replica))
        # paper 4.3.1: least-loaded, deterministic tie-break
        return min(pool, key=lambda c: (layout_penalty(c), c.refcount, c.replica))

    def _only_seeding_sources(
        self, st: ModelState, version: int, dest: ReplicaInfo
    ) -> bool:
        cands = self._source_candidates(st, version, dest)
        local = [c for c in cands if st.replicas[c.replica].datacenter == dest.datacenter]
        if not local:
            return False
        return all(c.seeding and c.status == IN_PROGRESS for c in local)

    def _cross_dc(self, st: ModelState, src: ReplicaVersionState, dest: ReplicaInfo) -> bool:
        return st.replicas[src.replica].datacenter != dest.datacenter

    def _source_payload_quantizable(
        self, st: ModelState, version: int, source_name: str, source_shards: int
    ) -> bool:
        """Whether negotiating a lossy reshard codec against this source
        can shrink any bytes: at least one transfer unit of its manifest
        carries a quantizable dtype. Falls back from the per-replica
        manifest to the shard-family manifest; an unseen manifest is
        treated as quantizable (optimistic — the worst case is
        passthrough framing, never corruption)."""
        from repro.transfer.codec import manifest_quantizable

        m = st.replica_manifests.get(version, {}).get((source_name, 0))
        if m is None:
            m = st.manifests.get(version, {}).get((source_shards, 0))
        if m is None:
            return True
        return manifest_quantizable(m)

    def _make_assignment(
        self,
        st: ModelState,
        version: int,
        src: ReplicaVersionState,
        *,
        dest: ReplicaInfo,
        plan: Optional[List[Tuple[str, int, int]]] = None,
        epoch: int = 0,
        record_stats: bool = False,
    ) -> Assignment:
        cross = self._cross_dc(st, src, dest)
        vmap = st.versions.get(version, {})
        # stats are only recorded on the LOGGED path (_assign): the
        # unlogged rebuild paths (get_assignment, redeem) re-derive the
        # same plan, and server.stats sits inside the failover state
        # digest — bumping it off-log would break replay equality.
        tally = {"degrade": False, "delta": False}

        def codec_for(is_cross: bool, source_shards: int, source_name: str) -> str:
            from repro.transfer.codec import get_codec, reshard_wire_codec

            # per-link negotiation: WAN-crossing slices carry the WAN
            # codec; intra-DC stays raw.
            if not is_cross:
                return "raw"
            resharded = source_shards != dest.num_shards
            aliased = False
            if not resharded:
                # aliased layout: same shard count but a different unit
                # slicing also runs the resharded interval-read path
                sm = st.replica_manifests.get(version, {}).get((source_name, 0))
                fam = st.manifests.get(version, {}).get((dest.num_shards, 0))
                aliased = (
                    sm is not None and fam is not None and not sm.same_layout(fam)
                )
            if resharded or aliased:
                # cross-layout pulls run the row-grid interval-read path:
                # the WAN codec rides the widened unit-range reads, with
                # delta collapsed to its base codec (residuals need the
                # destination's held bytes in the destination's layout,
                # which a cross-layout source does not have)
                codec = reshard_wire_codec(self._wan_codec)
                if not get_codec(codec).lossless and not (
                    self._source_payload_quantizable(
                        st, version, source_name, source_shards
                    )
                ):
                    # genuinely unalignable plan: every unit of the source
                    # payload would frame as passthrough (no quantizable
                    # dtype anywhere) — degrade to raw at PLAN time and
                    # tick the counter, not mid-flight
                    tally["degrade"] = True
                    return "raw"
                return codec
            codec = self._wan_codec
            # delta negotiation: both endpoints retired the same prior
            # version, so the source can ship int8 residuals against the
            # base the destination still holds. Any endpoint that cannot
            # (fresh destination, GC'd base, re-plan/steal/failover
            # reassert onto a snapshot-less source) negotiates — or falls
            # back on the wire to — the plain base codec.
            s_info = st.replicas.get(source_name)
            if (
                self._wan_delta
                and codec in ("raw", "int8")
                and dest.prior_version is not None
                and dest.prior_version < version
                and s_info is not None
                and s_info.prior_version == dest.prior_version
            ):
                tally["delta"] = True
                return f"delta:{codec}"
            return codec

        slices = []
        for name, a, b in plan or []:
            s_rv = vmap.get(name)
            if s_rv is None:
                continue
            s_cross = self._cross_dc(st, s_rv, dest)
            s_shards = st.replicas[name].num_shards
            slices.append(
                SourceSlice(
                    source=name,
                    source_kind=s_rv.kind,
                    transport="tcp" if s_cross else "rdma",
                    start_unit=a,
                    stop_unit=b,
                    seeding=s_cross,
                    source_shards=s_shards,
                    ceiling=self._source_ceiling(st, s_rv),
                    codec=codec_for(s_cross, s_shards, name),
                )
            )
        src_shards = st.replicas[src.replica].num_shards
        assignment = Assignment(
            version=version,
            source=src.replica,
            source_kind=src.kind,
            transport="tcp" if cross else "rdma",
            seeding=cross,
            source_shards=src_shards,
            dest_shards=dest.num_shards,
            sources=tuple(slices),
            epoch=epoch,
            codec=slices[0].codec
            if slices
            else codec_for(cross, src_shards, src.replica),
        )
        if record_stats:
            if tally["degrade"]:
                self.stats["codec_degrades"] += 1
            if tally["delta"]:
                self.stats["delta_assignments"] += 1
        return assignment

    # -- multi-source planning (windowed data plane) ----------------------------

    def _acquire_source(
        self, st: ModelState, src: ReplicaVersionState, dest: ReplicaInfo
    ) -> None:
        src.refcount += 1
        info = st.replicas.get(src.replica)
        n = info.num_shards if info is not None else dest.num_shards
        for s in range(n):
            src.shard_readers[s] = src.shard_readers.get(s, 0) + 1

    def _release_sources(
        self, vmap: Dict[str, ReplicaVersionState], rv: ReplicaVersionState
    ) -> None:
        """Drop every source reference a reader holds (its whole plan)."""
        names = {s for s, _, _ in rv.plan}
        if rv.source is not None:
            names.add(rv.source)
        for name in names:
            src = vmap.get(name)
            if src is None:
                continue
            if src.refcount > 0:
                src.refcount -= 1
            for k in src.shard_readers:
                if src.shard_readers[k] > 0:
                    src.shard_readers[k] -= 1
        rv.plan = []
        rv.source = None

    def _dest_num_units(
        self, st: ModelState, version: int, dest: ReplicaInfo
    ) -> Optional[int]:
        m = st.manifests.get(version, {}).get((dest.num_shards, 0))
        return None if m is None else m.num_units

    def _pref_key(self, st: ModelState, rv: ReplicaVersionState, dest: ReplicaInfo):
        """Topology preference: same-node > same-DC > cross-DC, then
        least-loaded, with deterministic tie-breaks."""
        info = st.replicas[rv.replica]
        dest_nodes = {w.node for w in dest.workers.values()}
        if dest_nodes & {w.node for w in info.workers.values()}:
            topo = 0
        elif info.datacenter == dest.datacenter:
            topo = 1
        else:
            topo = 2
        return (topo, rv.refcount, rv.depth, rv.replica)

    def _multi_pool(
        self, st: ModelState, version: int, dest: ReplicaInfo
    ) -> List[ReplicaVersionState]:
        """Replicas a multi-source plan may partition units across: fully
        published same-shard-count GPU replicas in the destination's
        datacenter whose manifests are byte-identical slicings (unit pulls
        are only interchangeable between identical layouts). Fewer than
        two means no multi-source plan (callers fall back to the legacy
        single-source scheduler, which also handles cross-DC seeding,
        offload copies and pipeline chaining off in-progress replicas)."""
        n_units = self._dest_num_units(st, version, dest)
        if n_units is None:
            return []
        out = []
        for rv in st.versions.get(version, {}).values():
            if rv.replica == dest.name or rv.status != PUBLISHED:
                continue
            if rv.kind != KIND_GPU:
                continue
            info = st.replicas.get(rv.replica)
            if info is None or info.failed or self._is_quarantined(info):
                continue
            if info.num_shards != dest.num_shards:
                continue
            if info.datacenter != dest.datacenter:
                continue
            # fully held: every shard's progress covers every unit
            if len(rv.progress) < info.num_shards or (
                rv.progress and min(rv.progress.values()) < n_units
            ):
                continue
            out.append(rv)
        if len(out) < 2:
            return out
        out.sort(key=lambda rv: self._pref_key(st, rv, dest))
        # layout-identity filter against the destination's own manifest
        # when it registered one (reshard readers do), else the shard-count
        # family. Same-count replicas sliced along other axes must not be
        # mixed into a unit-partitioned plan.
        ref = st.replica_manifests.get(version, {}).get(
            (dest.name, 0)
        ) or st.manifests.get(version, {}).get((dest.num_shards, 0))
        if ref is None:
            return out[:1]
        kept = []
        for rv in out:
            m = self._replica_manifest(st, version, rv.replica, 0)
            if m is not None and m.same_layout(ref):
                kept.append(rv)
        return kept

    # -- swarm replication: unit-granular availability map + planner ------------

    def _source_ceiling(self, st: ModelState, rv: ReplicaVersionState) -> int:
        """Progress ceiling of one source: ``-1`` (unbounded) for a fully
        published replica, else the min-over-shards completed prefix."""
        info = st.replicas.get(rv.replica)
        n_shards = info.num_shards if info is not None else len(rv.progress)
        if (
            rv.status == PUBLISHED
            and info is not None
            and len(rv.progress) >= info.num_shards
        ):
            return -1
        if not rv.progress or len(rv.progress) < n_shards:
            return 0  # a shard with no counter yet has served nothing
        return min(rv.progress.values())

    def _chain_reaches(
        self, vmap: Dict[str, ReplicaVersionState], name: str, target: str
    ) -> bool:
        """True when ``name``'s transitive source chain includes ``target``
        — admitting it as a swarm source for ``target`` would close a
        read cycle whose tails gate on each other forever."""
        seen: Set[str] = set()
        stack = [name]
        while stack:
            n = stack.pop()
            if n == target:
                return True
            if n in seen:
                continue
            seen.add(n)
            rv = vmap.get(n)
            if rv is None or rv.status != IN_PROGRESS:
                continue
            for s, _, _ in rv.plan:
                stack.append(s)
            if rv.source is not None:
                stack.append(rv.source)
        return False

    def availability(self, model: str, version: int) -> Dict[str, int]:
        """The unit-granular availability map (swarm replication): for
        every live replica holding ``version``, how many transfer units
        of its prefix are servable right now (``min`` over shards;
        published replicas report their full unit count). Diagnostic /
        test surface for the swarm planner's inputs."""
        self._check_alive()
        st = self._model(model)
        out: Dict[str, int] = {}
        for rv in st.versions.get(version, {}).values():
            info = st.replicas.get(rv.replica)
            if info is None or info.failed:
                continue
            if rv.status not in (PUBLISHED, IN_PROGRESS):
                continue
            m = self._replica_manifest(st, version, rv.replica, 0)
            full = m.num_units if m is not None else 0
            c = self._source_ceiling(st, rv)
            out[rv.replica] = full if c < 0 else min(c, full) if full else c
        return out

    # -- metrics (observability surface for the future networked server) -------

    def metrics(self) -> Dict[str, Dict[str, float]]:
        """Point-in-time metrics snapshot, split by replay contract:

        ``counters`` and ``state`` are derived purely from op-log-covered
        state (``stats`` + model/replica state), so two digest-equal
        servers — e.g. the original and its crash-recovered twin — report
        identical values. ``gauges`` carry wall-clock and log-transport
        values (failover recovery duration, op-log batching) that are
        intentionally exempt from that equality.

        Deliberately NOT guarded by the liveness check: scraping a
        crashed controller's last-known metrics is exactly how its death
        gets diagnosed."""
        counters: Dict[str, float] = {k: float(v) for k, v in self.stats.items()}
        state: Dict[str, float] = {
            "models": float(len(self._models)),
            "replicas_in_progress": 0.0,
            "replicas_published": 0.0,
            "replicas_draining": 0.0,
            "replicas_registered": 0.0,
            "replicas_failed": 0.0,
            "availability_units": 0.0,
            "plan_epochs": 0.0,
            "pending_replicates": 0.0,
        }
        by_status = {
            IN_PROGRESS: "replicas_in_progress",
            PUBLISHED: "replicas_published",
            DRAINING: "replicas_draining",
        }
        for st in self._models.values():
            state["pending_replicates"] += len(st.pending)
            for info in st.replicas.values():
                if info.failed:
                    state["replicas_failed"] += 1
                elif info.registered:
                    state["replicas_registered"] += 1
            for version, vmap in st.versions.items():
                for rv in vmap.values():
                    key = by_status.get(rv.status)
                    if key is not None:  # per-version replica states
                        state[key] += 1
                    state["plan_epochs"] += rv.assign_epoch
                    # availability depth of the latest version: how many
                    # servable units the swarm planner can draw on
                    if version == st.latest:
                        info = st.replicas.get(rv.replica)
                        if info is None or info.failed:
                            continue
                        if rv.status not in (PUBLISHED, IN_PROGRESS):
                            continue
                        m = self._replica_manifest(st, version, rv.replica, 0)
                        full = m.num_units if m is not None else 0
                        c = self._source_ceiling(st, rv)
                        state["availability_units"] += (
                            full if c < 0 else min(c, full) if full else c
                        )
        gauges: Dict[str, float] = {
            "failover_last_recovery_seconds": float(self.last_recovery_s),
        }
        log = self._log
        if log is not None:
            records = sum(1 for _ in log.committed())
            flushes = log.flushes
            gauges["oplog_committed_records"] = float(records)
            gauges["oplog_flushes"] = float(flushes)
            gauges["oplog_group_commit"] = float(log.group_commit)
            # avg records per durable flush: direct (in-memory) mode
            # commits record-at-a-time without flushing
            gauges["oplog_avg_batch"] = (
                records / flushes if flushes else (1.0 if records else 0.0)
            )
        return {"counters": counters, "state": state, "gauges": gauges}

    def metrics_text(self) -> str:
        """Prometheus-style text exposition of :meth:`metrics` (the scrape
        format the future networked server will serve on /metrics)."""
        m = self.metrics()
        lines: List[str] = []
        for section, ptype in (
            ("counters", "counter"),
            ("state", "gauge"),
            ("gauges", "gauge"),
        ):
            for name in sorted(m[section]):
                val = m[section][name]
                full = f"tensorhub_{name}"
                lines.append(f"# TYPE {full} {ptype}")
                text = f"{val:.6f}".rstrip("0").rstrip(".") if val % 1 else str(int(val))
                lines.append(f"{full} {text}")
        return "\n".join(lines) + "\n"

    def _swarm_pool(
        self, st: ModelState, version: int, dest: ReplicaInfo, start: int
    ) -> List[Tuple[ReplicaVersionState, int]]:
        """Sources a swarm plan may tile a destination's units across:
        same-DC, same-shard-count, layout-identical GPU replicas that are
        either fully published or in progress with a non-empty completed
        prefix beyond ``start``. Returns (state, ceiling) pairs in
        preference order — topology first, then load, then the deeper
        prefix — where ``ceiling`` is the unit count the source can serve
        today (``num_units`` for published replicas).

        Keeping the pool same-DC is what makes the WAN invariant hold: a
        same-DC *in-progress* peer always outranks a cross-DC published
        source (the latter is never admitted), so one seeding replica's
        prefix feeds its whole datacenter and the cross-DC link carries
        exactly one copy."""
        n_units = self._dest_num_units(st, version, dest)
        if n_units is None or not self._pipeline:
            return []
        vmap = st.versions.get(version, {})
        out: List[Tuple[ReplicaVersionState, int]] = []
        for rv in vmap.values():
            if rv.replica == dest.name or rv.kind != KIND_GPU:
                continue
            if rv.status not in (PUBLISHED, IN_PROGRESS):
                continue
            info = st.replicas.get(rv.replica)
            if info is None or info.failed or self._is_quarantined(info):
                continue
            if info.num_shards != dest.num_shards:
                continue
            if info.datacenter != dest.datacenter:
                continue
            c = self._source_ceiling(st, rv)
            ceiling = n_units if c < 0 else min(c, n_units)
            if ceiling <= start:
                continue  # nothing we still need; rejoins on announce/steal
            if rv.status == IN_PROGRESS and self._chain_reaches(
                vmap, rv.replica, dest.name
            ):
                continue
            out.append((rv, ceiling))
        if not out:
            return out

        def key(e: Tuple[ReplicaVersionState, int]):
            rv, ceiling = e
            topo, refcount, depth, name = self._pref_key(st, rv, dest)
            # availability breaks load ties: the deeper prefix first. For
            # an all-published pool every ceiling equals n_units and the
            # order collapses to the pre-swarm (topo, load, depth, name).
            return (topo, refcount, -ceiling, depth, name)

        out.sort(key=key)
        # layout-identity filter, exactly as in _multi_pool: unit pulls
        # are interchangeable only between byte-identical slicings
        ref = st.replica_manifests.get(version, {}).get(
            (dest.name, 0)
        ) or st.manifests.get(version, {}).get((dest.num_shards, 0))
        if ref is None:
            return out[:1]
        kept = []
        for rv, ceiling in out:
            m = self._replica_manifest(st, version, rv.replica, 0)
            if m is not None and m.same_layout(ref):
                kept.append((rv, ceiling))
        return kept

    def _swarm_supply(
        self,
        chosen: List[Tuple[ReplicaVersionState, int]],
        start: int,
        num_units: int,
    ) -> float:
        """Aggregate serving capacity of a candidate plan, in units of one
        dedicated uplink: each member contributes its load share
        (``1/(1+refcount)``) scaled by how much of the *remaining* range
        its prefix can actually serve. This is the chain-vs-swarm decision
        input: a dedicated in-progress relay moves bytes link-disjointly
        at one full uplink, so fanning out only wins when the pool offers
        at least that much — otherwise (single contended seed, lockstep
        prefixes) the swarm would starve itself and a staggered pipeline
        chain is strictly better."""
        span = max(1, num_units - start)
        supply = 0.0
        for rv, ceiling in chosen:
            if ceiling >= num_units:
                frac = 1.0
            else:
                frac = max(0.0, min(1.0, (ceiling - start) / span))
            supply += frac / (1.0 + rv.refcount)
        return supply

    def _swarm_wins(
        self,
        st: ModelState,
        version: int,
        dest: ReplicaInfo,
        pool: List[Tuple[ReplicaVersionState, int]],
        src: Optional[ReplicaVersionState],
        start: int,
        num_units: int,
    ) -> bool:
        """Whether to install a swarm plan instead of the legacy scheduler's
        choice: always when there is no dedicated relay to protect (the
        best single source is published or gone) or units are giant
        (store-and-forward granularity kills chains); else only when the
        pool's aggregate supply matches a dedicated uplink."""
        if src is None or src.status == PUBLISHED:
            return True
        if self._has_giant_units(st, version, dest):
            return True
        chosen = self._swarm_chosen(pool)
        return self._swarm_supply(chosen, start, num_units) >= 1.0

    def _swarm_chosen(
        self, pool: List[Tuple[ReplicaVersionState, int]]
    ) -> List[Tuple[ReplicaVersionState, int]]:
        """The plan members: the ``max_sources`` most-preferred sources,
        with the deepest-prefix source guaranteed a slot (it serves the
        tail — without it a plan of shallow prefixes could not tile the
        whole shard)."""
        chosen = list(pool[: self._max_sources])
        best = max(range(len(pool)), key=lambda i: (pool[i][1], -i))
        if all(pool[best][0] is not rv for rv, _ in chosen):
            chosen[-1] = pool[best]
        return chosen

    def _swarm_partition(
        self,
        pool: List[Tuple[ReplicaVersionState, int]],
        start: int,
        num_units: int,
    ) -> List[Tuple[str, int, int]]:
        """Ceiling-aware tiling of units ``[start, num_units)``.

        When every chosen source is fully available this degrades to the
        pre-swarm ``_partition_units`` (bit-for-bit — the ``swarm=False``
        parity anchor). Otherwise: partial prefixes serve the head of the
        range (their ceilings are prefixes, so low units are what they
        hold), sized by inverse load and *clipped to their ceilings*; the
        deepest-prefix source serves the tail. The tail slice is the only
        one allowed to extend past its source's ceiling, and only when no
        fully-published source is in the pool — those reads gate on the
        source's live progress counter (pipeline chaining), exactly like
        a PR 2 relay."""
        chosen = self._swarm_chosen(pool)
        if all(c >= num_units for _, c in chosen):
            return self._partition_units([rv for rv, _ in chosen], start, num_units)
        tail_i = max(range(len(chosen)), key=lambda i: (chosen[i][1], -i))
        tail_rv = chosen[tail_i][0]
        heads = sorted(
            (e for i, e in enumerate(chosen) if i != tail_i),
            key=lambda e: (e[1], e[0].replica),  # shallow prefixes first
        )
        remaining = num_units - start
        weights = {rv.replica: 1.0 / (1.0 + rv.refcount) for rv, _ in chosen}
        total = sum(weights.values())
        plan: List[Tuple[str, int, int]] = []
        pos = start
        for rv, ceiling in heads:
            share = max(1, int(remaining * weights[rv.replica] / total))
            n = max(0, min(share, ceiling - pos, num_units - pos))
            plan.append((rv.replica, pos, pos + n))
            pos += n
        plan.append((tail_rv.replica, pos, num_units))
        return plan

    def _partition_units(
        self,
        pool: List[ReplicaVersionState],
        start: int,
        num_units: int,
    ) -> List[Tuple[str, int, int]]:
        """Partition units ``[start, num_units)`` into contiguous ranges
        across the pool (preference order), sized inversely to each
        source's current reader load. The most-preferred source serves the
        head of the range — the units gating downstream pipeline chains.
        With fewer units than sources, the extra sources get empty ranges:
        they still join the plan so the data plane can spread the chunks
        of a giant unit across their uplinks."""
        remaining = num_units - start
        srcs = pool[: self._max_sources]
        if remaining < len(srcs):
            plan = []
            pos = start
            for i, rv in enumerate(srcs):
                n = 1 if i < remaining else 0
                plan.append((rv.replica, pos, pos + n))
                pos += n
            return plan
        weights = [1.0 / (1.0 + rv.refcount) for rv in srcs]
        total = sum(weights)
        shares = [max(1, int(remaining * w / total)) for w in weights]
        while sum(shares) > remaining:
            i = max(range(len(shares)), key=lambda j: (shares[j], j))
            shares[i] -= 1
        i = 0
        while sum(shares) < remaining:
            shares[i % len(shares)] += 1
            i += 1
        plan: List[Tuple[str, int, int]] = []
        pos = start
        for rv, n in zip(srcs, shares):
            plan.append((rv.replica, pos, pos + n))
            pos += n
        return plan

    def _has_giant_units(
        self, st: ModelState, version: int, dest: ReplicaInfo
    ) -> bool:
        """True when the destination's units exceed the chunk threshold —
        such workloads replicate badly over store-and-forward pipeline
        chains (a relay serves only completed units), so the scheduler
        prefers chunk-spreading them across fully-published replicas."""
        m = st.manifests.get(version, {}).get((dest.num_shards, 0))
        if m is None or not m.units:
            return False
        return max(u.nbytes for u in m.units) > self._chunk_hint

    def _plan_assignment(
        self, st: ModelState, dest: ReplicaInfo, version: int, *, start: int = 0
    ) -> Optional[List[Tuple[str, int, int]]]:
        """Multi-source plan when >=2 eligible published replicas exist
        (and the feature is on); else a single-slice plan from the legacy
        scheduler. None when no live source exists at all.

        An idle in-progress replica (the least-loaded candidate) beats a
        multi-source plan for fine-grained workloads: a dedicated pipeline
        relay moves bytes link-disjointly at full rate, while fanning the
        tail onto already-shared publisher uplinks would contend. Chains
        lose only when units are giant (store-and-forward granularity) —
        then the published pool with sub-unit chunking wins.

        Swarm replication generalizes both: in-progress replicas join the
        pool for the prefix they have completed, so every plan is a blend
        of published partitioning and pipeline chaining — the dedicated
        relay is just the degenerate one-member swarm."""
        src = self._find_source(st, version, dest)
        num_units = self._dest_num_units(st, version, dest)
        if num_units is not None:
            # a progress report past the unit count (client bug, adversarial
            # test) must not produce an inverted range
            start = min(start, num_units)
        if self._max_sources > 1:
            if num_units is not None and num_units - start >= 1:
                if self._swarm:
                    spool = self._swarm_pool(st, version, dest, start)
                    if len(spool) >= 2 and self._swarm_wins(
                        st, version, dest, spool, src, start, num_units
                    ):
                        return self._swarm_partition(spool, start, num_units)
                pool = self._multi_pool(st, version, dest)
                if len(pool) >= 2 and (
                    src is None
                    or src.status == PUBLISHED
                    or self._has_giant_units(st, version, dest)
                ):
                    return self._partition_units(pool, start, num_units)
        if src is None:
            return None
        return [(src.replica, start, -1 if num_units is None else num_units)]

    def _install_plan(
        self,
        st: ModelState,
        version: int,
        rv: ReplicaVersionState,
        dest_info: ReplicaInfo,
        plan: List[Tuple[str, int, int]],
    ) -> None:
        """Swap an in-progress reader onto a new plan (re-route/steal)."""
        vmap = st.versions[version]
        self._release_sources(vmap, rv)
        for name, _, _ in plan:
            self._acquire_source(st, vmap[name], dest_info)
        rv.plan = list(plan)
        rv.source = plan[0][0]
        rv.seeding = self._cross_dc(st, vmap[plan[0][0]], dest_info)
        rv.assign_epoch += 1
        rv.plan_gen = st.source_gen.get(version, 0)

    def _steal_work(
        self, st: ModelState, version: int, rv: ReplicaVersionState
    ) -> None:
        """Re-partition an in-progress reader's remaining units when the
        candidate pool gained a source its plan does not use."""
        if self._max_sources <= 1:
            return  # single-source mode: no mid-transfer re-partitioning
        info = st.replicas.get(rv.replica)
        if info is None or info.failed:
            return
        rv.plan_gen = st.source_gen.get(version, 0)  # scanned at this gen
        num_units = self._dest_num_units(st, version, info)
        if num_units is None:
            return
        start = min(rv.progress.values()) if rv.progress else 0
        if num_units - start < 2:
            return
        if self._swarm and self._pipeline:
            # Swarm growth: the availability map changed (a peer announced
            # its prefix, a replica published or completed). Re-partition
            # the *unserved tail* only when (a) the grown pool actually
            # out-supplies the current primary — a healthy dedicated chain
            # is never broken for a starving swarm — and (b) the plan
            # would gain a member; same-membership re-tilings are skipped
            # because the data plane's availability-aware claiming already
            # rebalances load inside the current membership without an
            # epoch bump. The bump reuses the PR 2 resume-from-prefix
            # machinery: the tail re-tiles, completed units are never
            # re-read.
            if num_units - start < 2 * self._max_sources:
                # an epoch bump drains the in-flight window and refills it
                # (a pipeline bubble of ~max_sources claims); a short tail
                # cannot amortize that, so the end-game keeps its plan
                return
            vmap = st.versions.get(version, {})
            primary = vmap.get(rv.source) if rv.source else None
            if (
                primary is not None
                and primary.status == IN_PROGRESS
                and not self._has_giant_units(st, version, info)
            ):
                # the primary is a live pipeline relay: its staggered
                # prefix moves bytes link-disjointly at full rate, and the
                # epidemic already flows through it — growing this plan
                # would trade a dedicated uplink for shares of contended
                # ones (chains break only on death or giant units)
                return
            spool = self._swarm_pool(st, version, info, start)
            if len(spool) >= 2 and self._swarm_wins(
                st, version, info, spool, primary, start, num_units
            ):
                plan = self._swarm_partition(spool, start, num_units)
                current = {s for s, _, _ in rv.plan}
                if not {s for s, _, _ in plan} <= current:
                    self._install_plan(st, version, rv, info, plan)
                    self.stats["swarm_grows"] += 1
                    self.stats["work_steals"] += 1
            return
        # Steal only where a re-partition can actually win: giant-unit
        # workloads (chunk spread rebalances as full copies appear), or a
        # single-source plan on a *contended* published source. Healthy
        # fine-grained pipeline chains and dedicated sources are left
        # alone — a dedicated relay moves bytes link-disjointly at full
        # rate, and re-planning it would only add churn.
        vmap = st.versions.get(version, {})
        primary = vmap.get(rv.source) if rv.source else None
        if not self._has_giant_units(st, version, info):
            if len(rv.plan) > 1:
                return
            if primary is not None and (
                primary.status == IN_PROGRESS or primary.refcount <= 1
            ):
                return
        pool = self._multi_pool(st, version, info)
        if len(pool) < 2:
            return
        current = {s for s, _, _ in rv.plan}
        if {p.replica for p in pool[: self._max_sources]} <= current:
            return
        plan = self._partition_units(pool, start, num_units)
        self._install_plan(st, version, rv, info, plan)
        self.stats["work_steals"] += 1

    def _assign(self, st: ModelState, dest: ReplicaInfo, version: int) -> Assignment:
        plan = self._plan_assignment(st, dest, version)
        if plan is None:
            raise VersionUnavailableError(
                f"model {st.name} v{version}: no live replica to serve the read"
            )
        vmap = st.versions[version]
        for name, _, _ in plan:
            self._acquire_source(st, vmap[name], dest)
        primary = vmap[plan[0][0]]
        assignment = self._make_assignment(
            st, version, primary, dest=dest, plan=plan, record_stats=True
        )
        self._install_replica_version(
            st,
            dest,
            version,
            status=IN_PROGRESS,
            kind=dest.kind,
            source=primary.replica,
            seeding=assignment.seeding,
        )
        rv = st.versions[version][dest.name]
        rv.plan = list(plan)
        rv.plan_gen = st.source_gen.get(version, 0)
        rv.depth = primary.depth + 1
        for s in range(dest.num_shards):
            rv.progress[s] = 0
        self.stats["replications_started"] += 1
        if len(plan) > 1:
            self.stats["multi_source_assignments"] += 1
        if any(s.ceiling >= 0 for s in assignment.sources):
            self.stats["swarm_assignments"] += 1  # a partial prefix serves
        return assignment

    def _ensure_offload_seed(
        self, st: ModelState, version: int, dest: ReplicaInfo
    ) -> bool:
        """At most one offload-seeding replica per datacenter (4.3.4).
        Returns True if this call created it (the caller's client library
        owns the background fetch)."""
        for rv in st.versions.get(version, {}).values():
            info = st.replicas.get(rv.replica)
            if (
                info is not None
                and rv.kind == KIND_OFFLOAD
                and info.datacenter == dest.datacenter
            ):
                return False  # someone already seeds this DC
        # The *caller* becomes the seeder: we install an in-progress offload
        # replica entry sourced cross-DC.
        off = offload_name(dest.name)
        offinfo = st.replicas.get(off)
        if offinfo is None:
            offinfo = ReplicaInfo(
                name=off,
                num_shards=dest.num_shards,
                datacenter=dest.datacenter,
                is_spot=dest.is_spot,
                kind=KIND_OFFLOAD,
                workers=dict(dest.workers),
                open_shards=set(dest.open_shards),
            )
            st.replicas[off] = offinfo
        src = self._find_source(st, version, offinfo)
        if src is None:
            return False
        self._acquire_source(st, src, offinfo)
        self._install_replica_version(
            st,
            offinfo,
            version,
            status=IN_PROGRESS,
            kind=KIND_OFFLOAD,
            source=src.replica,
            seeding=True,
        )
        rv = st.versions[version][off]
        rv.seed_cache = True
        rv.plan = [(src.replica, 0, -1)]
        rv.plan_gen = st.source_gen.get(version, 0)
        for s in range(offinfo.num_shards):
            rv.progress[s] = 0
        self.stats["replications_started"] += 1
        return True

    def _service_pending(self, st: ModelState) -> None:
        """Try to assign parked replicate() groups after every publish or
        completion."""
        still: List[_PendingReplicate] = []
        for p in st.pending:
            info = st.replicas.get(p.replica)
            if info is None or info.failed:
                continue  # group died while parked
            v = version_lib.resolve(p.spec, st.latest)
            if v is None or self._find_source(st, v, info) is None:
                still.append(p)
                continue
            p.assignment = self._assign(st, info, v)
            # deliver through txn cache so every shard sees it
            key = (p.replica, p.op_id)
            txn = st.txns.get(key)
            if txn is not None:
                txn.result = p.assignment
        st.pending = still

    # -- failure handling --------------------------------------------------------

    def _fail_replica(self, st: ModelState, replica: str, *, reason: str) -> None:
        self.stats["evictions"] += 1
        self._remove_replica(st, replica, reason=reason)
        # the offload twin lives in the same process: dies together
        off = offload_name(replica)
        if off in st.replicas and not st.replicas[off].failed:
            self._remove_replica(st, off, reason=reason)
        # Proactive blast-radius control: a swarm source sits in *many*
        # readers' plans, so waiting for each reader to observe its dead
        # flows (RDMA timeout) multiplies the detection latency across the
        # swarm. Re-partition every affected reader's unserved tail now;
        # the epoch bump reaches their data planes on the next claim.
        dead = {replica, off}
        for version in list(st.versions.keys()):
            vmap = st.versions.get(version, {})
            for rv in list(vmap.values()):
                if rv.status != IN_PROGRESS:
                    continue
                names = {s for s, _, _ in rv.plan}
                if rv.source is not None:
                    names.add(rv.source)
                if not names & dead:
                    continue
                info = st.replicas.get(rv.replica)
                if info is None or info.failed:
                    continue
                start = min(rv.progress.values()) if rv.progress else 0
                plan = self._plan_assignment(st, info, version, start=start)
                if plan is None:
                    continue  # no live source left; readers keep polling
                self._install_plan(st, version, rv, info, plan)
                self.stats["reassignments"] += 1

    def _remove_replica(self, st: ModelState, replica: str, *, reason: str) -> None:
        info = st.replicas.get(replica)
        if info is None:
            return
        info.failed = True
        for v in list(st.versions.keys()):
            self._drop_replica_version(st, replica, v)
        st.pending = [p for p in st.pending if p.replica != replica]
        for key in [k for k in st.txns if k[0] == replica]:
            del st.txns[key]
        for key in [k for k in st.done_txns if k[0] == replica]:
            del st.done_txns[key]
        for w in info.workers.values():
            self._emit(
                w.worker_id,
                Event(kind="evicted", model=st.name, replica=replica, reason=reason),
            )
        # readers sourced from this replica will report transfer failure and
        # be re-routed by report_transfer_failure/_reassign.
        del st.replicas[replica]

    def _current_state(
        self, st: ModelState, replica: str
    ) -> Optional[ReplicaVersionState]:
        info = st.replicas.get(replica)
        if info is None or info.current_version is None:
            return None
        return st.versions.get(info.current_version, {}).get(replica)

    def _reassign(self, st: ModelState, dest_replica: str) -> None:
        info = st.replicas.get(dest_replica)
        if info is None or info.failed:
            return
        # find dest's in-progress state (gpu or offload twin)
        for name in (dest_replica, offload_name(dest_replica)):
            rinfo = st.replicas.get(name)
            if rinfo is None:
                continue
            for version, vmap in st.versions.items():
                rv = vmap.get(name)
                if rv is None or rv.status != IN_PROGRESS:
                    continue
                planned = {s for s, _, _ in rv.plan}
                if rv.source is not None:
                    planned.add(rv.source)
                healthy = all(
                    s in vmap and not self._is_quarantined(st.replicas.get(s))
                    for s in planned
                )
                if planned and healthy:
                    continue  # every plan source still alive; nothing to do
                # re-partition the uncompleted tail across the survivors
                start = min(rv.progress.values()) if rv.progress else 0
                plan = self._plan_assignment(st, rinfo, version, start=start)
                if plan is None:
                    continue  # graceful: reader keeps polling, may error out
                if list(plan) == list(rv.plan):
                    # quarantine fallback landed on the identical plan (the
                    # suspect is the only source): bumping the epoch would
                    # drain the reader's window for nothing
                    continue
                self._install_plan(st, version, rv, rinfo, plan)
                self.stats["reassignments"] += 1


def offload_name(replica: str) -> str:
    return f"{replica}@offload"


# wire registration (op-log payloads + failover snapshots); _Txn is
# handled by repro.core.failover directly — its on_last callback cannot
# travel and is rebuilt from the op kind on restore
for _cls in (
    PublishResult,
    UnpublishResult,
    UpdateDecision,
    Event,
    ReplicaVersionState,
    ReplicaInfo,
    _PendingReplicate,
):
    meta_defaults.register_wire(_cls)
