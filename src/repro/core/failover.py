"""Controller failover: snapshot, replay, and recovery of the reference
server from its op log.

The server is deterministic — no wall clock, no RNG, time only as
explicit arguments — so replaying the logged op sequence rebuilds a
bit-identical ``ReferenceServer``. :func:`take_snapshot` serializes the
*entire* live state (models, replicas, per-version states, manifests,
open and retired group transactions, parked replicates, event queues,
stats) so that :meth:`~repro.core.oplog.OpLog.compact` can truncate
history: recovery then restores the snapshot and replays only the
suffix, making it O(live state) instead of O(history).

Replayed ops that raised during the live run raise identically during
replay (same state, same code path); :func:`recover` swallows them —
the live caller already saw the error, and any partial mutation the op
made before raising is reproduced exactly by re-running it.
"""

from __future__ import annotations

import hashlib
import json
import time
from typing import Any, Dict, Optional, Tuple

from repro.core.errors import TensorHubError
from repro.core.meta import from_wire, to_wire
from repro.core.oplog import OpLog, OpRecord, Snapshot
from repro.core.server import ModelState, ReferenceServer, _Txn

# ---------------------------------------------------------------------------
# state serialization
# ---------------------------------------------------------------------------


def _encode_txn(txn: _Txn) -> dict:
    # on_last is deliberately dropped: it is rebuilt from the op kind on
    # restore (only "complete" group ops carry one)
    return {
        "op": txn.op,
        "args_repr": txn.args_repr,
        "result": to_wire(txn.result),
        "arrived": sorted(txn.arrived),
    }


def _decode_txn(w: dict) -> _Txn:
    return _Txn(
        op=w["op"],
        args_repr=w["args_repr"],
        result=from_wire(w["result"]),
        arrived=set(w["arrived"]),
    )


def _encode_model(st: ModelState) -> dict:
    return {
        "name": st.name,
        "num_shards": st.num_shards,
        "latest": st.latest,
        "replicas": to_wire(st.replicas),
        "versions": to_wire(st.versions),
        "manifests": to_wire(st.manifests),
        "replica_manifests": to_wire(st.replica_manifests),
        "txns": [[to_wire(k), _encode_txn(t)] for k, t in st.txns.items()],
        "done_txns": [[to_wire(k), _encode_txn(t)] for k, t in st.done_txns.items()],
        "pending": to_wire(st.pending),
        "source_gen": to_wire(st.source_gen),
    }


def _decode_model(server: ReferenceServer, w: dict) -> ModelState:
    st = ModelState(name=w["name"])
    st.num_shards = w["num_shards"]
    st.latest = w["latest"]
    st.replicas = from_wire(w["replicas"])
    st.versions = from_wire(w["versions"])
    st.manifests = from_wire(w["manifests"])
    st.replica_manifests = from_wire(w["replica_manifests"])
    st.pending = from_wire(w["pending"])
    st.source_gen = from_wire(w["source_gen"])
    for kw, tw in w["txns"]:
        key = from_wire(kw)
        key = tuple(key) if isinstance(key, list) else key
        txn = _decode_txn(tw)
        if txn.op == "complete":
            # the only group op with a completion callback; its closure
            # binds (state, version, replica) — all replayable
            txn.on_last = server._complete_on_last(  # noqa: SLF001
                st, int(txn.args_repr), key[0]
            )
        st.txns[key] = txn
    for kw, tw in w["done_txns"]:
        key = from_wire(kw)
        st.done_txns[tuple(key) if isinstance(key, list) else key] = _decode_txn(tw)
    return st


def encode_state(server: ReferenceServer) -> dict:
    """The server's complete durable state as a JSON-able wire tree.

    The watcher-notification counter (``server.seq``) is deliberately
    excluded: it counts *calls* (including no-op polls the log skips),
    not state, so it is neither durable nor replay-deterministic."""
    return {
        "models": [
            [name, _encode_model(st)]
            for name, st in server._models.items()  # noqa: SLF001
        ],
        "events": to_wire(server._events),  # noqa: SLF001
        "stats": dict(server.stats),
    }


def restore_state(server: ReferenceServer, state: dict) -> None:
    """Overwrite ``server``'s state with a decoded snapshot."""
    server._models = {  # noqa: SLF001
        name: _decode_model(server, mw) for name, mw in state["models"]
    }
    server._events = from_wire(state["events"])  # noqa: SLF001
    server.stats = dict(state["stats"])


def take_snapshot(server: ReferenceServer, *, seq: Optional[int] = None) -> Snapshot:
    """Serialize the live server as of the last logged record. Pass the
    result to :meth:`OpLog.compact` to truncate the history it covers."""
    if seq is None:
        seq = server.log.last_seq if server.log is not None else 0
    return Snapshot(seq=seq, state=encode_state(server))


def state_digest(server: ReferenceServer) -> str:
    """Canonical fingerprint of the full server state — two servers with
    equal digests are bit-identical (the crash-sweep test oracle)."""
    return hashlib.sha256(
        json.dumps(encode_state(server), sort_keys=True).encode()
    ).hexdigest()


# ---------------------------------------------------------------------------
# replay / recovery
# ---------------------------------------------------------------------------


def apply_record(server: ReferenceServer, rec: OpRecord) -> None:
    """Re-execute one logged op. Deterministic failures are part of the
    history: an op that raised live raises identically here and is
    swallowed (its partial mutations replay exactly by re-running)."""
    try:
        getattr(server, rec.op)(**rec.kwargs())
    except (TensorHubError, ValueError):
        pass


def recover(
    log: OpLog, **config_overrides: Any
) -> ReferenceServer:
    """Rebuild a bit-identical server from an op log: construct from the
    logged config, restore the compaction snapshot (if any), replay the
    committed suffix, then attach the log so new ops keep appending
    where the crashed server stopped. Clients switch over via
    ``TensorHubClient.failover`` / ``SimCluster.crash_and_recover``."""
    t0 = time.perf_counter()
    cfg: Dict[str, Any] = dict(log.config or {})
    cfg.update(config_overrides)
    server = ReferenceServer(**cfg)
    start = 0
    if log.snapshot is not None:
        restore_state(server, log.snapshot.state)
        start = log.snapshot.seq
    for rec in log.committed(after=start):
        apply_record(server, rec)
    server.attach_log(log)
    # metrics gauge only — wall-clock values live outside the replayed
    # state digest, so the recovered twin still digests equal
    server.last_recovery_s = time.perf_counter() - t0
    return server


def recover_path(
    path: str, *, group_commit: int = 1, **config_overrides: Any
) -> ReferenceServer:
    """Recover a controller from its on-disk JSONL WAL and keep appending
    to the same file — the restart path of the networked controller
    (``repro.net.controller --recover``). The rebuilt server is
    bit-identical to the crashed one's durable state; new mutations flush
    to the same ``path`` with sequence numbers and blob keys continuing
    past the parsed maximum."""
    log = OpLog.open_path(path, group_commit=group_commit)
    return recover(log, **config_overrides)


def replay(
    records, *, config: Optional[Dict[str, Any]] = None
) -> ReferenceServer:
    """Replay a bare record sequence into a fresh (log-less) server —
    the replay-equivalence property tests drive this directly."""
    server = ReferenceServer(**(config or {}))
    for rec in records:
        apply_record(server, rec)
    return server


__all__ = [
    "Snapshot",
    "apply_record",
    "encode_state",
    "recover",
    "recover_path",
    "replay",
    "restore_state",
    "state_digest",
    "take_snapshot",
]
